//! Quickstart: how efficient is *your* conv layer on each architecture?
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a conv layer, evaluates the four analytic processor models at a
//! couple of technology nodes, then runs the two cycle-accurate machines
//! on the same layer — the 30-second tour of the library.

use aimc::analytic::{Processor, Workload};
use aimc::networks::ConvLayer;
use aimc::simulator::{optical4f, systolic};

fn main() {
    // A mid-size CNN layer: 512×512 feature map, 128→128 channels, 3×3.
    // (This is Table V of the paper.)
    let layer = ConvLayer::square(512, 128, 128, 3, 1);
    let w = Workload::from_layer(layer);

    println!("layer: n={} Ci={} Co={} k={}", layer.n, layer.c_in, layer.c_out, layer.kh);
    println!(
        "  MACs {:.2e}   arithmetic intensity: native {:.0} (eq.9), matmul {:.0} (eq.8)\n",
        layer.macs(),
        w.a_native,
        w.a_matmul
    );

    // 1. Analytic models (paper eqs. 3, 5, 14, 24) across nodes.
    println!("analytic efficiency (TOPS/W):");
    println!("  {:>9} {:>10} {:>10} {:>10} {:>10}", "node", "CPU", "DIM", "SP", "O4F");
    for node in [45.0, 28.0, 7.0] {
        print!("  {node:>7} nm");
        for p in Processor::ALL {
            print!(" {:>10.2}", p.efficiency(&w, node).tops_per_watt());
        }
        println!();
    }

    // 2. Cycle-accurate machines on the single layer at 28 nm.
    let node = 28.0;
    let sys = systolic::simulate_layer(&systolic::SystolicConfig::default(), &layer, node);
    let opt = optical4f::simulate_layer(&optical4f::Optical4FConfig::default(), &layer, node);
    println!("\ncycle-accurate @ {node} nm:");
    for (name, r) in [("systolic 256x256", &sys), ("optical 4F (4 Mpx)", &opt)] {
        println!(
            "  {name:20} {:8.2} TOPS/W   {:.4} pJ/MAC   breakdown: {}",
            r.tops_per_watt(),
            r.energy_per_mac() * 1e12,
            r.ledger
                .breakdown()
                .iter()
                .map(|(c, j)| format!("{} {:.0}%", c.label(), 100.0 * j / r.ledger.total()))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    println!(
        "\nheadline: the optical 4F machine is {:.0}x more energy-efficient than the\n\
         digital systolic array on this layer — the paper's scaling argument in action.",
        opt.tops_per_watt() / sys.tops_per_watt()
    );
}
