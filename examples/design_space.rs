//! Design-space exploration: the ablations DESIGN.md calls out.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```
//!
//! Sweeps the architectural knobs the paper's argument hinges on and
//! prints where the crossovers fall:
//!
//! * SLM size vs efficiency (the "analog wins with scale" claim, eq. 11);
//! * systolic array dimension (bigger is not free: SRAM banks shrink);
//! * bit precision (ADC/DAC/laser are exponential in B — eqs. A3/A4/A8);
//! * electro-optic modulator energy (the silicon-photonics bottleneck);
//! * DRAM weight streaming on/off for the systolic machine;
//! * full-aperture vs shuttered laser for the 4F machine.

use aimc::analytic::{photonic, Workload};
use aimc::energy::EnergyParams;
use aimc::networks::yolov3::yolov3;
use aimc::simulator::{optical4f, systolic, SweepCache};
use aimc::util::pool;

fn main() {
    let node = 28.0;
    let net = yolov3(1000);
    // Every whole-network sweep below fans out over the work-stealing
    // pool and shares one layer-dedup cache: knob settings that leave a
    // layer's simulation unchanged (they never do here — each knob is in
    // the config fingerprint) would be deduped automatically, and the
    // repeated residual-block shapes within YOLOv3 always are.
    let cache = SweepCache::new();
    println!("design-space exploration — YOLOv3 @ 1 Mpx, {node} nm\n");

    // ---- 1. SLM size sweep -------------------------------------------------
    println!("1) optical-4F SLM size (eq. 11: efficiency ∝ processor scale):");
    let mpxs = [0.25, 1.0, 4.0, 16.0, 64.0];
    for (mpx, r) in mpxs.iter().zip(pool::par_map(&mpxs, |&mpx| {
        let cfg = optical4f::Optical4FConfig {
            slm_pixels: (mpx * 1024.0 * 1024.0) as usize,
            ..Default::default()
        };
        cache.simulate_network(&cfg, &net, node)
    })) {
        println!(
            "   {mpx:5.2} Mpx : {:8.2} TOPS/W  ({:.4} pJ/MAC, {:.0} executions)",
            r.tops_per_watt(),
            r.energy_per_mac() * 1e12,
            r.time_units
        );
    }

    // ---- 2. systolic array dimension ---------------------------------------
    println!("\n2) systolic array dimension (SRAM fixed at 24 MiB total):");
    let dims = [64usize, 128, 256, 512, 1024];
    for (dim, (cfg, r)) in dims.iter().zip(pool::par_map(&dims, |&dim| {
        let cfg = systolic::SystolicConfig {
            dim,
            banks: dim,
            ..Default::default()
        };
        let r = cache.simulate_network(&cfg, &net, node);
        (cfg, r)
    })) {
        println!(
            "   {dim:4}x{dim:<4}: {:6.2} TOPS/W  (utilization {:4.1}%)",
            r.tops_per_watt(),
            100.0 * systolic::utilization(&cfg, &r)
        );
    }

    // ---- 3. bit precision --------------------------------------------------
    println!("\n3) bit precision (ADC/DAC/laser scale as 2^2B — eq. A3/A4/A8):");
    let w = Workload::reference();
    for bits in [4u32, 6, 8, 10, 12] {
        let e = EnergyParams {
            bits,
            ..Default::default()
        }
        .at_node(node);
        // Converter-bound compute term of the 4F machine (per eq. 24's N).
        let per_op = e.e_adc / 128.0 + (e.e_dac + e.e_opt) / 576.0;
        println!(
            "   B={bits:2}: e_adc {:8.4} pJ, e_dac {:7.4} pJ, 4F converter term {:9.6} pJ/op",
            e.e_adc * 1e12,
            e.e_dac * 1e12,
            per_op * 1e12
        );
    }

    // ---- 4. electro-optic modulator energy (planar photonics) --------------
    println!(
        "\n4) silicon-photonic modulator energy (today 7 pJ → future 0.5 pJ → research 0.05 pJ):"
    );
    for e_mod in [7e-12, 0.5e-12, 0.05e-12] {
        let cfg = photonic::Config {
            e_modulator: e_mod,
            ..photonic::Config::typical()
        };
        let eta = cfg.efficiency(&w, node).tops_per_watt();
        println!("   {:5.2} pJ/sample: {eta:8.2} TOPS/W", e_mod * 1e12);
    }

    // ---- 5. DRAM weight streaming ------------------------------------------
    println!("\n5) systolic DRAM weight streaming (paper's model charges 0):");
    let drams = [0.0, 5e-12, 20e-12];
    for (e_dram, r) in drams.iter().zip(pool::par_map(&drams, |&e_dram| {
        let cfg = systolic::SystolicConfig {
            e_dram_per_byte: e_dram,
            ..Default::default()
        };
        cache.simulate_network(&cfg, &net, node)
    })) {
        println!(
            "   {:4.0} pJ/B : {:6.2} TOPS/W",
            e_dram * 1e12,
            r.tops_per_watt()
        );
    }

    // ---- 6b. ReRAM weight reuse (extension machine) -------------------------
    println!("\n6b) ReRAM crossbar: weight-programming amortization (reuse count):");
    let reuses = [1.0, 100.0, 1e4, 1e6];
    for (reuse, r) in reuses.iter().zip(pool::par_map(&reuses, |&reuse| {
        let cfg = aimc::simulator::reram::ReramConfig {
            reuse,
            ..Default::default()
        };
        cache.simulate_network(&cfg, &net, node)
    })) {
        println!(
            "   reuse {reuse:8.0} : {:6.2} TOPS/W",
            r.tops_per_watt()
        );
    }

    // ---- 6c. photonic mesh size (extension machine) --------------------------
    println!("\n6c) photonic mesh dimension (eq. 11 again, planar this time):");
    let mesh_dims = [8usize, 40, 128, 512];
    for (dim, r) in mesh_dims.iter().zip(pool::par_map(&mesh_dims, |&dim| {
        let cfg = aimc::simulator::photonic::PhotonicConfig {
            dim,
            banks: dim,
            ..Default::default()
        };
        cache.simulate_network(&cfg, &net, node)
    })) {
        println!("   {dim:4}x{dim:<4}: {:6.2} TOPS/W", r.tops_per_watt());
    }

    // ---- 7. laser aperture policy ------------------------------------------
    println!("\n7) 4F laser: full-aperture (paper) vs shuttered illumination:");
    let apertures = [true, false];
    for (full, r) in apertures.iter().zip(pool::par_map(&apertures, |&full| {
        let cfg = optical4f::Optical4FConfig {
            laser_full_aperture: full,
            ..Default::default()
        };
        cache.simulate_network(&cfg, &net, node)
    })) {
        println!(
            "   {:9}: {:8.2} TOPS/W (laser share {:4.1}%)",
            if *full { "full" } else { "shuttered" },
            r.tops_per_watt(),
            100.0 * r.ledger.get(aimc::simulator::Component::Laser) / r.ledger.total()
        );
    }

    eprintln!("\nlayer-dedup cache: {}", cache.stats());
}
