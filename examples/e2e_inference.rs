//! End-to-end driver: the full three-layer stack on a real serving
//! workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```
//!
//! 1. loads the AOT-compiled SmallCNN artifacts (JAX+Pallas → HLO text →
//!    PJRT) for all three datapaths — the f32 oracle, the 8-bit systolic
//!    functional model and the optical-4F (FFT) functional model;
//! 2. verifies the three datapaths agree on a batch of synthetic images
//!    (argmax agreement + bounded relative error), proving the machine
//!    datapaths compute real convolutions;
//! 3. serves a batched request stream through the coordinator on each
//!    path, reporting latency percentiles and throughput;
//! 4. co-simulates the served network on the cycle-accurate systolic and
//!    optical-4F machines, reporting projected energy per inference.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use aimc::coordinator::energy::co_simulate;
use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{smallcnn_network, ConvPath, IMAGE_ELEMS, LOGITS};
use aimc::runtime::Engine;
use aimc::util::rng::Rng;

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn max_rel(a: &[f32], b: &[f32]) -> f32 {
    let scale = b.iter().fold(1e-9f32, |m, x| m.max(x.abs()));
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / scale)
        .fold(0.0, f32::max)
}

fn main() -> anyhow::Result<()> {
    println!("=== aimc end-to-end driver ===\n");
    let engine = Engine::discover()?;
    println!("PJRT platform: {}", engine.platform());

    // ---- 1+2: cross-datapath numerical agreement -------------------------
    let mut rng = Rng::new(2024);
    let n_check = 16;
    let images: Vec<Vec<f32>> = (0..n_check).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();

    let mut agree_sys = 0;
    let mut agree_fft = 0;
    let mut worst_sys = 0.0f32;
    let mut worst_fft = 0.0f32;
    for im in &images {
        let exact = engine.execute("smallcnn_exact", &[im.clone()])?;
        let sys = engine.execute("smallcnn_systolic", &[im.clone()])?;
        let fft = engine.execute("smallcnn_fft", &[im.clone()])?;
        assert_eq!(exact.len(), LOGITS);
        if argmax(&sys) == argmax(&exact) {
            agree_sys += 1;
        }
        if argmax(&fft) == argmax(&exact) {
            agree_fft += 1;
        }
        worst_sys = worst_sys.max(max_rel(&sys, &exact));
        worst_fft = worst_fft.max(max_rel(&fft, &exact));
    }
    println!("\ncross-datapath agreement over {n_check} images (vs f32 oracle):");
    println!("  systolic int8 : argmax {agree_sys}/{n_check}, max rel err {worst_sys:.4}");
    println!("  optical-4F fft: argmax {agree_fft}/{n_check}, max rel err {worst_fft:.4}");
    anyhow::ensure!(agree_sys >= n_check - 1, "systolic path disagrees too often");
    anyhow::ensure!(agree_fft >= n_check - 1, "fft path disagrees too often");
    anyhow::ensure!(worst_sys < 0.15 && worst_fft < 0.15, "quantization error too large");

    // ---- 3: serve a request stream on each path --------------------------
    let n_req = 96;
    for path in [ConvPath::Exact, ConvPath::Systolic, ConvPath::Fft] {
        let server = Server::start(ServerConfig {
            path,
            workers: 2,
            ..Default::default()
        })?;
        // Warm-up compiles the executables.
        server.infer_blocking(vec![0.0; IMAGE_ELEMS])?;

        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv()?.is_ok() {
                ok += 1;
            }
        }
        let wall = t0.elapsed();
        let m = server.shutdown();
        anyhow::ensure!(ok == n_req, "{path:?}: {ok}/{n_req} served");
        println!(
            "\nserve {:9}: {n_req} reqs in {:6.1} ms  ({:7.1} img/s) — {}",
            format!("{path:?}"),
            wall.as_secs_f64() * 1e3,
            n_req as f64 / wall.as_secs_f64(),
            m.summary()
        );
    }

    // ---- 4: energy co-simulation ------------------------------------------
    println!("\nprojected energy per inference (cycle-accurate machines):");
    for node in [45.0, 28.0, 7.0] {
        let r = co_simulate(&smallcnn_network(), node);
        println!("  {}", r.summary());
    }
    println!(
        "\nNote: SmallCNN's 64x64 maps underfill the 4 Mpx SLM, so the optical\n\
         machine loses here — run `aimc simulate --net YOLOv3 --machine optical4f`\n\
         for the paper-scale picture where it wins by an order of magnitude."
    );
    println!("\nE2E OK");
    Ok(())
}
