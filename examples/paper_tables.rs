//! Regenerate every table and figure of the paper in one run, writing
//! aligned text to stdout and CSVs to `results/`.
//!
//! ```sh
//! cargo run --release --example paper_tables
//! ```

use std::fs;
use std::path::Path;

use aimc::report;
use aimc::util::table::Table;

fn save(dir: &Path, name: &str, t: &Table) {
    println!("{}", t.render());
    fs::write(dir.join(format!("{name}.csv")), t.to_csv())
        .unwrap_or_else(|e| eprintln!("warn: writing {name}.csv: {e}"));
}

fn main() {
    let out = Path::new("results");
    fs::create_dir_all(out).expect("mkdir results/");
    let input = 1000;

    save(out, "table1", &report::table1(input));
    save(out, "table2", &report::table2(input));
    save(out, "table3", &report::table3(input));
    save(out, "table4", &report::table4());
    save(out, "fig6", &report::fig6());
    save(out, "fig7", &report::fig7());
    save(out, "fig8_yolov3", &report::fig8(None, input));
    save(out, "fig9_yolov3", &report::fig9(None, input));
    save(out, "fig10_vgg19", &report::fig10(Some("VGG19"), input));
    save(out, "fig10_yolov3", &report::fig10(Some("YOLOv3"), input));

    println!("CSV copies written to {}/", out.display());
}
