//! Regenerate every table and figure of the paper in one run, writing
//! aligned text to stdout and CSV + JSON to `results/` — every artifact
//! evaluated through ONE shared pool + sweep cache (the `aimc all`
//! scenario list), so repeated layer shapes simulate once.
//!
//! ```sh
//! cargo run --release --example paper_tables
//! ```

use std::fs;
use std::path::Path;

use aimc::report::{self, Dataset, EvalCtx};
use aimc::simulator::SweepCache;
use aimc::util::pool::Pool;

fn save(dir: &Path, name: &str, ds: &Dataset) {
    println!("{}", ds.render());
    fs::write(dir.join(format!("{name}.csv")), ds.to_csv())
        .unwrap_or_else(|e| eprintln!("warn: writing {name}.csv: {e}"));
    fs::write(dir.join(format!("{name}.json")), ds.to_json().pretty())
        .unwrap_or_else(|e| eprintln!("warn: writing {name}.json: {e}"));
}

fn main() {
    let out = Path::new("results");
    fs::create_dir_all(out).expect("mkdir results/");
    let input = 1000;

    let pool = Pool::auto();
    let cache = SweepCache::new();
    let ctx = EvalCtx {
        pool: &pool,
        cache: &cache,
    };

    let names = [
        "table1", "table2", "table3", "table4", "fig6", "fig7",
        "fig8_yolov3", "fig9_yolov3", "fig10_vgg19", "fig10_yolov3",
    ];
    let scenarios = report::all_scenarios(None, input);
    assert_eq!(
        names.len(),
        scenarios.len(),
        "file-name list out of sync with report::all_scenarios"
    );
    for (name, scenario) in names.iter().copied().zip(scenarios) {
        save(out, name, &scenario.eval(&ctx));
    }

    println!(
        "CSV + JSON copies written to {}/ (sweep cache: {})",
        out.display(),
        cache.stats()
    );
}
