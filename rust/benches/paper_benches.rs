//! `cargo bench` — regenerates every table and figure of the paper
//! (printing the same rows/series the paper reports) and times each
//! generator plus the runtime/serving hot paths.
//!
//! Custom harness (the offline build has no criterion): each benchmark
//! runs a warm-up pass then `iters` timed passes and reports min / median
//! / mean wall time. Timing output doubles as the §Perf baseline log in
//! EXPERIMENTS.md.

use std::time::{Duration, Instant};

use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{ConvPath, IMAGE_ELEMS};
use aimc::networks::{yolov3::yolov3, zoo};
use aimc::report;
use aimc::runtime::Engine;
use aimc::simulator::{optical4f, photonic, reram, sweep, systolic, OperatingPoint, SweepCache};
use aimc::technode::NODES;
use aimc::util::pool::Pool;
use aimc::util::rng::Rng;

/// Time `f` over `iters` iterations (after one warm-up); returns samples.
fn time_it<F: FnMut()>(iters: usize, mut f: F) -> Vec<Duration> {
    f(); // warm-up
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples
}

fn report_time(name: &str, samples: &[Duration], unit_work: Option<(f64, &str)>) {
    let mut us: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
    us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = us[0];
    let med = us[us.len() / 2];
    let mean = us.iter().sum::<f64>() / us.len() as f64;
    print!("bench {name:38} min {min:>10.1} µs   med {med:>10.1} µs   mean {mean:>10.1} µs");
    if let Some((per, what)) = unit_work {
        print!("   ({:.2} {what})", per / (med / 1e6));
    }
    println!();
}

fn median_us(samples: &[Duration]) -> f64 {
    let mut us: Vec<f64> = samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
    us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    us[us.len() / 2]
}

/// Serial vs parallel sweep-engine shootout over the full evaluation
/// grid (every machine × Table I network × node), recorded to
/// `BENCH_sweep.json` (override the path with `BENCH_JSON`) so the perf
/// trajectory is tracked from PR to PR.
fn bench_sweep_engine(input: usize) {
    let nets = zoo(input);
    let nodes: Vec<f64> = NODES.iter().map(|n| n.nm).collect();
    let ops = sweep::ops_at_nodes(&nodes);
    let machines = aimc::simulator::all_machines();
    let scfg = systolic::SystolicConfig::default();
    let ocfg = optical4f::Optical4FConfig::default();
    let rcfg = reram::ReramConfig::default();
    let pcfg = photonic::PhotonicConfig::default();

    // Baseline: the seed's path — hand-unrolled free-function calls, no
    // pool, no memoization.
    let serial = time_it(5, || {
        for net in &nets {
            for &nm in &nodes {
                let op = OperatingPoint::node(nm);
                let _ = systolic::simulate_network(&scfg, net, &op);
                let _ = reram::simulate_network(&rcfg, net, &op);
                let _ = photonic::simulate_network(&pcfg, net, &op);
                let _ = optical4f::simulate_network(&ocfg, net, &op);
            }
        }
    });
    report_time("sweep: serial direct (seed path)", &serial, None);

    // Engine, single worker: isolates the layer-dedup memoization win.
    let engine_1t = time_it(5, || {
        let cache = SweepCache::new();
        let _ = sweep::sweep_on(&Pool::new(1), &machines, &nets, &ops, &cache);
    });
    report_time("sweep: engine 1 thread (memo only)", &engine_1t, None);

    // Engine, all cores: memoization + work stealing.
    let pool = Pool::auto();
    let shared_cache = SweepCache::new();
    let engine = time_it(5, || {
        let cache = SweepCache::new();
        let _ = sweep::sweep_on(&pool, &machines, &nets, &ops, &cache);
    });
    report_time("sweep: engine parallel", &engine, None);
    // One extra pass on a shared cache for the hit/miss statistics.
    let _ = sweep::sweep_on(&pool, &machines, &nets, &ops, &shared_cache);

    // Precision axis: the same grid at 2 operating points per node (8x8
    // and 4x4) — the `aimc sweep --bits 8,4` path. The per-point cost
    // must stay flat: precision only rescales coefficients.
    let ops2: Vec<OperatingPoint> = nodes
        .iter()
        .flat_map(|&nm| [OperatingPoint::node(nm), OperatingPoint::node(nm).bits(4, 4)])
        .collect();
    let engine_bits = time_it(3, || {
        let cache = SweepCache::new();
        let _ = sweep::sweep_on(&pool, &machines, &nets, &ops2, &cache);
    });
    report_time("sweep: engine parallel x2 precisions", &engine_bits, None);

    // Full report regeneration (Fig. 6 + Tables I–III + Figs. 8–10 +
    // crossval) through the new engine.
    let figures = time_it(3, || {
        let _ = report::fig6().table();
        let _ = report::table1(input).table();
        let _ = report::table2(input).table();
        let _ = report::table3(input).table();
        let _ = report::fig8(None, input).table();
        let _ = report::fig9(None, input).table();
        let _ = report::fig10(Some("VGG19"), input).table();
        let _ = report::fig10(Some("YOLOv3"), input).table();
        let _ = report::crossval(None, input).table();
    });
    report_time("sweep: full report regen (engine)", &figures, None);

    // Persistent-cache shootout over the same grid: "cold" is a fresh
    // snapshot (load misses → simulate everything → save); "warm" loads
    // the snapshot the cold pass left behind and replays — the
    // `aimc sweep --cache-dir` repeat-invocation path.
    let snapshot = std::env::temp_dir().join(format!(
        "aimc-bench-sweepcache-{}.txt",
        std::process::id()
    ));
    let cold = time_it(3, || {
        let _ = std::fs::remove_file(&snapshot);
        let cache = SweepCache::load(&snapshot); // always empty: cold start
        let _ = sweep::sweep_on(&pool, &machines, &nets, &ops, &cache);
        cache.save(&snapshot).expect("snapshot save");
    });
    report_time("sweep: persistent cache cold", &cold, None);
    let mut warm_reuse = 0.0;
    let warm = time_it(3, || {
        let cache = SweepCache::load(&snapshot); // populated by the cold pass
        let _ = sweep::sweep_on(&pool, &machines, &nets, &ops, &cache);
        let total = (cache.hits() + cache.misses()).max(1);
        warm_reuse = 100.0 * cache.hits() as f64 / total as f64;
    });
    report_time("sweep: persistent cache warm", &warm, None);
    let _ = std::fs::remove_file(&snapshot);

    // Transformer decode streams through the same engine: gpt2-small at
    // a small (batch × context) grid, every machine × the intensity node
    // pair. Gated as a throughput (grid points per second) so the floor
    // check stays higher-is-better like the other gate metrics.
    let decode_cfg = aimc::networks::transformer::TransformerConfig::gpt2_small();
    let decode_nets: Vec<_> = [(1usize, 64usize), (4, 256), (16, 1024)]
        .iter()
        .map(|&(b, s)| decode_cfg.decode(b, s))
        .collect();
    let decode_ops = sweep::ops_at_nodes(&report::INTENSITY_NODES);
    let mut decode_points = 0usize;
    let decode = time_it(5, || {
        let cache = SweepCache::new();
        let recs = sweep::sweep_on(&pool, &machines, &decode_nets, &decode_ops, &cache);
        decode_points = recs.len();
    });
    let decode_ms = median_us(&decode) / 1e3;
    let decode_pps = decode_points as f64 / (decode_ms / 1e3);
    report_time(
        "sweep: transformer decode (gpt2)",
        &decode,
        Some((decode_points as f64, "points/s")),
    );

    let serial_ms = median_us(&serial) / 1e3;
    let engine_1t_ms = median_us(&engine_1t) / 1e3;
    let engine_ms = median_us(&engine) / 1e3;
    let engine_bits2_ms = median_us(&engine_bits) / 1e3;
    let cold_ms = median_us(&cold) / 1e3;
    let warm_ms = median_us(&warm) / 1e3;
    let json = format!(
        "{{\n  \"bench\": \"sweep\",\n  \"grid\": {{ \"machines\": {}, \"networks\": {}, \"nodes\": {} }},\n  \"threads\": {},\n  \"serial_direct_ms\": {serial_ms:.3},\n  \"engine_1thread_ms\": {engine_1t_ms:.3},\n  \"engine_parallel_ms\": {engine_ms:.3},\n  \"engine_parallel_bits2_ms\": {engine_bits2_ms:.3},\n  \"speedup_vs_serial\": {:.2},\n  \"cache\": {{ \"hits\": {}, \"misses\": {} }},\n  \"persistent_cache\": {{ \"cold_ms\": {cold_ms:.3}, \"warm_ms\": {warm_ms:.3}, \"warm_speedup\": {:.2}, \"warm_reuse_pct\": {warm_reuse:.1} }},\n  \"transformer_decode\": {{ \"streams\": {}, \"points\": {decode_points}, \"ms\": {decode_ms:.3}, \"points_per_s\": {decode_pps:.1} }},\n  \"report_regen_ms\": {:.3}\n}}\n",
        machines.len(),
        nets.len(),
        nodes.len(),
        pool.threads(),
        serial_ms / engine_ms,
        shared_cache.hits(),
        shared_cache.misses(),
        cold_ms / warm_ms,
        decode_nets.len(),
        median_us(&figures) / 1e3,
    );
    let path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&path, &json) {
        Ok(()) => println!("   wrote {path} (speedup {:.2}x over serial)", serial_ms / engine_ms),
        Err(e) => eprintln!("   warn: writing {path}: {e}"),
    }
}

/// Serving-path scaling harness: a (worker count × offered concurrency
/// × pricing mode) grid, recorded to `BENCH_serve.json` (override with
/// `BENCH_SERVE_JSON`). `offered` is realized as that many *client
/// threads* in a closed loop (one outstanding request each), so high
/// offered load exercises the sharded ingress the way production
/// traffic would — many threads admitting concurrently — instead of one
/// thread feeding a queue. Runs against the real PJRT engine when
/// artifacts are available and falls back to the deterministic
/// [`SimExecutor`] otherwise; the sim backend uses a deliberately small
/// per-batch cost so the serving path (admission, ingress shards,
/// dispatch, lanes, per-batch energy pricing) is the measured object,
/// not the executor's sleep. Each run carries a `"pricing"` tag
/// (`"cosim"` | `"surrogate"` | `"off"`) plus the energy accounting the
/// workers accumulated (omitted — not zeroed — when nothing was
/// priced), and the file ends with a pricing-path microbench:
/// `surrogate_vs_cosim_speedup` = fresh co-simulation time over
/// closed-form quote time for the resident network, the number the CI
/// bench gate floors. A sim-backend run also re-times the guard cell
/// under a scripted `FaultPlan` (`serve_under_faults`) so recovery
/// overhead is gated alongside fault-free throughput, and times a
/// heterogeneous 2-backend fleet cell (`serve_hetero`) so quote-based
/// routing is measured the same way.
fn bench_serve() {
    use aimc::coordinator::exec::SimExecutor;
    use aimc::coordinator::{energy, smallcnn_network};
    use aimc::energy::surrogate::{MachineKind, SurrogateTable};
    use aimc::networks::ConvLayer;
    use std::sync::Arc;

    let have_engine = Engine::discover().is_ok();
    let backend = if have_engine { "pjrt" } else { "sim" };
    // Enough requests that a run's wall time swamps thread start-up; the
    // PJRT backend is orders of magnitude slower per request, so it gets
    // a smaller grid.
    let n = if have_engine { 256usize } else { 4096 };
    let mut rng = Rng::new(2);
    // A small image pool: the bench times the server, not the PRNG.
    let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();

    // Fit the surrogate once, over the resident family padded with a few
    // same-family shapes so the fits are well-conditioned — the exact
    // table `aimc fit-surrogate && aimc serve --surrogate` would use for
    // this workload, minus the rest of the corpus.
    let table = {
        let mut layers = smallcnn_network().layers;
        layers.push(ConvLayer::square(32, 16, 64, 3, 1));
        layers.push(ConvLayer::square(16, 64, 8, 3, 1));
        layers.push(ConvLayer::square(96, 8, 24, 3, 1));
        layers.push(ConvLayer::square(12, 48, 48, 3, 1));
        Arc::new(
            SurrogateTable::fit(
                &SweepCache::new(),
                &[MachineKind::Systolic, MachineKind::Optical4F],
                &[45.0],
                &layers,
            )
            .expect("surrogate fit for the serving bench"),
        )
    };

    let mut runs = Vec::new();
    let mut run_one = |workers: usize, offered: usize, pricing: &str| {
        let cfg = ServerConfig {
            path: ConvPath::Exact,
            workers,
            warm_start: have_engine,
            max_pending: 4096,
            energy: pricing != "off",
            surrogate: (pricing == "surrogate").then(|| table.clone()),
            ..Default::default()
        };
        let server = if have_engine {
            Server::start(cfg).unwrap()
        } else {
            Server::start_sim(
                cfg,
                SimExecutor::new(Duration::from_micros(10), Duration::from_micros(1)),
            )
            .unwrap()
        };
        let _ = server.infer_blocking(images[0].clone()); // warm path
        let per_client = n / offered;
        let total = per_client * offered;
        let t0 = Instant::now();
        let ok: usize = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(offered);
            for c in 0..offered {
                let server = &server;
                let images = &images;
                handles.push(s.spawn(move || {
                    let mut ok = 0usize;
                    for i in 0..per_client {
                        let img = images[(c + i) % images.len()].clone();
                        if server.infer_blocking(img).is_ok() {
                            ok += 1;
                        }
                    }
                    ok
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let rps = total as f64 / wall;
        let energy_note = match m.systolic_uj_per_inference() {
            Some(sys) => format!("{sys:.2} µJ/inf systolic ({})", m.energy_source()),
            None => "energy n/a".to_string(),
        };
        println!(
            "serve[{backend}/{pricing}]: {workers} workers, {offered:>2} offered: \
             {rps:>8.0} req/s, p50 {:>7.2} ms, p99 {:>7.2} ms, mean batch {:.2}, {energy_note}",
            m.percentile_us(50.0) as f64 / 1e3,
            m.percentile_us(99.0) as f64 / 1e3,
            m.mean_batch(),
        );
        // Energy fields appear only when batches were actually priced —
        // absent, not 0.0, so a gate or plot can't mistake "pricing
        // disabled" for "free inference".
        let energy_fields = match (m.systolic_uj_per_inference(), m.optical_uj_per_inference()) {
            (Some(sys), Some(opt)) => format!(
                ", \"energy_node_nm\": {}, \"energy_bits\": \"{}x{}\", \
                 \"sys_uj_per_inf\": {sys:.4}, \
                 \"opt_uj_per_inf\": {opt:.4}, \"energy_batches\": {}, \"energy_images\": {}",
                m.energy_node_nm(),
                m.energy_bits().0,
                m.energy_bits().1,
                m.energy_batches(),
                m.energy_images(),
            ),
            _ => String::new(),
        };
        runs.push(format!(
            "    {{ \"workers\": {workers}, \"offered\": {offered}, \"pricing\": \"{pricing}\", \
             \"requests\": {total}, \"ok\": {ok}, \"throughput_rps\": {rps:.1}, \
             \"p50_us\": {}, \"p99_us\": {}, \"mean_batch\": {:.2}, \
             \"rejected\": {}{energy_fields} }}",
            m.percentile_us(50.0),
            m.percentile_us(99.0),
            m.mean_batch(),
            m.rejected(),
        ));
    };
    for &workers in &[1usize, 2, 4] {
        for &offered in &[1usize, 8, 32] {
            run_one(workers, offered, "cosim");
            run_one(workers, offered, "surrogate");
        }
    }
    // One pricing-off run at the guard cell: the latency cost of the
    // accounting itself.
    run_one(4, 32, "off");

    // The guard cell again under a scripted fault plan: every 5th batch
    // errors once (recovered by the default retry policy) and every 3rd
    // runs 2x slow. Throughput under recovery is its own gate key
    // (`serve_under_faults_rps`), so the retry/breaker machinery can't
    // silently become the bottleneck. Faults script into the sim
    // backend only, so a PJRT run omits the section (the gate then
    // skips the key with a note).
    let faulted_section = if have_engine {
        String::new()
    } else {
        use aimc::coordinator::exec::FaultPlan;
        let plan = FaultPlan::parse("error=5,slow=3:2").expect("bench fault plan");
        let cfg = ServerConfig {
            path: ConvPath::Exact,
            workers: 4,
            max_pending: 4096,
            energy: false,
            ..Default::default()
        };
        let server = Server::start_sim(
            cfg,
            SimExecutor::new(Duration::from_micros(10), Duration::from_micros(1))
                .with_plan(plan),
        )
        .unwrap();
        let offered = 32usize;
        let per_client = n / offered;
        let total = per_client * offered;
        let t0 = Instant::now();
        let ok: usize = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(offered);
            for c in 0..offered {
                let server = &server;
                let images = &images;
                handles.push(s.spawn(move || {
                    let mut ok = 0usize;
                    for i in 0..per_client {
                        let img = images[(c + i) % images.len()].clone();
                        if server.infer_blocking(img).is_ok() {
                            ok += 1;
                        }
                    }
                    ok
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        let rps = total as f64 / wall;
        println!(
            "serve[{backend}/faulted]: 4 workers, {offered:>2} offered: {rps:>8.0} req/s, \
             {} retries, {} breaker trip(s), {ok}/{total} ok",
            m.retries(),
            m.breaker_trips(),
        );
        format!(
            "  \"serve_under_faults\": {{ \"plan\": \"error=5,slow=3:2\", \"workers\": 4, \
             \"offered\": {offered}, \"requests\": {total}, \"ok\": {ok}, \
             \"throughput_rps\": {rps:.1}, \"retries\": {}, \"timeouts\": {}, \
             \"breaker_trips\": {} }},\n",
            m.retries(),
            m.timeouts(),
            m.breaker_trips(),
        )
    };

    // Heterogeneous-fleet grid cell: 2 backends (one lane each) × {8,
    // 32} offered, recorded as `serve_hetero` so quote-based routing has
    // its own gate key (`serve_hetero_rps`, warn-and-skip until
    // baselined). Sim-only like the faulted cell: fleets need the
    // per-lane SimExecutor factory.
    let hetero_section = if have_engine {
        String::new()
    } else {
        use aimc::coordinator::server::parse_fleet;
        let fleet_spec = "systolic@45:1,reram@45:1";
        let mut hetero_runs = Vec::new();
        for &offered in &[8usize, 32] {
            let cfg = ServerConfig {
                path: ConvPath::Exact,
                max_pending: 4096,
                energy: true,
                fleet: Some(parse_fleet(fleet_spec).expect("bench fleet spec")),
                ..Default::default()
            };
            let server = Server::start_with(cfg, |_| {
                Ok(SimExecutor::new(
                    Duration::from_micros(10),
                    Duration::from_micros(1),
                ))
            })
            .unwrap();
            let _ = server.infer_blocking(images[0].clone()); // warm path
            let per_client = n / offered;
            let total = per_client * offered;
            let t0 = Instant::now();
            let ok: usize = std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(offered);
                for c in 0..offered {
                    let server = &server;
                    let images = &images;
                    handles.push(s.spawn(move || {
                        let mut ok = 0usize;
                        for i in 0..per_client {
                            let img = images[(c + i) % images.len()].clone();
                            if server.infer_blocking(img).is_ok() {
                                ok += 1;
                            }
                        }
                        ok
                    }));
                }
                handles.into_iter().map(|h| h.join().unwrap()).sum()
            });
            let wall = t0.elapsed().as_secs_f64();
            let m = server.shutdown();
            let rps = total as f64 / wall;
            println!(
                "serve[{backend}/hetero]: fleet {fleet_spec}, {offered:>2} offered: \
                 {rps:>8.0} req/s, {} rerouted, {} backends in table",
                m.rerouted(),
                m.backends().len(),
            );
            let backends_json: Vec<String> = m
                .backends()
                .iter()
                .map(|(label, b)| {
                    format!(
                        "{{ \"backend\": \"{label}\", \"images\": {}, \"uj_per_inf\": {} }}",
                        b.images(),
                        match b.uj_per_inf() {
                            Some(uj) => format!("{uj:.4}"),
                            None => "null".to_string(),
                        },
                    )
                })
                .collect();
            hetero_runs.push(format!(
                "      {{ \"offered\": {offered}, \"requests\": {total}, \"ok\": {ok}, \
                 \"throughput_rps\": {rps:.1}, \"rerouted\": {}, \"per_backend\": [ {} ] }}",
                m.rerouted(),
                backends_json.join(", "),
            ));
        }
        format!(
            "  \"serve_hetero\": {{ \"fleet\": \"{fleet_spec}\", \"runs\": [\n{}\n    ] }},\n",
            hetero_runs.join(",\n")
        )
    };

    // Pricing-path microbench: what each path costs per quote of the
    // resident network. Co-simulation is timed cold (fresh cache — the
    // first batch anywhere on a worker) per sample; the surrogate quote
    // is so cheap it is timed in blocks.
    let net = smallcnn_network();
    let cosim_samples = time_it(20, || {
        let _ = energy::co_simulate(&net, &OperatingPoint::node(45.0));
    });
    let cosim_us = median_us(&cosim_samples);
    const QUOTES_PER_SAMPLE: usize = 1000;
    let quote_samples = time_it(20, || {
        for _ in 0..QUOTES_PER_SAMPLE {
            let _ = table.quote_network(&net, 45.0);
        }
    });
    let quote_us = median_us(&quote_samples) / QUOTES_PER_SAMPLE as f64;
    let speedup = cosim_us / quote_us;
    report_time("serve: cosim price (cold)", &cosim_samples, None);
    println!(
        "bench serve: surrogate quote                {quote_us:>10.3} µs/quote   \
         ({speedup:.0}x over cold co-simulation)"
    );

    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"backend\": \"{backend}\",\n  \"runs\": [\n{}\n  ],\n{faulted_section}{hetero_section}  \
         \"pricing_path\": {{ \"cosim_cold_us\": {cosim_us:.3}, \
         \"surrogate_quote_us\": {quote_us:.4} }},\n  \
         \"surrogate_vs_cosim_speedup\": {speedup:.1}\n}}\n",
        runs.join(",\n")
    );
    let path =
        std::env::var("BENCH_SERVE_JSON").unwrap_or_else(|_| "BENCH_serve.json".into());
    match std::fs::write(&path, &json) {
        Ok(()) => println!("   wrote {path} ({backend} backend)"),
        Err(e) => eprintln!("   warn: writing {path}: {e}"),
    }
}

fn main() {
    // `cargo bench -- <filter>` support (cargo injects flags like
    // `--bench`; ignore anything starting with '-').
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);
    let input = 1000;

    println!("=== aimc paper benches (tables + figures + hot paths) ===\n");

    // ---- Tables I–IV ------------------------------------------------------
    if run("table1") {
        println!("{}", report::table1(input).table().render());
        report_time("table1 (zoo stats ×8 nets)", &time_it(20, || {
            let _ = report::table1(input).table();
        }), None);
    }
    if run("table2") {
        println!("{}", report::table2(input).table().render());
        report_time("table2 (matmul dims)", &time_it(20, || {
            let _ = report::table2(input).table();
        }), None);
    }
    if run("table3") {
        println!("{}", report::table3(input).table().render());
        report_time("table3 (4F dims)", &time_it(20, || {
            let _ = report::table3(input).table();
        }), None);
    }
    if run("table4") {
        println!("{}", report::table4().table().render());
        report_time("table4 (energy constants)", &time_it(100, || {
            let _ = report::table4().table();
        }), None);
    }

    // ---- Figures 6–10 -------------------------------------------------------
    if run("fig6") {
        println!("{}", report::fig6().table().render());
        report_time("fig6 (4 models × 13 nodes)", &time_it(20, || {
            let _ = report::fig6().table();
        }), None);
    }
    if run("fig7") {
        println!("{}", report::fig7().table().render());
        report_time("fig7 (breakdown @32nm)", &time_it(50, || {
            let _ = report::fig7().table();
        }), None);
    }
    if run("fig8") {
        println!("{}", report::fig8(None, input).table().render());
        report_time("fig8 (systolic sim ×13 nodes)", &time_it(10, || {
            let _ = report::fig8(None, input).table();
        }), None);
    }
    if run("fig9") {
        println!("{}", report::fig9(None, input).table().render());
        report_time("fig9 (optical sim ×13 nodes)", &time_it(10, || {
            let _ = report::fig9(None, input).table();
        }), None);
    }
    if run("fig10") {
        println!("{}", report::fig10(Some("VGG19"), input).table().render());
        println!("{}", report::fig10(Some("YOLOv3"), input).table().render());
        report_time("fig10 (2 nets × 13 nodes)", &time_it(10, || {
            let _ = report::fig10(Some("VGG19"), input).table();
            let _ = report::fig10(Some("YOLOv3"), input).table();
        }), None);
    }

    // ---- Simulator hot paths ------------------------------------------------
    if run("sim") {
        let net = yolov3(input);
        let scfg = systolic::SystolicConfig::default();
        let ocfg = optical4f::Optical4FConfig::default();
        report_time(
            "sim: systolic YOLOv3 (1 net·node)",
            &time_it(50, || {
                let _ = systolic::simulate_network(&scfg, &net, &OperatingPoint::node(28.0));
            }),
            Some((net.num_layers() as f64, "layers/s")),
        );
        report_time(
            "sim: optical4f YOLOv3 (1 net·node)",
            &time_it(50, || {
                let _ = optical4f::simulate_network(&ocfg, &net, &OperatingPoint::node(28.0));
            }),
            Some((net.num_layers() as f64, "layers/s")),
        );
        report_time("zoo build (8 networks)", &time_it(50, || {
            let _ = zoo(input);
        }), None);
        // Full evaluation-section sweep: every net × node × both machines.
        let nets = zoo(input);
        report_time("sweep: 8 nets × 13 nodes × 2 machines", &time_it(5, || {
            for net in &nets {
                for node in aimc::technode::NODES {
                    let op = OperatingPoint::node(node.nm);
                    let _ = systolic::simulate_network(&scfg, net, &op);
                    let _ = optical4f::simulate_network(&ocfg, net, &op);
                }
            }
        }), None);
    }

    // ---- Parallel sweep engine ----------------------------------------------
    if run("sweep") {
        bench_sweep_engine(input);
    }

    // ---- Runtime / serving hot paths -----------------------------------------
    if run("runtime") {
        match Engine::discover() {
            Ok(engine) => {
                let mut rng = Rng::new(1);
                let img = rng.normal_vec(IMAGE_ELEMS);
                engine.warm_up(&["smallcnn_exact", "smallcnn_exact_b8"]).unwrap();
                report_time(
                    "runtime: smallcnn_exact b1",
                    &time_it(30, || {
                        let _ = engine.execute("smallcnn_exact", &[img.clone()]).unwrap();
                    }),
                    Some((1.0, "img/s")),
                );
                let img8: Vec<f32> = (0..8).flat_map(|_| img.clone()).collect();
                report_time(
                    "runtime: smallcnn_exact b8",
                    &time_it(30, || {
                        let _ = engine
                            .execute("smallcnn_exact_b8", &[img8.clone()])
                            .unwrap();
                    }),
                    Some((8.0, "img/s")),
                );
            }
            Err(e) => println!("runtime benches skipped: {e:#}"),
        }
    }

    if run("serve") {
        bench_serve();
    }

    println!("\nbenches done");
}
