//! Integration: the serving coordinator under load, across datapaths and
//! failure modes.

use std::time::Duration;

use aimc::coordinator::batcher::BatchPolicy;
use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{ConvPath, IMAGE_ELEMS, LOGITS};
use aimc::util::rng::Rng;

/// Start a server, or None when the PJRT feature / artifacts are
/// unavailable in this build environment (the tests then skip).
fn start(path: ConvPath, workers: usize) -> Option<Server> {
    match Server::start(ServerConfig {
        path,
        workers,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        },
        warm_start: false, // lazy compile: these tests don't time serving
        ..Default::default()
    }) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: {e:#}");
            None
        }
    }
}

#[test]
fn serves_concurrent_load_exact() {
    let Some(server) = start(ConvPath::Exact, 2) else {
        return;
    };
    server.infer_blocking(vec![0.0; IMAGE_ELEMS]).unwrap(); // warm-up
    let mut rng = Rng::new(11);
    let n = 40;
    server.metrics.lock().unwrap().start();
    let rxs: Vec<_> = (0..n)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), LOGITS);
        assert!(out.iter().all(|v| v.is_finite()));
    }
    server.metrics.lock().unwrap().stop();
    let m = server.shutdown();
    assert_eq!(m.count(), n + 1);
    assert!(m.throughput() > 0.0);
}

#[test]
fn systolic_path_serves_and_batches() {
    let Some(server) = start(ConvPath::Systolic, 1) else {
        return;
    };
    server.infer_blocking(vec![0.1; IMAGE_ELEMS]).unwrap();
    let mut rng = Rng::new(12);
    let rxs: Vec<_> = (0..8)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    // At least one multi-request batch should have formed.
    assert!(m.mean_batch() > 1.0, "{}", m.summary());
}

#[test]
fn fft_path_serves_batch1_only() {
    let Some(server) = start(ConvPath::Fft, 1) else {
        return;
    };
    let out = server.infer_blocking(vec![0.2; IMAGE_ELEMS]).unwrap();
    assert_eq!(out.len(), LOGITS);
    let m = server.shutdown();
    // FFT has no batched artifacts: every batch is size 1.
    assert!((m.mean_batch() - 1.0).abs() < 1e-9);
}

#[test]
fn bad_requests_rejected_good_ones_still_served() {
    let Some(server) = start(ConvPath::Exact, 1) else {
        return;
    };
    assert!(server.infer_blocking(vec![0.0; 3]).is_err());
    assert!(server.infer_blocking(vec![]).is_err());
    let ok = server.infer_blocking(vec![0.0; IMAGE_ELEMS]);
    assert!(ok.is_ok(), "server must survive bad requests");
    server.shutdown();
}

#[test]
fn shutdown_drains_in_flight_work() {
    let Some(server) = start(ConvPath::Exact, 2) else {
        return;
    };
    server.infer_blocking(vec![0.0; IMAGE_ELEMS]).unwrap();
    let mut rng = Rng::new(14);
    let rxs: Vec<_> = (0..16)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    // Shut down immediately — all 16 must still complete.
    let _ = server.shutdown();
    let mut done = 0;
    for rx in rxs {
        if let Ok(Ok(out)) = rx.recv() {
            assert_eq!(out.len(), LOGITS);
            done += 1;
        }
    }
    assert_eq!(done, 16, "shutdown dropped in-flight requests");
}

#[test]
fn shutdown_after_batched_round_is_prompt() {
    // Regression: `infer` counts in-flight per *request* but the worker
    // used to retire one unit per *batch*, so any multi-request batch
    // leaked the counter and `shutdown()` burned its full 30 s deadline.
    let Some(server) = start(ConvPath::Exact, 1) else {
        return;
    };
    server.infer_blocking(vec![0.0; IMAGE_ELEMS]).unwrap(); // compile
    let mut rng = Rng::new(16);
    let rxs: Vec<_> = (0..8)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let t0 = std::time::Instant::now();
    let m = server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "shutdown took {:?} after a batched round — in-flight accounting leaked ({})",
        t0.elapsed(),
        m.summary()
    );
    // The leak only reproduces on multi-request batches; make sure the
    // round actually batched instead of passing vacuously.
    assert!(m.mean_batch() > 1.0, "batching never engaged: {}", m.summary());
}

#[test]
fn deterministic_results_across_paths_and_servers() {
    let mut rng = Rng::new(15);
    let img = rng.normal_vec(IMAGE_ELEMS);
    let mut per_path = Vec::new();
    for path in [ConvPath::Exact, ConvPath::Systolic] {
        let Some(server) = start(path, 1) else {
            return;
        };
        let a = server.infer_blocking(img.clone()).unwrap();
        let b = server.infer_blocking(img.clone()).unwrap();
        assert_eq!(a, b, "same server must be deterministic");
        per_path.push(a);
        server.shutdown();
    }
    // Exact vs systolic agree within quantization error.
    let scale = per_path[0].iter().fold(1e-9f32, |m, v| m.max(v.abs()));
    for (a, b) in per_path[0].iter().zip(&per_path[1]) {
        assert!((a - b).abs() / scale < 0.15, "{a} vs {b}");
    }
}
