//! Integration: the serving coordinator under load, across datapaths,
//! backends and failure modes.
//!
//! Two sections:
//! * **Synthetic backend** ([`SimExecutor`]) — always runs, including in
//!   the offline build environment: lifecycle (shutdown-under-load,
//!   drop-with-pending), backpressure/admission across the ingress
//!   shards, and the exactly-one-response property over the sharded
//!   ingress + lanes (including concurrent client threads, which land
//!   on different ingress shards).
//! * **PJRT engine** — skips gracefully when artifacts / the `pjrt`
//!   feature are unavailable.

use std::time::{Duration, Instant};

use aimc::coordinator::batcher::BatchPolicy;
use aimc::coordinator::exec::SimExecutor;
use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{ConvPath, IMAGE_ELEMS, LOGITS};
use aimc::util::prop::{check, prop_assert};
use aimc::util::rng::Rng;

// ---------------------------------------------------------------------------
// Synthetic backend: runs everywhere.
// ---------------------------------------------------------------------------

fn sim_start(workers: usize, sim: SimExecutor) -> Server {
    Server::start_sim(
        ServerConfig {
            workers,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            warm_start: false,
            ..Default::default()
        },
        sim,
    )
    .expect("sim server needs no artifacts")
}

#[test]
fn shutdown_after_batched_round_is_prompt() {
    // Regression: `infer` counts in-flight per *request* but the worker
    // used to retire one unit per *batch*, so any multi-request batch
    // leaked the counter and `shutdown()` burned its full 30 s deadline.
    let server = sim_start(1, SimExecutor::instant());
    let mut rng = Rng::new(16);
    let rxs: Vec<_> = (0..8)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let t0 = Instant::now();
    let m = server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "shutdown took {:?} after a batched round — in-flight accounting leaked ({})",
        t0.elapsed(),
        m.summary()
    );
    // The leak only reproduces on multi-request batches; make sure the
    // workload actually batched instead of passing vacuously.
    assert!(m.mean_batch() > 1.0, "batching never engaged: {}", m.summary());
}

#[test]
fn shutdown_under_load_answers_everything() {
    // Fire a burst at slow workers and shut down immediately: shutdown
    // must drain — every admitted request answered, none stranded.
    let server = sim_start(2, SimExecutor::new(Duration::from_millis(1), Duration::ZERO));
    let mut rng = Rng::new(17);
    let n = 48;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    let m = server.shutdown();
    let mut done = 0;
    for rx in rxs {
        let out = rx
            .recv()
            .expect("request stranded without a response")
            .expect("admitted request must be served");
        assert_eq!(out.len(), LOGITS);
        done += 1;
    }
    assert_eq!(done, n, "shutdown dropped in-flight requests");
    assert_eq!(m.count(), n);
}

#[test]
fn drop_server_with_pending_requests_answers_all() {
    // Dropping the handle without shutdown() runs the same drain.
    let server = sim_start(2, SimExecutor::new(Duration::from_millis(1), Duration::ZERO));
    let mut rng = Rng::new(18);
    let rxs: Vec<_> = (0..32)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    drop(server);
    for rx in rxs {
        let resp = rx.recv().expect("drop stranded a pending request");
        resp.expect("admitted request must be served through drop-drain");
    }
}

#[test]
fn every_request_gets_exactly_one_response_prop() {
    // Property over the sharded path: for random worker counts, ingress
    // shard counts, batch policies and request counts, every submitted
    // request receives exactly one response, and served + rejected ==
    // submitted.
    check(25, |g| {
        let workers = g.usize(1, 4);
        let ingress_shards = g.usize(1, 6);
        let max_batch = g.usize(1, 8);
        let n = g.usize(0, 60);
        let server = Server::start_sim(
            ServerConfig {
                workers,
                ingress_shards,
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_micros(500),
                },
                warm_start: false,
                max_pending: 4096, // admission disabled for this property
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(1000 + g.seed);
        let rxs: Vec<_> = (0..n)
            .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let m = server.shutdown();
        let mut answered = 0usize;
        for rx in rxs {
            // Exactly one: a first recv must succeed…
            match rx.recv() {
                Ok(Ok(out)) => {
                    if out.len() != LOGITS {
                        return prop_assert(false, "bad logits length");
                    }
                    answered += 1;
                }
                Ok(Err(_)) => answered += 1,
                Err(_) => return prop_assert(false, "request got zero responses"),
            }
            // …and a second recv must find a closed channel, not a
            // duplicate response.
            if rx.try_recv().is_ok() {
                return prop_assert(false, "request got two responses");
            }
        }
        if answered != n {
            return prop_assert(false, "response count mismatch");
        }
        if m.count() + m.rejected() != n {
            return prop_assert(false, "served + rejected != submitted");
        }
        prop_assert(true, "")
    });
}

#[test]
fn exactly_one_response_across_concurrent_clients_prop() {
    // The multi-client variant: several client threads admit
    // concurrently, each landing on its own ingress shard (per-thread
    // hint). Every request must still get exactly one response, and the
    // books must balance at shutdown.
    check(8, |g| {
        let workers = g.usize(1, 4);
        let ingress_shards = g.usize(1, 6);
        let clients = g.usize(1, 6);
        let per_client = g.usize(0, 24);
        let base_seed = g.seed;
        let server = Server::start_sim(
            ServerConfig {
                workers,
                ingress_shards,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_micros(500),
                },
                warm_start: false,
                max_pending: 4096, // admission disabled for this property
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let answered: Result<usize, String> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(clients);
            for c in 0..clients {
                let server = &server;
                let seed = base_seed.wrapping_mul(31).wrapping_add(c as u64);
                handles.push(s.spawn(move || -> Result<usize, String> {
                    let mut rng = Rng::new(seed);
                    let mut answered = 0usize;
                    for _ in 0..per_client {
                        let rx = server.infer(rng.normal_vec(IMAGE_ELEMS));
                        // Exactly one: a first recv must succeed…
                        match rx.recv() {
                            Ok(Ok(out)) if out.len() != LOGITS => {
                                return Err("bad logits length".into())
                            }
                            Ok(_) => answered += 1,
                            Err(_) => return Err("request got zero responses".into()),
                        }
                        // …and a second must find a closed channel.
                        if rx.try_recv().is_ok() {
                            return Err("request got two responses".into());
                        }
                    }
                    Ok(answered)
                }));
            }
            let mut total = 0usize;
            for h in handles {
                total += h.join().unwrap()?;
            }
            Ok(total)
        });
        let m = server.shutdown();
        let answered = match answered {
            Ok(a) => a,
            Err(msg) => return Err(msg),
        };
        if answered != clients * per_client {
            return prop_assert(false, "response count mismatch");
        }
        prop_assert(
            m.count() + m.rejected() == clients * per_client,
            "served + rejected != submitted",
        )
    });
}

#[test]
fn admission_bound_is_strict_for_a_single_client() {
    // One client thread bursting against a stalled worker: with no
    // concurrent admitters the racy check-then-add pair cannot
    // overshoot, so admitted must stay ≤ max_pending even though the
    // requests spread across ingress shards (per-shard capacity is
    // max_pending / shards; the push probe chain fills every shard
    // before the ingress reports Full).
    let server = Server::start_sim(
        ServerConfig {
            workers: 1,
            warm_start: false,
            max_pending: 8,
            ingress_shards: 4,
            ..Default::default()
        },
        SimExecutor::new(Duration::from_millis(500), Duration::ZERO),
    )
    .unwrap();
    let mut rng = Rng::new(31);
    let rxs: Vec<_> = (0..64)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    let m = server.shutdown();
    let (mut served, mut shed) = (0, 0);
    for rx in rxs {
        match rx.recv().expect("one response per request") {
            Ok(_) => served += 1,
            Err(e) => {
                assert!(e.to_string().contains("overloaded"), "unexpected: {e:#}");
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, 64);
    assert!(served >= 1, "something must be admitted");
    assert!(served <= 8, "admitted {served} > max_pending 8");
    assert_eq!(m.count(), served);
    assert_eq!(m.rejected(), shed);
}

#[test]
fn drain_on_shutdown_with_uneven_shard_load() {
    // Client threads with skewed request counts land on different
    // ingress shards; shutting down mid-flight must answer every
    // admitted request no matter which shard it sits in.
    let server = Server::start_sim(
        ServerConfig {
            workers: 2,
            warm_start: false,
            max_pending: 4096,
            ingress_shards: 5,
            ..Default::default()
        },
        SimExecutor::new(Duration::from_millis(1), Duration::ZERO),
    )
    .unwrap();
    let counts = [40usize, 8, 1];
    let rxs: Vec<Vec<_>> = std::thread::scope(|s| {
        let handles: Vec<_> = counts
            .iter()
            .enumerate()
            .map(|(t, &k)| {
                let server = &server;
                s.spawn(move || {
                    let mut rng = Rng::new(40 + t as u64);
                    (0..k)
                        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let m = server.shutdown();
    let mut answered = 0;
    for rx in rxs.into_iter().flatten() {
        rx.recv()
            .expect("request stranded without a response")
            .expect("admitted request must be served through the drain");
        answered += 1;
    }
    assert_eq!(answered, 49);
    assert_eq!(m.count(), 49);
}

#[test]
fn backpressure_sheds_load_but_never_strands() {
    let server = Server::start_sim(
        ServerConfig {
            workers: 1,
            warm_start: false,
            max_pending: 4,
            ..Default::default()
        },
        SimExecutor::new(Duration::from_millis(10), Duration::ZERO),
    )
    .unwrap();
    let mut rng = Rng::new(19);
    let rxs: Vec<_> = (0..24)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    let (mut served, mut shed) = (0, 0);
    for rx in rxs {
        match rx.recv().expect("one response per request") {
            Ok(_) => served += 1,
            Err(e) => {
                assert!(e.to_string().contains("overloaded"), "unexpected: {e:#}");
                shed += 1;
            }
        }
    }
    assert_eq!(served + shed, 24);
    assert!(shed > 0, "24-burst against max_pending=4 must shed");
    let m = server.shutdown();
    assert_eq!(m.rejected(), shed);
}

#[test]
fn exactly_one_response_under_injected_faults_prop() {
    // The exactly-once property must survive chaos: random worker/shard
    // counts with scripted executor faults (transient errors, stalls,
    // slow batches) and random retry budgets. Responses may be errors,
    // but every submitted request gets exactly one, and the books
    // balance at shutdown.
    use aimc::coordinator::exec::FaultPlan;
    check(12, |g| {
        let workers = g.usize(1, 4);
        let ingress_shards = g.usize(1, 6);
        let max_batch = g.usize(1, 8);
        let n = g.usize(0, 40);
        let plan = FaultPlan {
            error_every: [0u64, 2, 3][g.usize(0, 2)],
            stall_every: [0u64, 5][g.usize(0, 1)],
            stall_for: Duration::from_millis(2),
            slow_every: [0u64, 3][g.usize(0, 1)],
            slow_factor: 4,
            backend: None,
        };
        let server = Server::start_sim(
            ServerConfig {
                workers,
                ingress_shards,
                policy: BatchPolicy {
                    max_batch,
                    max_wait: Duration::from_micros(500),
                },
                warm_start: false,
                max_pending: 4096, // admission disabled for this property
                energy: false,
                max_retries: g.usize(0, 2) as u32,
                retry_backoff: Duration::from_micros(100),
                breaker_threshold: g.usize(1, 3),
                breaker_cooldown: Duration::from_millis(5),
                ..Default::default()
            },
            SimExecutor::new(Duration::from_micros(50), Duration::ZERO).with_plan(plan),
        )
        .unwrap();
        let mut rng = Rng::new(5000 + g.seed);
        let rxs: Vec<_> = (0..n)
            .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let m = server.shutdown();
        let mut answered = 0usize;
        for rx in rxs {
            // Exactly one: a first recv must succeed (Ok or a fault
            // error — both are answers)…
            match rx.recv() {
                Ok(Ok(out)) => {
                    if out.len() != LOGITS {
                        return prop_assert(false, "bad logits length");
                    }
                    answered += 1;
                }
                Ok(Err(_)) => answered += 1,
                Err(_) => return prop_assert(false, "request got zero responses"),
            }
            // …and a second recv must find a closed channel.
            if rx.try_recv().is_ok() {
                return prop_assert(false, "request got two responses");
            }
        }
        if answered != n {
            return prop_assert(false, "response count mismatch");
        }
        // Every Ok answer is recorded; every retry/trip is accounted.
        prop_assert(
            m.count() + m.rejected() <= n,
            "served + rejected exceeds submitted",
        )
    });
}

#[test]
fn admission_bound_holds_under_injected_faults() {
    // The strict single-client admission bound must survive a faulting
    // worker: a burst against a slow, erroring executor still sheds
    // everything beyond max_pending, and every admitted request is
    // answered exactly once (Ok or the injected fault's error).
    use aimc::coordinator::exec::FaultPlan;
    let plan = FaultPlan::parse("error=2").unwrap();
    let server = Server::start_sim(
        ServerConfig {
            workers: 1,
            warm_start: false,
            max_pending: 8,
            ingress_shards: 4,
            energy: false,
            max_retries: 0,
            ..Default::default()
        },
        SimExecutor::new(Duration::from_millis(500), Duration::ZERO).with_plan(plan),
    )
    .unwrap();
    let mut rng = Rng::new(51);
    let rxs: Vec<_> = (0..64)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    let m = server.shutdown();
    let (mut delivered, mut shed) = (0, 0);
    for rx in rxs {
        match rx.recv().expect("one response per request") {
            Ok(_) => delivered += 1,
            Err(e) if e.to_string().contains("overloaded") => shed += 1,
            Err(e) => {
                assert!(
                    e.to_string().contains("injected transient fault"),
                    "unexpected: {e:#}"
                );
                delivered += 1;
            }
        }
    }
    assert_eq!(delivered + shed, 64);
    assert!(delivered >= 1, "something must be admitted");
    assert!(delivered <= 8, "admitted {delivered} > max_pending 8");
    assert!(m.count() <= delivered, "only delivered Oks are recorded");
}

#[test]
fn hetero_fleet_exactly_once_under_backend_targeted_faults_prop() {
    // The exactly-once property over a heterogeneous fleet: random
    // 2–3-backend fleets under a randomized FaultPlan that may target a
    // single machine kind (the server specializes the plan per lane via
    // `for_backend`, exactly like `aimc serve --chaos backend=…`).
    // Every request gets exactly one answer, and every dispatched image
    // is accounted to some backend's shard.
    use aimc::coordinator::exec::FaultPlan;
    use aimc::coordinator::server::parse_fleet;
    use aimc::energy::surrogate::MachineKind;
    check(10, |g| {
        let fleet_spec = [
            "systolic@45:1,reram@45:1",
            "systolic@45:2,optical4f@45:1",
            "reram@45:1,photonic@45:1,systolic@45:1",
        ][g.usize(0, 2)];
        let plan = FaultPlan {
            error_every: [0u64, 2, 3][g.usize(0, 2)],
            stall_every: [0u64, 5][g.usize(0, 1)],
            stall_for: Duration::from_millis(1),
            slow_every: 0,
            slow_factor: 1,
            backend: [None, Some(MachineKind::Systolic), Some(MachineKind::Reram)]
                [g.usize(0, 2)],
        };
        let n = g.usize(0, 40);
        let cfg = ServerConfig {
            fleet: Some(parse_fleet(fleet_spec).unwrap()),
            policy: BatchPolicy {
                max_batch: g.usize(1, 8),
                max_wait: Duration::from_micros(500),
            },
            warm_start: false,
            max_pending: 4096, // admission disabled for this property
            energy: false,
            max_retries: g.usize(0, 2) as u32,
            retry_backoff: Duration::from_micros(100),
            breaker_threshold: g.usize(1, 3),
            breaker_cooldown: Duration::from_millis(5),
            ..Default::default()
        };
        let specs = cfg.fleet_workers().unwrap();
        let server = Server::start_with(cfg, move |w| {
            Ok(SimExecutor::new(Duration::from_micros(50), Duration::ZERO)
                .with_plan(plan.for_backend(specs[w].kind)))
        })
        .unwrap();
        let mut rng = Rng::new(9000 + g.seed);
        let rxs: Vec<_> = (0..n)
            .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let m = server.shutdown();
        let mut answered = 0usize;
        for rx in rxs {
            // Exactly one: a first recv must succeed (Ok or a fault
            // error — both are answers)…
            match rx.recv() {
                Ok(Ok(out)) => {
                    if out.len() != LOGITS {
                        return prop_assert(false, "bad logits length");
                    }
                    answered += 1;
                }
                Ok(Err(_)) => answered += 1,
                Err(_) => return prop_assert(false, "request got zero responses"),
            }
            // …and a second recv must find a closed channel.
            if rx.try_recv().is_ok() {
                return prop_assert(false, "request got two responses");
            }
        }
        if answered != n {
            return prop_assert(false, "response count mismatch");
        }
        // Every dispatched image lands in exactly one backend's shard.
        let shard_images: usize = m.backends().values().map(|b| b.images()).sum();
        prop_assert(
            shard_images == n,
            "per-backend shards must account every dispatched image",
        )
    });
}

#[test]
fn hetero_fleet_admission_bound_holds_under_backend_faults_prop() {
    // The strict single-client admission bound over a heterogeneous
    // fleet: a burst against slow fleet lanes — one of which may be
    // error-injected — still sheds everything beyond max_pending, and
    // every admitted request is answered exactly once.
    use aimc::coordinator::exec::FaultPlan;
    use aimc::coordinator::server::parse_fleet;
    use aimc::energy::surrogate::MachineKind;
    check(5, |g| {
        let max_pending = [4usize, 8][g.usize(0, 1)];
        let plan = FaultPlan {
            error_every: [0u64, 2][g.usize(0, 1)],
            stall_every: 0,
            stall_for: Duration::ZERO,
            slow_every: 0,
            slow_factor: 1,
            backend: [None, Some(MachineKind::Systolic), Some(MachineKind::Reram)]
                [g.usize(0, 2)],
        };
        let cfg = ServerConfig {
            fleet: Some(parse_fleet("systolic@45:1,reram@45:1").unwrap()),
            warm_start: false,
            max_pending,
            ingress_shards: 4,
            energy: false,
            max_retries: 0,
            ..Default::default()
        };
        let specs = cfg.fleet_workers().unwrap();
        let server = Server::start_with(cfg, move |w| {
            Ok(SimExecutor::new(Duration::from_millis(200), Duration::ZERO)
                .with_plan(plan.for_backend(specs[w].kind)))
        })
        .unwrap();
        let mut rng = Rng::new(700 + g.seed);
        let rxs: Vec<_> = (0..48)
            .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let m = server.shutdown();
        let (mut delivered, mut shed) = (0usize, 0usize);
        for rx in rxs {
            match rx.recv().expect("one response per request") {
                Ok(_) => delivered += 1,
                Err(e) if e.to_string().contains("overloaded") => shed += 1,
                Err(e) => {
                    if !e.to_string().contains("injected transient fault") {
                        return prop_assert(false, "unexpected error kind");
                    }
                    delivered += 1;
                }
            }
        }
        if delivered + shed != 48 {
            return prop_assert(false, "lost a response");
        }
        if delivered < 1 {
            return prop_assert(false, "nothing admitted");
        }
        prop_assert(
            delivered <= max_pending,
            "admitted more than max_pending across fleet lanes",
        )
    });
}

/// Batch-counting executor for the routing test: tallies served images
/// per worker and fails while its `degraded` flag is set.
struct CountingExec {
    images: std::sync::Arc<std::sync::atomic::AtomicUsize>,
    degraded: std::sync::Arc<std::sync::atomic::AtomicBool>,
}

impl aimc::coordinator::exec::Executor for CountingExec {
    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        use std::sync::atomic::Ordering;
        let batch = artifact
            .rsplit_once("_b")
            .and_then(|(_, n)| n.parse().ok())
            .unwrap_or(1);
        assert_eq!(inputs[0].len(), batch * IMAGE_ELEMS);
        self.images.fetch_add(batch, Ordering::SeqCst);
        if self.degraded.load(Ordering::SeqCst) {
            anyhow::bail!("injected transient fault (degraded backend)");
        }
        Ok(vec![0.0; batch * LOGITS])
    }
}

#[test]
fn routing_shifts_off_degraded_backend_and_returns_after_cooldown() {
    // Chaos on the quote-preferred backend must *move the load*: the
    // systolic lane is cheapest for SmallCNN, so it gets everything
    // until it starts failing; its breaker then opens and batches land
    // on the optical lane (counted as reroutes). After the fault clears
    // and the cooldown expires, routing returns to the systolic lane.
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;

    use aimc::coordinator::server::parse_fleet;

    let systolic_images = Arc::new(AtomicUsize::new(0));
    let optical_images = Arc::new(AtomicUsize::new(0));
    let systolic_down = Arc::new(AtomicBool::new(true));
    let healthy = Arc::new(AtomicBool::new(false));

    let cooldown = Duration::from_millis(300);
    let cfg = ServerConfig {
        fleet: Some(parse_fleet("systolic@45:1,optical4f@45:1").unwrap()),
        warm_start: false,
        max_pending: 4096,
        energy: false,
        max_retries: 0,
        breaker_threshold: 1,
        breaker_cooldown: cooldown,
        ..Default::default()
    };
    let specs = cfg.fleet_workers().unwrap();
    assert_eq!(specs[0].label(), "systolic@45", "lane order follows the spec");
    let (sys_n, opt_n) = (systolic_images.clone(), optical_images.clone());
    let (sys_down, ok_flag) = (systolic_down.clone(), healthy.clone());
    let server = Server::start_with(cfg, move |w| {
        Ok(if w == 0 {
            CountingExec {
                images: sys_n.clone(),
                degraded: sys_down.clone(),
            }
        } else {
            CountingExec {
                images: opt_n.clone(),
                degraded: ok_flag.clone(),
            }
        })
    })
    .unwrap();

    let mut rng = Rng::new(61);
    // Phase A: the preferred (systolic) lane is degraded. The first
    // request deterministically routes there (cheapest, breaker closed),
    // fails with retries off, and trips the threshold-1 breaker.
    let first = server.infer_blocking(rng.normal_vec(IMAGE_ELEMS));
    assert!(first.is_err(), "degraded preferred lane must fail first");
    // Give the worker a beat to publish the open breaker.
    std::thread::sleep(Duration::from_millis(30));
    for _ in 0..6 {
        server
            .infer_blocking(rng.normal_vec(IMAGE_ELEMS))
            .expect("open breaker must detour to the healthy backend");
    }
    let optical_during_outage = optical_images.load(Ordering::SeqCst);
    assert!(
        optical_during_outage >= 6,
        "load must shift to the healthy backend, got {optical_during_outage}"
    );

    // Phase B: fault clears, cooldown expires — routing must return.
    systolic_down.store(false, Ordering::SeqCst);
    std::thread::sleep(cooldown + Duration::from_millis(100));
    let systolic_before_recovery = systolic_images.load(Ordering::SeqCst);
    for _ in 0..4 {
        server
            .infer_blocking(rng.normal_vec(IMAGE_ELEMS))
            .expect("recovered backend must serve");
    }
    let systolic_after = systolic_images.load(Ordering::SeqCst);
    assert!(
        systolic_after >= systolic_before_recovery + 4,
        "routing must return to the cheapest backend after cooldown \
         ({systolic_before_recovery} -> {systolic_after})"
    );
    assert_eq!(
        optical_images.load(Ordering::SeqCst),
        optical_during_outage,
        "recovered fleet must stop paying the expensive backend"
    );

    let m = server.shutdown();
    assert!(m.breaker_trips() >= 1, "{}", m.summary());
    assert!(m.rerouted() >= 6, "{}", m.summary());
    assert!(
        m.backends()["systolic@45"].images() > 0
            && m.backends()["optical4f@45"].images() > 0,
        "both backends must appear in the shards:\n{}",
        m.backend_table().unwrap()
    );
}

#[test]
fn sim_results_deterministic_across_servers() {
    let mut rng = Rng::new(20);
    let img = rng.normal_vec(IMAGE_ELEMS);
    let a = {
        let s = sim_start(2, SimExecutor::instant());
        s.infer_blocking(img.clone()).unwrap()
    };
    let b = {
        let s = sim_start(4, SimExecutor::instant());
        s.infer_blocking(img.clone()).unwrap()
    };
    assert_eq!(a, b, "same image must map to the same logits everywhere");
}

// ---------------------------------------------------------------------------
// PJRT engine: skips when the build environment has no artifacts.
// ---------------------------------------------------------------------------

/// Start a server, or None when the PJRT feature / artifacts are
/// unavailable in this build environment (the tests then skip).
fn start(path: ConvPath, workers: usize) -> Option<Server> {
    match Server::start(ServerConfig {
        path,
        workers,
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        },
        warm_start: false, // lazy compile: these tests don't time serving
        ..Default::default()
    }) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: {e:#}");
            None
        }
    }
}

#[test]
fn serves_concurrent_load_exact() {
    let Some(server) = start(ConvPath::Exact, 2) else {
        return;
    };
    server.infer_blocking(vec![0.0; IMAGE_ELEMS]).unwrap(); // warm-up
    let mut rng = Rng::new(11);
    let n = 40;
    let rxs: Vec<_> = (0..n)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), LOGITS);
        assert!(out.iter().all(|v| v.is_finite()));
    }
    let m = server.shutdown();
    assert_eq!(m.count(), n + 1);
    assert!(m.throughput() > 0.0);
}

#[test]
fn systolic_path_serves_and_batches() {
    let Some(server) = start(ConvPath::Systolic, 1) else {
        return;
    };
    server.infer_blocking(vec![0.1; IMAGE_ELEMS]).unwrap();
    let mut rng = Rng::new(12);
    let rxs: Vec<_> = (0..8)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let m = server.shutdown();
    // At least one multi-request batch should have formed.
    assert!(m.mean_batch() > 1.0, "{}", m.summary());
}

#[test]
fn fft_path_serves_batch1_only() {
    let Some(server) = start(ConvPath::Fft, 1) else {
        return;
    };
    let out = server.infer_blocking(vec![0.2; IMAGE_ELEMS]).unwrap();
    assert_eq!(out.len(), LOGITS);
    let m = server.shutdown();
    // FFT has no batched artifacts: every batch is size 1.
    assert!((m.mean_batch() - 1.0).abs() < 1e-9);
}

#[test]
fn bad_requests_rejected_good_ones_still_served() {
    let Some(server) = start(ConvPath::Exact, 1) else {
        return;
    };
    assert!(server.infer_blocking(vec![0.0; 3]).is_err());
    assert!(server.infer_blocking(vec![]).is_err());
    let ok = server.infer_blocking(vec![0.0; IMAGE_ELEMS]);
    assert!(ok.is_ok(), "server must survive bad requests");
    server.shutdown();
}

#[test]
fn shutdown_drains_in_flight_work() {
    let Some(server) = start(ConvPath::Exact, 2) else {
        return;
    };
    server.infer_blocking(vec![0.0; IMAGE_ELEMS]).unwrap();
    let mut rng = Rng::new(14);
    let rxs: Vec<_> = (0..16)
        .map(|_| server.infer(rng.normal_vec(IMAGE_ELEMS)))
        .collect();
    // Shut down immediately — all 16 must still complete.
    let _ = server.shutdown();
    let mut done = 0;
    for rx in rxs {
        if let Ok(Ok(out)) = rx.recv() {
            assert_eq!(out.len(), LOGITS);
            done += 1;
        }
    }
    assert_eq!(done, 16, "shutdown dropped in-flight requests");
}

#[test]
fn deterministic_results_across_paths_and_servers() {
    let mut rng = Rng::new(15);
    let img = rng.normal_vec(IMAGE_ELEMS);
    let mut per_path = Vec::new();
    for path in [ConvPath::Exact, ConvPath::Systolic] {
        let Some(server) = start(path, 1) else {
            return;
        };
        let a = server.infer_blocking(img.clone()).unwrap();
        let b = server.infer_blocking(img.clone()).unwrap();
        assert_eq!(a, b, "same server must be deterministic");
        per_path.push(a);
        server.shutdown();
    }
    // Exact vs systolic agree within quantization error.
    let scale = per_path[0].iter().fold(1e-9f32, |m, v| m.max(v.abs()));
    for (a, b) in per_path[0].iter().zip(&per_path[1]) {
        assert!((a - b).abs() / scale < 0.15, "{a} vs {b}");
    }
}
