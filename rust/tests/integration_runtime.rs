//! Integration: the AOT bridge end to end — every artifact compiles on
//! PJRT, replays its golden, and the three SmallCNN datapaths agree on
//! fresh random inputs (python never ran on any of these numbers).

use aimc::runtime::{artifact::max_rel_err, Engine};
use aimc::util::rng::Rng;

/// Discover the engine, or None when the PJRT feature / artifacts are
/// unavailable in this build environment (the tests then skip — the
/// same convention the server integration tests use).
fn engine() -> Option<Engine> {
    match Engine::discover() {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping: {e:#}");
            None
        }
    }
}

#[test]
fn all_artifacts_replay_their_goldens() {
    let Some(e) = engine() else { return };
    for name in e.artifact_names() {
        let rtol = e.manifest().get(&name).unwrap().rtol;
        let err = e
            .verify_golden(&name)
            .unwrap_or_else(|x| panic!("{name}: {x:#}"));
        assert!(err <= rtol, "{name}: max rel err {err} > rtol {rtol}");
    }
}

#[test]
fn conv_artifacts_sys_and_fft_agree_on_fresh_input() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(99);
    let x = rng.normal_vec(8 * 64 * 64);
    let w = rng.normal_vec(16 * 8 * 3 * 3);
    let sys = e
        .execute("conv_sys_n64_ci8_co16_k3", &[x.clone(), w.clone()])
        .unwrap();
    let fft = e
        .execute("conv_fft_n64_ci8_co16_k3", &[x, w])
        .unwrap();
    assert_eq!(sys.len(), 16 * 62 * 62);
    // Two *different machines* computing the same convolution at 8-bit
    // precision: they agree within combined quantization error.
    let err = max_rel_err(&sys, &fft);
    assert!(err < 0.1, "machine datapaths disagree: {err}");
}

#[test]
fn smallcnn_three_paths_agree_on_fresh_images() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(7);
    for _ in 0..4 {
        let img = rng.normal_vec(3 * 64 * 64);
        let exact = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
        let sys = e.execute("smallcnn_systolic", &[img.clone()]).unwrap();
        let fft = e.execute("smallcnn_fft", &[img]).unwrap();
        assert!(max_rel_err(&sys, &exact) < 0.15, "systolic vs exact");
        assert!(max_rel_err(&fft, &exact) < 0.15, "fft vs exact");
    }
}

#[test]
fn batched_artifacts_match_singles() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(13);
    let imgs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(3 * 64 * 64)).collect();
    let packed: Vec<f32> = imgs.iter().flatten().copied().collect();
    let batched = e.execute("smallcnn_exact_b4", &[packed]).unwrap();
    assert_eq!(batched.len(), 4 * 10);
    for (i, img) in imgs.iter().enumerate() {
        let single = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
        let b = &batched[i * 10..(i + 1) * 10];
        assert!(
            max_rel_err(b, &single) < 1e-4,
            "batch element {i} diverges from single execution"
        );
    }
}

#[test]
fn qgemm_linear_in_scale() {
    // The quantized GEMM datapath rescales with its inputs (per-tensor
    // scales): doubling x doubles the output within quantization error.
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let x = rng.normal_vec(256 * 128);
    let w = rng.normal_vec(128 * 256);
    let y1 = e.execute("qgemm_256x128x256", &[x.clone(), w.clone()]).unwrap();
    let x2: Vec<f32> = x.iter().map(|v| v * 2.0).collect();
    let y2 = e.execute("qgemm_256x128x256", &[x2, w]).unwrap();
    let halved: Vec<f32> = y2.iter().map(|v| v / 2.0).collect();
    assert!(max_rel_err(&halved, &y1) < 0.02);
}
