//! Property-based tests (in-tree `util::prop` runner) over the
//! coordinator and simulator invariants DESIGN.md §5 calls out:
//! batching conservation, tiling/energy invariants, C′ packing bounds,
//! analytic-model limits and monotonicities.

use aimc::analytic::Workload;
use aimc::coordinator::batcher::plan_batches;
use aimc::energy::EnergyParams;
use aimc::networks::stats::optical4f_dims;
use aimc::networks::ConvLayer;
use aimc::simulator::{optical4f, systolic, Component, OperatingPoint};
use aimc::util::prop::{check, prop_assert, prop_close};

fn random_layer(g: &mut aimc::util::prop::Gen) -> ConvLayer {
    let k = *g.choose(&[1usize, 3, 5, 7]);
    let n = g.usize(k.max(4), 300);
    ConvLayer::square(
        n,
        g.usize(1, 512),
        g.usize(1, 512),
        k,
        *g.choose(&[1usize, 1, 1, 2]),
    )
}

#[test]
fn prop_batch_plans_conserve_requests() {
    check(500, |g| {
        let pending = g.usize(0, 200);
        let plan = plan_batches(pending, &[8, 4, 1]);
        let total: usize = plan.iter().sum();
        prop_assert(total == pending, "requests lost or duplicated")?;
        prop_assert(
            plan.iter().all(|b| [8, 4, 1].contains(b)),
            "plan uses uncompiled batch size",
        )
    });
}

#[test]
fn prop_systolic_macs_equal_gemm_size() {
    // The simulator must do exactly L'·N'·M' MACs for any layer and any
    // array size — tiling must never add or drop work.
    check(120, |g| {
        let layer = random_layer(g);
        let dim = *g.choose(&[32usize, 64, 256, 300]);
        let cfg = systolic::SystolicConfig {
            dim,
            banks: dim,
            ..Default::default()
        };
        let r = systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0));
        let (l, n, m) = layer.matmul_dims();
        prop_close(r.macs, l * n * m, 1e-9, "MAC conservation")
    });
}

#[test]
fn prop_systolic_sram_traffic_lower_bound() {
    // SRAM traffic ≥ one read of the Toeplitz + one write of the output,
    // for any tiling.
    check(120, |g| {
        let layer = random_layer(g);
        let cfg = systolic::SystolicConfig::default();
        let r = systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0));
        let (l, n, m) = layer.matmul_dims();
        let e_b = aimc::energy::sram::energy_per_byte_45nm(cfg.bank_bytes());
        let floor = (l * n + l * m) * e_b;
        prop_assert(
            r.ledger.get(Component::Sram) >= floor * (1.0 - 1e-9),
            "SRAM below physical floor",
        )
    });
}

#[test]
fn prop_optical_c_prime_packing() {
    // eq. (22): C′ channels of s² pixels never exceed the SLM (unless
    // clamped to 1 for spatial tiling); C′ never exceeds Cᵢ.
    check(300, |g| {
        let layer = random_layer(g);
        let cfg = optical4f::Optical4FConfig::default();
        let s = layer.n + layer.kh.max(layer.kw) - 1;
        let c = cfg.channels_at_once(s, layer.c_in);
        prop_assert(c >= 1 && c <= layer.c_in.max(1), "C' out of range")?;
        if c > 1 {
            prop_assert(c * s * s <= cfg.slm_pixels, "C' overpacks the SLM")
        } else {
            Ok(())
        }
    });
}

#[test]
fn prop_optical_execution_count() {
    // executions = patches · ⌈Cᵢ/C′⌉ · (1 + Cᵢ₊₁) exactly.
    check(120, |g| {
        let layer = random_layer(g);
        let cfg = optical4f::Optical4FConfig::default();
        let r = optical4f::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0));
        let k = layer.kh.max(layer.kw);
        let patches = cfg.spatial_patches(layer.n, k);
        let s2 = if patches == 1 {
            (layer.n + k - 1) * (layer.n + k - 1)
        } else {
            cfg.slm_pixels
        };
        let cp = cfg.channels_at_once((s2 as f64).sqrt() as usize, layer.c_in);
        let groups = layer.c_in.div_ceil(cp);
        let want = (patches * groups * (1 + layer.c_out)) as f64;
        prop_close(r.time_units, want, 1e-12, "execution count")
    });
}

#[test]
fn prop_ledger_total_is_sum_of_components() {
    check(100, |g| {
        let layer = random_layer(g);
        let r = optical4f::simulate_layer(
            &optical4f::Optical4FConfig::default(),
            &layer,
            &OperatingPoint::node(45.0),
        );
        let sum: f64 = Component::ALL.iter().map(|&c| r.ledger.get(c)).sum();
        prop_close(r.ledger.total(), sum, 1e-12, "ledger additivity")
    });
}

#[test]
fn prop_efficiency_monotone_in_intensity() {
    // eq. (5): more arithmetic intensity never hurts.
    check(200, |g| {
        let cfg = aimc::analytic::in_memory::Config::tpu_like();
        let mut w1 = Workload::reference();
        let mut w2 = Workload::reference();
        let a1 = g.f64(1.0, 5000.0);
        let a2 = g.f64(1.0, 5000.0);
        w1.a_matmul = a1.min(a2);
        w2.a_matmul = a1.max(a2);
        let e1 = cfg.efficiency(&w1, 45.0).tops_per_watt();
        let e2 = cfg.efficiency(&w2, 45.0).tops_per_watt();
        prop_assert(e2 >= e1 - 1e-12, "η must be monotone in a")
    });
}

#[test]
fn prop_energy_monotone_in_bits() {
    check(100, |g| {
        let b = g.u32(2, 14);
        let lo = EnergyParams { bits: b, ..Default::default() }.at_node(45.0);
        let hi = EnergyParams { bits: b + 1, ..Default::default() }.at_node(45.0);
        prop_assert(hi.e_adc > lo.e_adc, "ADC monotone")?;
        prop_assert(hi.e_mac > lo.e_mac, "MAC monotone")?;
        prop_assert(hi.e_opt > lo.e_opt, "laser monotone")
    });
}

#[test]
fn prop_node_scaling_monotone_and_bounded() {
    check(200, |g| {
        let a = g.f64(7.0, 180.0);
        let b = g.f64(7.0, 180.0);
        let (lo, hi) = (a.min(b), a.max(b));
        let s_lo = aimc::technode::scale_from_45nm(lo);
        let s_hi = aimc::technode::scale_from_45nm(hi);
        prop_assert(s_lo <= s_hi + 1e-12, "scale monotone in feature size")?;
        prop_assert(s_lo > 0.0, "scale positive")
    });
}

#[test]
fn prop_simulator_energy_scales_with_node_but_not_below_wire_floor() {
    // Total energy at a smaller node is smaller, but bounded below by the
    // node-independent wire/laser terms.
    check(60, |g| {
        let layer = random_layer(g);
        let cfg = systolic::SystolicConfig::default();
        let e45 = systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0));
        let e7 = systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(7.0));
        prop_assert(
            e7.ledger.total() < e45.ledger.total(),
            "smaller node must be cheaper",
        )?;
        let wire = e45.ledger.get(Component::Load);
        prop_close(
            e7.ledger.get(Component::Load),
            wire,
            1e-12,
            "wire term node-independent",
        )?;
        prop_assert(
            e7.ledger.total() >= wire * (1.0 - 1e-12),
            "total bounded by wire floor",
        )
    });
}

#[test]
fn prop_lower_precision_never_costs_more() {
    // Quantizing to fewer bits shrinks every datapath event but changes
    // no schedule: same MACs, same execution count, lower energy.
    check(60, |g| {
        let layer = random_layer(g);
        let cfg = systolic::SystolicConfig::default();
        let full = systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0));
        let quant =
            systolic::simulate_layer(&cfg, &layer, &OperatingPoint::node(45.0).bits(4, 4));
        prop_assert(
            quant.ledger.total() < full.ledger.total(),
            "4x4 must price below 8x8",
        )?;
        prop_close(quant.macs, full.macs, 1e-12, "same MAC count")?;
        prop_close(quant.time_units, full.time_units, 1e-12, "same schedule")
    });
}

#[test]
fn prop_table3_n_equals_2m_in_infinite_slm_limit() {
    check(200, |g| {
        let layer = random_layer(g);
        let (_, n, m) = optical4f_dims(&layer, None);
        prop_close(n, 2.0 * m, 1e-12, "N = 2M at C'→∞")
    });
}

#[test]
fn prop_finite_slm_never_beats_infinite() {
    check(200, |g| {
        let layer = random_layer(g);
        let px = g.usize(1 << 16, 1 << 26);
        let (_, n_fin, _) = optical4f_dims(&layer, Some(px));
        let (_, n_inf, _) = optical4f_dims(&layer, None);
        prop_assert(n_fin <= n_inf + 1e-9, "finite SLM can't amortize more")
    });
}
