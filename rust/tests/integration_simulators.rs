//! Integration: cycle-accurate simulators vs analytic models across the
//! whole zoo — the Fig. 8/9 agreement claim, asserted for every network,
//! plus the paper's §VIII headline orderings.

use aimc::analytic::{Processor, Workload};
use aimc::networks::zoo;
use aimc::report::figures::median_layer;
use aimc::simulator::{optical4f, systolic, OperatingPoint};

#[test]
fn systolic_sim_tracks_analytic_for_every_network() {
    let cfg = systolic::SystolicConfig::default();
    let ana = aimc::analytic::in_memory::Config::tpu_like();
    for net in zoo(1000) {
        let w = Workload::from_layer(median_layer(&net));
        for node in [45.0, 7.0] {
            let sim = systolic::simulate_network(&cfg, &net, &OperatingPoint::node(node))
                .tops_per_watt();
            let a = ana.efficiency(&w, node).tops_per_watt();
            let ratio = sim / a;
            assert!(
                (0.25..4.0).contains(&ratio),
                "{} @ {node}nm: sim {sim:.2} vs analytic {a:.2}",
                net.name
            );
        }
    }
}

#[test]
fn optical_sim_tracks_analytic_for_every_network() {
    let cfg = optical4f::Optical4FConfig::default();
    let ana = aimc::analytic::optical4f::Config::default_4mpx();
    for net in zoo(1000) {
        let w = Workload::from_layer(median_layer(&net));
        for node in [45.0, 7.0] {
            let sim = optical4f::simulate_network(&cfg, &net, &OperatingPoint::node(node))
                .tops_per_watt();
            let a = ana.efficiency(&w, node).tops_per_watt();
            let ratio = sim / a;
            // The cycle model charges real execution counts + full-
            // aperture laser; the analytic model is the optimistic bound
            // evaluated on one representative (median-intensity) layer.
            // For heterogeneous nets whose median layer is a 1×1 conv
            // (InceptionV3) the whole-network result sits far below that
            // single-layer bound at small nodes — the honest envelope is
            // wide, but the sim must never *beat* the bound by much.
            assert!(
                (0.01..4.0).contains(&ratio),
                "{} @ {node}nm: sim {sim:.2} vs analytic {a:.2}",
                net.name
            );
        }
    }
}

#[test]
fn optical_beats_systolic_on_every_paper_network() {
    // §VIII: analog in-memory at 4F scale wins on all eight CNNs.
    let s_cfg = systolic::SystolicConfig::default();
    let o_cfg = optical4f::Optical4FConfig::default();
    for net in zoo(1000) {
        let op = OperatingPoint::node(28.0);
        let s = systolic::simulate_network(&s_cfg, &net, &op).tops_per_watt();
        let o = optical4f::simulate_network(&o_cfg, &net, &op).tops_per_watt();
        assert!(
            o > 2.0 * s,
            "{}: optical {o:.2} should beat systolic {s:.2}",
            net.name
        );
    }
}

#[test]
fn processor_ordering_on_every_network_median_layer() {
    // Fig. 6's ordering holds per network, not just on Table V's layer.
    for net in zoo(1000) {
        let w = Workload::from_layer(median_layer(&net));
        let eta: Vec<f64> = Processor::ALL
            .iter()
            .map(|p| p.efficiency(&w, 32.0).tops_per_watt())
            .collect();
        assert!(
            eta[0] < eta[1] && eta[1] < eta[3],
            "{}: {eta:?}",
            net.name
        );
    }
}

#[test]
fn high_intensity_advantage_analytic_vs_cycle_model() {
    // eq. (5): the SRAM term shrinks with a, so *analytically* VGG16
    // (a≈2262) beats GoogLeNet (a≈200) on the in-memory machine.
    let ana = aimc::analytic::in_memory::Config::tpu_like();
    let w_vgg = Workload::from_layer(median_layer(&aimc::networks::vgg::vgg16(1000)));
    let w_goog =
        Workload::from_layer(median_layer(&aimc::networks::googlenet::googlenet(1000)));
    assert!(
        ana.efficiency(&w_vgg, 45.0).tops_per_watt()
            > ana.efficiency(&w_goog, 45.0).tops_per_watt()
    );
    // The cycle-accurate machine narrows that gap to ~nothing: VGG16's
    // N′ = 2304 » 256 forces 9 contraction passes with 32-bit partial-sum
    // spill, eating exactly the SRAM savings its intensity bought. The
    // two land within 5% of each other — an effect only the cycle model
    // can see (and a good reason the paper built one).
    let cfg = systolic::SystolicConfig::default();
    let op = OperatingPoint::node(45.0);
    let vgg = systolic::simulate_network(&cfg, &aimc::networks::vgg::vgg16(1000), &op);
    let goog =
        systolic::simulate_network(&cfg, &aimc::networks::googlenet::googlenet(1000), &op);
    let ratio = vgg.tops_per_watt() / goog.tops_per_watt();
    assert!(
        (0.9..1.15).contains(&ratio),
        "VGG16 {:.3} vs GoogLeNet {:.3}",
        vgg.tops_per_watt(),
        goog.tops_per_watt()
    );
}

#[test]
fn energy_additivity_network_equals_sum_of_layers() {
    let cfg = systolic::SystolicConfig::default();
    let ocfg = optical4f::Optical4FConfig::default();
    let op = OperatingPoint::node(45.0);
    for net in zoo(1000).into_iter().take(3) {
        let whole_s = systolic::simulate_network(&cfg, &net, &op);
        let whole_o = optical4f::simulate_network(&ocfg, &net, &op);
        let mut sum_s = 0.0;
        let mut sum_o = 0.0;
        for l in &net.layers {
            sum_s += systolic::simulate_layer(&cfg, l, &op).ledger.total();
            sum_o += optical4f::simulate_layer(&ocfg, l, &op).ledger.total();
        }
        assert!((whole_s.ledger.total() - sum_s).abs() / sum_s < 1e-9);
        assert!((whole_o.ledger.total() - sum_o).abs() / sum_o < 1e-9);
    }
}

#[test]
fn reram_ceiling_between_dim_and_optical() {
    // §A2: memristive analog tops out ≈20 TOPS/W — above the digital
    // systolic result but below what the 4F machine reaches at scale.
    let ceiling =
        aimc::energy::reram::ReramArray::default().efficiency_ceiling() / 1e12 / 2.0;
    let net = aimc::networks::yolov3::yolov3(1000);
    let op = OperatingPoint::node(28.0);
    let s = systolic::simulate_network(&systolic::SystolicConfig::default(), &net, &op)
        .tops_per_watt();
    let o = optical4f::simulate_network(&optical4f::Optical4FConfig::default(), &net, &op)
        .tops_per_watt();
    assert!(s < ceiling, "systolic {s} below ReRAM ceiling {ceiling}");
    assert!(o > ceiling, "optical {o} above ReRAM ceiling {ceiling}");
}
