//! Golden pins for the Scenario API redesign: the rendered text and CSV
//! of every report subcommand must be **byte-identical** to the
//! pre-scenario CLI. Each `legacy_*` function below is a faithful
//! mirror of the hand-rolled driver the scenario replaced (same call
//! sequence, same `format!` strings, same row order); the tests assert
//! the new `Scenario → Dataset → sink` route reproduces it exactly.
//!
//! All tests share one process-wide [`SweepCache`]: legacy and scenario
//! sides replay identical [`SimResult`]s from it, and repeated layer
//! shapes across tests simulate once — the same dedup contract the CLI
//! relies on.

use std::sync::OnceLock;

use aimc::analytic::{Processor, Workload};
use aimc::networks::{by_name, zoo, Network};
use aimc::report::figures::median_layer;
use aimc::report::{self, EvalCtx};
use aimc::simulator::machine::all_machines;
use aimc::simulator::{
    optical4f, sweep, systolic, Component, Machine, OperatingPoint, SimResult, SweepCache,
};
use aimc::technode::NODES;
use aimc::util::json::Json;
use aimc::util::pool::Pool;
use aimc::util::table::{sci, Table};

fn shared_cache() -> &'static SweepCache {
    static CACHE: OnceLock<SweepCache> = OnceLock::new();
    CACHE.get_or_init(SweepCache::new)
}

fn ctx() -> EvalCtx<'static> {
    static POOL: OnceLock<Pool> = OnceLock::new();
    EvalCtx {
        pool: POOL.get_or_init(Pool::auto),
        cache: shared_cache(),
    }
}

/// Assert the scenario's text and CSV renderings both match the legacy
/// table byte for byte.
fn assert_golden(legacy: &Table, scenario: &report::Scenario) {
    let ds = scenario.eval(&ctx());
    assert_eq!(
        legacy.render(),
        ds.render(),
        "text rendering drifted: {}",
        legacy.title
    );
    assert_eq!(
        legacy.to_csv(),
        ds.to_csv(),
        "CSV rendering drifted: {}",
        legacy.title
    );
}

fn net_or_yolo(name: Option<&str>, input: usize) -> Network {
    name.and_then(|n| by_name(n, input))
        .unwrap_or_else(|| aimc::networks::yolov3::yolov3(input))
}

// ---- legacy mirrors (verbatim ports of the pre-scenario drivers) -------

fn legacy_fig6() -> Table {
    let w = Workload::reference();
    let mut t = Table::new(
        "Fig. 6 — analytic efficiency vs technology node (TOPS/W, Table V layer)",
        &["node (nm)", "CPU", "DIM", "SP", "O4F"],
    );
    for n in NODES {
        let mut cells = vec![format!("{:.0}", n.nm)];
        for p in Processor::ALL {
            cells.push(format!("{:.3}", p.efficiency(&w, n.nm).tops_per_watt()));
        }
        t.row(cells);
    }
    t
}

fn legacy_fig7() -> Table {
    let w = Workload::reference();
    let mut t = Table::new(
        "Fig. 7 — energy per operation breakdown at 32 nm (pJ/op, Table V layer)",
        &["processor", "memory", "compute", "total", "eta (TOPS/W)"],
    );
    for p in Processor::ALL {
        let e = p.efficiency(&w, 32.0);
        t.row(vec![
            p.short().to_string(),
            format!("{:.4}", e.e_mem * 1e12),
            format!("{:.4}", e.e_comp * 1e12),
            format!("{:.4}", e.per_op() * 1e12),
            format!("{:.3}", e.tops_per_watt()),
        ]);
    }
    t
}

fn legacy_fig8(net: Option<&str>, input: usize, cache: &SweepCache) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = systolic::SystolicConfig::default();
    let med_layer = median_layer(&net);
    let w = Workload::from_layer(med_layer);
    let mut t = Table::new(
        &format!(
            "Fig. 8 — systolic array, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
            net.name, input
        ),
        &["node (nm)", "cycle-accurate", "analytic eq.(5)", "ratio"],
    );
    for n in NODES {
        let sim = cache
            .simulate_network(&cfg, &net, &OperatingPoint::node(n.nm))
            .tops_per_watt();
        let ana = aimc::analytic::in_memory::Config::tpu_like()
            .efficiency(&w, n.nm)
            .tops_per_watt();
        t.row(vec![
            format!("{:.0}", n.nm),
            format!("{sim:.3}"),
            format!("{ana:.3}"),
            format!("{:.2}", sim / ana),
        ]);
    }
    t
}

fn legacy_fig9(net: Option<&str>, input: usize, cache: &SweepCache) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = optical4f::Optical4FConfig::default();
    let w = Workload::from_layer(median_layer(&net));
    let mut t = Table::new(
        &format!(
            "Fig. 9 — optical 4F, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
            net.name, input
        ),
        &["node (nm)", "cycle-accurate", "analytic eq.(24)", "ratio"],
    );
    for n in NODES {
        let sim = cache
            .simulate_network(&cfg, &net, &OperatingPoint::node(n.nm))
            .tops_per_watt();
        let ana = aimc::analytic::optical4f::Config::default_4mpx()
            .efficiency(&w, n.nm)
            .tops_per_watt();
        t.row(vec![
            format!("{:.0}", n.nm),
            format!("{sim:.3}"),
            format!("{ana:.3}"),
            format!("{:.2}", sim / ana),
        ]);
    }
    t
}

fn legacy_fig10(net: Option<&str>, input: usize, cache: &SweepCache) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = optical4f::Optical4FConfig::default();
    let mut t = Table::new(
        &format!(
            "Fig. 10 — optical 4F energy distribution, {} @ {} px (pJ/MAC)",
            net.name, input
        ),
        &["node (nm)", "DAC", "ADC", "SRAM", "laser", "total"],
    );
    for n in NODES {
        let r = cache.simulate_network(&cfg, &net, &OperatingPoint::node(n.nm));
        let per = |c: Component| r.ledger.get(c) / r.macs * 1e12;
        t.row(vec![
            format!("{:.0}", n.nm),
            format!("{:.4}", per(Component::Dac)),
            format!("{:.4}", per(Component::Adc)),
            format!("{:.4}", per(Component::Sram)),
            format!("{:.4}", per(Component::Laser)),
            format!("{:.4}", r.energy_per_mac() * 1e12),
        ]);
    }
    t
}

fn legacy_crossval(net: Option<&str>, input: usize, cache: &SweepCache) -> Table {
    let net = net_or_yolo(net, input);
    let machines = all_machines();
    let mut t = Table::new(
        &format!(
            "Cross-validation (extension) — cycle-accurate TOPS/W, {} @ {} px",
            net.name, input
        ),
        &["node (nm)", "systolic", "ReRAM", "photonic", "optical 4F"],
    );
    for n in NODES {
        let mut cells = vec![format!("{:.0}", n.nm)];
        for m in &machines {
            cells.push(format!(
                "{:.3}",
                cache
                    .simulate_network(m.as_ref(), &net, &OperatingPoint::node(n.nm))
                    .tops_per_watt()
            ));
        }
        t.row(cells);
    }
    t
}

fn legacy_table1(input: usize) -> Table {
    let mut t = Table::new(
        "Table I — conv-layer statistics (1 Mpx input; ours / paper)",
        &[
            "network", "layers", "med n", "med Ci", "max N", "avg k", "total K",
            "med Ci+1", "med a", "paper a",
        ],
    );
    for net in zoo(input) {
        let r = aimc::networks::stats::table1_row(&net);
        let pa = report::PAPER_TABLE1
            .iter()
            .find(|p| p.0 == net.name)
            .map(|p| p.8)
            .unwrap_or(f64::NAN);
        t.row(vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_ci),
            sci(r.max_input),
            format!("{:.1}", r.avg_k),
            sci(r.total_weights),
            format!("{:.0}", r.median_co),
            format!("{:.0}", r.median_a),
            format!("{pa:.0}"),
        ]);
    }
    t
}

fn legacy_table2(input: usize) -> Table {
    let mut t = Table::new(
        "Table II — median matmul dims (eq. 16; ours / paper)",
        &["network", "layers", "L'", "N'", "M'", "paper L'", "paper N'", "paper M'"],
    );
    for net in zoo(input) {
        let r = aimc::networks::stats::table2_row(&net);
        let p = report::PAPER_TABLE2
            .iter()
            .find(|p| p.0 == net.name)
            .copied()
            .unwrap_or((net.name, f64::NAN, f64::NAN, f64::NAN));
        t.row(vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_l),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_m),
            format!("{:.0}", p.1),
            format!("{:.0}", p.2),
            format!("{:.0}", p.3),
        ]);
    }
    t
}

fn legacy_table3(input: usize) -> Table {
    let mut t = Table::new(
        "Table III — median optical-4F dims (eq. 23, C'→∞; ours / paper)",
        &["network", "layers", "L", "N", "M", "paper L", "paper N", "paper M"],
    );
    for net in zoo(input) {
        let r = aimc::networks::stats::table3_row(&net, None);
        let p = report::PAPER_TABLE3
            .iter()
            .find(|p| p.0 == net.name)
            .copied()
            .unwrap_or((net.name, f64::NAN, f64::NAN, f64::NAN));
        t.row(vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_l),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_m),
            format!("{:.0}", p.1),
            format!("{:.0}", p.2),
            format!("{:.0}", p.3),
        ]);
    }
    t
}

fn legacy_table4() -> Table {
    use aimc::energy::{
        constants,
        converter::{adc_energy, dac_energy},
        load::presets,
        logic::mac_energy,
        optical::{gamma_opt, optical_energy},
        reram::ReramArray,
        sram,
    };
    let mut t = Table::new(
        "Table IV — energy per operation (45 nm, 0.9 V, 8-bit)",
        &["quantity", "ours (pJ)", "paper (pJ)"],
    );
    let mut row = |name: &str, ours_j: f64, paper_pj: f64| {
        t.row(vec![
            name.to_string(),
            format!("{:.4}", ours_j * 1e12),
            format!("{paper_pj}"),
        ]);
    };
    row(
        "e_m (96kB SRAM, per byte)",
        sram::energy_per_byte_45nm(96 * 1024),
        4.3,
    );
    row("e_mac", mac_energy(constants::GAMMA_MAC_45NM, 8), 0.23);
    row("e_adc", adc_energy(constants::GAMMA_ADC_45NM, 8), 0.25);
    row("e_dac", dac_energy(constants::GAMMA_DAC, 8), 0.01);
    row("e_opt", optical_energy(constants::ETA_OPT, 8), 0.01);
    row("e_load 4um pitch N=256", presets::reram_256().energy(), 0.08);
    row("e_load 250um pitch N=40", presets::photonic_40().energy(), 0.8);
    row("e_load 2.5um pitch N=2048", presets::slm_2048().energy(), 0.04);
    let arr = ReramArray::default();
    row("e_ReRAM per MAC (A11, 70 mV)", arr.energy_per_mac(), 0.05);
    t.row(vec![
        "ReRAM ceiling (TOPS/W)".into(),
        format!("{:.1}", 1.0 / (arr.energy_per_mac() * 1e12)),
        "20".into(),
    ]);
    t.row(vec![
        "gamma_mac / adc / dac / opt".into(),
        format!(
            "{:.0} / {:.0} / {:.0} / {:.0}",
            constants::GAMMA_MAC_45NM,
            constants::GAMMA_ADC_45NM,
            constants::GAMMA_DAC,
            gamma_opt(0.5)
        ),
        "1.2e5 / 927* / 39 / 105".into(),
    ]);
    t
}

fn legacy_zoo(input: usize) -> Table {
    let mut t = Table::new(
        &format!("network zoo @ {input} px"),
        &["network", "conv layers", "GMACs", "weights (M)"],
    );
    for net in zoo(input) {
        t.row(vec![
            net.name.to_string(),
            net.num_layers().to_string(),
            format!("{:.1}", net.total_macs() / 1e9),
            format!("{:.1}", net.total_weights() / 1e6),
        ]);
    }
    t
}

fn legacy_sweep(input: usize, cache: &SweepCache) -> Table {
    let machines = all_machines();
    let nets = zoo(input);
    let nodes: Vec<f64> = NODES.iter().map(|n| n.nm).collect();
    let ops = sweep::ops_at_nodes(&nodes);
    let records = sweep::sweep_on(&Pool::auto(), &machines, &nets, &ops, cache);
    let mut t = Table::new(
        &format!(
            "sweep — cycle-accurate TOPS/W, {} machines × {} networks × {} nodes @ {input} px",
            machines.len(),
            nets.len(),
            nodes.len()
        ),
        &["network", "node (nm)", "systolic", "ReRAM", "photonic", "optical 4F"],
    );
    let stride = nets.len() * nodes.len();
    for ni in 0..nets.len() {
        for ki in 0..nodes.len() {
            let mut cells = vec![nets[ni].name.to_string(), format!("{:.0}", nodes[ki])];
            for mi in 0..machines.len() {
                let r = &records[mi * stride + ni * nodes.len() + ki];
                cells.push(format!("{:.3}", r.result.tops_per_watt()));
            }
            t.row(cells);
        }
    }
    t
}

// ---- the pins ----------------------------------------------------------

#[test]
fn golden_fig6() {
    assert_golden(&legacy_fig6(), &report::fig6());
}

#[test]
fn golden_fig7() {
    assert_golden(&legacy_fig7(), &report::fig7());
}

#[test]
fn golden_fig8() {
    assert_golden(&legacy_fig8(None, 1000, shared_cache()), &report::fig8(None, 1000));
}

#[test]
fn golden_fig9() {
    assert_golden(&legacy_fig9(None, 1000, shared_cache()), &report::fig9(None, 1000));
}

#[test]
fn golden_fig10_both_networks() {
    assert_golden(
        &legacy_fig10(Some("VGG19"), 1000, shared_cache()),
        &report::fig10(Some("VGG19"), 1000),
    );
    assert_golden(
        &legacy_fig10(Some("YOLOv3"), 1000, shared_cache()),
        &report::fig10(Some("YOLOv3"), 1000),
    );
}

#[test]
fn golden_crossval() {
    assert_golden(
        &legacy_crossval(None, 1000, shared_cache()),
        &report::crossval(None, 1000),
    );
}

#[test]
fn golden_table1() {
    assert_golden(&legacy_table1(1000), &report::table1(1000));
}

#[test]
fn golden_table2() {
    assert_golden(&legacy_table2(1000), &report::table2(1000));
}

#[test]
fn golden_table3() {
    assert_golden(&legacy_table3(1000), &report::table3(1000));
}

#[test]
fn golden_table4() {
    assert_golden(&legacy_table4(), &report::table4());
}

#[test]
fn golden_zoo() {
    assert_golden(&legacy_zoo(1000), &report::zoo_scenario(1000));
}

#[test]
fn golden_sweep_grid() {
    // Reduced input keeps the full 4×8×13 grid affordable in debug
    // builds; both sides run at the same resolution, so the pin is as
    // strict as at 1 Mpx.
    let input = 240;
    assert_golden(&legacy_sweep(input, shared_cache()), &report::sweep_scenario(input));
}

#[test]
fn golden_all_list_matches_legacy_emission_order() {
    let titles: Vec<String> = report::all_scenarios(None, 1000)
        .iter()
        .map(|s| s.title().to_string())
        .collect();
    assert_eq!(
        titles,
        vec![
            "Table I — conv-layer statistics (1 Mpx input; ours / paper)".to_string(),
            "Table II — median matmul dims (eq. 16; ours / paper)".into(),
            "Table III — median optical-4F dims (eq. 23, C'→∞; ours / paper)".into(),
            "Table IV — energy per operation (45 nm, 0.9 V, 8-bit)".into(),
            "Fig. 6 — analytic efficiency vs technology node (TOPS/W, Table V layer)".into(),
            "Fig. 7 — energy per operation breakdown at 32 nm (pJ/op, Table V layer)".into(),
            "Fig. 8 — systolic array, YOLOv3 @ 1000 px: cycle-accurate vs analytic (TOPS/W)".into(),
            "Fig. 9 — optical 4F, YOLOv3 @ 1000 px: cycle-accurate vs analytic (TOPS/W)".into(),
            "Fig. 10 — optical 4F energy distribution, VGG19 @ 1000 px (pJ/MAC)".into(),
            "Fig. 10 — optical 4F energy distribution, YOLOv3 @ 1000 px (pJ/MAC)".into(),
        ]
    );
}

#[test]
fn json_sink_emits_one_valid_document_for_all() {
    // Local twin of the CI smoke step: `aimc all --format json` buffers
    // every dataset and prints one top-level array — build the same
    // array here (small input) and require it to parse.
    let input = 120;
    let c = ctx();
    let docs: Vec<Json> = report::all_scenarios(None, input)
        .iter()
        .map(|s| s.eval(&c).to_json())
        .collect();
    let rendered = Json::Arr(docs).pretty();
    let parsed = Json::parse(&rendered).expect("aimc all --format json must be valid JSON");
    match parsed {
        Json::Arr(items) => {
            assert_eq!(items.len(), 10);
            // Every dataset object carries title/columns/rows with typed
            // cells (numbers stay numbers — the sweep columns must not be
            // strings).
            for item in &items {
                match item {
                    Json::Obj(fields) => {
                        assert_eq!(fields[0].0, "title");
                        assert_eq!(fields[1].0, "columns");
                        assert_eq!(fields[2].0, "rows");
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn persisted_cache_makes_second_sweep_pure_replay() {
    // The `aimc sweep --cache-dir` contract: run once, persist, run
    // again from the snapshot — the second run must be 100% cache reuse
    // (zero misses) and byte-identical output.
    let input = 160;
    let path = std::env::temp_dir().join(format!(
        "aimc-golden-sweepcache-{}.txt",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);

    let pool = Pool::auto();
    let first_cache = SweepCache::new();
    let first = report::sweep_scenario(input).eval(&EvalCtx {
        pool: &pool,
        cache: &first_cache,
    });
    first_cache.save(&path).expect("snapshot written");

    let second_cache = SweepCache::load(&path);
    assert_eq!(second_cache.len(), first_cache.len(), "full snapshot restored");
    let second = report::sweep_scenario(input).eval(&EvalCtx {
        pool: &pool,
        cache: &second_cache,
    });
    assert_eq!(second_cache.misses(), 0, "persisted run must not simulate");
    assert!(second_cache.hits() > 0);
    let reuse = second_cache.hits() as f64
        / (second_cache.hits() + second_cache.misses()) as f64;
    assert_eq!(reuse, 1.0, "reuse must be 100%: {}", second_cache.stats());
    assert_eq!(first.render(), second.render(), "replayed output drifted");
    let _ = std::fs::remove_file(&path);
}

/// The per-layer prefetch inside `Scenario::eval` (the unique
/// (machine, layer, node) warm-up pass that fans the grid out across
/// the pool) must not change a single bit of any dataset: a
/// single-thread evaluation on a cold cache and a many-thread one must
/// produce identical typed cells, not merely identical renderings.
#[test]
fn scenario_layer_prefetch_bit_identical_datasets() {
    let input = 160;
    let scenarios = [
        report::sweep_scenario(input),
        report::fig8(None, input),
        report::crossval(None, input),
    ];
    for s in &scenarios {
        let serial_cache = SweepCache::new();
        let serial = s.eval(&EvalCtx {
            pool: &Pool::new(1),
            cache: &serial_cache,
        });
        let par_cache = SweepCache::new();
        let par = s.eval(&EvalCtx {
            pool: &Pool::new(8),
            cache: &par_cache,
        });
        assert_eq!(serial.columns, par.columns, "{}", s.title());
        assert_eq!(serial.rows, par.rows, "{}: dataset drifted", s.title());
    }
}

/// `aimc pareto --format csv|json` sink parity: exact CSV header, one
/// line per (node × bits) grid point, and the JSON document must agree
/// cell-for-cell with the CSV under each column's declared number
/// format — numbers stay numbers in JSON, labels stay strings.
#[test]
fn golden_pareto_csv_json_sink_parity() {
    let ds = report::pareto_scenario(120).eval(&ctx());
    assert_eq!(
        ds.rows.len(),
        report::PARETO_NODES.len() * report::PARETO_DEFAULT_BITS.len()
    );
    let csv = ds.to_csv();
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "node (nm),bits,SNR (dB),eff. bits,accuracy,\
         systolic uJ/inf,systolic time,reram uJ/inf,reram time,\
         photonic uJ/inf,photonic time,optical4f uJ/inf,optical4f time"
    );
    let data: Vec<&str> = lines.collect();
    assert_eq!(data.len(), ds.rows.len());
    assert_csv_json_parity(&ds, &data);
}

/// `aimc intensity --format csv|json` sink parity on the tiny config:
/// the CI smoke validates the JSON artifact parses; this pins the
/// cell-level agreement between the two sinks.
#[test]
fn golden_intensity_csv_json_sink_parity() {
    use aimc::networks::transformer::TransformerConfig;
    let ds = report::intensity_scenario(
        &TransformerConfig::tiny(),
        None,
        &[45.0],
        &[],
        &[1],
        &[64],
    )
    .eval(&ctx());
    // Two phases × one batch × one seq × one node.
    assert_eq!(ds.rows.len(), 2);
    let csv = ds.to_csv();
    let mut lines = csv.lines();
    assert_eq!(
        lines.next().unwrap(),
        "phase,batch,seq,tokens/inf,FLOPs/byte,node (nm),\
         systolic uJ/inf,systolic uJ/tok,reram uJ/inf,reram uJ/tok,\
         photonic uJ/inf,photonic uJ/tok,optical4f uJ/inf,optical4f uJ/tok"
    );
    let data: Vec<&str> = lines.collect();
    assert_eq!(data.len(), ds.rows.len());
    assert_csv_json_parity(&ds, &data);
}

/// Shared half of the sink-parity pins: every CSV cell must equal the
/// JSON cell rendered under the column's [`report::NumFmt`]. None of
/// these datasets emit cells containing commas, so a plain split is the
/// exact inverse of the RFC-4180 writer here.
fn assert_csv_json_parity(ds: &report::Dataset, csv_data: &[&str]) {
    let parsed = Json::parse(&ds.to_json().pretty()).expect("JSON sink must parse");
    let Json::Obj(fields) = &parsed else {
        panic!("JSON sink must emit an object")
    };
    assert_eq!(fields[0].0, "title");
    assert_eq!(fields[1].0, "columns");
    assert_eq!(fields[2].0, "rows");
    let Json::Arr(jrows) = &fields[2].1 else {
        panic!("rows must be an array")
    };
    assert_eq!(jrows.len(), csv_data.len());
    for (ri, line) in csv_data.iter().enumerate() {
        let cells: Vec<&str> = line.split(',').collect();
        assert_eq!(cells.len(), ds.columns.len(), "row {ri} width");
        let Json::Arr(jrow) = &jrows[ri] else {
            panic!("row {ri} must be an array")
        };
        for (ci, jcell) in jrow.iter().enumerate() {
            let expect = match jcell {
                Json::Num(v) => report::Value::Num(*v).render(ds.fmts[ci]),
                Json::Str(s) => s.clone(),
                other => panic!("row {ri} col {ci}: {other:?}"),
            };
            assert_eq!(cells[ci], expect, "row {ri} col {ci} drifted between sinks");
        }
    }
}

/// The fan-out path behind `aimc simulate`: unique-layer `par_map`
/// pricing must merge bit-identically to the serial network walk, for
/// every machine.
#[test]
fn layer_fanout_merge_bit_identical() {
    let net = aimc::networks::yolov3::yolov3(300);
    for m in all_machines() {
        let op = OperatingPoint::node(28.0);
        let serial: SimResult = m.simulate_network(&net, &op);
        for threads in [1, 4] {
            let cache = SweepCache::new();
            let par = cache.simulate_network_par(&Pool::new(threads), m.as_ref(), &net, &op);
            assert_eq!(serial.macs, par.macs, "{}", m.name());
            assert_eq!(serial.ops, par.ops, "{}", m.name());
            assert_eq!(serial.time_units, par.time_units, "{}", m.name());
            for c in Component::ALL {
                assert_eq!(
                    serial.ledger.get(c),
                    par.ledger.get(c),
                    "{} {c:?}",
                    m.name()
                );
            }
        }
    }
}
