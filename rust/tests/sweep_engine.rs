//! The parallel sweep engine's correctness contract, property-tested:
//!
//! * memoized sweeps are **bit-identical** to direct `simulate_network`
//!   across random layer zoos, nodes and all four machines;
//! * `pool::par_map` output ordering matches the serial map at any
//!   thread count;
//! * the full grid runner produces the same records serially and in
//!   parallel, in the same order.

use aimc::networks::{ConvLayer, Network};
use aimc::simulator::machine::{all_machines, by_name};
use aimc::simulator::{Component, Machine, OperatingPoint, SweepCache};
use aimc::simulator::sweep::{ops_at_nodes, sweep_on, SweepRecord};
use aimc::util::pool::Pool;
use aimc::util::prop::{check, prop_assert, Gen};

/// A random operating point: node × a few precision pairs, so the memo
/// and snapshot layers are exercised across the full key.
fn random_op(g: &mut Gen) -> OperatingPoint {
    let node = *g.choose(&[45.0, 32.0, 28.0, 14.0, 7.0]);
    let (bx, bw) = *g.choose(&[(8u32, 8u32), (4, 4), (8, 4), (12, 12)]);
    OperatingPoint::node(node).bits(bx, bw)
}

/// A random — but modestly sized, these run hundreds of times — layer.
fn random_layer(g: &mut Gen) -> ConvLayer {
    let k = *g.choose(&[1usize, 3, 5]);
    ConvLayer::square(
        g.usize(k.max(4), 96),
        g.usize(1, 64),
        g.usize(1, 64),
        k,
        *g.choose(&[1usize, 1, 2]),
    )
}

/// A random layer zoo with deliberate duplicates, so the memo layer has
/// something to dedup (each drawn shape appears 1–3 times).
fn random_net(g: &mut Gen) -> Network {
    let distinct = g.usize(1, 6);
    let mut layers = Vec::new();
    for _ in 0..distinct {
        let l = random_layer(g);
        for _ in 0..g.usize(1, 3) {
            layers.push(l);
        }
    }
    Network {
        name: "prop-zoo",
        layers,
    }
}

fn assert_bit_identical(
    a: &aimc::simulator::SimResult,
    b: &aimc::simulator::SimResult,
    what: &str,
) -> Result<(), String> {
    prop_assert(a.macs == b.macs, &format!("{what}: macs"))?;
    prop_assert(a.ops == b.ops, &format!("{what}: ops"))?;
    prop_assert(a.time_units == b.time_units, &format!("{what}: time"))?;
    for c in Component::ALL {
        prop_assert(
            a.ledger.get(c) == b.ledger.get(c),
            &format!("{what}: ledger {c:?}"),
        )?;
    }
    Ok(())
}

#[test]
fn prop_cached_sweep_bit_identical_across_all_machines() {
    let machines = all_machines();
    check(30, |g| {
        let net = random_net(g);
        let op = random_op(g);
        for m in &machines {
            let direct = m.simulate_network(&net, &op);
            let cache = SweepCache::new();
            let cold = cache.simulate_network(m.as_ref(), &net, &op);
            let warm = cache.simulate_network(m.as_ref(), &net, &op);
            assert_bit_identical(&direct, &cold, &format!("{} cold", m.name()))?;
            assert_bit_identical(&direct, &warm, &format!("{} warm", m.name()))?;
            // The dedup must actually engage: unique tuples simulated
            // once, duplicates + the warm pass served from memory.
            prop_assert(
                cache.misses() <= net.num_layers(),
                "misses bounded by layer count",
            )?;
            prop_assert(
                cache.hits() + cache.misses() == 2 * net.num_layers(),
                "every lookup accounted",
            )?;
            if net.num_layers() > cache.len() {
                prop_assert(cache.hits() > 0, "duplicate shapes must hit")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cache_shared_across_nets_nodes_and_machines_stays_exact() {
    // One long-lived cache fed from many networks/nodes (the sweep-grid
    // usage pattern) must return the same bits as fresh simulation for
    // every query, in any interleaving.
    let machines = all_machines();
    let cache = SweepCache::new();
    check(25, |g| {
        let net = random_net(g);
        let op = random_op(g);
        let m = g.choose(&machines);
        let direct = m.simulate_network(&net, &op);
        let cached = cache.simulate_network(m.as_ref(), &net, &op);
        assert_bit_identical(&direct, &cached, m.name())
    });
}

#[test]
fn prop_par_map_ordering_matches_serial() {
    check(60, |g| {
        let n = g.usize(0, 400);
        let threads = g.usize(1, 16);
        let items: Vec<u64> = (0..n as u64).map(|i| i * 37 + 11).collect();
        let serial: Vec<u64> = items.iter().map(|x| x ^ (x << 7)).collect();
        let parallel = Pool::new(threads).par_map(&items, |x| x ^ (x << 7));
        prop_assert(
            parallel == serial,
            &format!("order diverged (n={n}, threads={threads})"),
        )
    });
}

#[test]
fn prop_parallel_network_sim_deterministic_across_thread_counts() {
    // Simulating through par_map with any thread count must equal the
    // serial result record-for-record (f64 merges happen per network
    // inside one worker, so no reassociation can occur).
    let machines = all_machines();
    check(10, |g| {
        let nets: Vec<Network> = (0..g.usize(1, 4)).map(|_| random_net(g)).collect();
        let ops = [
            OperatingPoint::node(45.0),
            OperatingPoint::node(7.0).bits(4, 4),
        ];
        let serial = sweep_on(
            &Pool::new(1),
            &machines,
            &nets,
            &ops,
            &SweepCache::new(),
        );
        for threads in [2, 5, 13] {
            let par = sweep_on(
                &Pool::new(threads),
                &machines,
                &nets,
                &ops,
                &SweepCache::new(),
            );
            prop_assert(par.len() == serial.len(), "record count")?;
            for (a, b) in serial.iter().zip(&par) {
                prop_assert(
                    a.machine == b.machine
                        && a.network == b.network
                        && a.op == b.op,
                    "record order",
                )?;
                assert_bit_identical(&a.result, &b.result, a.machine)?;
            }
        }
        Ok(())
    });
}

#[test]
fn grid_runner_covers_full_grid_in_declared_order() {
    let machines = all_machines();
    let nets = vec![
        aimc::networks::yolov3::yolov3(200),
        aimc::networks::vgg::vgg16(200),
    ];
    let ops = ops_at_nodes(&[45.0, 28.0, 7.0]);
    let cache = SweepCache::new();
    let recs: Vec<SweepRecord> = sweep_on(&Pool::auto(), &machines, &nets, &ops, &cache);
    assert_eq!(recs.len(), 4 * 2 * 3);
    let mut i = 0;
    for m in &machines {
        for net in &nets {
            for op in &ops {
                assert_eq!(recs[i].machine, m.name());
                assert_eq!(recs[i].network, net.name);
                assert_eq!(recs[i].op, *op);
                assert!(recs[i].result.ops > 0.0);
                i += 1;
            }
        }
    }
    // VGG16 repeats conv shapes back-to-back; across 3 nodes × 4
    // machines the cache must have deduped a substantial share.
    assert!(cache.hits() > 0, "{}", cache.stats());
}

#[test]
fn machine_lookup_round_trips_cli_names() {
    for m in all_machines() {
        let again = by_name(m.name()).expect(m.name());
        assert_eq!(again.name(), m.name());
        assert_eq!(again.fingerprint(), m.fingerprint());
    }
}

// ---- persistent cache (save/load snapshots) ----------------------------

/// Unique temp path per test so parallel test threads never collide.
fn temp_snapshot(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "aimc-sweepcache-test-{}-{tag}.txt",
        std::process::id()
    ))
}

#[test]
fn prop_snapshot_round_trip_bit_identical() {
    let machines = all_machines();
    let path = temp_snapshot("roundtrip");
    check(15, |g| {
        let net = random_net(g);
        let op = random_op(g);
        let m = g.choose(&machines);
        let cache = SweepCache::new();
        let direct = cache.simulate_network(m.as_ref(), &net, &op);
        cache.save(&path).expect("save");
        let restored = SweepCache::load(&path);
        prop_assert(restored.len() == cache.len(), "entry count restored")?;
        let replayed = restored.simulate_network(m.as_ref(), &net, &op);
        prop_assert(restored.misses() == 0, "replay must not simulate")?;
        assert_bit_identical(&direct, &replayed, m.name())
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_corruption_is_ignored_not_trusted() {
    let cache = SweepCache::new();
    let net = aimc::networks::yolov3::yolov3(200);
    let m = by_name("systolic").unwrap();
    let op45 = OperatingPoint::node(45.0);
    let _ = cache.simulate_network(m.as_ref(), &net, &op45);
    let path = temp_snapshot("corrupt");
    cache.save(&path).expect("save");
    let good = std::fs::read_to_string(&path).unwrap();

    // A pristine snapshot loads in full…
    assert_eq!(SweepCache::load(&path).len(), cache.len());

    // …and every corruption mode loads as EMPTY (fresh simulation), not
    // partially:
    let cases: Vec<(&str, String)> = vec![
        ("missing file", String::new()),
        ("garbage", "not a snapshot at all\n".to_string()),
        ("wrong version", good.replacen("-v3", "-v9", 1)),
        ("truncated body", {
            let cut = good.len() / 2;
            good[..cut].to_string()
        }),
        ("dropped line", {
            let mut lines: Vec<&str> = good.lines().collect();
            lines.remove(lines.len() / 2);
            format!("{}\n", lines.join("\n"))
        }),
        ("extra line", format!("{good}deadbeef\n")),
        ("negative energy", {
            // Flip one stored f64 to a negative value's bit pattern.
            let neg = format!("{:016x}", (-1.0f64).to_bits());
            let mut lines: Vec<String> = good.lines().map(String::from).collect();
            let mut tok: Vec<String> =
                lines[1].split_whitespace().map(String::from).collect();
            let last = tok.len() - 1;
            tok[last] = neg;
            lines[1] = tok.join(" ");
            format!("{}\n", lines.join("\n"))
        }),
    ];
    for (what, text) in cases {
        if what == "missing file" {
            let _ = std::fs::remove_file(&path);
        } else {
            std::fs::write(&path, &text).unwrap();
        }
        let loaded = SweepCache::load(&path);
        assert_eq!(loaded.len(), 0, "{what}: corrupt snapshot must load empty");
        // And a fresh simulation through it still produces exact results.
        let r = loaded.simulate_network(m.as_ref(), &net, &op45);
        let direct = m.simulate_network(&net, &op45);
        assert_bit_identical(&direct, &r, what).unwrap();
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_never_aliases_across_config_fingerprints() {
    // Process A persists a cache built with one systolic config; process
    // B (simulated here by a reload) runs a DIFFERENT config: the
    // snapshot must contribute zero hits — fingerprints key the entries.
    use aimc::simulator::systolic::SystolicConfig;
    let layer = ConvLayer::square(64, 32, 32, 3, 1);
    let net = Network {
        name: "one-layer",
        layers: vec![layer],
    };
    let small = SystolicConfig {
        dim: 64,
        banks: 64,
        ..Default::default()
    };
    let big = SystolicConfig::default();

    let op45 = OperatingPoint::node(45.0);
    let path = temp_snapshot("alias");
    let writer = SweepCache::new();
    let small_result = writer.simulate_network(&small, &net, &op45);
    writer.save(&path).expect("save");

    let reader = SweepCache::load(&path);
    let big_result = reader.simulate_network(&big, &net, &op45);
    assert_eq!(reader.hits(), 0, "different fingerprint must not hit");
    assert_eq!(reader.misses(), 1);
    assert!(
        small_result.ledger.total() != big_result.ledger.total(),
        "distinct configs must price differently"
    );
    // Same config + same snapshot DOES hit, bit-identically.
    let reader2 = SweepCache::load(&path);
    let replay = reader2.simulate_network(&small, &net, &op45);
    assert_eq!(reader2.hits(), 1);
    assert_eq!(reader2.misses(), 0);
    assert_bit_identical(&small_result, &replay, "same fingerprint").unwrap();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn snapshot_files_are_deterministic() {
    // Same cache contents → same bytes (entries are key-sorted), so
    // repeated CLI runs don't churn the cache directory.
    let cache = SweepCache::new();
    let net = aimc::networks::vgg::vgg16(200);
    for m in all_machines() {
        let _ = cache.simulate_network(m.as_ref(), &net, &OperatingPoint::node(28.0));
        let _ = cache.simulate_network(m.as_ref(), &net, &OperatingPoint::node(28.0).bits(4, 8));
    }
    let (p1, p2) = (temp_snapshot("det1"), temp_snapshot("det2"));
    cache.save(&p1).unwrap();
    SweepCache::load(&p1).save(&p2).unwrap();
    assert_eq!(
        std::fs::read_to_string(&p1).unwrap(),
        std::fs::read_to_string(&p2).unwrap()
    );
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}
