//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the request path.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only thing that touches the compiled graphs afterwards. Interchange is
//! HLO *text* (see aot.py for why serialized protos are rejected by
//! xla_extension 0.5.1).
//!
//! * [`artifact`] — manifest parsing + golden input/output loading.
//! * [`engine`] — PJRT CPU client wrapper: compile once, execute many.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactSpec, Manifest};
pub use engine::Engine;

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$AIMC_ARTIFACTS` override, else walk
/// up from the current dir looking for `artifacts/manifest.tsv`.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("AIMC_ARTIFACTS") {
        let pb = std::path::PathBuf::from(p);
        if pb.join("manifest.tsv").exists() {
            return Some(pb);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.tsv").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn artifacts_discoverable_from_repo() {
        // `make artifacts` must have run for the full pipeline; the
        // offline build image has no JAX, so absence is only an error
        // when explicitly demanded (CI with artifacts baked in sets
        // AIMC_REQUIRE_ARTIFACTS=1).
        let dir = super::find_artifacts_dir();
        if std::env::var("AIMC_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
            assert!(
                dir.is_some(),
                "artifacts/manifest.tsv not found — run `make artifacts`"
            );
        } else if dir.is_none() {
            eprintln!("skipping: artifacts/ not present (run `make artifacts`)");
        }
    }
}
