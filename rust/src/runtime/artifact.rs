//! Artifact manifest: what `aot.py` shipped and how to call it.
//!
//! `manifest.tsv` line format (tab-separated):
//! `name \t in_shapes \t out_shape \t rtol`
//! where `in_shapes` is `;`-separated, each shape `,`-separated dims,
//! e.g. `conv_sys_n64_ci8_co16_k3 \t 8,64,64;16,8,3,3 \t 16,62,62 \t 0.05`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One compiled artifact's calling convention.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shape: Vec<usize>,
    /// Relative tolerance for golden replay.
    pub rtol: f64,
}

impl ArtifactSpec {
    /// Number of f32 elements of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_len(&self) -> usize {
        self.output_shape.iter().product()
    }

    /// Path of the HLO text file inside `dir`.
    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }

    /// Path of golden input `i`.
    pub fn golden_in_path(&self, dir: &Path, i: usize) -> PathBuf {
        dir.join(format!("{}.in{}.f32", self.name, i))
    }

    pub fn golden_out_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.out.f32", self.name))
    }
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|d| {
            d.trim()
                .parse::<usize>()
                .with_context(|| format!("bad dim {d:?} in shape {s:?}"))
        })
        .collect()
}

/// The full artifact set.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `dir/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.tsv"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 4 {
                bail!("manifest line {}: want 4 fields, got {}", lineno + 1, fields.len());
            }
            let input_shapes = fields[1]
                .split(';')
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: fields[0].to_string(),
                input_shapes,
                output_shape: parse_shape(fields[2])?,
                rtol: fields[3]
                    .parse()
                    .with_context(|| format!("bad rtol {:?}", fields[3]))?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Load a raw little-endian f32 file.
    pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load all golden inputs of an artifact.
    pub fn golden_inputs(&self, spec: &ArtifactSpec) -> Result<Vec<Vec<f32>>> {
        (0..spec.input_shapes.len())
            .map(|i| {
                let v = Self::read_f32(&spec.golden_in_path(&self.dir, i))?;
                if v.len() != spec.input_len(i) {
                    bail!(
                        "{} input {}: {} elements, expected {}",
                        spec.name,
                        i,
                        v.len(),
                        spec.input_len(i)
                    );
                }
                Ok(v)
            })
            .collect()
    }

    /// Load the golden output of an artifact.
    pub fn golden_output(&self, spec: &ArtifactSpec) -> Result<Vec<f32>> {
        let v = Self::read_f32(&spec.golden_out_path(&self.dir))?;
        if v.len() != spec.output_len() {
            bail!(
                "{} output: {} elements, expected {}",
                spec.name,
                v.len(),
                spec.output_len()
            );
        }
        Ok(v)
    }
}

/// Max relative error between two vectors (scaled by the max |b|).
pub fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let scale = b
        .iter()
        .map(|v| v.abs() as f64)
        .fold(1e-30f64, f64::max);
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y).abs() as f64) / scale)
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str =
        "qgemm\t256,128;128,256\t256,256\t0.05\nsmallcnn\t3,64,64\t10\t1e-5\n";

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("qgemm").unwrap();
        assert_eq!(a.input_shapes, vec![vec![256, 128], vec![128, 256]]);
        assert_eq!(a.output_shape, vec![256, 256]);
        assert_eq!(a.input_len(0), 256 * 128);
        assert_eq!(a.output_len(), 256 * 256);
        assert!((m.get("smallcnn").unwrap().rtol - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn parse_skips_blank_and_comments() {
        let m = Manifest::parse(Path::new("/tmp"), "# c\n\nqgemm\t2,2\t2,2\t0.1\n").unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(Manifest::parse(Path::new("/tmp"), "name\tonly_two\n").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "n\t1,x\t1\t0.1\n").is_err());
    }

    #[test]
    fn paths_formatted() {
        let m = Manifest::parse(Path::new("/art"), SAMPLE).unwrap();
        let a = m.get("qgemm").unwrap();
        assert_eq!(a.hlo_path(&m.dir).to_str().unwrap(), "/art/qgemm.hlo.txt");
        assert_eq!(
            a.golden_in_path(&m.dir, 1).to_str().unwrap(),
            "/art/qgemm.in1.f32"
        );
        assert_eq!(
            a.golden_out_path(&m.dir).to_str().unwrap(),
            "/art/qgemm.out.f32"
        );
    }

    #[test]
    fn read_f32_round_trip() {
        let vals = [1.5f32, -2.25, 0.0, 3.5e7];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = std::env::temp_dir().join("aimc_test_read_f32.bin");
        std::fs::write(&p, &bytes).unwrap();
        let got = Manifest::read_f32(&p).unwrap();
        assert_eq!(got, vals);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn read_f32_rejects_misaligned() {
        let p = std::env::temp_dir().join("aimc_test_misaligned.bin");
        std::fs::write(&p, [0u8, 1, 2]).unwrap();
        assert!(Manifest::read_f32(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn max_rel_err_basics() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let e = max_rel_err(&[1.1, 2.0], &[1.0, 2.0]);
        assert!((e - 0.05).abs() < 1e-6); // 0.1 / max|b|=2.0
    }

    #[test]
    fn real_manifest_loads_if_present() {
        if let Some(dir) = crate::runtime::find_artifacts_dir() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 6, "expected the aot.py artifact set");
            assert!(m.get("smallcnn_exact").is_some());
            // Goldens are readable and correctly sized.
            let spec = m.get("smallcnn_exact").unwrap().clone();
            let ins = m.golden_inputs(&spec).unwrap();
            assert_eq!(ins.len(), 1);
            assert_eq!(ins[0].len(), 3 * 64 * 64);
            assert_eq!(m.golden_output(&spec).unwrap().len(), 10);
        }
    }
}
