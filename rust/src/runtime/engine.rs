//! PJRT execution engine: compile each HLO-text artifact once on the CPU
//! PJRT client, then execute with plain `Vec<f32>` I/O from the serving
//! hot path.
//!
//! Compilation is lazy (first call) and cached; executions are
//! `&self`-threadsafe behind per-executable mutexes so the coordinator's
//! worker pool can share one engine.
//!
//! The `xla` crate (and its native `libxla_extension`) is only available
//! behind the `pjrt` cargo feature; without it a stub [`Engine`] with the
//! identical API errors on construction, so every simulator / analytic /
//! report path builds and runs in the offline environment.

#[cfg(feature = "pjrt")]
pub use real::Engine;
#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use anyhow::{anyhow, Context, Result};

    use crate::runtime::artifact::{ArtifactSpec, Manifest};

    /// A compiled artifact ready to execute.
    struct Loaded {
        exe: xla::PjRtLoadedExecutable,
        spec: ArtifactSpec,
    }

    /// The engine owns the PJRT client and all compiled executables.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: Manifest,
        loaded: Mutex<HashMap<String, &'static Loaded>>,
    }

    impl Engine {
        /// Create an engine over an artifacts directory.
        pub fn new(dir: &Path) -> Result<Engine> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            let manifest = Manifest::load(dir)?;
            Ok(Engine {
                client,
                manifest,
                loaded: Mutex::new(HashMap::new()),
            })
        }

        /// Create an engine using artifact auto-discovery.
        pub fn discover() -> Result<Engine> {
            let dir = crate::runtime::find_artifacts_dir().ok_or_else(|| {
                anyhow!("artifacts/manifest.tsv not found — run `make artifacts`")
            })?;
            Engine::new(&dir)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Names of all available artifacts.
        pub fn artifact_names(&self) -> Vec<String> {
            self.manifest
                .artifacts
                .iter()
                .map(|a| a.name.clone())
                .collect()
        }

        /// Compile (once) and return the cached executable for `name`.
        fn load(&self, name: &str) -> Result<&'static Loaded> {
            if let Some(l) = self.loaded.lock().unwrap().get(name) {
                return Ok(l);
            }
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
                .clone();
            let hlo = spec.hlo_path(&self.manifest.dir);
            let proto = xla::HloModuleProto::from_text_file(
                hlo.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", hlo.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            // Executables live for the process lifetime; leak to get a stable
            // reference that avoids cloning non-Clone PJRT handles per call.
            let leaked: &'static Loaded = Box::leak(Box::new(Loaded { exe, spec }));
            self.loaded
                .lock()
                .unwrap()
                .insert(name.to_string(), leaked);
            Ok(leaked)
        }

        /// Eagerly compile a set of artifacts (warm-up).
        pub fn warm_up(&self, names: &[&str]) -> Result<()> {
            for n in names {
                self.load(n)?;
            }
            Ok(())
        }

        /// Execute artifact `name` with the given inputs.
        pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
            let l = self.load(name)?;
            if inputs.len() != l.spec.input_shapes.len() {
                anyhow::bail!(
                    "{name}: got {} inputs, expects {}",
                    inputs.len(),
                    l.spec.input_shapes.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, data) in inputs.iter().enumerate() {
                if data.len() != l.spec.input_len(i) {
                    anyhow::bail!(
                        "{name} input {i}: {} elements, expects {}",
                        data.len(),
                        l.spec.input_len(i)
                    );
                }
                let dims: Vec<i64> =
                    l.spec.input_shapes[i].iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
                literals.push(lit);
            }
            let result = l
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e:?}"))?;
            // aot.py lowers with return_tuple=True → 1-tuple.
            let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
            let vals = out
                .to_vec::<f32>()
                .map_err(|e| anyhow!("to_vec: {e:?}"))?;
            if vals.len() != l.spec.output_len() {
                anyhow::bail!(
                    "{name}: output {} elements, manifest says {}",
                    vals.len(),
                    l.spec.output_len()
                );
            }
            Ok(vals)
        }

        /// Replay an artifact against its golden input/output. Returns the
        /// max relative error (must be ≤ spec.rtol to pass).
        pub fn verify_golden(&self, name: &str) -> Result<f64> {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?
                .clone();
            let inputs = self.manifest.golden_inputs(&spec)?;
            let want = self.manifest.golden_output(&spec)?;
            let got = self.execute(name, &inputs)?;
            Ok(crate::runtime::artifact::max_rel_err(&got, &want))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::runtime::artifact::Manifest;

    const NO_PJRT: &str =
        "aimc was built without the `pjrt` feature — rebuild with \
         `cargo build --features pjrt` (requires the xla crate) to load \
         AOT artifacts";

    /// API-compatible stand-in for the PJRT engine: construction always
    /// fails with a clear message, so callers (server, CLI `verify`,
    /// benches) degrade gracefully instead of failing to compile.
    pub struct Engine {
        manifest: Manifest,
    }

    impl Engine {
        pub fn new(_dir: &Path) -> Result<Engine> {
            bail!(NO_PJRT)
        }

        pub fn discover() -> Result<Engine> {
            bail!(NO_PJRT)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "none (pjrt feature disabled)".to_string()
        }

        pub fn artifact_names(&self) -> Vec<String> {
            self.manifest
                .artifacts
                .iter()
                .map(|a| a.name.clone())
                .collect()
        }

        pub fn warm_up(&self, _names: &[&str]) -> Result<()> {
            bail!(NO_PJRT)
        }

        pub fn execute(&self, _name: &str, _inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
            bail!(NO_PJRT)
        }

        pub fn verify_golden(&self, _name: &str) -> Result<f64> {
            bail!(NO_PJRT)
        }
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        crate::runtime::find_artifacts_dir().map(|d| Engine::new(&d).unwrap())
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(e) = engine() else { return };
        assert!(e.execute("nope", &[]).is_err());
    }

    #[test]
    fn wrong_arity_errors() {
        let Some(e) = engine() else { return };
        assert!(e.execute("smallcnn_exact", &[]).is_err());
    }

    #[test]
    fn wrong_input_len_errors() {
        let Some(e) = engine() else { return };
        assert!(e.execute("smallcnn_exact", &[vec![0.0; 7]]).is_err());
    }

    #[test]
    fn golden_replay_smallcnn_exact() {
        let Some(e) = engine() else { return };
        let err = e.verify_golden("smallcnn_exact").unwrap();
        assert!(err < 1e-5, "max rel err {err}");
    }

    #[test]
    fn golden_replay_qgemm() {
        let Some(e) = engine() else { return };
        let err = e.verify_golden("qgemm_256x128x256").unwrap();
        assert!(err < 1e-4, "max rel err {err}");
    }

    #[test]
    fn execute_is_deterministic() {
        let Some(e) = engine() else { return };
        let spec = e.manifest().get("smallcnn_exact").unwrap().clone();
        let inputs = e.manifest().golden_inputs(&spec).unwrap();
        let a = e.execute("smallcnn_exact", &inputs).unwrap();
        let b = e.execute("smallcnn_exact", &inputs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn stub_absent_when_pjrt_enabled() {
        // With the feature on, discovery either finds artifacts or fails
        // with the make-artifacts hint — never the stub's message.
        if let Err(e) = Engine::discover() {
            assert!(!format!("{e:#}").contains("pjrt feature"));
        }
    }
}
