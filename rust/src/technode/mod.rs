//! CMOS technology-node energy scaling (Stillmaker & Baas, *Integration*
//! 2017: "Scaling equations for the accurate prediction of CMOS device
//! performance from 180 nm to 7 nm").
//!
//! The paper scales all CMOS energies (SRAM, MAC, ADC, DAC) from their
//! 45 nm calibration to nodes from 180 nm down to 7 nm, while wire-load
//! (`e_load`) and laser (`e_opt`) energies stay fixed. We model switching
//! energy as E ∝ C·V²: capacitance proportional to feature size, supply
//! voltage from the node's typical V_dd, i.e.
//!
//!   scale(node) = (node/45) · (V_dd(node)/0.9)²
//!
//! which reproduces Stillmaker & Baas's ~11× energy gain from 45 → 7 nm
//! and ~16× loss back to 180 nm.

/// A technology node: feature size and nominal supply voltage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Node {
    pub nm: f64,
    pub vdd: f64,
}

/// The node ladder used across the paper's figures (180 → 7 nm), with
/// typical nominal supply voltages per Stillmaker & Baas Table 2.
pub const NODES: &[Node] = &[
    Node { nm: 180.0, vdd: 1.8 },
    Node { nm: 130.0, vdd: 1.3 },
    Node { nm: 90.0, vdd: 1.1 },
    Node { nm: 65.0, vdd: 1.1 },
    Node { nm: 45.0, vdd: 0.9 },
    Node { nm: 32.0, vdd: 0.9 },
    Node { nm: 28.0, vdd: 0.9 },
    Node { nm: 22.0, vdd: 0.8 },
    Node { nm: 20.0, vdd: 0.8 },
    Node { nm: 16.0, vdd: 0.8 },
    Node { nm: 14.0, vdd: 0.8 },
    Node { nm: 10.0, vdd: 0.75 },
    Node { nm: 7.0, vdd: 0.7 },
];

/// Reference node the paper calibrates energies at.
pub const REF_NODE_NM: f64 = 45.0;
pub const REF_VDD: f64 = 0.9;

/// Look up a node's nominal V_dd, interpolating (log-size) between ladder
/// entries for off-ladder sizes.
pub fn vdd_for(nm: f64) -> f64 {
    assert!(nm > 0.0, "node must be positive");
    if nm >= NODES[0].nm {
        return NODES[0].vdd;
    }
    let last = NODES[NODES.len() - 1];
    if nm <= last.nm {
        return last.vdd;
    }
    for w in NODES.windows(2) {
        let (a, b) = (w[0], w[1]);
        if nm <= a.nm && nm >= b.nm {
            // Linear in log(feature size).
            let t = (a.nm.ln() - nm.ln()) / (a.nm.ln() - b.nm.ln());
            return a.vdd + t * (b.vdd - a.vdd);
        }
    }
    unreachable!()
}

/// Energy scale factor relative to the 45 nm calibration:
/// multiply a 45 nm energy by this to get the energy at `nm`.
pub fn scale_from_45nm(nm: f64) -> f64 {
    let v = vdd_for(nm);
    (nm / REF_NODE_NM) * (v / REF_VDD) * (v / REF_VDD)
}

/// Scale an energy between two arbitrary nodes.
pub fn rescale(energy: f64, from_nm: f64, to_nm: f64) -> f64 {
    energy * scale_from_45nm(to_nm) / scale_from_45nm(from_nm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_reference() {
        assert!((scale_from_45nm(45.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_decreasing_along_ladder() {
        let scales: Vec<f64> = NODES.iter().map(|n| scale_from_45nm(n.nm)).collect();
        for w in scales.windows(2) {
            assert!(w[1] < w[0], "scaling must shrink with node: {w:?}");
        }
    }

    #[test]
    fn stillmaker_baas_magnitudes() {
        // ~16× more energy at 180 nm, ~10× less at 7 nm (S&B report ≈11×
        // for 45→7; our V²·C model gives 9.4% ≈ 10.6×).
        let s180 = scale_from_45nm(180.0);
        let s7 = scale_from_45nm(7.0);
        assert!(s180 > 12.0 && s180 < 20.0, "180 nm scale {s180}");
        assert!(s7 < 0.12 && s7 > 0.07, "7 nm scale {s7}");
    }

    #[test]
    fn vdd_interpolates() {
        let v = vdd_for(100.0); // between 130 (1.3 V) and 90 (1.1 V)
        assert!(v > 1.1 && v < 1.3, "{v}");
    }

    #[test]
    fn vdd_clamps_outside_ladder() {
        assert_eq!(vdd_for(250.0), 1.8);
        assert_eq!(vdd_for(5.0), 0.7);
    }

    #[test]
    fn rescale_round_trip() {
        let e = 1e-12;
        let there = rescale(e, 45.0, 7.0);
        let back = rescale(there, 7.0, 45.0);
        assert!((back - e).abs() / e < 1e-12);
    }

    #[test]
    fn ladder_matches_paper_range() {
        assert_eq!(NODES.first().unwrap().nm, 180.0);
        assert_eq!(NODES.last().unwrap().nm, 7.0);
    }

    #[test]
    #[should_panic]
    fn zero_node_rejected() {
        let _ = vdd_for(0.0);
    }
}
