//! Figures 6–10 (and the cross-validation extension) as [`Scenario`]s —
//! declarative specs evaluated by [`Scenario::eval`] through a shared
//! pool + cache into typed [`crate::report::Dataset`]s.
//!
//! Fig. 6 runs on the sweep engine like everything else: its four
//! curves are [`crate::simulator::AnalyticMachine`]s over a single-layer
//! reference network, so analytic and cycle-accurate figures share one
//! grid, one cache and one rendering path. The cycle-accurate figures (8–10,
//! crossval) declare their machine/network/node grids and derive their
//! columns from [`RowCtx::sim`]; the closed-form comparison columns
//! (eqs. 5/24) are derived columns evaluated per row. Rendered text is
//! byte-identical to the pre-scenario drivers (pinned in
//! `tests/scenario_golden.rs`).

use crate::analytic::{Processor, Workload};
use crate::networks::{by_name, ConvLayer, Network};
use crate::report::scenario::{RowCtx, Scenario};
use crate::simulator::machine::{all_analytic_machines, all_machines};
use crate::simulator::{optical4f, systolic, Component};

/// The single-layer network wrapping Table V's reference layer — what
/// Fig. 6's analytic machines sweep over.
pub fn reference_network() -> Network {
    Network {
        name: "Table V reference layer",
        layers: vec![ConvLayer::square(512, 128, 128, 3, 1)],
    }
}

/// Fig. 6: analytic η (TOPS/W) vs technology node for the four
/// processor classes on Table V's reference layer — evaluated through
/// the sweep engine via [`AnalyticMachine`], one column per processor.
///
/// [`AnalyticMachine`]: crate::simulator::AnalyticMachine
pub fn fig6() -> Scenario {
    let mut s = Scenario::new(
        "Fig. 6 — analytic efficiency vs technology node (TOPS/W, Table V layer)",
    )
    .machines(all_analytic_machines())
    .network(reference_network())
    .node_ladder()
    .over_nodes()
    .num("node (nm)", 0, |c: &RowCtx| c.node());
    for (mi, p) in Processor::ALL.iter().enumerate() {
        s = s.num(p.short(), 3, move |c: &RowCtx| c.sim(mi).tops_per_watt());
    }
    s
}

/// Fig. 7: per-op energy split (memory vs compute, pJ) per processor at
/// 32 nm on the reference layer. One row per processor class; every
/// column derives from the same closed-form [`Processor::efficiency`].
pub fn fig7() -> Scenario {
    let eff = |c: &RowCtx| Processor::ALL[c.index].efficiency(&Workload::reference(), 32.0);
    Scenario::new("Fig. 7 — energy per operation breakdown at 32 nm (pJ/op, Table V layer)")
        .items(Processor::ALL.len())
        .text("processor", |c: &RowCtx| {
            Processor::ALL[c.index].short().to_string()
        })
        .num("memory", 4, move |c: &RowCtx| eff(c).e_mem * 1e12)
        .num("compute", 4, move |c: &RowCtx| eff(c).e_comp * 1e12)
        .num("total", 4, move |c: &RowCtx| eff(c).per_op() * 1e12)
        .num("eta (TOPS/W)", 3, move |c: &RowCtx| {
            eff(c).tops_per_watt()
        })
}

fn net_or_yolo(name: Option<&str>, input: usize) -> Network {
    name.and_then(|n| by_name(n, input))
        .unwrap_or_else(|| crate::networks::yolov3::yolov3(input))
}

/// Fig. 8: systolic-array efficiency vs node — cycle-accurate model vs
/// the analytic eq. (5), running YOLOv3 (or `net`) at 1 Mpx.
pub fn fig8(net: Option<&str>, input: usize) -> Scenario {
    let net = net_or_yolo(net, input);
    // The analytic curve uses the network's median-layer workload.
    let w = Workload::from_layer(median_layer(&net));
    let title = format!(
        "Fig. 8 — systolic array, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
        net.name, input
    );
    let ana = move |node: f64| {
        crate::analytic::in_memory::Config::tpu_like()
            .efficiency(&w, node)
            .tops_per_watt()
    };
    Scenario::new(title)
        .machine(Box::new(systolic::SystolicConfig::default()))
        .network(net)
        .node_ladder()
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .num("cycle-accurate", 3, |c: &RowCtx| c.sim(0).tops_per_watt())
        .num("analytic eq.(5)", 3, move |c: &RowCtx| ana(c.node()))
        // Re-deriving both operands costs one cache-hit merge + one
        // closed-form eval per row; identical bits to the neighbouring
        // columns, so the printed ratio is exactly sim/ana.
        .num("ratio", 2, move |c: &RowCtx| {
            c.sim(0).tops_per_watt() / ana(c.node())
        })
}

/// Fig. 9: optical 4F efficiency vs node — cycle-accurate vs eq. (24).
pub fn fig9(net: Option<&str>, input: usize) -> Scenario {
    let net = net_or_yolo(net, input);
    let w = Workload::from_layer(median_layer(&net));
    let title = format!(
        "Fig. 9 — optical 4F, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
        net.name, input
    );
    let ana = move |node: f64| {
        crate::analytic::optical4f::Config::default_4mpx()
            .efficiency(&w, node)
            .tops_per_watt()
    };
    Scenario::new(title)
        .machine(Box::new(optical4f::Optical4FConfig::default()))
        .network(net)
        .node_ladder()
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .num("cycle-accurate", 3, |c: &RowCtx| c.sim(0).tops_per_watt())
        .num("analytic eq.(24)", 3, move |c: &RowCtx| ana(c.node()))
        .num("ratio", 2, move |c: &RowCtx| {
            c.sim(0).tops_per_watt() / ana(c.node())
        })
}

/// Fig. 10: optical-4F energy-cost distribution (pJ/MAC by component)
/// across nodes for one network (paper shows VGG19 and YOLOv3).
pub fn fig10(net: Option<&str>, input: usize) -> Scenario {
    let net = net_or_yolo(net, input);
    let title = format!(
        "Fig. 10 — optical 4F energy distribution, {} @ {} px (pJ/MAC)",
        net.name, input
    );
    let per = |c: &RowCtx, comp: Component| {
        let r = c.sim(0);
        r.ledger.get(comp) / r.macs * 1e12
    };
    Scenario::new(title)
        .machine(Box::new(optical4f::Optical4FConfig::default()))
        .network(net)
        .node_ladder()
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .num("DAC", 4, move |c: &RowCtx| per(c, Component::Dac))
        .num("ADC", 4, move |c: &RowCtx| per(c, Component::Adc))
        .num("SRAM", 4, move |c: &RowCtx| per(c, Component::Sram))
        .num("laser", 4, move |c: &RowCtx| per(c, Component::Laser))
        .num("total", 4, |c: &RowCtx| c.sim(0).energy_per_mac() * 1e12)
}

/// Extension (beyond the paper): cycle-accurate cross-validation of all
/// FOUR processor classes vs technology node on one network. The paper
/// builds cycle models only for the systolic array and the 4F machine;
/// with the [`crate::simulator::reram`] and [`crate::simulator::photonic`]
/// extensions, Fig. 6's ordering can be checked end to end.
pub fn crossval(net: Option<&str>, input: usize) -> Scenario {
    let net = net_or_yolo(net, input);
    let title = format!(
        "Cross-validation (extension) — cycle-accurate TOPS/W, {} @ {} px",
        net.name, input
    );
    // all_machines() is Fig. 6 chart order: systolic, ReRAM, photonic,
    // 4F — the column order below.
    let mut s = Scenario::new(title)
        .machines(all_machines())
        .network(net)
        .node_ladder()
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node());
    for (mi, col) in ["systolic", "ReRAM", "photonic", "optical 4F"]
        .into_iter()
        .enumerate()
    {
        s = s.num(col, 3, move |c: &RowCtx| c.sim(mi).tops_per_watt());
    }
    s
}

/// The layer whose arithmetic intensity is the network median — the
/// "representative layer" the analytic curves are evaluated on.
pub fn median_layer(net: &Network) -> crate::networks::ConvLayer {
    let mut idx: Vec<usize> = (0..net.layers.len()).collect();
    idx.sort_by(|&a, &b| {
        net.layers[a]
            .arithmetic_intensity()
            .partial_cmp(&net.layers[b].arithmetic_intensity())
            .unwrap()
    });
    net.layers[idx[idx.len() / 2]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::technode::NODES;

    #[test]
    fn fig6_shape() {
        let t = fig6().table();
        assert_eq!(t.rows.len(), NODES.len());
        // Efficiency ordering holds on every row: CPU < DIM < SP < O4F.
        for row in &t.rows {
            let vals: Vec<f64> = row[1..].iter().map(|c| c.parse().unwrap()).collect();
            assert!(vals[0] < vals[1] && vals[1] < vals[2] && vals[2] < vals[3],
                "ordering violated: {row:?}");
        }
    }

    #[test]
    fn fig6_matches_closed_form_processor_models() {
        // The sweep-engine (AnalyticMachine) route must reproduce the
        // direct closed-form numbers at printed precision on every row.
        let t = fig6().table();
        let w = Workload::reference();
        for (row, n) in t.rows.iter().zip(NODES) {
            for (cell, p) in row[1..].iter().zip(Processor::ALL) {
                let want = format!("{:.3}", p.efficiency(&w, n.nm).tops_per_watt());
                assert_eq!(cell, &want, "{} @ {} nm", p.short(), n.nm);
            }
        }
    }

    #[test]
    fn fig7_cpu_memory_bound_o4f_compute_light() {
        let t = fig7().table();
        let cpu: Vec<f64> = t.rows[0][1..=2].iter().map(|c| c.parse().unwrap()).collect();
        let o4f: Vec<f64> = t.rows[3][1..=2].iter().map(|c| c.parse().unwrap()).collect();
        assert!(cpu[0] > cpu[1], "CPU memory-dominated");
        assert!(o4f[1] < o4f[0], "O4F compute below memory");
    }

    #[test]
    fn fig8_sim_tracks_analytic_within_factor_3() {
        let t = fig8(None, 1000).table();
        for row in &t.rows {
            let ratio: f64 = row[3].parse().unwrap();
            assert!(
                ratio > 1.0 / 3.0 && ratio < 3.0,
                "cycle vs analytic diverged: {row:?}"
            );
        }
    }

    #[test]
    fn fig8_sim_tracks_analytic_at_every_node() {
        // The paper reports a slight cycle-vs-analytic divergence at small
        // nodes because their eq. (5) omits the node-independent e_load;
        // our analytic Config includes the same hop bundle (§VII.A), so
        // the two stay within ±2× everywhere — and both flatten at 7 nm
        // for the same physical reason (wire-dominated loads).
        let t = fig8(None, 1000).table();
        for row in &t.rows {
            let ratio: f64 = row[3].parse().unwrap();
            assert!((0.5..2.0).contains(&ratio), "row {row:?}");
        }
    }

    #[test]
    fn fig9_rows_and_positive() {
        let t = fig9(None, 1000).table();
        assert_eq!(t.rows.len(), NODES.len());
        for row in &t.rows {
            assert!(row[1].parse::<f64>().unwrap() > 0.0);
            assert!(row[2].parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn fig10_laser_constant_dac_flat() {
        let t = fig10(None, 1000).table();
        let lasers: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        let spread = lasers.iter().cloned().fold(f64::MIN, f64::max)
            - lasers.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-6, "laser pJ/MAC must be node-constant");
        // DAC at 45 vs 7 nm nearly flat (paper §VII.C).
        let idx45 = NODES.iter().position(|n| n.nm == 45.0).unwrap();
        let dac45: f64 = t.rows[idx45][1].parse().unwrap();
        let dac7: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!(dac7 / dac45 > 0.6, "{dac7} / {dac45}");
    }

    #[test]
    fn fig10_vgg19_higher_sram_than_yolo() {
        // §VII.C: "a network with a much larger arithmetic intensity as
        // in the case of VGG19 presents a higher SRAM energy per MAC" —
        // the finite-SLM placement artifact.
        let tv = fig10(Some("VGG19"), 1000).table();
        let ty = fig10(Some("YOLOv3"), 1000).table();
        let idx45 = NODES.iter().position(|n| n.nm == 45.0).unwrap();
        let sram_v: f64 = tv.rows[idx45][3].parse().unwrap();
        let sram_y: f64 = ty.rows[idx45][3].parse().unwrap();
        assert!(sram_v > sram_y, "VGG19 {sram_v} !> YOLOv3 {sram_y}");
    }

    #[test]
    fn median_layer_is_a_layer_of_the_net() {
        let net = crate::networks::vgg::vgg16(1000);
        let l = median_layer(&net);
        assert!(net.layers.contains(&l));
    }
}

#[cfg(test)]
mod crossval_tests {
    use super::*;
    use crate::technode::NODES;

    #[test]
    fn crossval_has_all_four_machines() {
        let t = crossval(None, 1000).table();
        assert_eq!(t.headers.len(), 5);
        assert_eq!(t.rows.len(), NODES.len());
        // At 32 nm the cycle-accurate ordering of Fig. 6 holds:
        // systolic < {ReRAM, photonic} < optical 4F.
        let idx = NODES.iter().position(|n| n.nm == 32.0).unwrap();
        let vals: Vec<f64> = t.rows[idx][1..].iter().map(|c| c.parse().unwrap()).collect();
        let (sys, rr, ph, o4f) = (vals[0], vals[1], vals[2], vals[3]);
        assert!(rr > sys, "ReRAM {rr} !> systolic {sys}");
        assert!(ph > sys, "photonic {ph} !> systolic {sys}");
        assert!(o4f > rr && o4f > ph, "4F must top the chart");
    }
}
