//! Figures 6–10 as data tables (one row per x-axis point, one column per
//! series — ready for plotting or eyeballing in the terminal).
//!
//! Every simulator-backed node sweep (Figs. 8–10, crossval) runs through
//! the parallel engine: rows are computed by [`pool::par_map`] workers
//! (one per x-axis point) against a shared [`SweepCache`], then emitted
//! in axis order — so the rendered tables are byte-identical to the
//! serial path while the wall clock scales with cores and repeated layer
//! shapes simulate once. The closed-form figures (6–7) stay serial: their
//! whole sweep costs less than a thread spawn.

use crate::analytic::{Processor, Workload};
use crate::networks::{by_name, Network};
use crate::simulator::{all_machines, optical4f, systolic, Component, SweepCache};
use crate::technode::NODES;
use crate::util::pool;
use crate::util::table::Table;

/// Fig. 6: analytic η (TOPS/W) vs technology node for the four
/// processor classes on Table V's reference layer.
pub fn fig6() -> Table {
    let w = Workload::reference();
    let mut t = Table::new(
        "Fig. 6 — analytic efficiency vs technology node (TOPS/W, Table V layer)",
        &["node (nm)", "CPU", "DIM", "SP", "O4F"],
    );
    // Closed-form: the whole sweep is microseconds of arithmetic, so a
    // serial loop beats paying the pool's thread spawn/join here. The
    // simulator-backed figures (8–10, crossval) are the parallel ones.
    for n in NODES {
        let mut cells = vec![format!("{:.0}", n.nm)];
        for p in Processor::ALL {
            cells.push(format!("{:.3}", p.efficiency(&w, n.nm).tops_per_watt()));
        }
        t.row(cells);
    }
    t
}

/// Fig. 7: per-op energy split (memory vs compute, pJ) per processor at
/// 32 nm on the reference layer.
pub fn fig7() -> Table {
    let w = Workload::reference();
    let mut t = Table::new(
        "Fig. 7 — energy per operation breakdown at 32 nm (pJ/op, Table V layer)",
        &["processor", "memory", "compute", "total", "eta (TOPS/W)"],
    );
    for p in Processor::ALL {
        let e = p.efficiency(&w, 32.0);
        t.row(vec![
            p.short().to_string(),
            format!("{:.4}", e.e_mem * 1e12),
            format!("{:.4}", e.e_comp * 1e12),
            format!("{:.4}", e.per_op() * 1e12),
            format!("{:.3}", e.tops_per_watt()),
        ]);
    }
    t
}

fn net_or_yolo(name: Option<&str>, input: usize) -> Network {
    name.and_then(|n| by_name(n, input))
        .unwrap_or_else(|| crate::networks::yolov3::yolov3(input))
}

/// Fig. 8: systolic-array efficiency vs node — cycle-accurate model vs
/// the analytic eq. (5), running YOLOv3 (or `net`) at 1 Mpx.
pub fn fig8(net: Option<&str>, input: usize) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = systolic::SystolicConfig::default();
    // The analytic curve uses the network's median-layer workload.
    let med_layer = median_layer(&net);
    let w = Workload::from_layer(med_layer);
    let mut t = Table::new(
        &format!(
            "Fig. 8 — systolic array, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
            net.name, input
        ),
        &["node (nm)", "cycle-accurate", "analytic eq.(5)", "ratio"],
    );
    let cache = SweepCache::new();
    for row in pool::par_map(NODES, |n| {
        let sim = cache.simulate_network(&cfg, &net, n.nm).tops_per_watt();
        let ana = crate::analytic::in_memory::Config::tpu_like()
            .efficiency(&w, n.nm)
            .tops_per_watt();
        vec![
            format!("{:.0}", n.nm),
            format!("{sim:.3}"),
            format!("{ana:.3}"),
            format!("{:.2}", sim / ana),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Fig. 9: optical 4F efficiency vs node — cycle-accurate vs eq. (24).
pub fn fig9(net: Option<&str>, input: usize) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = optical4f::Optical4FConfig::default();
    let w = Workload::from_layer(median_layer(&net));
    let mut t = Table::new(
        &format!(
            "Fig. 9 — optical 4F, {} @ {} px: cycle-accurate vs analytic (TOPS/W)",
            net.name, input
        ),
        &["node (nm)", "cycle-accurate", "analytic eq.(24)", "ratio"],
    );
    let cache = SweepCache::new();
    for row in pool::par_map(NODES, |n| {
        let sim = cache.simulate_network(&cfg, &net, n.nm).tops_per_watt();
        let ana = crate::analytic::optical4f::Config::default_4mpx()
            .efficiency(&w, n.nm)
            .tops_per_watt();
        vec![
            format!("{:.0}", n.nm),
            format!("{sim:.3}"),
            format!("{ana:.3}"),
            format!("{:.2}", sim / ana),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Fig. 10: optical-4F energy-cost distribution (pJ/MAC by component)
/// across nodes for one network (paper shows VGG19 and YOLOv3).
pub fn fig10(net: Option<&str>, input: usize) -> Table {
    let net = net_or_yolo(net, input);
    let cfg = optical4f::Optical4FConfig::default();
    let mut t = Table::new(
        &format!(
            "Fig. 10 — optical 4F energy distribution, {} @ {} px (pJ/MAC)",
            net.name, input
        ),
        &["node (nm)", "DAC", "ADC", "SRAM", "laser", "total"],
    );
    let cache = SweepCache::new();
    for row in pool::par_map(NODES, |n| {
        let r = cache.simulate_network(&cfg, &net, n.nm);
        let per = |c: Component| r.ledger.get(c) / r.macs * 1e12;
        vec![
            format!("{:.0}", n.nm),
            format!("{:.4}", per(Component::Dac)),
            format!("{:.4}", per(Component::Adc)),
            format!("{:.4}", per(Component::Sram)),
            format!("{:.4}", per(Component::Laser)),
            format!("{:.4}", r.energy_per_mac() * 1e12),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Extension (beyond the paper): cycle-accurate cross-validation of all
/// FOUR processor classes vs technology node on one network. The paper
/// builds cycle models only for the systolic array and the 4F machine;
/// with the [`crate::simulator::reram`] and [`crate::simulator::photonic`]
/// extensions, Fig. 6's ordering can be checked end to end.
pub fn crossval(net: Option<&str>, input: usize) -> Table {
    let net = net_or_yolo(net, input);
    // all_machines() is Fig. 6 chart order: systolic, ReRAM, photonic, 4F
    // — the column order below.
    let machines = all_machines();
    let mut t = Table::new(
        &format!(
            "Cross-validation (extension) — cycle-accurate TOPS/W, {} @ {} px",
            net.name, input
        ),
        &["node (nm)", "systolic", "ReRAM", "photonic", "optical 4F"],
    );
    let cache = SweepCache::new();
    // One grid point per (node, machine), stolen across all cores.
    let mut points = Vec::new();
    for n in NODES {
        for mi in 0..machines.len() {
            points.push((n.nm, mi));
        }
    }
    let etas = pool::par_map(&points, |&(nm, mi)| {
        cache
            .simulate_network(machines[mi].as_ref(), &net, nm)
            .tops_per_watt()
    });
    for (i, n) in NODES.iter().enumerate() {
        let mut cells = vec![format!("{:.0}", n.nm)];
        for mi in 0..machines.len() {
            cells.push(format!("{:.3}", etas[i * machines.len() + mi]));
        }
        t.row(cells);
    }
    t
}

/// The layer whose arithmetic intensity is the network median — the
/// "representative layer" the analytic curves are evaluated on.
pub fn median_layer(net: &Network) -> crate::networks::ConvLayer {
    let mut idx: Vec<usize> = (0..net.layers.len()).collect();
    idx.sort_by(|&a, &b| {
        net.layers[a]
            .arithmetic_intensity()
            .partial_cmp(&net.layers[b].arithmetic_intensity())
            .unwrap()
    });
    net.layers[idx[idx.len() / 2]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_shape() {
        let t = fig6();
        assert_eq!(t.rows.len(), NODES.len());
        // Efficiency ordering holds on every row: CPU < DIM < SP < O4F.
        for row in &t.rows {
            let vals: Vec<f64> = row[1..].iter().map(|c| c.parse().unwrap()).collect();
            assert!(vals[0] < vals[1] && vals[1] < vals[2] && vals[2] < vals[3],
                "ordering violated: {row:?}");
        }
    }

    #[test]
    fn fig7_cpu_memory_bound_o4f_compute_light() {
        let t = fig7();
        let cpu: Vec<f64> = t.rows[0][1..=2].iter().map(|c| c.parse().unwrap()).collect();
        let o4f: Vec<f64> = t.rows[3][1..=2].iter().map(|c| c.parse().unwrap()).collect();
        assert!(cpu[0] > cpu[1], "CPU memory-dominated");
        assert!(o4f[1] < o4f[0], "O4F compute below memory");
    }

    #[test]
    fn fig8_sim_tracks_analytic_within_factor_3() {
        let t = fig8(None, 1000);
        for row in &t.rows {
            let ratio: f64 = row[3].parse().unwrap();
            assert!(
                ratio > 1.0 / 3.0 && ratio < 3.0,
                "cycle vs analytic diverged: {row:?}"
            );
        }
    }

    #[test]
    fn fig8_sim_tracks_analytic_at_every_node() {
        // The paper reports a slight cycle-vs-analytic divergence at small
        // nodes because their eq. (5) omits the node-independent e_load;
        // our analytic Config includes the same hop bundle (§VII.A), so
        // the two stay within ±2× everywhere — and both flatten at 7 nm
        // for the same physical reason (wire-dominated loads).
        let t = fig8(None, 1000);
        for row in &t.rows {
            let ratio: f64 = row[3].parse().unwrap();
            assert!((0.5..2.0).contains(&ratio), "row {row:?}");
        }
    }

    #[test]
    fn fig9_rows_and_positive() {
        let t = fig9(None, 1000);
        assert_eq!(t.rows.len(), NODES.len());
        for row in &t.rows {
            assert!(row[1].parse::<f64>().unwrap() > 0.0);
            assert!(row[2].parse::<f64>().unwrap() > 0.0);
        }
    }

    #[test]
    fn fig10_laser_constant_dac_flat() {
        let t = fig10(None, 1000);
        let lasers: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
        let spread = lasers.iter().cloned().fold(f64::MIN, f64::max)
            - lasers.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-6, "laser pJ/MAC must be node-constant");
        // DAC at 45 vs 7 nm nearly flat (paper §VII.C).
        let idx45 = NODES.iter().position(|n| n.nm == 45.0).unwrap();
        let dac45: f64 = t.rows[idx45][1].parse().unwrap();
        let dac7: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!(dac7 / dac45 > 0.6, "{dac7} / {dac45}");
    }

    #[test]
    fn fig10_vgg19_higher_sram_than_yolo() {
        // §VII.C: "a network with a much larger arithmetic intensity as
        // in the case of VGG19 presents a higher SRAM energy per MAC" —
        // the finite-SLM placement artifact.
        let tv = fig10(Some("VGG19"), 1000);
        let ty = fig10(Some("YOLOv3"), 1000);
        let idx45 = NODES.iter().position(|n| n.nm == 45.0).unwrap();
        let sram_v: f64 = tv.rows[idx45][3].parse().unwrap();
        let sram_y: f64 = ty.rows[idx45][3].parse().unwrap();
        assert!(sram_v > sram_y, "VGG19 {sram_v} !> YOLOv3 {sram_y}");
    }

    #[test]
    fn median_layer_is_a_layer_of_the_net() {
        let net = crate::networks::vgg::vgg16(1000);
        let l = median_layer(&net);
        assert!(net.layers.contains(&l));
    }
}

#[cfg(test)]
mod crossval_tests {
    use super::*;

    #[test]
    fn crossval_has_all_four_machines() {
        let t = crossval(None, 1000);
        assert_eq!(t.headers.len(), 5);
        assert_eq!(t.rows.len(), NODES.len());
        // At 32 nm the cycle-accurate ordering of Fig. 6 holds:
        // systolic < {ReRAM, photonic} < optical 4F.
        let idx = NODES.iter().position(|n| n.nm == 32.0).unwrap();
        let vals: Vec<f64> = t.rows[idx][1..].iter().map(|c| c.parse().unwrap()).collect();
        let (sys, rr, ph, o4f) = (vals[0], vals[1], vals[2], vals[3]);
        assert!(rr > sys, "ReRAM {rr} !> systolic {sys}");
        assert!(ph > sys, "photonic {ph} !> systolic {sys}");
        assert!(o4f > rr && o4f > ph, "4F must top the chart");
    }
}
