//! The declarative evaluation pipeline: **`Scenario` → `Dataset` → sink**.
//!
//! A [`Scenario`] is a typed experiment spec — machines (cycle-accurate
//! *and* analytic, via [`crate::simulator::AnalyticMachine`]) × networks
//! × technology nodes (× optionally bit widths, via [`Scenario::bits`],
//! which crosses every node with every `(bits_x, bits_w)` pair
//! bits-minor) × derived columns — with one of four row axes.
//! One engine ([`Scenario::eval`]) evaluates every scenario the same
//! way: the (machine × network × operating point) grid is prefetched
//! through a shared [`Pool`] into a shared [`SweepCache`] (so repeated
//! layer shapes simulate once, across *all* scenarios of a CLI
//! invocation),
//! then rows are assembled in parallel and returned as a typed
//! [`Dataset`] — named columns of [`Value::Num`]/[`Value::Text`] cells,
//! not pre-formatted strings.
//!
//! Sinks are pluggable and render-only:
//!
//! * [`Dataset::to_table`] / [`Dataset::render`] — aligned text, byte-
//!   identical to the pre-scenario hand-rolled drivers (golden-pinned in
//!   `tests/scenario_golden.rs`);
//! * [`Dataset::to_csv`] — RFC-4180 CSV;
//! * [`Dataset::to_json`] — a [`Json`] object carrying the title, column
//!   names and raw (full-precision) cell values.
//!
//! Formatting lives in the column spec as a [`NumFmt`], so the text/CSV
//! sinks reproduce the paper's printed precision while the JSON sink
//! keeps every bit of the underlying `f64`.

use std::collections::HashSet;

use crate::networks::{ConvLayer, Network};
use crate::simulator::{Machine, NoiseModel, OpKey, OperatingPoint, SimResult, SweepCache};
use crate::util::json::Json;
use crate::util::pool::Pool;
use crate::util::table::{sci, Table};

/// One typed cell of a [`Dataset`].
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A number, rendered by the column's [`NumFmt`] in text/CSV sinks
    /// and at full precision in the JSON sink.
    Num(f64),
    /// Free text, rendered verbatim by every sink (used for labels and
    /// the occasional pre-formatted footer cell).
    Text(String),
}

impl Value {
    pub fn text(s: impl Into<String>) -> Value {
        Value::Text(s.into())
    }

    /// Render for the text/CSV sinks.
    pub fn render(&self, fmt: NumFmt) -> String {
        match self {
            Value::Text(s) => s.clone(),
            Value::Num(v) => match fmt {
                NumFmt::Fixed(p) => format!("{:.*}", p, v),
                NumFmt::Sci => sci(*v),
                NumFmt::Display => format!("{v}"),
            },
        }
    }

    /// Convert for the JSON sink (non-finite numbers become `null`).
    pub fn to_json(&self) -> Json {
        match self {
            Value::Num(v) => Json::Num(*v),
            Value::Text(s) => Json::Str(s.clone()),
        }
    }
}

/// Per-column number formatting for the text/CSV sinks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumFmt {
    /// `format!("{:.p}")` — fixed decimals (the paper's table style).
    Fixed(usize),
    /// [`sci`] — `1.6e7`-style engineering notation.
    Sci,
    /// `format!("{}")` — shortest round-trip.
    Display,
}

/// The evaluated result of a scenario: a titled, typed column store.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub title: String,
    pub columns: Vec<String>,
    /// One [`NumFmt`] per column (parallel to `columns`).
    pub fmts: Vec<NumFmt>,
    /// Row-major cells; every row is `columns.len()` wide.
    pub rows: Vec<Vec<Value>>,
}

impl Dataset {
    /// Format every cell by its column's [`NumFmt`] into an aligned-text
    /// [`Table`].
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &self.title,
            &self.columns.iter().map(|c| c.as_str()).collect::<Vec<_>>(),
        );
        for row in &self.rows {
            t.row(
                row.iter()
                    .zip(&self.fmts)
                    .map(|(v, &f)| v.render(f))
                    .collect(),
            );
        }
        t
    }

    /// Aligned-text sink.
    pub fn render(&self) -> String {
        self.to_table().render()
    }

    /// CSV sink (RFC-4180; see [`Table::to_csv`] for why the title is
    /// not embedded).
    pub fn to_csv(&self) -> String {
        self.to_table().to_csv()
    }

    /// JSON sink: `{"title": …, "columns": […], "rows": [[…], …]}` with
    /// raw numeric cells.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("title".to_string(), Json::Str(self.title.clone())),
            (
                "columns".to_string(),
                Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
            ),
            (
                "rows".to_string(),
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(Value::to_json).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Output format selector for the CLI sinks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputFormat {
    Text,
    Csv,
    Json,
}

impl OutputFormat {
    pub fn parse(s: &str) -> Option<OutputFormat> {
        match s.to_ascii_lowercase().as_str() {
            "text" | "txt" => Some(OutputFormat::Text),
            "csv" => Some(OutputFormat::Csv),
            "json" => Some(OutputFormat::Json),
            _ => None,
        }
    }
}

/// What one table row ranges over.
#[derive(Clone, Debug)]
enum RowAxis {
    /// One row per operating point — technology node, crossed bits-minor
    /// with the scenario's bit widths when [`Scenario::bits`] was set
    /// (the scenario's first network is the row's network).
    Nodes,
    /// One row per network (the scenario's first operating point, if
    /// any, is the row's point).
    Networks,
    /// Network-major × operating-point-minor cross product (the `sweep`
    /// grid).
    NetworkNode,
    /// `n` free-form rows addressed by [`RowCtx::index`] (static tables
    /// like Table IV, or per-processor rows like Fig. 7).
    Items(usize),
}

/// Results of the prefetch phase, keyed by (machine index, network
/// index, operating-point key) — what [`RowCtx::sim`] serves from.
type GridResults = std::collections::HashMap<(usize, usize, OpKey), SimResult>;

/// Everything a column closure may ask about its row. Simulation goes
/// through [`RowCtx::sim`], which serves the evaluation's prefetched
/// grid results directly (bit-identical to a direct simulation — they
/// ARE the cache's in-layer-order merges), so column re-reads neither
/// re-merge layers nor distort the shared cache's hit/miss statistics.
pub struct RowCtx<'a> {
    /// Row number in axis order (also the item index for
    /// `Scenario::items` scenarios).
    pub index: usize,
    net_idx: Option<usize>,
    network: Option<&'a Network>,
    op: Option<OperatingPoint>,
    machines: &'a [Box<dyn Machine>],
    cache: &'a SweepCache,
    grid: &'a GridResults,
}

impl RowCtx<'_> {
    /// The row's network. Panics if the scenario declared none.
    pub fn net(&self) -> &Network {
        self.network.expect("scenario has no network for this row")
    }

    /// The row's operating point. Panics if the scenario declared no
    /// nodes.
    pub fn op(&self) -> OperatingPoint {
        self.op.expect("scenario has no operating point for this row")
    }

    /// The row's technology node in nm. Panics if the scenario declared
    /// none.
    pub fn node(&self) -> f64 {
        self.op().node_nm
    }

    /// The row's bit widths as a `"8x8"`-style label.
    pub fn bits_label(&self) -> String {
        self.op().bits_label()
    }

    /// Simulation result of machine `mi` (index into the scenario's
    /// machine list) on the row's (network, operating point): served
    /// from the prefetched grid, falling back to the shared cache for
    /// any combination the prefetch didn't cover (e.g. an `items` axis).
    pub fn sim(&self, mi: usize) -> SimResult {
        if let (Some(ni), Some(op)) = (self.net_idx, self.op) {
            if let Some(r) = self.grid.get(&(mi, ni, op.key())) {
                return r.clone();
            }
        }
        let op = self.op();
        self.cache
            .simulate_network(self.machines[mi].as_ref(), self.net(), &op)
    }
}

type CellFn = dyn Fn(&RowCtx) -> Value + Send + Sync;

struct ColumnSpec {
    name: String,
    fmt: NumFmt,
    cell: Box<CellFn>,
}

/// Shared evaluation resources: every scenario of a CLI invocation (or
/// an `aimc all` run) evaluates through ONE pool and ONE cache, so
/// layer shapes repeated across figures simulate exactly once.
pub struct EvalCtx<'a> {
    pub pool: &'a Pool,
    pub cache: &'a SweepCache,
}

/// A declarative experiment spec. See the module docs for the model;
/// see `report::figures` / `report::tables` for every paper artifact
/// expressed as one.
pub struct Scenario {
    title: String,
    machines: Vec<Box<dyn Machine>>,
    networks: Vec<Network>,
    nodes: Vec<f64>,
    /// `(bits_x, bits_w)` pairs crossed bits-minor with `nodes`. Empty
    /// means default precision (8×8, noiseless) — the pre-precision
    /// behaviour every golden test pins.
    bits: Vec<(u32, u32)>,
    /// Noise/fault models crossed noise-innermost with nodes × bits.
    /// Empty means the noiseless ideal device — the pre-fault behaviour
    /// every golden test pins.
    noises: Vec<NoiseModel>,
    axis: RowAxis,
    columns: Vec<ColumnSpec>,
}

impl Scenario {
    pub fn new(title: impl Into<String>) -> Scenario {
        Scenario {
            title: title.into(),
            machines: Vec::new(),
            networks: Vec::new(),
            nodes: Vec::new(),
            bits: Vec::new(),
            noises: Vec::new(),
            axis: RowAxis::Items(0),
            columns: Vec::new(),
        }
    }

    // ---- grid builders ---------------------------------------------------

    pub fn machine(mut self, m: Box<dyn Machine>) -> Self {
        self.machines.push(m);
        self
    }

    pub fn machines(mut self, ms: Vec<Box<dyn Machine>>) -> Self {
        self.machines.extend(ms);
        self
    }

    pub fn network(mut self, n: Network) -> Self {
        self.networks.push(n);
        self
    }

    pub fn networks(mut self, ns: Vec<Network>) -> Self {
        self.networks.extend(ns);
        self
    }

    pub fn nodes(mut self, nodes: &[f64]) -> Self {
        self.nodes.extend_from_slice(nodes);
        self
    }

    /// The full technology ladder of [`crate::technode::NODES`].
    pub fn node_ladder(self) -> Self {
        let ladder: Vec<f64> = crate::technode::NODES.iter().map(|n| n.nm).collect();
        self.nodes(&ladder)
    }

    /// Cross every node with these `(bits_x, bits_w)` pairs, bits-minor:
    /// each node's rows appear consecutively, one per pair. Leaving this
    /// unset evaluates at default precision (8×8, noiseless) exactly as
    /// before the precision axis existed.
    pub fn bits(mut self, bits: &[(u32, u32)]) -> Self {
        self.bits.extend_from_slice(bits);
        self
    }

    /// Cross every (node × bits) point with these noise/fault models,
    /// noise-innermost: each (node, bits) pair's rows appear
    /// consecutively, one per model. Leaving this unset evaluates the
    /// noiseless ideal device exactly as before the fault axis existed.
    pub fn noise_models(mut self, noises: &[NoiseModel]) -> Self {
        self.noises.extend_from_slice(noises);
        self
    }

    // ---- row axis --------------------------------------------------------

    pub fn over_nodes(mut self) -> Self {
        self.axis = RowAxis::Nodes;
        self
    }

    pub fn over_networks(mut self) -> Self {
        self.axis = RowAxis::Networks;
        self
    }

    pub fn over_network_nodes(mut self) -> Self {
        self.axis = RowAxis::NetworkNode;
        self
    }

    pub fn items(mut self, n: usize) -> Self {
        self.axis = RowAxis::Items(n);
        self
    }

    // ---- columns ---------------------------------------------------------

    /// The general column: any [`NumFmt`], any [`Value`].
    pub fn column<F>(mut self, name: &str, fmt: NumFmt, cell: F) -> Self
    where
        F: Fn(&RowCtx) -> Value + Send + Sync + 'static,
    {
        self.columns.push(ColumnSpec {
            name: name.to_string(),
            fmt,
            cell: Box::new(cell),
        });
        self
    }

    /// Numeric column with fixed decimals.
    pub fn num<F>(self, name: &str, decimals: usize, f: F) -> Self
    where
        F: Fn(&RowCtx) -> f64 + Send + Sync + 'static,
    {
        self.column(name, NumFmt::Fixed(decimals), move |c: &RowCtx| {
            Value::Num(f(c))
        })
    }

    /// Numeric column in `1.6e7`-style engineering notation.
    pub fn sci<F>(self, name: &str, f: F) -> Self
    where
        F: Fn(&RowCtx) -> f64 + Send + Sync + 'static,
    {
        self.column(name, NumFmt::Sci, move |c: &RowCtx| Value::Num(f(c)))
    }

    /// Text column.
    pub fn text<F>(self, name: &str, f: F) -> Self
    where
        F: Fn(&RowCtx) -> String + Send + Sync + 'static,
    {
        self.column(name, NumFmt::Display, move |c: &RowCtx| Value::Text(f(c)))
    }

    // ---- introspection ---------------------------------------------------

    pub fn title(&self) -> &str {
        &self.title
    }

    /// The scenario's operating points: nodes crossed bits-minor with
    /// the `bits` pairs (plain default precision when no bits were
    /// set), then noise-innermost with the `noises` models (noiseless
    /// when none were set).
    fn operating_points(&self) -> Vec<OperatingPoint> {
        let base: Vec<OperatingPoint> = if self.bits.is_empty() {
            self.nodes.iter().map(|&nm| OperatingPoint::node(nm)).collect()
        } else {
            let mut out = Vec::with_capacity(self.nodes.len() * self.bits.len());
            for &nm in &self.nodes {
                for &(bx, bw) in &self.bits {
                    out.push(OperatingPoint::node(nm).bits(bx, bw));
                }
            }
            out
        };
        if self.noises.is_empty() {
            base
        } else {
            let mut out = Vec::with_capacity(base.len() * self.noises.len());
            for op in base {
                for &noise in &self.noises {
                    out.push(op.with_noise(noise));
                }
            }
            out
        }
    }

    /// Operating points per node (≥ 1; the bits × noise multiplier).
    fn bits_arity(&self) -> usize {
        self.bits.len().max(1) * self.noises.len().max(1)
    }

    /// Rows this scenario will produce.
    pub fn row_count(&self) -> usize {
        match self.axis {
            RowAxis::Nodes => self.nodes.len() * self.bits_arity(),
            RowAxis::Networks => self.networks.len(),
            RowAxis::NetworkNode => {
                self.networks.len() * self.nodes.len() * self.bits_arity()
            }
            RowAxis::Items(n) => n,
        }
    }

    /// (machine × network × operating point) simulation grid points
    /// behind this scenario (0 for purely derived scenarios).
    pub fn grid_points(&self) -> usize {
        self.machines.len()
            * self.networks.len().max(1)
            * (self.nodes.len() * self.bits_arity()).max(1)
    }

    // ---- evaluation ------------------------------------------------------

    /// One row descriptor per axis position: (index, network index,
    /// operating point).
    fn row_specs(&self) -> Vec<(usize, Option<usize>, Option<OperatingPoint>)> {
        let first_net = if self.networks.is_empty() { None } else { Some(0) };
        let ops = self.operating_points();
        let first_op = ops.first().copied();
        match self.axis {
            RowAxis::Nodes => ops
                .iter()
                .enumerate()
                .map(|(i, &op)| (i, first_net, Some(op)))
                .collect(),
            RowAxis::Networks => (0..self.networks.len())
                .map(|i| (i, Some(i), first_op))
                .collect(),
            RowAxis::NetworkNode => {
                let mut out = Vec::with_capacity(self.networks.len() * ops.len());
                let mut index = 0;
                for ni in 0..self.networks.len() {
                    for &op in &ops {
                        out.push((index, Some(ni), Some(op)));
                        index += 1;
                    }
                }
                out
            }
            RowAxis::Items(n) => (0..n).map(|i| (i, first_net, first_op)).collect(),
        }
    }

    /// Evaluate through the shared pool + cache into a typed [`Dataset`].
    ///
    /// Two parallel phases: (1) prefetch — the unique (machine, layer,
    /// node) jobs behind every grid point a row could touch fan out
    /// across the pool first (so one huge network, or a grid skewed
    /// toward a few networks × many nodes, spreads over all workers
    /// instead of serializing inside grid points), then the (machine,
    /// network, node) merges — now pure cache hits — are kept; (2)
    /// assembly —
    /// rows are built in parallel, their column closures served from
    /// the kept grid results, so a column reading the same point twice
    /// costs a map lookup, not a re-merge, and the cache's hit/miss
    /// counters keep measuring layer dedup only. Rows come back in axis
    /// order regardless of worker scheduling ([`Pool::par_map`] is
    /// order-preserving), so rendered output is deterministic.
    pub fn eval(&self, ctx: &EvalCtx) -> Dataset {
        let specs = self.row_specs();
        let mut grid = GridResults::new();
        if !self.machines.is_empty() {
            let mut seen = HashSet::new();
            let mut points: Vec<(usize, usize, OperatingPoint)> = Vec::new();
            for &(_, ni, op) in &specs {
                if let (Some(ni), Some(op)) = (ni, op) {
                    if seen.insert((ni, op.key())) {
                        for mi in 0..self.machines.len() {
                            points.push((mi, ni, op));
                        }
                    }
                }
            }
            // Per-layer fan-out: warm the shared cache over the unique
            // (machine, layer, operating point) jobs of the whole grid
            // in one pool pass. Layer results are keyed deterministically
            // in the cache, so the merges below are bit-identical to a
            // cold serial evaluation (golden-pinned in
            // scenario_golden.rs) — only the parallel grain changes.
            let mut layer_seen = HashSet::new();
            let mut layer_jobs: Vec<(usize, ConvLayer, OperatingPoint)> = Vec::new();
            for &(mi, ni, op) in &points {
                for layer in &self.networks[ni].layers {
                    if layer_seen.insert((mi, *layer, op.key())) {
                        layer_jobs.push((mi, *layer, op));
                    }
                }
            }
            ctx.pool.par_for_each(&layer_jobs, |&(mi, ref layer, op)| {
                ctx.cache.simulate_layer(self.machines[mi].as_ref(), layer, &op);
            });
            let results = ctx.pool.par_map(&points, |&(mi, ni, op)| {
                ctx.cache
                    .simulate_network(self.machines[mi].as_ref(), &self.networks[ni], &op)
            });
            for (&(mi, ni, op), r) in points.iter().zip(results) {
                grid.insert((mi, ni, op.key()), r);
            }
        }
        let grid = &grid;
        let rows = ctx.pool.par_map(&specs, |&(index, ni, op)| {
            let rc = RowCtx {
                index,
                net_idx: ni,
                network: ni.map(|i| &self.networks[i]),
                op,
                machines: &self.machines,
                cache: ctx.cache,
                grid,
            };
            self.columns
                .iter()
                .map(|c| (c.cell)(&rc))
                .collect::<Vec<Value>>()
        });
        Dataset {
            title: self.title.clone(),
            columns: self.columns.iter().map(|c| c.name.clone()).collect(),
            fmts: self.columns.iter().map(|c| c.fmt).collect(),
            rows,
        }
    }

    /// [`Scenario::eval`] with a throwaway pool + cache — convenience
    /// for tests and one-off calls.
    pub fn dataset(&self) -> Dataset {
        let pool = Pool::auto();
        let cache = SweepCache::new();
        self.eval(&EvalCtx {
            pool: &pool,
            cache: &cache,
        })
    }

    /// Evaluate and format as an aligned-text [`Table`].
    pub fn table(&self) -> Table {
        self.dataset().to_table()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;
    use crate::simulator::machine::all_machines;
    use crate::simulator::systolic;

    #[test]
    fn value_rendering_matches_legacy_formats() {
        assert_eq!(Value::Num(45.0).render(NumFmt::Fixed(0)), "45");
        assert_eq!(Value::Num(3.14159).render(NumFmt::Fixed(3)), "3.142");
        assert_eq!(Value::Num(1.6e7).render(NumFmt::Sci), sci(1.6e7));
        assert_eq!(Value::Num(4.3).render(NumFmt::Display), "4.3");
        assert_eq!(
            Value::text("label").render(NumFmt::Fixed(4)),
            "label",
            "text ignores the numeric format"
        );
    }

    #[test]
    fn axis_row_counts() {
        let nodes = [45.0, 28.0, 7.0];
        let s = Scenario::new("t")
            .network(yolov3(100))
            .network(yolov3(120))
            .nodes(&nodes);
        assert_eq!(s.row_count(), 0, "default Items(0)");
        let s = s.over_network_nodes();
        assert_eq!(s.row_count(), 6);
        assert_eq!(Scenario::new("t").nodes(&nodes).over_nodes().row_count(), 3);
        assert_eq!(Scenario::new("t").items(7).row_count(), 7);
    }

    #[test]
    fn eval_assembles_rows_in_axis_order() {
        let s = Scenario::new("order")
            .nodes(&[45.0, 28.0, 7.0])
            .over_nodes()
            .num("node (nm)", 0, |c: &RowCtx| c.node())
            .num("idx", 0, |c: &RowCtx| c.index as f64);
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 3);
        assert_eq!(ds.rows[0], vec![Value::Num(45.0), Value::Num(0.0)]);
        assert_eq!(ds.rows[2], vec![Value::Num(7.0), Value::Num(2.0)]);
        let t = ds.to_table();
        assert_eq!(t.rows[1], vec!["28".to_string(), "1".to_string()]);
    }

    #[test]
    fn sim_columns_match_direct_simulation_bit_for_bit() {
        let net = yolov3(200);
        let cfg = systolic::SystolicConfig::default();
        let direct = systolic::simulate_network(&cfg, &net, &OperatingPoint::node(45.0));
        let s = Scenario::new("sim")
            .machine(Box::new(cfg))
            .network(net)
            .nodes(&[45.0])
            .over_nodes()
            .num("eta", 12, |c: &RowCtx| c.sim(0).tops_per_watt());
        let ds = s.dataset();
        match &ds.rows[0][0] {
            Value::Num(v) => assert_eq!(*v, direct.tops_per_watt()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shared_cache_dedups_across_scenarios() {
        let pool = Pool::new(2);
        let cache = SweepCache::new();
        let ctx = EvalCtx {
            pool: &pool,
            cache: &cache,
        };
        let mk = |title: &str| {
            Scenario::new(title)
                .machines(all_machines())
                .network(yolov3(200))
                .nodes(&[45.0, 7.0])
                .over_nodes()
                .num("eta", 3, |c: &RowCtx| c.sim(0).tops_per_watt())
        };
        let _ = mk("first").eval(&ctx);
        let misses_after_first = cache.misses();
        let _ = mk("second").eval(&ctx);
        assert_eq!(
            cache.misses(),
            misses_after_first,
            "second scenario must be pure cache hits"
        );
    }

    #[test]
    fn dataset_json_sink_parses_and_keeps_types() {
        let s = Scenario::new("json, \"quoted\" title")
            .items(2)
            .text("label", |c: &RowCtx| format!("row{}", c.index))
            .num("value", 3, |c: &RowCtx| c.index as f64 + 0.5);
        let ds = s.dataset();
        let parsed = Json::parse(&ds.to_json().pretty()).unwrap();
        match parsed {
            Json::Obj(fields) => {
                assert_eq!(fields[0].0, "title");
                assert_eq!(fields[0].1, Json::Str("json, \"quoted\" title".into()));
                match &fields[2].1 {
                    Json::Arr(rows) => {
                        assert_eq!(rows.len(), 2);
                        match &rows[1] {
                            Json::Arr(cells) => {
                                assert_eq!(cells[0], Json::Str("row1".into()));
                                assert_eq!(cells[1], Json::Num(1.5));
                            }
                            other => panic!("{other:?}"),
                        }
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bits_axis_crosses_nodes_bits_minor() {
        let s = Scenario::new("bits")
            .machine(Box::new(systolic::SystolicConfig::default()))
            .network(yolov3(100))
            .nodes(&[45.0, 7.0])
            .bits(&[(8, 8), (4, 4)])
            .over_nodes()
            .num("node (nm)", 0, |c: &RowCtx| c.node())
            .text("bits", |c: &RowCtx| c.bits_label())
            .sci("J/inf", |c: &RowCtx| c.sim(0).ledger.total());
        assert_eq!(s.row_count(), 4);
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 4);
        // Bits-minor: 45/8x8, 45/4x4, 7/8x8, 7/4x4.
        assert_eq!(ds.rows[0][0], Value::Num(45.0));
        assert_eq!(ds.rows[0][1], Value::text("8x8"));
        assert_eq!(ds.rows[1][1], Value::text("4x4"));
        assert_eq!(ds.rows[2][0], Value::Num(7.0));
        // Lower precision prices below 8×8 at the same node.
        let (Value::Num(e8), Value::Num(e4)) = (&ds.rows[0][2], &ds.rows[1][2]) else {
            panic!("numeric cells expected");
        };
        assert!(e4 < e8);
    }

    #[test]
    fn noise_axis_crosses_innermost() {
        use crate::simulator::FaultModel;
        let noises: Vec<NoiseModel> = [0.0, 0.05]
            .iter()
            .map(|&r| NoiseModel {
                faults: FaultModel::at_rate(r),
                ..Default::default()
            })
            .collect();
        let s = Scenario::new("faults")
            .machine(Box::new(systolic::SystolicConfig::default()))
            .network(yolov3(100))
            .nodes(&[45.0, 7.0])
            .noise_models(&noises)
            .over_nodes()
            .num("node (nm)", 0, |c: &RowCtx| c.node())
            .num("stuck", 3, |c: &RowCtx| c.op().noise.faults.stuck_rate)
            .sci("J/inf", |c: &RowCtx| c.sim(0).ledger.total());
        assert_eq!(s.row_count(), 4);
        let ds = s.dataset();
        // Noise-innermost: 45/clean, 45/faulty, 7/clean, 7/faulty.
        assert_eq!(ds.rows[0][0], Value::Num(45.0));
        assert_eq!(ds.rows[0][1], Value::Num(0.0));
        assert_eq!(ds.rows[1][1], Value::Num(0.05));
        assert_eq!(ds.rows[2][0], Value::Num(7.0));
        // Injected faults surcharge energy at the same node.
        let (Value::Num(clean), Value::Num(faulty)) = (&ds.rows[0][2], &ds.rows[1][2])
        else {
            panic!("numeric cells expected");
        };
        assert!(faulty > clean);
    }

    #[test]
    fn default_precision_rows_unchanged_without_bits() {
        // No `.bits(…)` call ⇒ identical row structure and values to the
        // pre-precision engine (the golden tests pin full outputs; this
        // pins the engine-level equivalence directly).
        let net = yolov3(100);
        let cfg = systolic::SystolicConfig::default();
        let direct = systolic::simulate_network(&cfg, &net, &OperatingPoint::node(45.0));
        let s = Scenario::new("plain")
            .machine(Box::new(cfg))
            .network(net)
            .nodes(&[45.0])
            .over_nodes()
            .sci("J/inf", |c: &RowCtx| c.sim(0).ledger.total());
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 1);
        match &ds.rows[0][0] {
            Value::Num(v) => assert_eq!(v.to_bits(), direct.ledger.total().to_bits()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn output_format_parses() {
        assert_eq!(OutputFormat::parse("text"), Some(OutputFormat::Text));
        assert_eq!(OutputFormat::parse("CSV"), Some(OutputFormat::Csv));
        assert_eq!(OutputFormat::parse("json"), Some(OutputFormat::Json));
        assert_eq!(OutputFormat::parse("yaml"), None);
    }
}
