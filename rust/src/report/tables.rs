//! Tables I–IV (plus VI/VII footers) as [`Scenario`]s.
//!
//! Tables I–III range over the Table I zoo (one row per network) with
//! every column derived from [`crate::networks::stats`]; Table IV is a
//! static item scenario whose typed rows are computed once from the
//! Appendix-A energy models. Rendered text is byte-identical to the
//! pre-scenario drivers (pinned in `tests/scenario_golden.rs`).

use std::sync::Arc;

use crate::energy::{
    constants,
    converter::{adc_energy, dac_energy},
    load::presets,
    logic::mac_energy,
    optical::{gamma_opt, optical_energy},
    reram::ReramArray,
    sram,
};
use crate::networks::{stats, zoo, Network};
use crate::report::scenario::{NumFmt, RowCtx, Scenario, Value};

/// Paper-printed Table I rows (for the comparison column):
/// (name, layers, median n, median Cᵢ, max N, avg k, total K, median Cᵢ₊₁, median a).
pub const PAPER_TABLE1: &[(&str, usize, f64, f64, f64, f64, f64, f64, f64)] = &[
    ("DenseNet201", 200, 62.0, 128.0, 1.6e7, 2.0, 1.8e7, 128.0, 292.0),
    ("GoogLeNet", 59, 61.0, 480.0, 3.9e6, 2.1, 6.1e6, 128.0, 200.0),
    ("InceptionResNetV2", 244, 60.0, 320.0, 8.0e6, 1.9, 8.0e7, 192.0, 291.0),
    ("InceptionV3", 94, 60.0, 192.0, 8.0e6, 2.4, 3.7e7, 192.0, 295.0),
    ("ResNet152", 155, 63.0, 256.0, 1.6e7, 1.7, 5.8e7, 256.0, 390.0),
    ("VGG16", 13, 249.0, 256.0, 6.4e7, 3.0, 1.5e7, 256.0, 2262.0),
    ("VGG19", 16, 186.0, 256.0, 6.4e7, 3.0, 2.0e7, 384.0, 2527.0),
    ("YOLOv3", 75, 62.0, 256.0, 3.2e7, 2.0, 6.2e7, 256.0, 504.0),
];

fn paper1(name: &str) -> Option<&'static (&'static str, usize, f64, f64, f64, f64, f64, f64, f64)> {
    PAPER_TABLE1.iter().find(|r| r.0 == name)
}

/// Table I: conv-layer statistics of the eight networks (ours vs paper).
///
/// The per-network stats row is computed ONCE here (it sorts the layer
/// population for its medians); the column closures only address it by
/// row index — on the `over_networks` axis, row index == network index.
pub fn table1(input: usize) -> Scenario {
    let nets = zoo(input);
    let rows: Arc<Vec<stats::Table1Row>> =
        Arc::new(nets.iter().map(stats::table1_row).collect());
    let col = |rows: &Arc<Vec<stats::Table1Row>>, f: fn(&stats::Table1Row) -> f64| {
        let rows = rows.clone();
        move |c: &RowCtx| f(&rows[c.index])
    };
    Scenario::new("Table I — conv-layer statistics (1 Mpx input; ours / paper)")
        .networks(nets)
        .over_networks()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("layers", 0, col(&rows, |r| r.num_layers as f64))
        .num("med n", 0, col(&rows, |r| r.median_n))
        .num("med Ci", 0, col(&rows, |r| r.median_ci))
        .sci("max N", col(&rows, |r| r.max_input))
        .num("avg k", 1, col(&rows, |r| r.avg_k))
        .sci("total K", col(&rows, |r| r.total_weights))
        .num("med Ci+1", 0, col(&rows, |r| r.median_co))
        .num("med a", 0, col(&rows, |r| r.median_a))
        .num("paper a", 0, |c: &RowCtx| {
            paper1(c.net().name).map(|p| p.8).unwrap_or(f64::NAN)
        })
}

/// Paper Table II rows: (name, L′, N′, M′).
pub const PAPER_TABLE2: &[(&str, f64, f64, f64)] = &[
    ("DenseNet201", 3844.0, 1152.0, 128.0),
    ("GoogLeNet", 3721.0, 528.0, 128.0),
    ("InceptionResNetV2", 3600.0, 432.0, 192.0),
    ("InceptionV3", 3600.0, 768.0, 192.0),
    ("ResNet152", 3969.0, 1024.0, 256.0),
    ("VGG16", 62001.0, 2304.0, 256.0),
    ("VGG19", 38688.0, 2304.0, 384.0),
    ("YOLOv3", 3844.0, 1024.0, 256.0),
];

fn paper2(name: &str) -> (f64, f64, f64) {
    PAPER_TABLE2
        .iter()
        .find(|p| p.0 == name)
        .map(|p| (p.1, p.2, p.3))
        .unwrap_or((f64::NAN, f64::NAN, f64::NAN))
}

/// Table II: median conv-as-matmul dimensions (eq. 16).
pub fn table2(input: usize) -> Scenario {
    let nets = zoo(input);
    let rows: Arc<Vec<stats::Table2Row>> =
        Arc::new(nets.iter().map(stats::table2_row).collect());
    let col = |rows: &Arc<Vec<stats::Table2Row>>, f: fn(&stats::Table2Row) -> f64| {
        let rows = rows.clone();
        move |c: &RowCtx| f(&rows[c.index])
    };
    Scenario::new("Table II — median matmul dims (eq. 16; ours / paper)")
        .networks(nets)
        .over_networks()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("layers", 0, col(&rows, |r| r.num_layers as f64))
        .num("L'", 0, col(&rows, |r| r.median_l))
        .num("N'", 0, col(&rows, |r| r.median_n))
        .num("M'", 0, col(&rows, |r| r.median_m))
        .num("paper L'", 0, |c: &RowCtx| paper2(c.net().name).0)
        .num("paper N'", 0, |c: &RowCtx| paper2(c.net().name).1)
        .num("paper M'", 0, |c: &RowCtx| paper2(c.net().name).2)
}

/// Paper Table III rows: (name, L, N, M) at C′ → ∞.
pub const PAPER_TABLE3: &[(&str, f64, f64, f64)] = &[
    ("DenseNet201", 3844.0, 272.0, 136.0),
    ("GoogLeNet", 3721.0, 128.0, 64.0),
    ("InceptionResNetV2", 3600.0, 224.0, 112.0),
    ("InceptionV3", 3600.0, 240.0, 120.0),
    ("ResNet152", 3969.0, 1024.0, 512.0),
    ("VGG16", 62001.0, 2304.0, 1152.0),
    ("VGG19", 38688.0, 3456.0, 1728.0),
    ("YOLOv3", 3844.0, 512.0, 256.0),
];

fn paper3(name: &str) -> (f64, f64, f64) {
    PAPER_TABLE3
        .iter()
        .find(|p| p.0 == name)
        .map(|p| (p.1, p.2, p.3))
        .unwrap_or((f64::NAN, f64::NAN, f64::NAN))
}

/// Table III: median optical-4F amortization dims (eq. 23, infinite SLM).
pub fn table3(input: usize) -> Scenario {
    let nets = zoo(input);
    let rows: Arc<Vec<stats::Table3Row>> =
        Arc::new(nets.iter().map(|n| stats::table3_row(n, None)).collect());
    let col = |rows: &Arc<Vec<stats::Table3Row>>, f: fn(&stats::Table3Row) -> f64| {
        let rows = rows.clone();
        move |c: &RowCtx| f(&rows[c.index])
    };
    Scenario::new("Table III — median optical-4F dims (eq. 23, C'→∞; ours / paper)")
        .networks(nets)
        .over_networks()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("layers", 0, col(&rows, |r| r.num_layers as f64))
        .num("L", 0, col(&rows, |r| r.median_l))
        .num("N", 0, col(&rows, |r| r.median_n))
        .num("M", 0, col(&rows, |r| r.median_m))
        .num("paper L", 0, |c: &RowCtx| paper3(c.net().name).0)
        .num("paper N", 0, |c: &RowCtx| paper3(c.net().name).1)
        .num("paper M", 0, |c: &RowCtx| paper3(c.net().name).2)
}

/// Table IV (with Tables VI and VII as footer rows): energies per
/// operation at 45 nm, 0.9 V, 8 bit — ours vs the paper's printed
/// values. A static item scenario: the typed rows are computed once
/// here; the column specs only address them.
pub fn table4() -> Scenario {
    let arr = ReramArray::default();
    let mut rows: Vec<(String, Value, Value)> = Vec::new();
    let mut row = |name: &str, ours_j: f64, paper_pj: f64| {
        rows.push((
            name.to_string(),
            Value::Num(ours_j * 1e12),
            Value::Num(paper_pj),
        ));
    };
    row(
        "e_m (96kB SRAM, per byte)",
        sram::energy_per_byte_45nm(96 * 1024),
        4.3,
    );
    row("e_mac", mac_energy(constants::GAMMA_MAC_45NM, 8), 0.23);
    row("e_adc", adc_energy(constants::GAMMA_ADC_45NM, 8), 0.25);
    row("e_dac", dac_energy(constants::GAMMA_DAC, 8), 0.01);
    row("e_opt", optical_energy(constants::ETA_OPT, 8), 0.01);
    row("e_load 4um pitch N=256", presets::reram_256().energy(), 0.08);
    row("e_load 250um pitch N=40", presets::photonic_40().energy(), 0.8);
    row("e_load 2.5um pitch N=2048", presets::slm_2048().energy(), 0.04);
    // §A2 ReRAM bound + Table VII γs as footer rows (pre-formatted: the
    // ceiling prints at one decimal, the γs as a compound cell).
    row("e_ReRAM per MAC (A11, 70 mV)", arr.energy_per_mac(), 0.05);
    rows.push((
        "ReRAM ceiling (TOPS/W)".to_string(),
        Value::text(format!("{:.1}", 1.0 / (arr.energy_per_mac() * 1e12))),
        Value::text("20"),
    ));
    rows.push((
        "gamma_mac / adc / dac / opt".to_string(),
        Value::text(format!(
            "{:.0} / {:.0} / {:.0} / {:.0}",
            constants::GAMMA_MAC_45NM,
            constants::GAMMA_ADC_45NM,
            constants::GAMMA_DAC,
            gamma_opt(0.5)
        )),
        Value::text("1.2e5 / 927* / 39 / 105"),
    ));

    let rows = Arc::new(rows);
    let (r1, r2, r3) = (rows.clone(), rows.clone(), rows.clone());
    Scenario::new("Table IV — energy per operation (45 nm, 0.9 V, 8-bit)")
        .items(rows.len())
        .column("quantity", NumFmt::Display, move |c: &RowCtx| {
            Value::Text(r1[c.index].0.clone())
        })
        .column("ours (pJ)", NumFmt::Fixed(4), move |c: &RowCtx| {
            r2[c.index].1.clone()
        })
        .column("paper (pJ)", NumFmt::Display, move |c: &RowCtx| {
            r3[c.index].2.clone()
        })
}

/// Networks helper reused by figures: the Table I zoo plus SmallCNN.
pub fn all_networks(input: usize) -> Vec<Network> {
    let mut v = zoo(input);
    v.push(crate::coordinator::smallcnn_network());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_8_networks_and_10_columns() {
        let t = table1(1000).table();
        assert_eq!(t.rows.len(), 8);
        assert_eq!(t.headers.len(), 10);
    }

    #[test]
    fn table1_ours_close_to_paper_for_vgg() {
        let t = table1(1000).table();
        let vgg = t.rows.iter().find(|r| r[0] == "VGG16").unwrap();
        let ours: f64 = vgg[8].parse().unwrap();
        let paper: f64 = vgg[9].parse().unwrap();
        assert!((ours - paper).abs() / paper < 0.1, "{ours} vs {paper}");
    }

    #[test]
    fn table2_table3_render() {
        let t2 = table2(1000).table();
        let t3 = table3(1000).table();
        assert_eq!(t2.rows.len(), 8);
        assert_eq!(t3.rows.len(), 8);
        assert!(t2.render().contains("VGG19"));
        assert!(t3.render().contains("YOLOv3"));
    }

    #[test]
    fn table4_matches_paper_within_rounding() {
        let t = table4().table();
        for row in &t.rows {
            let (Ok(ours), Ok(paper)) = (row[1].parse::<f64>(), row[2].parse::<f64>()) else {
                continue; // footer rows
            };
            // Paper prints 1-2 significant digits; allow 15%.
            assert!(
                (ours - paper).abs() / paper < 0.15,
                "{}: ours {ours} vs paper {paper}",
                row[0]
            );
        }
    }

    #[test]
    fn csv_export_works() {
        let csv = table1(1000).table().to_csv();
        assert!(csv.lines().count() == 9);
        assert!(csv.starts_with("network,"));
    }
}
