//! Tables I–IV (plus VI/VII footers) as renderable [`Table`]s.
//!
//! Tables I–III compute one row per network; rows are evaluated by
//! [`pool::par_map`] workers and emitted in zoo order, so output is
//! byte-identical to the serial path.

use crate::energy::{
    self, constants,
    converter::{adc_energy, dac_energy},
    load::presets,
    logic::mac_energy,
    optical::{gamma_opt, optical_energy},
    reram::ReramArray,
    sram,
};
use crate::networks::{stats, zoo, Network};
use crate::util::pool;
use crate::util::table::{sci, Table};

/// Paper-printed Table I rows (for the comparison column):
/// (name, layers, median n, median Cᵢ, max N, avg k, total K, median Cᵢ₊₁, median a).
pub const PAPER_TABLE1: &[(&str, usize, f64, f64, f64, f64, f64, f64, f64)] = &[
    ("DenseNet201", 200, 62.0, 128.0, 1.6e7, 2.0, 1.8e7, 128.0, 292.0),
    ("GoogLeNet", 59, 61.0, 480.0, 3.9e6, 2.1, 6.1e6, 128.0, 200.0),
    ("InceptionResNetV2", 244, 60.0, 320.0, 8.0e6, 1.9, 8.0e7, 192.0, 291.0),
    ("InceptionV3", 94, 60.0, 192.0, 8.0e6, 2.4, 3.7e7, 192.0, 295.0),
    ("ResNet152", 155, 63.0, 256.0, 1.6e7, 1.7, 5.8e7, 256.0, 390.0),
    ("VGG16", 13, 249.0, 256.0, 6.4e7, 3.0, 1.5e7, 256.0, 2262.0),
    ("VGG19", 16, 186.0, 256.0, 6.4e7, 3.0, 2.0e7, 384.0, 2527.0),
    ("YOLOv3", 75, 62.0, 256.0, 3.2e7, 2.0, 6.2e7, 256.0, 504.0),
];

fn paper1(name: &str) -> Option<&'static (&'static str, usize, f64, f64, f64, f64, f64, f64, f64)> {
    PAPER_TABLE1.iter().find(|r| r.0 == name)
}

/// Table I: conv-layer statistics of the eight networks (ours vs paper).
pub fn table1(input: usize) -> Table {
    let mut t = Table::new(
        "Table I — conv-layer statistics (1 Mpx input; ours / paper)",
        &[
            "network", "layers", "med n", "med Ci", "max N", "avg k", "total K",
            "med Ci+1", "med a", "paper a",
        ],
    );
    let nets = zoo(input);
    for row in pool::par_map(&nets, |net| {
        let r = stats::table1_row(net);
        let pa = paper1(net.name).map(|p| p.8).unwrap_or(f64::NAN);
        vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_ci),
            sci(r.max_input),
            format!("{:.1}", r.avg_k),
            sci(r.total_weights),
            format!("{:.0}", r.median_co),
            format!("{:.0}", r.median_a),
            format!("{pa:.0}"),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Paper Table II rows: (name, L′, N′, M′).
pub const PAPER_TABLE2: &[(&str, f64, f64, f64)] = &[
    ("DenseNet201", 3844.0, 1152.0, 128.0),
    ("GoogLeNet", 3721.0, 528.0, 128.0),
    ("InceptionResNetV2", 3600.0, 432.0, 192.0),
    ("InceptionV3", 3600.0, 768.0, 192.0),
    ("ResNet152", 3969.0, 1024.0, 256.0),
    ("VGG16", 62001.0, 2304.0, 256.0),
    ("VGG19", 38688.0, 2304.0, 384.0),
    ("YOLOv3", 3844.0, 1024.0, 256.0),
];

/// Table II: median conv-as-matmul dimensions (eq. 16).
pub fn table2(input: usize) -> Table {
    let mut t = Table::new(
        "Table II — median matmul dims (eq. 16; ours / paper)",
        &["network", "layers", "L'", "N'", "M'", "paper L'", "paper N'", "paper M'"],
    );
    let nets = zoo(input);
    for row in pool::par_map(&nets, |net| {
        let r = stats::table2_row(net);
        let p = PAPER_TABLE2
            .iter()
            .find(|p| p.0 == net.name)
            .copied()
            .unwrap_or((net.name, f64::NAN, f64::NAN, f64::NAN));
        vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_l),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_m),
            format!("{:.0}", p.1),
            format!("{:.0}", p.2),
            format!("{:.0}", p.3),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Paper Table III rows: (name, L, N, M) at C′ → ∞.
pub const PAPER_TABLE3: &[(&str, f64, f64, f64)] = &[
    ("DenseNet201", 3844.0, 272.0, 136.0),
    ("GoogLeNet", 3721.0, 128.0, 64.0),
    ("InceptionResNetV2", 3600.0, 224.0, 112.0),
    ("InceptionV3", 3600.0, 240.0, 120.0),
    ("ResNet152", 3969.0, 1024.0, 512.0),
    ("VGG16", 62001.0, 2304.0, 1152.0),
    ("VGG19", 38688.0, 3456.0, 1728.0),
    ("YOLOv3", 3844.0, 512.0, 256.0),
];

/// Table III: median optical-4F amortization dims (eq. 23, infinite SLM).
pub fn table3(input: usize) -> Table {
    let mut t = Table::new(
        "Table III — median optical-4F dims (eq. 23, C'→∞; ours / paper)",
        &["network", "layers", "L", "N", "M", "paper L", "paper N", "paper M"],
    );
    let nets = zoo(input);
    for row in pool::par_map(&nets, |net| {
        let r = stats::table3_row(net, None);
        let p = PAPER_TABLE3
            .iter()
            .find(|p| p.0 == net.name)
            .copied()
            .unwrap_or((net.name, f64::NAN, f64::NAN, f64::NAN));
        vec![
            r.name.to_string(),
            r.num_layers.to_string(),
            format!("{:.0}", r.median_l),
            format!("{:.0}", r.median_n),
            format!("{:.0}", r.median_m),
            format!("{:.0}", p.1),
            format!("{:.0}", p.2),
            format!("{:.0}", p.3),
        ]
    }) {
        t.row(row);
    }
    t
}

/// Table IV (with Tables VI and VII as footer rows): energies per
/// operation at 45 nm, 0.9 V, 8 bit — ours vs the paper's printed values.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV — energy per operation (45 nm, 0.9 V, 8-bit)",
        &["quantity", "ours (pJ)", "paper (pJ)"],
    );
    let mut row = |name: &str, ours_j: f64, paper_pj: f64| {
        t.row(vec![
            name.to_string(),
            format!("{:.4}", ours_j * 1e12),
            format!("{paper_pj}"),
        ]);
    };
    row(
        "e_m (96kB SRAM, per byte)",
        sram::energy_per_byte_45nm(96 * 1024),
        4.3,
    );
    row("e_mac", mac_energy(constants::GAMMA_MAC_45NM, 8), 0.23);
    row("e_adc", adc_energy(constants::GAMMA_ADC_45NM, 8), 0.25);
    row("e_dac", dac_energy(constants::GAMMA_DAC, 8), 0.01);
    row("e_opt", optical_energy(constants::ETA_OPT, 8), 0.01);
    row("e_load 4um pitch N=256", presets::reram_256().energy(), 0.08);
    row("e_load 250um pitch N=40", presets::photonic_40().energy(), 0.8);
    row("e_load 2.5um pitch N=2048", presets::slm_2048().energy(), 0.04);
    // §A2 ReRAM bound + Table VII γs as footer rows.
    let arr = ReramArray::default();
    row("e_ReRAM per MAC (A11, 70 mV)", arr.energy_per_mac(), 0.05);
    t.row(vec![
        "ReRAM ceiling (TOPS/W)".into(),
        format!("{:.1}", 1.0 / (arr.energy_per_mac() * 1e12)),
        "20".into(),
    ]);
    t.row(vec![
        "gamma_mac / adc / dac / opt".into(),
        format!(
            "{:.0} / {:.0} / {:.0} / {:.0}",
            constants::GAMMA_MAC_45NM,
            constants::GAMMA_ADC_45NM,
            constants::GAMMA_DAC,
            gamma_opt(0.5)
        ),
        "1.2e5 / 927* / 39 / 105".into(),
    ]);
    t
}

/// Networks helper reused by figures: the Table I zoo plus SmallCNN.
pub fn all_networks(input: usize) -> Vec<Network> {
    let mut v = zoo(input);
    v.push(crate::coordinator::smallcnn_network());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_8_networks_and_10_columns() {
        let t = table1(1000);
        assert_eq!(t.rows.len(), 8);
        assert_eq!(t.headers.len(), 10);
    }

    #[test]
    fn table1_ours_close_to_paper_for_vgg() {
        let t = table1(1000);
        let vgg = t.rows.iter().find(|r| r[0] == "VGG16").unwrap();
        let ours: f64 = vgg[8].parse().unwrap();
        let paper: f64 = vgg[9].parse().unwrap();
        assert!((ours - paper).abs() / paper < 0.1, "{ours} vs {paper}");
    }

    #[test]
    fn table2_table3_render() {
        let t2 = table2(1000);
        let t3 = table3(1000);
        assert_eq!(t2.rows.len(), 8);
        assert_eq!(t3.rows.len(), 8);
        assert!(t2.render().contains("VGG19"));
        assert!(t3.render().contains("YOLOv3"));
    }

    #[test]
    fn table4_matches_paper_within_rounding() {
        let t = table4();
        for row in &t.rows {
            let (Ok(ours), Ok(paper)) = (row[1].parse::<f64>(), row[2].parse::<f64>()) else {
                continue; // footer rows
            };
            // Paper prints 1-2 significant digits; allow 15%.
            assert!(
                (ours - paper).abs() / paper < 0.15,
                "{}: ours {ours} vs paper {paper}",
                row[0]
            );
        }
    }

    #[test]
    fn csv_export_works() {
        let csv = table1(1000).to_csv();
        assert!(csv.lines().count() == 9);
        assert!(csv.starts_with("network,"));
    }
}
