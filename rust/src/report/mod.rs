//! Report generators: regenerate every table and figure of the paper's
//! evaluation section as aligned text tables (or CSV), with the paper's
//! printed values alongside for comparison where applicable.
//!
//! | generator | paper artifact |
//! |---|---|
//! | [`tables::table1`] | Table I — CNN conv-layer statistics |
//! | [`tables::table2`] | Table II — median matmul dims L′,N′,M′ |
//! | [`tables::table3`] | Table III — median 4F dims L,N,M |
//! | [`tables::table4`] | Table IV — energy per operation (+VI, VII) |
//! | [`figures::fig6`] | Fig. 6 — analytic η vs technology node |
//! | [`figures::fig7`] | Fig. 7 — memory/compute energy split @32 nm |
//! | [`figures::fig8`] | Fig. 8 — systolic cycle-accurate vs analytic |
//! | [`figures::fig9`] | Fig. 9 — optical 4F cycle-accurate vs analytic |
//! | [`figures::fig10`] | Fig. 10 — 4F energy distribution vs node |

pub mod figures;
pub mod tables;

pub use figures::*;
pub use tables::*;
