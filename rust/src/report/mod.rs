//! Report generation: the **Scenario → Dataset → sink** pipeline.
//!
//! Every table, figure and sweep of the paper's evaluation section is a
//! declarative [`Scenario`] — machines (cycle-accurate and analytic) ×
//! networks × technology nodes × derived columns — evaluated by ONE
//! engine ([`Scenario::eval`]) through a shared [`crate::util::pool`]
//! `Pool` + [`crate::simulator::SweepCache`] into a typed [`Dataset`]
//! (columns of [`Value::Num`]/[`Value::Text`], not pre-formatted
//! strings), then rendered by a pluggable sink: aligned text
//! ([`Dataset::render`]), RFC-4180 CSV ([`Dataset::to_csv`]) or JSON
//! ([`Dataset::to_json`] via [`crate::util::json`]).
//!
//! | generator | paper artifact |
//! |---|---|
//! | [`tables::table1`] | Table I — CNN conv-layer statistics |
//! | [`tables::table2`] | Table II — median matmul dims L′,N′,M′ |
//! | [`tables::table3`] | Table III — median 4F dims L,N,M |
//! | [`tables::table4`] | Table IV — energy per operation (+VI, VII) |
//! | [`figures::fig6`] | Fig. 6 — analytic η vs node (sweep engine via `AnalyticMachine`) |
//! | [`figures::fig7`] | Fig. 7 — memory/compute energy split @32 nm |
//! | [`figures::fig8`] | Fig. 8 — systolic cycle-accurate vs analytic |
//! | [`figures::fig9`] | Fig. 9 — optical 4F cycle-accurate vs analytic |
//! | [`figures::fig10`] | Fig. 10 — 4F energy distribution vs node |
//! | [`figures::crossval`] | extension — all four machines cross-validated |
//! | [`zoo_scenario`] | `aimc zoo` — network inventory |
//! | [`sweep_scenario`] | `aimc sweep` — full machine × network × node grid |
//! | [`surrogate_crossval_scenario`] | `aimc surrogate-crossval` — fitted energy surrogate vs cycle sims |
//!
//! [`all_scenarios`] is the `aimc all` list: one shared cache/pool
//! evaluates the lot, so layer shapes repeated across artifacts
//! simulate exactly once per process (and once per *cache directory*
//! when the CLI persists the sweep cache).

pub mod figures;
pub mod scenario;
pub mod tables;

pub use figures::*;
pub use scenario::{Dataset, EvalCtx, NumFmt, OutputFormat, RowCtx, Scenario, Value};
pub use tables::*;

use crate::networks::zoo;

/// `aimc zoo`: the Table I network inventory at `input` px.
pub fn zoo_scenario(input: usize) -> Scenario {
    Scenario::new(format!("network zoo @ {input} px"))
        .networks(zoo(input))
        .over_networks()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("conv layers", 0, |c: &RowCtx| c.net().num_layers() as f64)
        .num("GMACs", 1, |c: &RowCtx| c.net().total_macs() / 1e9)
        .num("weights (M)", 1, |c: &RowCtx| c.net().total_weights() / 1e6)
}

/// `aimc sweep`: the full evaluation grid — every machine × every zoo
/// network × every node of the ladder, one row per (network, node).
pub fn sweep_scenario(input: usize) -> Scenario {
    let machines = crate::simulator::machine::all_machines();
    let nets = zoo(input);
    let nodes: Vec<f64> = crate::technode::NODES.iter().map(|n| n.nm).collect();
    let title = format!(
        "sweep — cycle-accurate TOPS/W, {} machines × {} networks × {} nodes @ {input} px",
        machines.len(),
        nets.len(),
        nodes.len()
    );
    let mut s = Scenario::new(title)
        .machines(machines)
        .networks(nets)
        .nodes(&nodes)
        .over_network_nodes()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("node (nm)", 0, |c: &RowCtx| c.node());
    for (mi, col) in ["systolic", "ReRAM", "photonic", "optical 4F"]
        .into_iter()
        .enumerate()
    {
        s = s.num(col, 3, move |c: &RowCtx| c.sim(mi).tops_per_watt());
    }
    s
}

/// `aimc surrogate-crossval`: fit the closed-form energy surrogate from
/// the cycle simulators, then score it against them — one row per node
/// of the ladder, one column per machine holding the worst per-layer
/// relative energy error (%) over the full training corpus (zoo shapes
/// + the Table V reference layer + the serving CNN). Every cell must
/// stay within [`crate::energy::surrogate::ERR_BOUND`]; the CLI command exits non-zero
/// on any violation, and `report::tests` pins the bound.
///
/// Fit and scoring both run at construction time through one private
/// cache (the fit is the expensive part; scoring replays its layer
/// simulations as cache hits), so the scenario itself is purely derived
/// — `eval` just assembles the precomputed grid.
///
/// Deliberately NOT in [`all_scenarios`]: it is an acceptance gate for
/// the serving fast path, not a paper artifact.
pub fn surrogate_crossval_scenario(input: usize) -> Scenario {
    use crate::energy::surrogate::{self, MachineKind, SurrogateTable};
    use crate::simulator::SweepCache;

    let cache = SweepCache::new();
    let mut layers = surrogate::training_corpus(input);
    layers.extend(crate::coordinator::smallcnn_network().layers);
    let layers = surrogate::dedup_layers(layers);
    let nodes = surrogate::default_nodes();
    let table = SurrogateTable::fit(&cache, &MachineKind::ALL, &nodes, &layers)
        .expect("surrogate fit over the zoo corpus");
    let points = surrogate::crossval(&table, &cache, &MachineKind::ALL, &nodes, &layers);

    let title = format!(
        "surrogate crossval — worst |rel err| % vs cycle sims over {} layers @ {input} px \
         (bound {:.0}%)",
        layers.len(),
        surrogate::ERR_BOUND * 100.0
    );
    let nodes_col = nodes.clone();
    let mut s = Scenario::new(title)
        .items(nodes.len())
        .num("node (nm)", 0, move |c: &RowCtx| nodes_col[c.index]);
    for kind in MachineKind::ALL {
        let per_node: Vec<f64> = nodes
            .iter()
            .map(|&nm| {
                points
                    .iter()
                    .find(|p| p.kind == kind && p.node_nm == nm)
                    .map(|p| p.max_rel_err * 100.0)
                    .unwrap_or(100.0)
            })
            .collect();
        s = s.num(kind.name(), 4, move |c: &RowCtx| per_node[c.index]);
    }
    s
}

/// The `aimc all` scenario list, in the CLI's historical emission order.
pub fn all_scenarios(net: Option<&str>, input: usize) -> Vec<Scenario> {
    vec![
        table1(input),
        table2(input),
        table3(input),
        table4(),
        fig6(),
        fig7(),
        fig8(net, input),
        fig9(net, input),
        fig10(Some("VGG19"), input),
        fig10(Some("YOLOv3"), input),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SweepCache;
    use crate::util::pool::Pool;

    #[test]
    fn zoo_scenario_lists_the_zoo() {
        let t = zoo_scenario(1000).table();
        assert_eq!(t.rows.len(), 8);
        assert!(t.rows.iter().any(|r| r[0] == "YOLOv3"));
        for row in &t.rows {
            assert!(row[2].parse::<f64>().unwrap() > 0.0, "{row:?}");
        }
    }

    #[test]
    fn sweep_scenario_covers_the_grid() {
        let s = sweep_scenario(200);
        assert_eq!(s.grid_points(), 4 * 8 * crate::technode::NODES.len());
        assert_eq!(s.row_count(), 8 * crate::technode::NODES.len());
    }

    #[test]
    fn surrogate_crossval_stays_within_bound() {
        // The acceptance gate behind `aimc serve --surrogate`: on every
        // machine × node of the ladder, the fitted models must agree
        // with the cycle simulators within ERR_BOUND on every corpus
        // layer. Small input keeps the fit quick; the shapes still span
        // all four families of the zoo.
        let ds = surrogate_crossval_scenario(120).dataset();
        assert_eq!(ds.rows.len(), crate::technode::NODES.len());
        let bound_pct = crate::energy::surrogate::ERR_BOUND * 100.0;
        for row in &ds.rows {
            for (cell, col) in row.iter().zip(&ds.columns).skip(1) {
                match cell {
                    Value::Num(pct) => assert!(
                        *pct <= bound_pct,
                        "{col}: {pct:.4}% exceeds {bound_pct}% in {row:?}"
                    ),
                    other => panic!("{other:?}"),
                }
            }
        }
    }

    #[test]
    fn all_scenarios_share_one_cache() {
        // `aimc all` evaluates ten scenarios through one pool + cache.
        // The last scenario, fig10(YOLOv3), prices the same (optical 4F
        // default config × YOLOv3 × node ladder) grid fig9 already
        // simulated — with a genuinely shared cache it must add ZERO
        // misses. (Within-scenario hits can't satisfy this: the
        // assertion fails if each eval() gets a private cache.)
        let list = all_scenarios(None, 120);
        assert_eq!(list.len(), 10);
        let pool = Pool::auto();
        let cache = SweepCache::new();
        let ctx = EvalCtx {
            pool: &pool,
            cache: &cache,
        };
        let mut misses_before_last = 0;
        for (i, s) in list.iter().enumerate() {
            if i == list.len() - 1 {
                misses_before_last = cache.misses();
            }
            let ds = s.eval(&ctx);
            assert!(!ds.rows.is_empty(), "{}", s.title());
            for row in &ds.rows {
                assert_eq!(row.len(), ds.columns.len());
            }
        }
        assert_eq!(
            cache.misses(),
            misses_before_last,
            "fig10(YOLOv3) must replay fig9's grid from the shared cache: {}",
            cache.stats()
        );
        assert!(cache.hits() > 0, "shared cache must see reuse: {}", cache.stats());
    }
}
