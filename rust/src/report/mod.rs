//! Report generation: the **Scenario → Dataset → sink** pipeline.
//!
//! Every table, figure and sweep of the paper's evaluation section is a
//! declarative [`Scenario`] — machines (cycle-accurate and analytic) ×
//! networks × technology nodes × derived columns — evaluated by ONE
//! engine ([`Scenario::eval`]) through a shared [`crate::util::pool`]
//! `Pool` + [`crate::simulator::SweepCache`] into a typed [`Dataset`]
//! (columns of [`Value::Num`]/[`Value::Text`], not pre-formatted
//! strings), then rendered by a pluggable sink: aligned text
//! ([`Dataset::render`]), RFC-4180 CSV ([`Dataset::to_csv`]) or JSON
//! ([`Dataset::to_json`] via [`crate::util::json`]).
//!
//! | generator | paper artifact |
//! |---|---|
//! | [`tables::table1`] | Table I — CNN conv-layer statistics |
//! | [`tables::table2`] | Table II — median matmul dims L′,N′,M′ |
//! | [`tables::table3`] | Table III — median 4F dims L,N,M |
//! | [`tables::table4`] | Table IV — energy per operation (+VI, VII) |
//! | [`figures::fig6`] | Fig. 6 — analytic η vs node (sweep engine via `AnalyticMachine`) |
//! | [`figures::fig7`] | Fig. 7 — memory/compute energy split @32 nm |
//! | [`figures::fig8`] | Fig. 8 — systolic cycle-accurate vs analytic |
//! | [`figures::fig9`] | Fig. 9 — optical 4F cycle-accurate vs analytic |
//! | [`figures::fig10`] | Fig. 10 — 4F energy distribution vs node |
//! | [`figures::crossval`] | extension — all four machines cross-validated |
//! | [`zoo_scenario`] | `aimc zoo` — network inventory |
//! | [`sweep_scenario`] | `aimc sweep` — full machine × network × node grid |
//! | [`sweep_scenario_with_bits`] | `aimc sweep --bits` — the grid crossed with bit widths |
//! | [`surrogate_crossval_scenario`] | `aimc surrogate-crossval` — fitted energy surrogate vs cycle sims |
//! | [`pareto_scenario`] | `aimc pareto` — energy × latency × accuracy over node × bits |
//! | [`intensity_scenario`] | `aimc intensity` — transformer prefill/decode intensity crossover |
//! | [`faults_scenario`] | `aimc faults` — energy/accuracy degradation over a fault-rate grid |
//!
//! [`all_scenarios`] is the `aimc all` list: one shared cache/pool
//! evaluates the lot, so layer shapes repeated across artifacts
//! simulate exactly once per process (and once per *cache directory*
//! when the CLI persists the sweep cache).

pub mod figures;
pub mod scenario;
pub mod tables;

pub use figures::*;
pub use scenario::{Dataset, EvalCtx, NumFmt, OutputFormat, RowCtx, Scenario, Value};
pub use tables::*;

use crate::networks::zoo;

/// `aimc zoo`: the Table I network inventory at `input` px.
pub fn zoo_scenario(input: usize) -> Scenario {
    Scenario::new(format!("network zoo @ {input} px"))
        .networks(zoo(input))
        .over_networks()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("conv layers", 0, |c: &RowCtx| c.net().num_layers() as f64)
        .num("GMACs", 1, |c: &RowCtx| c.net().total_macs() / 1e9)
        .num("weights (M)", 1, |c: &RowCtx| c.net().total_weights() / 1e6)
}

/// `aimc sweep`: the full evaluation grid — every machine × every zoo
/// network × every node of the ladder, one row per (network, node).
pub fn sweep_scenario(input: usize) -> Scenario {
    let machines = crate::simulator::machine::all_machines();
    let nets = zoo(input);
    let nodes: Vec<f64> = crate::technode::NODES.iter().map(|n| n.nm).collect();
    let title = format!(
        "sweep — cycle-accurate TOPS/W, {} machines × {} networks × {} nodes @ {input} px",
        machines.len(),
        nets.len(),
        nodes.len()
    );
    let mut s = Scenario::new(title)
        .machines(machines)
        .networks(nets)
        .nodes(&nodes)
        .over_network_nodes()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("node (nm)", 0, |c: &RowCtx| c.node());
    for (mi, col) in ["systolic", "ReRAM", "photonic", "optical 4F"]
        .into_iter()
        .enumerate()
    {
        s = s.num(col, 3, move |c: &RowCtx| c.sim(mi).tops_per_watt());
    }
    s
}

/// [`sweep_scenario`] crossed with explicit `(bits_x, bits_w)` pairs:
/// each (network, node) row fans out bits-minor into one row per pair,
/// with a `bits` label column inserted after the node. An empty `bits`
/// list falls back to the plain (unlabeled, default-precision) sweep, so
/// `aimc sweep` without `--bits` is byte-identical to before.
pub fn sweep_scenario_with_bits(input: usize, bits: &[(u32, u32)]) -> Scenario {
    if bits.is_empty() {
        return sweep_scenario(input);
    }
    let machines = crate::simulator::machine::all_machines();
    let nets = zoo(input);
    let nodes: Vec<f64> = crate::technode::NODES.iter().map(|n| n.nm).collect();
    let title = format!(
        "sweep — cycle-accurate TOPS/W, {} machines × {} networks × {} nodes × {} precisions @ {input} px",
        machines.len(),
        nets.len(),
        nodes.len(),
        bits.len()
    );
    let mut s = Scenario::new(title)
        .machines(machines)
        .networks(nets)
        .nodes(&nodes)
        .bits(bits)
        .over_network_nodes()
        .text("network", |c: &RowCtx| c.net().name.to_string())
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .text("bits", |c: &RowCtx| c.bits_label());
    for (mi, col) in ["systolic", "ReRAM", "photonic", "optical 4F"]
        .into_iter()
        .enumerate()
    {
        s = s.num(col, 3, move |c: &RowCtx| c.sim(mi).tops_per_watt());
    }
    s
}

/// The default `aimc pareto` precision grid.
pub const PARETO_DEFAULT_BITS: [(u32, u32); 4] = [(4, 4), (6, 6), (8, 8), (12, 12)];

/// The default `aimc pareto` node grid: the scaling-era slice of the
/// ladder the paper's §VII discussion centers on.
pub const PARETO_NODES: [f64; 4] = [45.0, 28.0, 14.0, 7.0];

/// `aimc pareto`: the energy × latency × accuracy frontier over a
/// (node × bits) grid for all four cycle machines on YOLOv3. Each row is
/// one operating point: the seeded-RNG estimator
/// ([`crate::simulator::accuracy`]) supplies effective SNR / ENOB / an
/// accuracy-retention proxy, and the cycle simulators supply µJ/inference
/// and schedule time per machine — everything needed to read off which
/// precision dominates at which node.
///
/// Deliberately NOT in [`all_scenarios`]: it is a design-space tool, not
/// a paper artifact (the golden test pins `all_scenarios` to the paper's
/// ten outputs).
pub fn pareto_scenario(input: usize) -> Scenario {
    pareto_scenario_with_bits(input, &PARETO_DEFAULT_BITS)
}

/// [`pareto_scenario`] over an explicit precision grid (`--bits`).
pub fn pareto_scenario_with_bits(input: usize, bits: &[(u32, u32)]) -> Scenario {
    use crate::simulator::accuracy::{estimate_network, AccuracyEstimate};
    use crate::simulator::{OpKey, OperatingPoint};
    use std::collections::HashMap;
    use std::sync::Arc;

    let net = crate::networks::yolov3::yolov3(input);
    let bits: Vec<(u32, u32)> = if bits.is_empty() {
        PARETO_DEFAULT_BITS.to_vec()
    } else {
        bits.to_vec()
    };
    // The accuracy estimate depends only on (network, operating point) —
    // precompute it per grid point so the three derived columns share
    // one estimate instead of re-running the Monte-Carlo per column.
    let mut estimates: HashMap<OpKey, AccuracyEstimate> = HashMap::new();
    for &nm in &PARETO_NODES {
        for &(bx, bw) in &bits {
            let op = OperatingPoint::node(nm).bits(bx, bw);
            estimates.insert(op.key(), estimate_network(&net, &op));
        }
    }
    let estimates = Arc::new(estimates);

    let title = format!(
        "pareto — energy × latency × accuracy, {} @ {input} px over {} nodes × {} precisions",
        net.name,
        PARETO_NODES.len(),
        bits.len()
    );
    let est = |f: fn(&AccuracyEstimate) -> f64| {
        let estimates = Arc::clone(&estimates);
        move |c: &RowCtx| f(&estimates[&c.op().key()])
    };
    let mut s = Scenario::new(title)
        .machines(crate::simulator::machine::all_machines())
        .network(net)
        .nodes(&PARETO_NODES)
        .bits(&bits)
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .text("bits", |c: &RowCtx| c.bits_label())
        .num("SNR (dB)", 2, est(|e| e.snr_db))
        .num("eff. bits", 2, est(|e| e.effective_bits))
        .num("accuracy", 4, est(|e| e.retention));
    for (mi, m) in ["systolic", "reram", "photonic", "optical4f"]
        .into_iter()
        .enumerate()
    {
        s = s.num(&format!("{m} uJ/inf"), 3, move |c: &RowCtx| {
            c.sim(mi).ledger.total() * 1e6
        });
        s = s.sci(&format!("{m} time"), move |c: &RowCtx| c.sim(mi).time_units);
    }
    s
}

/// The default `aimc faults` fault-rate ladder: clean baseline, then
/// three decades of injected device-fault severity.
pub const FAULTS_DEFAULT_RATES: [f64; 4] = [0.0, 0.001, 0.01, 0.05];

/// The default `aimc faults` node grid: the paper's 45 nm anchor plus
/// the 7 nm end of the scaling ladder.
pub const FAULTS_NODES: [f64; 2] = [45.0, 7.0];

/// `aimc faults`: device-fault degradation curves. A fault-rate ladder
/// (each rate mapped to a bundled [`crate::simulator::FaultModel`] —
/// stuck cells + conductance drift + IR drop at that severity) is
/// crossed with nodes × precisions; every row reports the seeded
/// accuracy estimator's effective SNR / ENOB / retention under those
/// faults and the fault-derated µJ/inference of all four cycle
/// machines, so the energy-vs-robustness erosion of the analog
/// advantage can be read straight off the table. Rate 0.0 rows are
/// bit-identical to the clean `pareto` pricing — the identity-derate
/// contract.
///
/// Deliberately NOT in [`all_scenarios`]: like `pareto`, a design-space
/// tool, not a paper artifact.
pub fn faults_scenario(input: usize, rates: &[f64], bits: &[(u32, u32)]) -> Scenario {
    use crate::simulator::accuracy::{estimate_network, AccuracyEstimate};
    use crate::simulator::{FaultModel, NoiseModel, OpKey, OperatingPoint};
    use std::collections::HashMap;
    use std::sync::Arc;

    let net = crate::networks::yolov3::yolov3(input);
    let rates: Vec<f64> = if rates.is_empty() {
        FAULTS_DEFAULT_RATES.to_vec()
    } else {
        rates.to_vec()
    };
    let bits: Vec<(u32, u32)> = if bits.is_empty() {
        vec![(8, 8)]
    } else {
        bits.to_vec()
    };
    let noises: Vec<NoiseModel> = rates
        .iter()
        .map(|&r| NoiseModel {
            faults: FaultModel::at_rate(r),
            ..Default::default()
        })
        .collect();
    // One Monte-Carlo estimate per grid point, shared by the three
    // accuracy-derived columns (same trick as `pareto`).
    let mut estimates: HashMap<OpKey, AccuracyEstimate> = HashMap::new();
    for &nm in &FAULTS_NODES {
        for &(bx, bw) in &bits {
            for &noise in &noises {
                let op = OperatingPoint::node(nm).bits(bx, bw).with_noise(noise);
                estimates.insert(op.key(), estimate_network(&net, &op));
            }
        }
    }
    let estimates = Arc::new(estimates);

    let title = format!(
        "faults — energy × accuracy degradation, {} @ {input} px over {} nodes × {} precisions × {} fault rates",
        net.name,
        FAULTS_NODES.len(),
        bits.len(),
        rates.len()
    );
    let est = |f: fn(&AccuracyEstimate) -> f64| {
        let estimates = Arc::clone(&estimates);
        move |c: &RowCtx| f(&estimates[&c.op().key()])
    };
    let mut s = Scenario::new(title)
        .machines(crate::simulator::machine::all_machines())
        .network(net)
        .nodes(&FAULTS_NODES)
        .bits(&bits)
        .noise_models(&noises)
        .over_nodes()
        .num("node (nm)", 0, |c: &RowCtx| c.node())
        .text("bits", |c: &RowCtx| c.bits_label())
        .num("fault rate", 4, |c: &RowCtx| c.op().noise.faults.stuck_rate)
        .num("SNR (dB)", 2, est(|e| e.snr_db))
        .num("eff. bits", 2, est(|e| e.effective_bits))
        .num("accuracy", 4, est(|e| e.retention));
    for (mi, m) in ["systolic", "reram", "photonic", "optical4f"]
        .into_iter()
        .enumerate()
    {
        s = s.num(&format!("{m} uJ/inf"), 3, move |c: &RowCtx| {
            c.sim(mi).ledger.total() * 1e6
        });
    }
    s
}

/// Default node grid for the `aimc intensity` crossover trace: the
/// paper's 45 nm anchor plus the 7 nm end of the scaling ladder.
pub const INTENSITY_NODES: [f64; 2] = [45.0, 7.0];

/// `aimc intensity`: the arithmetic-intensity crossover trace. One
/// transformer config is swept as a grid of *streams* — phase
/// (prefill/decode) × batch × sequence length, each stream a distinct
/// [`crate::networks::Network`] of GEMM/GEMV layers — and every stream
/// is priced by all four cycle machines at every (node × bits)
/// operating point. Each row reports the stream's FLOPs/byte (the
/// x-axis of the paper's roofline argument) alongside µJ/inference and
/// µJ/token per machine, so the point where the in-memory machines
/// overtake the systolic array as intensity falls — the decode regime —
/// can be read straight off the table.
///
/// Deliberately NOT in [`all_scenarios`]: like `pareto`, it is a
/// design-space tool, not a paper artifact (the golden test pins
/// `all_scenarios` to the paper's ten outputs).
pub fn intensity_scenario(
    cfg: &crate::networks::transformer::TransformerConfig,
    phase: Option<crate::networks::transformer::Phase>,
    nodes: &[f64],
    bits: &[(u32, u32)],
    batches: &[usize],
    seqs: &[usize],
) -> Scenario {
    use crate::networks::stats;
    use crate::networks::transformer::{Phase, DEFAULT_BATCHES, DEFAULT_SEQS};
    use std::sync::Arc;

    /// Per-stream metadata recovered per row via `index / ops_per_net`
    /// (rows are network-major, operating-point-minor).
    struct Stream {
        phase: &'static str,
        batch: f64,
        seq: f64,
        tokens: f64,
        intensity: f64,
    }

    let phases: &[Phase] = match phase {
        Some(Phase::Prefill) => &[Phase::Prefill],
        Some(Phase::Decode) => &[Phase::Decode],
        None => &[Phase::Prefill, Phase::Decode],
    };
    let batches = if batches.is_empty() {
        DEFAULT_BATCHES.to_vec()
    } else {
        batches.to_vec()
    };
    let seqs = if seqs.is_empty() {
        DEFAULT_SEQS.to_vec()
    } else {
        seqs.to_vec()
    };
    let mut nets = Vec::new();
    let mut meta = Vec::new();
    for &ph in phases {
        for &b in &batches {
            for &sq in &seqs {
                let net = cfg.stream(ph, b, sq);
                meta.push(Stream {
                    phase: ph.label(),
                    batch: b as f64,
                    seq: sq as f64,
                    tokens: ph.tokens(b, sq) as f64,
                    intensity: stats::network_intensity(&net, 1.0),
                });
                nets.push(net);
            }
        }
    }
    let ops_per_net = nodes.len().max(1) * bits.len().max(1);
    let meta = Arc::new(meta);
    let title = format!(
        "intensity — {}: prefill→decode crossover, {} streams × {} operating points",
        cfg.name,
        nets.len(),
        ops_per_net
    );
    let md = |g: fn(&Stream) -> f64| {
        let meta = Arc::clone(&meta);
        move |c: &RowCtx| g(&meta[c.index / ops_per_net])
    };
    let phase_meta = Arc::clone(&meta);
    let mut s = Scenario::new(title)
        .machines(crate::simulator::machine::all_machines())
        .networks(nets)
        .nodes(nodes);
    if !bits.is_empty() {
        s = s.bits(bits);
    }
    let mut s = s
        .over_network_nodes()
        .text("phase", move |c: &RowCtx| {
            phase_meta[c.index / ops_per_net].phase.to_string()
        })
        .num("batch", 0, md(|m| m.batch))
        .num("seq", 0, md(|m| m.seq))
        .num("tokens/inf", 0, md(|m| m.tokens))
        .num("FLOPs/byte", 2, md(|m| m.intensity))
        .num("node (nm)", 0, |c: &RowCtx| c.node());
    if !bits.is_empty() {
        s = s.text("bits", |c: &RowCtx| c.bits_label());
    }
    for (mi, m) in ["systolic", "reram", "photonic", "optical4f"]
        .into_iter()
        .enumerate()
    {
        s = s.num(&format!("{m} uJ/inf"), 3, move |c: &RowCtx| {
            c.sim(mi).ledger.total() * 1e6
        });
        let meta = Arc::clone(&meta);
        s = s.num(&format!("{m} uJ/tok"), 4, move |c: &RowCtx| {
            c.sim(mi).ledger.total() * 1e6 / meta[c.index / ops_per_net].tokens
        });
    }
    s
}

/// `aimc surrogate-crossval`: fit the closed-form energy surrogate from
/// the cycle simulators, then score it against them — one row per node
/// of the ladder, one column per machine holding the worst per-layer
/// relative energy error (%) over the full training corpus (zoo shapes
/// + the Table V reference layer + the serving CNN). Every cell must
/// stay within [`crate::energy::surrogate::ERR_BOUND`]; the CLI command exits non-zero
/// on any violation, and `report::tests` pins the bound.
///
/// Fit and scoring both run at construction time through one private
/// cache (the fit is the expensive part; scoring replays its layer
/// simulations as cache hits), so the scenario itself is purely derived
/// — `eval` just assembles the precomputed grid.
///
/// Deliberately NOT in [`all_scenarios`]: it is an acceptance gate for
/// the serving fast path, not a paper artifact.
pub fn surrogate_crossval_scenario(input: usize) -> Scenario {
    use crate::energy::surrogate::{self, MachineKind, SurrogateTable};
    use crate::simulator::SweepCache;

    let cache = SweepCache::new();
    let mut layers = surrogate::training_corpus(input);
    layers.extend(crate::coordinator::smallcnn_network().layers);
    let layers = surrogate::dedup_layers(layers);
    let nodes = surrogate::default_nodes();
    let table = SurrogateTable::fit(&cache, &MachineKind::ALL, &nodes, &layers)
        .expect("surrogate fit over the zoo corpus");
    let points = surrogate::crossval(&table, &cache, &MachineKind::ALL, &nodes, &layers);

    let title = format!(
        "surrogate crossval — worst |rel err| % vs cycle sims over {} layers @ {input} px \
         (bound {:.0}%)",
        layers.len(),
        surrogate::ERR_BOUND * 100.0
    );
    let nodes_col = nodes.clone();
    let mut s = Scenario::new(title)
        .items(nodes.len())
        .num("node (nm)", 0, move |c: &RowCtx| nodes_col[c.index]);
    for kind in MachineKind::ALL {
        let per_node: Vec<f64> = nodes
            .iter()
            .map(|&nm| {
                points
                    .iter()
                    .find(|p| p.kind == kind && p.node_nm == nm)
                    .map(|p| p.max_rel_err * 100.0)
                    .unwrap_or(100.0)
            })
            .collect();
        s = s.num(kind.name(), 4, move |c: &RowCtx| per_node[c.index]);
    }
    s
}

/// The `aimc all` scenario list, in the CLI's historical emission order.
pub fn all_scenarios(net: Option<&str>, input: usize) -> Vec<Scenario> {
    vec![
        table1(input),
        table2(input),
        table3(input),
        table4(),
        fig6(),
        fig7(),
        fig8(net, input),
        fig9(net, input),
        fig10(Some("VGG19"), input),
        fig10(Some("YOLOv3"), input),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SweepCache;
    use crate::util::pool::Pool;

    #[test]
    fn zoo_scenario_lists_the_zoo() {
        let t = zoo_scenario(1000).table();
        assert_eq!(t.rows.len(), 8);
        assert!(t.rows.iter().any(|r| r[0] == "YOLOv3"));
        for row in &t.rows {
            assert!(row[2].parse::<f64>().unwrap() > 0.0, "{row:?}");
        }
    }

    #[test]
    fn sweep_scenario_covers_the_grid() {
        let s = sweep_scenario(200);
        assert_eq!(s.grid_points(), 4 * 8 * crate::technode::NODES.len());
        assert_eq!(s.row_count(), 8 * crate::technode::NODES.len());
    }

    #[test]
    fn pareto_scenario_spans_nodes_times_bits() {
        let s = pareto_scenario(120);
        assert_eq!(
            s.row_count(),
            PARETO_NODES.len() * PARETO_DEFAULT_BITS.len()
        );
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 16);
        // Columns: node, bits, 3 accuracy-derived, then (µJ, time) × 4.
        assert_eq!(ds.columns.len(), 5 + 8);
        // Within one node, retention rises and energy falls with bits ×
        // energy rises with bits (monotone trade-off the frontier is
        // built from).
        let num = |v: &Value| match v {
            Value::Num(x) => *x,
            other => panic!("{other:?}"),
        };
        let acc4 = num(&ds.rows[0][4]);
        let acc12 = num(&ds.rows[3][4]);
        assert!(acc4 < acc12, "retention must rise with bits");
        let e4 = num(&ds.rows[0][5]);
        let e12 = num(&ds.rows[3][5]);
        assert!(e4 < e12, "systolic energy must rise with bits");
    }

    #[test]
    fn faults_scenario_traces_degradation_curves() {
        let s = faults_scenario(120, &[0.0, 0.05], &[]);
        // 2 nodes × 1 precision × 2 rates.
        assert_eq!(s.row_count(), 4);
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 4);
        // Columns: node, bits, rate, 3 accuracy-derived, then µJ × 4.
        assert_eq!(ds.columns.len(), 6 + 4);
        let num = |v: &Value| match v {
            Value::Num(x) => *x,
            other => panic!("{other:?}"),
        };
        // Rate-innermost: rows 0/1 are 45 nm clean/faulty.
        assert_eq!(num(&ds.rows[0][2]), 0.0);
        assert_eq!(num(&ds.rows[1][2]), 0.05);
        // Faults must cost accuracy AND energy on every machine.
        assert!(num(&ds.rows[1][3]) < num(&ds.rows[0][3]), "SNR degrades");
        assert!(num(&ds.rows[1][5]) < num(&ds.rows[0][5]), "retention degrades");
        for mi in 0..4 {
            assert!(
                num(&ds.rows[1][6 + mi]) > num(&ds.rows[0][6 + mi]),
                "machine {mi} energy must rise under faults"
            );
        }
        // Same seed ⇒ same curves: a rebuilt scenario is value-identical.
        let again = faults_scenario(120, &[0.0, 0.05], &[]).dataset();
        for (a, b) in ds.rows.iter().zip(&again.rows) {
            assert_eq!(a, b, "faults scenario must be deterministic");
        }
    }

    #[test]
    fn sweep_with_bits_adds_rows_and_label_column() {
        let plain = sweep_scenario(120);
        let with = sweep_scenario_with_bits(120, &[(8, 8), (4, 4)]);
        assert_eq!(with.row_count(), 2 * plain.row_count());
        // Empty bits list falls back to the byte-identical plain sweep.
        let fallback = sweep_scenario_with_bits(120, &[]);
        assert_eq!(fallback.title(), plain.title());
        assert_eq!(fallback.row_count(), plain.row_count());
    }

    #[test]
    fn intensity_scenario_traces_both_phases() {
        use crate::networks::transformer::TransformerConfig;
        let cfg = TransformerConfig::tiny();
        let s = intensity_scenario(&cfg, None, &[45.0], &[], &[1, 4], &[64]);
        // 2 phases × 2 batches × 1 seq = 4 streams × 1 operating point.
        assert_eq!(s.row_count(), 4);
        let ds = s.dataset();
        assert_eq!(ds.rows.len(), 4);
        // Columns: phase, batch, seq, tokens/inf, FLOPs/byte, node,
        // then (uJ/inf, uJ/tok) × 4 machines.
        assert_eq!(ds.columns.len(), 6 + 8);
        let num = |v: &Value| match v {
            Value::Num(x) => *x,
            other => panic!("{other:?}"),
        };
        // Networks are phase-major: prefill streams first, then decode,
        // and decode must sit far lower on the FLOPs/byte axis.
        assert_eq!(ds.rows[0][0], Value::Text("prefill".into()));
        assert_eq!(ds.rows[2][0], Value::Text("decode".into()));
        assert!(num(&ds.rows[0][4]) > num(&ds.rows[2][4]));
        // Energy columns positive/finite and µJ/tok = µJ/inf ÷ tokens.
        for row in &ds.rows {
            let tokens = num(&row[3]);
            for mi in 0..4 {
                let inf = num(&row[6 + 2 * mi]);
                let tok = num(&row[6 + 2 * mi + 1]);
                assert!(inf.is_finite() && inf > 0.0, "{row:?}");
                assert!((tok - inf / tokens).abs() <= inf * 1e-9, "{row:?}");
            }
        }
    }

    #[test]
    fn surrogate_crossval_stays_within_bound() {
        // The acceptance gate behind `aimc serve --surrogate`: on every
        // machine × node of the ladder, the fitted models must agree
        // with the cycle simulators within ERR_BOUND on every corpus
        // layer. Small input keeps the fit quick; the shapes still span
        // all four families of the zoo.
        let ds = surrogate_crossval_scenario(120).dataset();
        assert_eq!(ds.rows.len(), crate::technode::NODES.len());
        let bound_pct = crate::energy::surrogate::ERR_BOUND * 100.0;
        for row in &ds.rows {
            for (cell, col) in row.iter().zip(&ds.columns).skip(1) {
                match cell {
                    Value::Num(pct) => assert!(
                        *pct <= bound_pct,
                        "{col}: {pct:.4}% exceeds {bound_pct}% in {row:?}"
                    ),
                    other => panic!("{other:?}"),
                }
            }
        }
    }

    #[test]
    fn all_scenarios_share_one_cache() {
        // `aimc all` evaluates ten scenarios through one pool + cache.
        // The last scenario, fig10(YOLOv3), prices the same (optical 4F
        // default config × YOLOv3 × node ladder) grid fig9 already
        // simulated — with a genuinely shared cache it must add ZERO
        // misses. (Within-scenario hits can't satisfy this: the
        // assertion fails if each eval() gets a private cache.)
        let list = all_scenarios(None, 120);
        assert_eq!(list.len(), 10);
        let pool = Pool::auto();
        let cache = SweepCache::new();
        let ctx = EvalCtx {
            pool: &pool,
            cache: &cache,
        };
        let mut misses_before_last = 0;
        for (i, s) in list.iter().enumerate() {
            if i == list.len() - 1 {
                misses_before_last = cache.misses();
            }
            let ds = s.eval(&ctx);
            assert!(!ds.rows.is_empty(), "{}", s.title());
            for row in &ds.rows {
                assert_eq!(row.len(), ds.columns.len());
            }
        }
        assert_eq!(
            cache.misses(),
            misses_before_last,
            "fig10(YOLOv3) must replay fig9's grid from the shared cache: {}",
            cache.stats()
        );
        assert!(cache.hits() > 0, "shared cache must see reuse: {}", cache.stats());
    }
}
