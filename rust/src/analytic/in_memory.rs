//! Digital in-memory (systolic array) model — eq. (5).
//!
//! An in-memory compute device reads each input once and writes each
//! output once, so the memory term shrinks with the algorithm's
//! arithmetic intensity: η = 1/(e_m/a + e_op). The per-MAC compute term
//! follows §VII.A's TPU-like accounting: the 8-bit MAC itself, the
//! inter-tile load (eq. A6, node-independent) and the in-tile register
//! traffic for the 8-bit operand + 32-bit accumulator (40 bits).

use super::{Efficiency, Workload};
use crate::energy::{
    constants::{SYSTOLIC_DIM, TOTAL_SRAM_BYTES},
    load::presets,
    sram::{bank_bytes, Sram},
    EnergyParams,
};

/// Architectural parameters of the digital in-memory processor.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Systolic array dimension (array is `dim × dim`).
    pub dim: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// Number of SRAM banks (one per array port in the TPU floorplan).
    pub banks: usize,
    /// Bits moved per MAC between tiles (8-bit input + 32-bit psum).
    pub bits_per_hop: u32,
    /// Bytes of in-tile register file touched per MAC.
    pub reg_bytes_per_mac: f64,
}

impl Config {
    /// The paper's §VI/§VII.A parameters: 256×256 weight-stationary array,
    /// 24 MiB SRAM in 256 banks of 96 KB.
    pub fn tpu_like() -> Self {
        Config {
            dim: SYSTOLIC_DIM,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: SYSTOLIC_DIM,
            bits_per_hop: 40,
            reg_bytes_per_mac: 5.0,
        }
    }

    /// Bank size in bytes.
    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }

    /// Per-MAC compute energy at a node (§VII.A accounting), J.
    pub fn e_mac_total(&self, node_nm: f64) -> f64 {
        let e = EnergyParams::default().at_node(node_nm);
        // Inter-tile hop: eq. (A6) at the 34.8 µm tile pitch — NOT node
        // scaled (wire-dominated; §VII.A keeps it fixed).
        let e_hop = presets::systolic_hop().energy() * self.bits_per_hop as f64;
        // In-tile register traffic: 8 KB SRAM scaled to a 5-byte word.
        let e_reg = Sram::at_node(5, node_nm).energy_per_byte * self.reg_bytes_per_mac;
        e.e_mac + e_hop + e_reg
    }

    /// eq. (5): η = 1/(e_m/a + e_op), per-op accounting (2 ops = 1 MAC).
    /// The systolic array reads the k²-duplicated Toeplitz activations, so
    /// `a` is the matmul intensity (eq. 8 — Table V's 230).
    pub fn efficiency(&self, w: &Workload, node_nm: f64) -> Efficiency {
        let sram = Sram::at_node(self.bank_bytes(), node_nm);
        Efficiency {
            e_mem: sram.energy_per_byte / w.a_matmul,
            // Per op = per MAC / 2 ops… the paper's eq. (5) uses e_op as
            // the *per-operation* energy with N_op = 2·MACs; we charge the
            // full MAC bundle to the MAC and divide by 2 ops.
            e_comp: self.e_mac_total(node_nm) / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_size_is_96kb() {
        assert_eq!(Config::tpu_like().bank_bytes(), 96 * 1024);
    }

    #[test]
    fn per_mac_bundle_at_45nm() {
        // e_mac 0.23 + hop 0.113 + reg 0.155 ≈ 0.5 pJ.
        let e = Config::tpu_like().e_mac_total(45.0);
        assert!((e * 1e12 - 0.5).abs() < 0.05, "{} pJ", e * 1e12);
    }

    #[test]
    fn eta_on_reference_layer_45nm() {
        // 1/(4.33/230 + 0.25) pJ ≈ 3.7 TOPS/W (per-op accounting).
        let eta = Config::tpu_like()
            .efficiency(&Workload::reference(), 45.0)
            .tops_per_watt();
        assert!(eta > 2.0 && eta < 6.0, "η = {eta}");
    }

    #[test]
    fn paper_5_tops_at_28nm() {
        // §VI: "we predict that number should be roughly 5 TOPS/W" for
        // the TPU parameters at 28 nm.
        let eta = Config::tpu_like()
            .efficiency(&Workload::reference(), 28.0)
            .tops_per_watt();
        assert!(eta > 3.0 && eta < 9.0, "η = {eta}");
    }

    #[test]
    fn memory_term_shrinks_with_intensity() {
        let cfg = Config::tpu_like();
        let mut lo = Workload::reference();
        lo.a_matmul = 10.0;
        let mut hi = Workload::reference();
        hi.a_matmul = 1000.0;
        let e_lo = cfg.efficiency(&lo, 45.0);
        let e_hi = cfg.efficiency(&hi, 45.0);
        assert!(e_hi.e_mem < e_lo.e_mem / 50.0);
        assert_eq!(e_hi.e_comp, e_lo.e_comp);
    }

    #[test]
    fn hop_term_does_not_scale_with_node() {
        let cfg = Config::tpu_like();
        let e45 = cfg.e_mac_total(45.0);
        let e7 = cfg.e_mac_total(7.0);
        // The fixed hop term keeps the 7 nm bundle well above pure
        // CMOS scaling (which would be ~0.094×).
        assert!(e7 / e45 > 0.2, "ratio {}", e7 / e45);
    }
}
