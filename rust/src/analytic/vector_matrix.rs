//! Vector–matrix multiplication — eq. (13), §IV.A.
//!
//! The case the paper warns about: when the analog processor must be
//! *reconfigured per input vector* (batch 1, e.g. autoregressive MLP /
//! attention projections), the weight-DAC term `e_dac,2` is amortized by
//! nothing — "the middle term is proportional neither to 1/N nor 1/M" —
//! and the O(N) analog advantage collapses. Streaming L rows (eq. 14)
//! restores it. This module quantifies the batch-size crossover.

use super::Efficiency;
use crate::energy::{
    constants::{E_EO_MODULATOR_FUTURE, PHOTONIC_DIM},
    load::presets,
    EnergyParams,
};

/// An N×M analog processor multiplying L-row batches against a resident
/// matrix that must be reconfigured once per batch.
#[derive(Clone, Copy, Debug)]
pub struct VectorMatrix {
    /// Processor input dimension N̂ (clamps N).
    pub dim_n: usize,
    /// Processor output dimension M̂ (clamps M).
    pub dim_m: usize,
    /// Modulator energy per weight/input sample, J.
    pub e_modulator: f64,
}

impl VectorMatrix {
    /// The paper's §VI photonic mesh.
    pub fn photonic_40() -> Self {
        VectorMatrix {
            dim_n: PHOTONIC_DIM,
            dim_m: PHOTONIC_DIM,
            e_modulator: E_EO_MODULATOR_FUTURE,
        }
    }

    /// eq. (13) generalized with batch L (eq. 14 at L→∞, eq. 13 at L=1):
    /// per-op energy e_op = e_dac1/M + e_dac2/L + e_adc/N, ×2 signed,
    /// ÷2 ops/MAC. Matrix dims (n, m) clamp to the processor (eq. 15).
    pub fn e_comp_per_op(&self, n: usize, m: usize, batch: usize, node_nm: f64) -> f64 {
        let e = EnergyParams::default().at_node(node_nm);
        let n_eff = (n.min(self.dim_n)) as f64;
        let m_eff = (m.min(self.dim_m)) as f64;
        let l = batch.max(1) as f64;
        let e_dac_in = e.e_dac + self.e_modulator + e.e_opt;
        let e_dac_w = e.e_dac + self.e_modulator + presets::photonic_40().energy();
        2.0 * (e_dac_in / m_eff + e_dac_w / l + e.e_adc / n_eff) / 2.0
    }

    /// Efficiency at a batch size (compute term only — weights resident
    /// in the mesh, activations assumed streamed from registers; the
    /// memory side is workload-specific and handled by the full models).
    pub fn efficiency(&self, n: usize, m: usize, batch: usize, node_nm: f64) -> Efficiency {
        Efficiency {
            e_mem: 0.0,
            e_comp: self.e_comp_per_op(n, m, batch, node_nm),
        }
    }

    /// Smallest batch at which the reconfiguration term stops dominating:
    /// e_dac2/L ≤ frac · (e_dac1/M + e_adc/N).
    pub fn amortization_batch(&self, n: usize, m: usize, node_nm: f64, frac: f64) -> usize {
        let e = EnergyParams::default().at_node(node_nm);
        let n_eff = (n.min(self.dim_n)) as f64;
        let m_eff = (m.min(self.dim_m)) as f64;
        let e_dac_in = e.e_dac + self.e_modulator + e.e_opt;
        let e_dac_w = e.e_dac + self.e_modulator + presets::photonic_40().energy();
        let steady = e_dac_in / m_eff + e.e_adc / n_eff;
        (e_dac_w / (frac * steady)).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_one_pays_full_reconfiguration() {
        // eq. (13): at L=1 the weight term is ~e_dac,2 per output — far
        // above the streamed case.
        let vm = VectorMatrix::photonic_40();
        let e1 = vm.e_comp_per_op(512, 512, 1, 45.0);
        let e_stream = vm.e_comp_per_op(512, 512, 100_000, 45.0);
        assert!(e1 > 20.0 * e_stream, "{e1} vs {e_stream}");
    }

    #[test]
    fn monotone_in_batch() {
        let vm = VectorMatrix::photonic_40();
        let es: Vec<f64> = [1usize, 4, 16, 64, 256, 4096]
            .iter()
            .map(|&l| vm.e_comp_per_op(512, 512, l, 45.0))
            .collect();
        for w in es.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn amortization_batch_is_consistent() {
        let vm = VectorMatrix::photonic_40();
        let l = vm.amortization_batch(512, 512, 45.0, 0.1);
        // At that batch the reconfig term is ≤10% of the steady terms.
        let e = EnergyParams::default().at_node(45.0);
        let e_dac_w = e.e_dac + vm.e_modulator + presets::photonic_40().energy();
        let steady = vm.e_comp_per_op(512, 512, usize::MAX, 45.0) * 2.0 / 2.0;
        assert!(e_dac_w / l as f64 <= 0.1 * (steady * 2.0) / 2.0 + 1e-18);
        // And it is a non-trivial batch: reconfiguration is expensive.
        assert!(l > 50, "crossover batch {l}");
    }

    #[test]
    fn clamped_by_processor_dims() {
        let vm = VectorMatrix::photonic_40();
        // A 4096-wide matrix amortizes no better than the 40-port mesh.
        let wide = vm.e_comp_per_op(4096, 4096, 1000, 45.0);
        let clamp = vm.e_comp_per_op(40, 40, 1000, 45.0);
        assert!((wide - clamp).abs() / clamp < 1e-12);
    }
}
