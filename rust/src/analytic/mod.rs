//! Closed-form efficiency models — the paper's eqs. (3), (5), (13)/(14)
//! and (18)–(24) — for the four processor classes compared in Figs. 6–7.
//!
//! Every model exposes the same interface: given a [`Workload`] (a conv
//! layer described by its dimensions and arithmetic intensity) and a
//! technology node, produce an [`Efficiency`] — energy per operation
//! split into *memory* and *compute* components, from which
//! η = 1/(e_mem + e_comp) in ops/J. Fig. 6 plots η vs node; Fig. 7 plots
//! the two components per processor.

pub mod cpu;
pub mod in_memory;
pub mod optical4f;
pub mod photonic;
pub mod vector_matrix;

use crate::networks::ConvLayer;

/// A workload for the analytic models: one convolutional layer plus both
/// of its arithmetic intensities.
///
/// `a_matmul` (eq. 8) is what a matrix-multiplication machine — the
/// systolic array or a planar photonic mesh, which both consume the
/// k²-duplicated Toeplitz input — can exploit; Table V's a = 230 is this
/// number. `a_native` (eq. 9) is the convolution-native intensity only an
/// operator-specialized processor (the 4F machine) reaches.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub layer: ConvLayer,
    /// eq. (9): native convolution arithmetic intensity.
    pub a_native: f64,
    /// eq. (8): conv-as-matmul arithmetic intensity.
    pub a_matmul: f64,
}

impl Workload {
    pub fn from_layer(layer: ConvLayer) -> Self {
        Workload {
            layer,
            a_native: layer.arithmetic_intensity(),
            a_matmul: layer.matmul_arithmetic_intensity(),
        }
    }

    /// Table V's reference layer: n=512, Cᵢ=Cᵢ₊₁=128, k=3 (a ≈ 230).
    pub fn reference() -> Self {
        Workload::from_layer(ConvLayer::square(512, 128, 128, 3, 1))
    }
}

/// Per-operation energy split of a processor on a workload.
#[derive(Clone, Copy, Debug)]
pub struct Efficiency {
    /// Memory-access energy per operation, J/op.
    pub e_mem: f64,
    /// Computational energy per operation, J/op.
    pub e_comp: f64,
}

impl Efficiency {
    /// Total energy per operation.
    pub fn per_op(&self) -> f64 {
        self.e_mem + self.e_comp
    }

    /// η in ops per joule (eq. 2).
    pub fn ops_per_joule(&self) -> f64 {
        1.0 / self.per_op()
    }

    /// η in the paper's TOPS/W unit.
    pub fn tops_per_watt(&self) -> f64 {
        self.ops_per_joule() / 1e12
    }
}

/// The four processor classes of Figs. 6–7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Processor {
    /// SISD CPU, eq. (3).
    Cpu,
    /// Digital in-memory (systolic array), eq. (5).
    DigitalInMemory,
    /// Planar silicon-photonic analog array, eqs. (13)/(14).
    SiliconPhotonic,
    /// Optical 4F convolution machine, eqs. (23)/(24).
    Optical4F,
}

impl Processor {
    pub const ALL: [Processor; 4] = [
        Processor::Cpu,
        Processor::DigitalInMemory,
        Processor::SiliconPhotonic,
        Processor::Optical4F,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Processor::Cpu => "CPU (SISD)",
            Processor::DigitalInMemory => "digital in-memory",
            Processor::SiliconPhotonic => "silicon photonic",
            Processor::Optical4F => "optical 4F",
        }
    }

    /// Short label used in Fig. 7 ("DIM", "SP", "O4F").
    pub fn short(&self) -> &'static str {
        match self {
            Processor::Cpu => "CPU",
            Processor::DigitalInMemory => "DIM",
            Processor::SiliconPhotonic => "SP",
            Processor::Optical4F => "O4F",
        }
    }

    /// Evaluate this processor's analytic model on a workload at a node,
    /// using the paper's §VI architectural parameters.
    pub fn efficiency(&self, w: &Workload, node_nm: f64) -> Efficiency {
        match self {
            Processor::Cpu => cpu::efficiency(node_nm),
            Processor::DigitalInMemory => {
                in_memory::Config::tpu_like().efficiency(w, node_nm)
            }
            Processor::SiliconPhotonic => {
                photonic::Config::typical().efficiency(w, node_nm)
            }
            Processor::Optical4F => {
                optical4f::Config::default_4mpx().efficiency(w, node_nm)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_workload_matches_table_v() {
        let w = Workload::reference();
        assert!((w.a_matmul - 230.0).abs() < 6.0, "a_mm = {}", w.a_matmul);
        assert!((w.a_native - 1149.0).abs() < 10.0, "a9 = {}", w.a_native);
    }

    #[test]
    fn efficiency_arithmetic() {
        let e = Efficiency {
            e_mem: 3e-13,
            e_comp: 2e-13,
        };
        assert!((e.per_op() - 5e-13).abs() < 1e-25);
        assert!((e.tops_per_watt() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig6_ordering_at_32nm() {
        // The paper's headline ordering: CPU << DIM < SP < O4F, with
        // roughly an order of magnitude between successive classes.
        let w = Workload::reference();
        let eta: Vec<f64> = Processor::ALL
            .iter()
            .map(|p| p.efficiency(&w, 32.0).tops_per_watt())
            .collect();
        assert!(eta[0] * 3.0 < eta[1], "CPU {} !<< DIM {}", eta[0], eta[1]);
        assert!(eta[1] < eta[2], "DIM {} !< SP {}", eta[1], eta[2]);
        assert!(eta[2] < eta[3], "SP {} !< O4F {}", eta[2], eta[3]);
        assert!(eta[3] > 10.0 * eta[1], "O4F {} should be ≳10× DIM {}", eta[3], eta[1]);
    }

    #[test]
    fn all_processors_improve_with_node() {
        let w = Workload::reference();
        for p in Processor::ALL {
            let e180 = p.efficiency(&w, 180.0).tops_per_watt();
            let e7 = p.efficiency(&w, 7.0).tops_per_watt();
            assert!(e7 > e180, "{}: {e180} -> {e7}", p.label());
        }
    }

    #[test]
    fn fig7_memory_dominates_cpu_compute_dominates_dim() {
        // Fig. 7's story: in-memory compute pushes memory energy below
        // compute energy; CPUs are memory-dominated.
        let w = Workload::reference();
        let cpu = Processor::Cpu.efficiency(&w, 32.0);
        let dim = Processor::DigitalInMemory.efficiency(&w, 32.0);
        assert!(cpu.e_mem > cpu.e_comp, "CPU must be memory-bound");
        assert!(dim.e_comp > dim.e_mem, "DIM must be compute-bound");
    }
}
