//! SISD CPU model — eq. (3).
//!
//! A scalar machine reads three values and writes one per MAC regardless
//! of operator structure (N_m = 2·N_op), so
//! η = 1/(2·e_m + e_op). With Table IV's 45 nm numbers this is
//! ≈ 0.11 TOPS/W — the paper's "0.1–1 TOPS/W … consistent with state of
//! the art" anchor.

use super::Efficiency;
use crate::energy::{sram::Sram, EnergyParams};

/// Memory bank the scalar datapath reads from (96 KB, the same bank size
/// as the TPU comparison so the contrast isolates *architecture*, not
/// memory technology).
pub const CPU_BANK_BYTES: usize = 96 * 1024;

/// eq. (3) at a technology node.
pub fn efficiency(node_nm: f64) -> Efficiency {
    let e = EnergyParams::default().at_node(node_nm);
    let sram = Sram::at_node(CPU_BANK_BYTES, node_nm);
    // Per *operation* (2 ops per MAC): N_m/N_op = 2 accesses/op (paper:
    // four accesses per two ops), each a one-byte operand at 8 bits.
    Efficiency {
        e_mem: 2.0 * sram.energy_per_byte,
        // e_op: the MAC pair (mul+add) costs e_mac; per op that's /2,
        // but the paper folds the whole MAC into e_op ≈ e_mac. We follow
        // the paper: η = 1/(2e_m + e_mac).
        e_comp: e.e_mac,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_0_1_tops_at_45nm() {
        // §II: "0.1-1 TOPS/W"; with 96 KB banks: 1/(2·4.33+0.23) ≈ 0.11.
        let eta = efficiency(45.0).tops_per_watt();
        assert!((eta - 0.112).abs() < 0.01, "η = {eta}");
    }

    #[test]
    fn memory_bound() {
        let e = efficiency(45.0);
        assert!(e.e_mem > 10.0 * e.e_comp);
    }

    #[test]
    fn improves_with_node_but_stays_under_1_tops() {
        let eta7 = efficiency(7.0).tops_per_watt();
        assert!(eta7 > efficiency(45.0).tops_per_watt());
        assert!(eta7 < 2.0, "CPU stays ~order 0.1-1 TOPS/W: {eta7}");
    }
}
