//! Optical 4F convolution machine — eqs. (18)–(24).
//!
//! The reflection-mode, two-chip machine of Fig. 5: an SLM/metasurface +
//! CIS pair on either side of a single lens. Per layer it (1) loads the
//! optical Fourier transform of C′ input channels onto the Fourier-plane
//! SLM and (2) streams kernels through the object plane, measuring one
//! output channel per execution. The per-op energy follows
//!
//!   e_op = e_dac/M + e_dac/L + e_adc/N          (eq. 24)
//!
//! with L = n², M = k²Cᵢ₊₁/2, N = k²C′Cᵢ₊₁/(C′+Cᵢ₊₁) (eq. 23) and
//! C′ = ⌊N̂/n²⌋ (eq. 22); e_dac includes the SLM active-matrix load and
//! the laser shot-noise energy (§VII.B).

use super::{Efficiency, Workload};
use crate::energy::{
    constants::{SLM_PIXELS, TOTAL_SRAM_BYTES},
    load::presets,
    sram::{bank_bytes, Sram},
    EnergyParams,
};
use crate::networks::stats::optical4f_dims;

/// Architectural parameters of the optical 4F machine.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// SLM pixel count N̂ (4 Mpx default).
    pub slm_pixels: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// SRAM bank count (§VII.B: 2048 banks of 12 KB, one per SLM row).
    pub banks: usize,
}

impl Config {
    /// The paper's §VI/§VII.B machine: 4 Mpx SLMs, 24 MiB SRAM / 2048.
    pub fn default_4mpx() -> Self {
        Config {
            slm_pixels: SLM_PIXELS,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: 2048,
        }
    }

    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }

    /// Effective per-sample DAC energy driving one SLM pixel: converter
    /// circuit + segmented active-matrix line load + laser photons.
    pub fn e_dac_slm(&self, node_nm: f64) -> f64 {
        let e = EnergyParams::default().at_node(node_nm);
        e.e_dac + presets::slm_2048().energy() + e.e_opt
    }

    /// eq. (24) on a conv layer, at a node.
    pub fn efficiency(&self, w: &Workload, node_nm: f64) -> Efficiency {
        let e = EnergyParams::default().at_node(node_nm);
        let (l, n, m) = optical4f_dims(&w.layer, Some(self.slm_pixels));
        let e_dac = self.e_dac_slm(node_nm);
        // eq. (24); the signed-value factor is baked into M (eq. 23c).
        let per_mac = e_dac / m + e_dac / l + e.e_adc / n;
        // Native convolution — no Toeplitz duplication — so the SRAM term
        // amortizes over the layer's *native* intensity (eq. 9 / eq. 21).
        let sram = Sram::at_node(self.bank_bytes(), node_nm);
        Efficiency {
            e_mem: sram.energy_per_byte / w.a_native,
            e_comp: per_mac / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_size_12kb() {
        assert_eq!(Config::default_4mpx().bank_bytes(), 12 * 1024);
    }

    #[test]
    fn e_dac_slm_mostly_load() {
        // 0.01 (circuit) + 0.04 (load) + 0.01 (laser) ≈ 0.06 pJ.
        let e = Config::default_4mpx().e_dac_slm(45.0);
        assert!((e * 1e12 - 0.06).abs() < 0.01, "{} pJ", e * 1e12);
    }

    #[test]
    fn order_100_tops_at_45nm() {
        // §VI: another order of magnitude beyond silicon photonics.
        let eta = Config::default_4mpx()
            .efficiency(&Workload::reference(), 45.0)
            .tops_per_watt();
        assert!(eta > 50.0 && eta < 500.0, "η = {eta}");
    }

    #[test]
    fn compute_below_memory() {
        // Fig. 7: the 4F machine pushes compute energy *below* the
        // in-memory-compute memory floor.
        let e = Config::default_4mpx().efficiency(&Workload::reference(), 32.0);
        assert!(e.e_comp < e.e_mem, "e_comp {} !< e_mem {}", e.e_comp, e.e_mem);
    }

    #[test]
    fn bigger_slm_helps_until_channels_exhausted() {
        let w = Workload::reference(); // n=512, Ci=128
        let small = Config {
            slm_pixels: 1024 * 1024,
            ..Config::default_4mpx()
        };
        let big = Config {
            slm_pixels: 64 * 1024 * 1024,
            ..Config::default_4mpx()
        };
        let e_small = small.efficiency(&w, 45.0);
        let e_big = big.efficiency(&w, 45.0);
        assert!(e_big.e_comp < e_small.e_comp);
    }
}
