//! Planar silicon-photonic analog array — eqs. (13)/(14).
//!
//! A `dim × dim` array of electro-optic modulators (MZI mesh or VOA
//! crossbar) performing matrix–matrix multiplication:
//!
//!   e_op = e_dac,1/M + e_dac,2/L + e_adc/N     (eq. 14)
//!
//! with every term doubled for signed values (§IV.A), M and N clamped to
//! the array dimensions (eq. 15), and L the (unbounded) streaming
//! dimension. DAC energies include the modulator drive and the array
//! line load (eq. A5); inputs additionally pay the shot-noise-limited
//! laser energy (eq. A8).

use super::{Efficiency, Workload};
use crate::energy::{
    constants::{E_EO_MODULATOR_FUTURE, PHOTONIC_DIM, TOTAL_SRAM_BYTES},
    load::presets,
    sram::{bank_bytes, Sram},
    EnergyParams,
};

/// Architectural parameters of the planar photonic processor.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Array dimension (N̂ = M̂ = dim).
    pub dim: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// SRAM bank count (§VI: 40 banks of 600 KB).
    pub banks: usize,
    /// Electro-optic modulator energy per sample, J (§VI assumes the
    /// technology improves to 0.5 pJ).
    pub e_modulator: f64,
}

impl Config {
    /// §VI parameters: 40×40 array (100–400 µm modulator pitches cap
    /// practical meshes), 24 MiB SRAM in 40 banks.
    pub fn typical() -> Self {
        Config {
            dim: PHOTONIC_DIM,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: PHOTONIC_DIM,
            e_modulator: E_EO_MODULATOR_FUTURE,
        }
    }

    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }

    /// eq. (14) on a conv layer mapped through eq. (16), at a node.
    pub fn efficiency(&self, w: &Workload, node_nm: f64) -> Efficiency {
        let e = EnergyParams::default().at_node(node_nm);
        let (l_dim, n_dim, m_dim) = w.layer.matmul_dims();
        // eq. (15): amortization clamped by the physical array.
        let m = m_dim.min(self.dim as f64);
        let n = n_dim.min(self.dim as f64);
        let l = l_dim; // streaming (time) dimension, not hardware-limited

        // eq. (A5)+(A7): input DAC drives modulator + laser; weight DAC
        // drives modulator + array line load.
        let e_dac_in = e.e_dac + self.e_modulator + e.e_opt;
        let e_dac_w = e.e_dac + self.e_modulator + presets::photonic_40().energy();

        // eq. (14), ×2 for signed values (§IV.A), halved per op
        // (N_op = 2·MACs).
        let per_mac = 2.0 * (e_dac_in / m + e_dac_w / l + e.e_adc / n);
        // The matmul mapping reads the k²-duplicated Toeplitz activations,
        // so the SRAM term uses the *matmul* intensity (eq. 8).
        let a_mm = w.layer.matmul_arithmetic_intensity();
        let sram = Sram::at_node(self.bank_bytes(), node_nm);
        Efficiency {
            e_mem: sram.energy_per_byte / a_mm,
            e_comp: per_mac / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_size_600kb() {
        let c = Config::typical();
        assert_eq!(c.bank_bytes(), TOTAL_SRAM_BYTES / 40);
    }

    #[test]
    fn order_10_tops_at_45nm() {
        // §VI: roughly an order of magnitude above digital in-memory.
        let eta = Config::typical()
            .efficiency(&Workload::reference(), 45.0)
            .tops_per_watt();
        assert!(eta > 5.0 && eta < 80.0, "η = {eta}");
    }

    #[test]
    fn amortization_clamped_by_array() {
        // Reference layer: M' = 128 > 40, N' = 1152 > 40 ⇒ both clamp.
        let cfg = Config::typical();
        let w = Workload::reference();
        let e40 = cfg.efficiency(&w, 45.0);
        let big = Config {
            dim: 4096,
            ..cfg
        };
        let e_big = big.efficiency(&w, 45.0);
        assert!(
            e_big.e_comp < e40.e_comp,
            "bigger array must amortize converters better"
        );
    }

    #[test]
    fn modulator_energy_dominates_compute() {
        // §VI: "computational energy consumption is highly limited by the
        // optical modulator technology".
        let cfg = Config::typical();
        let w = Workload::reference();
        let base = cfg.efficiency(&w, 45.0).e_comp;
        let better = Config {
            e_modulator: 0.05e-12,
            ..cfg
        }
        .efficiency(&w, 45.0)
        .e_comp;
        assert!(better < base / 2.0, "{base} -> {better}");
    }
}
