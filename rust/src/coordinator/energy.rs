//! Per-batch energy co-simulation.
//!
//! While the execution backend computes the *answer*, the cycle-accurate
//! simulators price the same layer schedule on the paper's machines, so
//! every served batch carries a projected joules-per-inference for each
//! architecture — the hw/sw-codesign readout of the serving stack.
//!
//! Two pricing paths feed the metrics:
//!
//! * **co-simulation** — workers call [`co_simulate_cached`] against one
//!   [`SweepCache`] shared by all workers: the first batch anywhere
//!   simulates the layer schedule, every later batch is map lookups.
//! * **surrogate** — when the server was started with a fitted
//!   [`crate::energy::surrogate::SurrogateTable`], the network is priced
//!   *once* at startup through the closed-form models
//!   (`SurrogateTable::quote_network_op`) and the steady-state loop never
//!   touches a simulator: per-batch accounting is a multiply, and the
//!   same quote powers per-request µJ attribution and the
//!   `max_uj_per_inf` admission policy.
//!
//! Both paths price at the server's full [`OperatingPoint`] — node *and*
//! bit widths (`--bits` on `aimc serve`) — so precision shows up in the
//! per-batch µJ, the admission decisions and the bench JSON.
//!
//! Either way the per-batch reports accumulate into the worker's metrics
//! shard (`Metrics::record_energy` / `record_priced_energy`, tagged with
//! the pricing source) and merge at shutdown, so `aimc serve` and
//! `BENCH_serve.json` report measured latency/throughput alongside
//! projected µJ-per-inference from the same workload.

use crate::networks::Network;
use crate::simulator::{optical4f, systolic, OperatingPoint, SimResult, SweepCache};

/// Energy projections for one inference of `net` at an operating point.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub systolic: SimResult,
    pub optical4f: SimResult,
    pub op: OperatingPoint,
}

impl EnergyReport {
    /// Joules per single inference on the systolic machine.
    pub fn systolic_joules(&self) -> f64 {
        self.systolic.ledger.total()
    }

    /// Joules per single inference on the optical 4F machine.
    pub fn optical_joules(&self) -> f64 {
        self.optical4f.ledger.total()
    }

    pub fn summary(&self) -> String {
        format!(
            "@{} nm {}b: systolic {:.2} µJ ({:.2} TOPS/W) | optical-4F {:.2} µJ ({:.2} TOPS/W)",
            self.op.node_nm,
            self.op.bits_label(),
            self.systolic_joules() * 1e6,
            self.systolic.tops_per_watt(),
            self.optical_joules() * 1e6,
            self.optical4f.tops_per_watt(),
        )
    }
}

/// Price one inference of `net` on both machines.
pub fn co_simulate(net: &Network, op: &OperatingPoint) -> EnergyReport {
    co_simulate_cached(net, op, &SweepCache::new())
}

/// [`co_simulate`] through a shared layer-dedup cache — a server pricing
/// the same layer schedule on every batch pays the simulators once.
pub fn co_simulate_cached(net: &Network, op: &OperatingPoint, cache: &SweepCache) -> EnergyReport {
    let sys = systolic::SystolicConfig::default();
    let opt = optical4f::Optical4FConfig::default();
    EnergyReport {
        systolic: cache.simulate_network(&sys, net, op),
        optical4f: cache.simulate_network(&opt, net, op),
        op: *op,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::smallcnn_network;

    fn op45() -> OperatingPoint {
        OperatingPoint::node(45.0)
    }

    #[test]
    fn co_sim_smallcnn() {
        let r = co_simulate(&smallcnn_network(), &op45());
        assert!(r.systolic_joules() > 0.0);
        assert!(r.optical_joules() > 0.0);
        assert_eq!(r.systolic.macs, r.optical4f.macs);
        assert!(r.summary().contains("TOPS/W"));
        assert!(r.summary().contains("8x8b"), "{}", r.summary());
    }

    #[test]
    fn cached_co_sim_identical_and_reuses_entries() {
        let net = smallcnn_network();
        let direct = co_simulate(&net, &op45());
        let cache = SweepCache::new();
        let first = co_simulate_cached(&net, &op45(), &cache);
        let misses_after_first = cache.misses();
        let second = co_simulate_cached(&net, &op45(), &cache);
        assert_eq!(direct.systolic_joules(), first.systolic_joules());
        assert_eq!(direct.optical_joules(), second.optical_joules());
        assert_eq!(
            cache.misses(),
            misses_after_first,
            "second pricing must be pure cache hits"
        );
    }

    #[test]
    fn lower_serving_precision_prices_below_default() {
        let net = smallcnn_network();
        let full = co_simulate(&net, &op45());
        let quant = co_simulate(&net, &op45().bits(4, 4));
        assert!(quant.systolic_joules() < full.systolic_joules());
        assert!(quant.optical_joules() < full.optical_joules());
        assert_eq!(full.systolic.macs, quant.systolic.macs, "same work, cheaper events");
    }

    #[test]
    fn small_images_favor_systolic() {
        // SmallCNN's 64×64 maps under-fill the 4 Mpx SLM: the full-
        // aperture laser cost is amortized over almost no work, so the
        // optical machine loses at tiny scale — the paper's scaling
        // argument run in reverse (analog wins only at scale).
        let r = co_simulate(&smallcnn_network(), &op45());
        assert!(
            r.optical4f.tops_per_watt() < r.systolic.tops_per_watt(),
            "optical {} vs systolic {}",
            r.optical4f.tops_per_watt(),
            r.systolic.tops_per_watt()
        );
    }

    #[test]
    fn yolo_favors_optical() {
        // …and at the paper's 1 Mpx scale the ordering flips.
        let r = co_simulate(&crate::networks::yolov3::yolov3(1000), &op45());
        assert!(r.optical4f.tops_per_watt() > r.systolic.tops_per_watt());
    }
}
