//! Per-batch energy co-simulation.
//!
//! While the execution backend computes the *answer*, the cycle-accurate
//! simulators price the same layer schedule on the paper's machines, so
//! every served batch carries a projected joules-per-inference for each
//! architecture — the hw/sw-codesign readout of the serving stack.
//!
//! Two pricing paths feed the metrics:
//!
//! * **co-simulation** — workers call [`co_simulate_cached`] against one
//!   [`SweepCache`] shared by all workers: the first batch anywhere
//!   simulates the layer schedule, every later batch is map lookups.
//! * **surrogate** — when the server was started with a fitted
//!   [`crate::energy::surrogate::SurrogateTable`], the network is priced
//!   *once* at startup through the closed-form models
//!   (`SurrogateTable::quote_network_op`) and the steady-state loop never
//!   touches a simulator: per-batch accounting is a multiply, and the
//!   same quote powers per-request µJ attribution and the
//!   `max_uj_per_inf` admission policy.
//!
//! Both paths price at the server's full [`OperatingPoint`] — node *and*
//! bit widths (`--bits` on `aimc serve`) — so precision shows up in the
//! per-batch µJ, the admission decisions and the bench JSON.
//!
//! Either way the per-batch reports accumulate into the worker's metrics
//! shard (`Metrics::record_energy` / `record_priced_energy`, tagged with
//! the pricing source) and merge at shutdown, so `aimc serve` and
//! `BENCH_serve.json` report measured latency/throughput alongside
//! projected µJ-per-inference from the same workload.

use crate::energy::surrogate::MachineKind;
use crate::networks::Network;
use crate::simulator::{optical4f, systolic, OperatingPoint, SimResult, SweepCache};

/// Energy projections for one inference of `net` at an operating point.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub systolic: SimResult,
    pub optical4f: SimResult,
    pub op: OperatingPoint,
}

impl EnergyReport {
    /// Joules per single inference on the systolic machine.
    pub fn systolic_joules(&self) -> f64 {
        self.systolic.ledger.total()
    }

    /// Joules per single inference on the optical 4F machine.
    pub fn optical_joules(&self) -> f64 {
        self.optical4f.ledger.total()
    }

    pub fn summary(&self) -> String {
        format!(
            "@{} nm {}b: systolic {:.2} µJ ({:.2} TOPS/W) | optical-4F {:.2} µJ ({:.2} TOPS/W)",
            self.op.node_nm,
            self.op.bits_label(),
            self.systolic_joules() * 1e6,
            self.systolic.tops_per_watt(),
            self.optical_joules() * 1e6,
            self.optical4f.tops_per_watt(),
        )
    }
}

/// Price one inference of `net` on both machines.
pub fn co_simulate(net: &Network, op: &OperatingPoint) -> EnergyReport {
    co_simulate_cached(net, op, &SweepCache::new())
}

/// [`co_simulate`] through a shared layer-dedup cache — a server pricing
/// the same layer schedule on every batch pays the simulators once.
pub fn co_simulate_cached(net: &Network, op: &OperatingPoint, cache: &SweepCache) -> EnergyReport {
    let sys = systolic::SystolicConfig::default();
    let opt = optical4f::Optical4FConfig::default();
    EnergyReport {
        systolic: cache.simulate_network(&sys, net, op),
        optical4f: cache.simulate_network(&opt, net, op),
        op: *op,
    }
}

/// Nominal wall-clock per simulator time unit for one machine kind, in
/// nanoseconds. `SimResult::time_units` is machine-specific (systolic
/// cycles, ReRAM passes, photonic reconfigurations, 4F SLM executions);
/// these constants turn it into a *routing signal* for `--slo-ns` —
/// comparable across backends in order of magnitude, deliberately NOT a
/// timing model (the repo has no cycle-time model; see ROADMAP).
pub fn nominal_step_ns(kind: MachineKind) -> f64 {
    match kind {
        // GHz-class digital array: ~1 ns per systolic cycle.
        MachineKind::Systolic => 1.0,
        // A ReRAM crossbar pass is DAC→analog MAC→ADC: ~100 ns.
        MachineKind::Reram => 100.0,
        // Photonic mesh reconfiguration: ~10 ns (thermo-optic settle).
        MachineKind::Photonic => 10.0,
        // 4F SLM frame load + exposure: ~10 µs per execution.
        MachineKind::Optical4F => 10_000.0,
    }
}

/// Per-inference cost of one fleet backend, resolved at startup and
/// captured by that backend's lanes: the dispatcher routes each planned
/// batch to the live lane minimizing `j_per_inf` (or `ns_per_inf` under
/// an SLO) — see `coordinator::server`.
#[derive(Clone, Copy, Debug)]
pub struct BackendQuote {
    pub kind: MachineKind,
    /// Projected joules per single inference at the lane's operating
    /// point.
    pub j_per_inf: f64,
    /// Nominal nanoseconds per inference (`time_units ×
    /// [`nominal_step_ns`]`) — a cross-backend routing signal, not a
    /// latency prediction. `None` when the quote came from the surrogate
    /// alone (the closed-form table only models joules) and no SLO asked
    /// for it.
    pub ns_per_inf: Option<f64>,
    /// Which path priced it: `"surrogate"` or `"co-simulation"`.
    pub source: &'static str,
}

/// Price one inference of `net` on `kind`'s default-config cycle machine
/// through the shared cache — the co-simulation path behind a fleet
/// lane's [`BackendQuote`] (the surrogate path only covers joules, so
/// `ns_per_inf` always comes from here).
pub fn co_simulate_kind(
    kind: MachineKind,
    net: &Network,
    op: &OperatingPoint,
    cache: &SweepCache,
) -> BackendQuote {
    let r = cache.simulate_network(kind.machine().as_ref(), net, op);
    BackendQuote {
        kind,
        j_per_inf: r.ledger.total(),
        ns_per_inf: Some(r.time_units * nominal_step_ns(kind)),
        source: "co-simulation",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::smallcnn_network;

    fn op45() -> OperatingPoint {
        OperatingPoint::node(45.0)
    }

    #[test]
    fn co_sim_smallcnn() {
        let r = co_simulate(&smallcnn_network(), &op45());
        assert!(r.systolic_joules() > 0.0);
        assert!(r.optical_joules() > 0.0);
        assert_eq!(r.systolic.macs, r.optical4f.macs);
        assert!(r.summary().contains("TOPS/W"));
        assert!(r.summary().contains("8x8b"), "{}", r.summary());
    }

    #[test]
    fn cached_co_sim_identical_and_reuses_entries() {
        let net = smallcnn_network();
        let direct = co_simulate(&net, &op45());
        let cache = SweepCache::new();
        let first = co_simulate_cached(&net, &op45(), &cache);
        let misses_after_first = cache.misses();
        let second = co_simulate_cached(&net, &op45(), &cache);
        assert_eq!(direct.systolic_joules(), first.systolic_joules());
        assert_eq!(direct.optical_joules(), second.optical_joules());
        assert_eq!(
            cache.misses(),
            misses_after_first,
            "second pricing must be pure cache hits"
        );
    }

    #[test]
    fn lower_serving_precision_prices_below_default() {
        let net = smallcnn_network();
        let full = co_simulate(&net, &op45());
        let quant = co_simulate(&net, &op45().bits(4, 4));
        assert!(quant.systolic_joules() < full.systolic_joules());
        assert!(quant.optical_joules() < full.optical_joules());
        assert_eq!(full.systolic.macs, quant.systolic.macs, "same work, cheaper events");
    }

    #[test]
    fn small_images_favor_systolic() {
        // SmallCNN's 64×64 maps under-fill the 4 Mpx SLM: the full-
        // aperture laser cost is amortized over almost no work, so the
        // optical machine loses at tiny scale — the paper's scaling
        // argument run in reverse (analog wins only at scale).
        let r = co_simulate(&smallcnn_network(), &op45());
        assert!(
            r.optical4f.tops_per_watt() < r.systolic.tops_per_watt(),
            "optical {} vs systolic {}",
            r.optical4f.tops_per_watt(),
            r.systolic.tops_per_watt()
        );
    }

    #[test]
    fn per_kind_quote_matches_the_pair_co_sim() {
        // The fleet quote for systolic/optical4f must agree with the
        // legacy two-machine report — same simulators, same cache keys.
        let net = smallcnn_network();
        let cache = SweepCache::new();
        let pair = co_simulate_cached(&net, &op45(), &cache);
        let sys = co_simulate_kind(MachineKind::Systolic, &net, &op45(), &cache);
        let opt = co_simulate_kind(MachineKind::Optical4F, &net, &op45(), &cache);
        assert_eq!(sys.j_per_inf, pair.systolic_joules());
        assert_eq!(opt.j_per_inf, pair.optical_joules());
        assert_eq!(sys.source, "co-simulation");
        for kind in MachineKind::ALL {
            let q = co_simulate_kind(kind, &net, &op45(), &cache);
            assert!(q.j_per_inf > 0.0, "{kind:?}");
            assert!(q.ns_per_inf.unwrap() > 0.0, "{kind:?}");
            assert_eq!(
                q.ns_per_inf.unwrap(),
                cache
                    .simulate_network(kind.machine().as_ref(), &net, &op45())
                    .time_units
                    * nominal_step_ns(kind)
            );
        }
    }

    #[test]
    fn yolo_favors_optical() {
        // …and at the paper's 1 Mpx scale the ordering flips.
        let r = co_simulate(&crate::networks::yolov3::yolov3(1000), &op45());
        assert!(r.optical4f.tops_per_watt() > r.systolic.tops_per_watt());
    }
}
