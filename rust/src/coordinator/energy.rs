//! Per-request energy co-simulation.
//!
//! While the PJRT engine computes the *answer*, the cycle-accurate
//! simulators price the same layer schedule on the paper's machines, so
//! every served batch carries a projected joules-per-inference for each
//! architecture — the hw/sw-codesign readout of the serving stack.

use crate::simulator::{optical4f, systolic, SimResult};
use crate::networks::Network;

/// Energy projections for one inference of `net` at `node_nm`.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub systolic: SimResult,
    pub optical4f: SimResult,
    pub node_nm: f64,
}

impl EnergyReport {
    /// Joules per single inference on the systolic machine.
    pub fn systolic_joules(&self) -> f64 {
        self.systolic.ledger.total()
    }

    /// Joules per single inference on the optical 4F machine.
    pub fn optical_joules(&self) -> f64 {
        self.optical4f.ledger.total()
    }

    pub fn summary(&self) -> String {
        format!(
            "@{} nm: systolic {:.2} µJ ({:.2} TOPS/W) | optical-4F {:.2} µJ ({:.2} TOPS/W)",
            self.node_nm,
            self.systolic_joules() * 1e6,
            self.systolic.tops_per_watt(),
            self.optical_joules() * 1e6,
            self.optical4f.tops_per_watt(),
        )
    }
}

/// Price one inference of `net` on both machines.
pub fn co_simulate(net: &Network, node_nm: f64) -> EnergyReport {
    EnergyReport {
        systolic: systolic::simulate_network(&systolic::SystolicConfig::default(), net, node_nm),
        optical4f: optical4f::simulate_network(
            &optical4f::Optical4FConfig::default(),
            net,
            node_nm,
        ),
        node_nm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::smallcnn_network;

    #[test]
    fn co_sim_smallcnn() {
        let r = co_simulate(&smallcnn_network(), 45.0);
        assert!(r.systolic_joules() > 0.0);
        assert!(r.optical_joules() > 0.0);
        assert_eq!(r.systolic.macs, r.optical4f.macs);
        assert!(r.summary().contains("TOPS/W"));
    }

    #[test]
    fn small_images_favor_systolic() {
        // SmallCNN's 64×64 maps under-fill the 4 Mpx SLM: the full-
        // aperture laser cost is amortized over almost no work, so the
        // optical machine loses at tiny scale — the paper's scaling
        // argument run in reverse (analog wins only at scale).
        let r = co_simulate(&smallcnn_network(), 45.0);
        assert!(
            r.optical4f.tops_per_watt() < r.systolic.tops_per_watt(),
            "optical {} vs systolic {}",
            r.optical4f.tops_per_watt(),
            r.systolic.tops_per_watt()
        );
    }

    #[test]
    fn yolo_favors_optical() {
        // …and at the paper's 1 Mpx scale the ordering flips.
        let r = co_simulate(&crate::networks::yolov3::yolov3(1000), 45.0);
        assert!(r.optical4f.tops_per_watt() > r.systolic.tops_per_watt());
    }
}
