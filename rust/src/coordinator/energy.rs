//! Per-batch energy co-simulation.
//!
//! While the execution backend computes the *answer*, the cycle-accurate
//! simulators price the same layer schedule on the paper's machines, so
//! every served batch carries a projected joules-per-inference for each
//! architecture — the hw/sw-codesign readout of the serving stack.
//!
//! Two pricing paths feed the metrics:
//!
//! * **co-simulation** — workers call [`co_simulate_cached`] against one
//!   [`SweepCache`] shared by all workers: the first batch anywhere
//!   simulates the layer schedule, every later batch is map lookups.
//! * **surrogate** — when the server was started with a fitted
//!   [`crate::energy::surrogate::SurrogateTable`], the network is priced
//!   *once* at startup through the closed-form models
//!   (`SurrogateTable::quote_network`) and the steady-state loop never
//!   touches a simulator: per-batch accounting is a multiply, and the
//!   same quote powers per-request µJ attribution and the
//!   `max_uj_per_inf` admission policy.
//!
//! Either way the per-batch reports accumulate into the worker's metrics
//! shard (`Metrics::record_energy` / `record_priced_energy`, tagged with
//! the pricing source) and merge at shutdown, so `aimc serve` and
//! `BENCH_serve.json` report measured latency/throughput alongside
//! projected µJ-per-inference from the same workload.

use crate::networks::Network;
use crate::simulator::{optical4f, systolic, SimResult, SweepCache};

/// Energy projections for one inference of `net` at `node_nm`.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub systolic: SimResult,
    pub optical4f: SimResult,
    pub node_nm: f64,
}

impl EnergyReport {
    /// Joules per single inference on the systolic machine.
    pub fn systolic_joules(&self) -> f64 {
        self.systolic.ledger.total()
    }

    /// Joules per single inference on the optical 4F machine.
    pub fn optical_joules(&self) -> f64 {
        self.optical4f.ledger.total()
    }

    pub fn summary(&self) -> String {
        format!(
            "@{} nm: systolic {:.2} µJ ({:.2} TOPS/W) | optical-4F {:.2} µJ ({:.2} TOPS/W)",
            self.node_nm,
            self.systolic_joules() * 1e6,
            self.systolic.tops_per_watt(),
            self.optical_joules() * 1e6,
            self.optical4f.tops_per_watt(),
        )
    }
}

/// Price one inference of `net` on both machines.
pub fn co_simulate(net: &Network, node_nm: f64) -> EnergyReport {
    co_simulate_cached(net, node_nm, &SweepCache::new())
}

/// [`co_simulate`] through a shared layer-dedup cache — a server pricing
/// the same layer schedule on every batch pays the simulators once.
pub fn co_simulate_cached(net: &Network, node_nm: f64, cache: &SweepCache) -> EnergyReport {
    let sys = systolic::SystolicConfig::default();
    let opt = optical4f::Optical4FConfig::default();
    EnergyReport {
        systolic: cache.simulate_network(&sys, net, node_nm),
        optical4f: cache.simulate_network(&opt, net, node_nm),
        node_nm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::smallcnn_network;

    #[test]
    fn co_sim_smallcnn() {
        let r = co_simulate(&smallcnn_network(), 45.0);
        assert!(r.systolic_joules() > 0.0);
        assert!(r.optical_joules() > 0.0);
        assert_eq!(r.systolic.macs, r.optical4f.macs);
        assert!(r.summary().contains("TOPS/W"));
    }

    #[test]
    fn cached_co_sim_identical_and_reuses_entries() {
        let net = smallcnn_network();
        let direct = co_simulate(&net, 45.0);
        let cache = SweepCache::new();
        let first = co_simulate_cached(&net, 45.0, &cache);
        let misses_after_first = cache.misses();
        let second = co_simulate_cached(&net, 45.0, &cache);
        assert_eq!(direct.systolic_joules(), first.systolic_joules());
        assert_eq!(direct.optical_joules(), second.optical_joules());
        assert_eq!(
            cache.misses(),
            misses_after_first,
            "second pricing must be pure cache hits"
        );
    }

    #[test]
    fn small_images_favor_systolic() {
        // SmallCNN's 64×64 maps under-fill the 4 Mpx SLM: the full-
        // aperture laser cost is amortized over almost no work, so the
        // optical machine loses at tiny scale — the paper's scaling
        // argument run in reverse (analog wins only at scale).
        let r = co_simulate(&smallcnn_network(), 45.0);
        assert!(
            r.optical4f.tops_per_watt() < r.systolic.tops_per_watt(),
            "optical {} vs systolic {}",
            r.optical4f.tops_per_watt(),
            r.systolic.tops_per_watt()
        );
    }

    #[test]
    fn yolo_favors_optical() {
        // …and at the paper's 1 Mpx scale the ordering flips.
        let r = co_simulate(&crate::networks::yolov3::yolov3(1000), 45.0);
        assert!(r.optical4f.tops_per_watt() > r.systolic.tops_per_watt());
    }
}
