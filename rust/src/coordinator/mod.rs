//! Layer-3 serving coordinator.
//!
//! The paper's contribution is the architecture analysis, so L3 here is a
//! lean but real inference server over the PJRT [`crate::runtime`]:
//!
//! * [`batcher`] — dynamic batching: requests accumulate up to a batch
//!   budget or a deadline, whichever first, and the dispatcher picks the
//!   largest compiled batch variant that fits (mirroring eq. 22's C′
//!   channel-packing decision on the optical machine: batching amortizes
//!   fixed per-execution cost over more useful work).
//! * [`server`] — the sharded serving path (std threads; the offline
//!   environment has no tokio), sharded end to end: N bounded ingress
//!   shards picked per client thread behind a sharded admission counter
//!   (`max_pending`), a dispatcher that drains the shards round-robin
//!   and hands planned batches to per-worker SPSC lanes (least-loaded),
//!   per-worker metrics shards merged at shutdown, and a condvar drain
//!   barrier so shutdown (or drop) answers every admitted request
//!   before joining threads. See `coordinator/README.md` for the full
//!   data flow.
//! * [`exec`] — execution backends behind the [`exec::Executor`] trait:
//!   the PJRT engine, or the deterministic [`exec::SimExecutor`] so the
//!   serving path runs (tests, `cargo bench -- serve`) without
//!   artifacts.
//! * [`metrics`] — latency/throughput accounting (p50/p95/p99, batch
//!   histogram, rejected count) plus accumulated per-batch energy,
//!   sharded per worker.
//! * [`energy`] — per-batch energy co-simulation: each worker prices
//!   every batch it executes on the cycle-accurate systolic and
//!   optical-4F machines (through one shared layer-dedup cache), so the
//!   server reports projected joules-per-inference alongside latency,
//!   from the same workload.
//!
//! The SmallCNN layer schedule (mirroring `python/compile/model.py`) is
//! defined in [`smallcnn_network`] for the co-simulation.

pub mod batcher;
pub mod energy;
pub mod exec;
pub mod metrics;
pub mod server;

use crate::networks::{ConvLayer, Network};

/// SmallCNN conv topology — MUST mirror `python/compile/model.py`
/// (`SMALLCNN_CHANNELS = (3, 8, 16, 32, 32)`, k=3, pools after the first
/// three convs, input 64×64).
pub fn smallcnn_network() -> Network {
    // Input 64 → conv(62) pool(31) → conv(29) pool(14) → conv(12) pool(6)
    // → conv(4). Spatial entries are the conv *input* sizes.
    let chans = [3usize, 8, 16, 32, 32];
    let mut layers = Vec::new();
    let mut n = 64usize;
    for i in 0..chans.len() - 1 {
        layers.push(ConvLayer::square(n, chans[i], chans[i + 1], 3, 1));
        n -= 2; // valid 3×3
        if i < 3 {
            n /= 2; // avg-pool 2×2 (truncating)
        }
    }
    Network {
        name: "SmallCNN",
        layers,
    }
}

/// Which compiled datapath variant serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvPath {
    /// f32 oracle (XLA-native convs).
    Exact,
    /// 8-bit weight-stationary systolic functional model (Pallas qmatmul).
    Systolic,
    /// Optical-4F functional model (FFT + Pallas Fourier-plane kernel).
    Fft,
}

impl ConvPath {
    pub fn artifact_prefix(&self) -> &'static str {
        match self {
            ConvPath::Exact => "smallcnn_exact",
            ConvPath::Systolic => "smallcnn_systolic",
            ConvPath::Fft => "smallcnn_fft",
        }
    }

    /// Batch sizes with compiled variants, largest first (see aot.py).
    pub fn available_batches(&self) -> &'static [usize] {
        match self {
            ConvPath::Exact | ConvPath::Systolic => &[8, 4, 1],
            ConvPath::Fft => &[1],
        }
    }

    /// Artifact name for a given compiled batch size.
    pub fn artifact_for_batch(&self, batch: usize) -> String {
        if batch == 1 {
            self.artifact_prefix().to_string()
        } else {
            format!("{}_b{}", self.artifact_prefix(), batch)
        }
    }

    pub fn parse(s: &str) -> Option<ConvPath> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Some(ConvPath::Exact),
            "systolic" => Some(ConvPath::Systolic),
            "fft" | "optical" | "4f" => Some(ConvPath::Fft),
            _ => None,
        }
    }
}

/// SmallCNN I/O geometry (mirrors model.py).
pub const IMAGE_ELEMS: usize = 3 * 64 * 64;
pub const LOGITS: usize = 10;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smallcnn_topology_mirrors_python() {
        let net = smallcnn_network();
        assert_eq!(net.num_layers(), 4);
        let l = &net.layers;
        assert_eq!((l[0].n, l[0].c_in, l[0].c_out), (64, 3, 8));
        assert_eq!((l[1].n, l[1].c_in, l[1].c_out), (31, 8, 16));
        assert_eq!((l[2].n, l[2].c_in, l[2].c_out), (14, 16, 32));
        assert_eq!((l[3].n, l[3].c_in, l[3].c_out), (6, 32, 32));
        for layer in l {
            assert_eq!((layer.kh, layer.stride), (3, 1));
        }
    }

    #[test]
    fn smallcnn_macs_positive() {
        // conv0: 62²·9·3·8 ≈ 0.93 M MACs dominates.
        let m = smallcnn_network().total_macs();
        assert!(m > 1.0e6 && m < 1.0e7, "MACs = {m:.3e}");
    }

    #[test]
    fn conv_path_artifacts() {
        assert_eq!(ConvPath::Exact.artifact_for_batch(1), "smallcnn_exact");
        assert_eq!(
            ConvPath::Systolic.artifact_for_batch(8),
            "smallcnn_systolic_b8"
        );
        assert_eq!(ConvPath::Fft.available_batches(), &[1]);
    }

    #[test]
    fn conv_path_parse() {
        assert_eq!(ConvPath::parse("FFT"), Some(ConvPath::Fft));
        assert_eq!(ConvPath::parse("systolic"), Some(ConvPath::Systolic));
        assert_eq!(ConvPath::parse("4f"), Some(ConvPath::Fft));
        assert_eq!(ConvPath::parse("x"), None);
    }
}
