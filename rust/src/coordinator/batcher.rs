//! Dynamic batching policy.
//!
//! Requests accumulate until either the batch budget is reached or the
//! oldest request has waited `max_wait` — the classic latency/throughput
//! dial. The dispatcher then greedily decomposes the pending set into the
//! largest *compiled* batch variants (8 / 4 / 1 for the CNN artifacts),
//! because PJRT executables have static shapes.

use std::time::Duration;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Upper bound on requests pulled per dispatch round.
    pub max_batch: usize,
    /// Deadline: dispatch whatever is pending once the oldest request
    /// has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Greedily split `pending` requests into compiled batch sizes
/// (`variants` must be sorted descending, e.g. `[8, 4, 1]`).
/// Returns the execution plan, e.g. 11 pending → `[8, 1, 1, 1]`: after
/// the 8, only 3 remain, which no 4-variant can carry. Padding a partial
/// batch up to a larger variant is never done — padded slots are wasted
/// compute — so remainders always drain on smaller variants, ultimately
/// the required batch-1.
pub fn plan_batches(pending: usize, variants: &[usize]) -> Vec<usize> {
    assert!(!variants.is_empty());
    debug_assert!(
        variants.windows(2).all(|w| w[0] > w[1]),
        "variants must be strictly descending"
    );
    assert_eq!(
        *variants.last().unwrap(),
        1,
        "a batch-1 variant is required to drain remainders"
    );
    let mut plan = Vec::new();
    let mut left = pending;
    for &v in variants {
        while left >= v {
            plan.push(v);
            left -= v;
        }
    }
    plan
}

/// Decide whether to dispatch now.
pub fn should_dispatch(policy: &BatchPolicy, pending: usize, oldest_wait: Duration) -> bool {
    pending >= policy.max_batch || (pending > 0 && oldest_wait >= policy.max_wait)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_exact_multiples() {
        assert_eq!(plan_batches(8, &[8, 4, 1]), vec![8]);
        assert_eq!(plan_batches(12, &[8, 4, 1]), vec![8, 4]);
        assert_eq!(plan_batches(16, &[8, 4, 1]), vec![8, 8]);
    }

    #[test]
    fn plan_remainders_drain_on_batch1() {
        assert_eq!(plan_batches(11, &[8, 4, 1]), vec![8, 1, 1, 1]);
        assert_eq!(plan_batches(3, &[8, 4, 1]), vec![1, 1, 1]);
        assert_eq!(plan_batches(7, &[8, 4, 1]), vec![4, 1, 1, 1]);
    }

    #[test]
    fn plan_zero_is_empty() {
        assert_eq!(plan_batches(0, &[8, 4, 1]), Vec::<usize>::new());
    }

    #[test]
    fn plan_single_variant() {
        assert_eq!(plan_batches(5, &[1]), vec![1; 5]);
    }

    #[test]
    fn plan_conserves_requests() {
        for pending in 0..50 {
            let total: usize = plan_batches(pending, &[8, 4, 1]).iter().sum();
            assert_eq!(total, pending);
        }
    }

    #[test]
    #[should_panic(expected = "batch-1 variant")]
    fn plan_requires_batch1() {
        plan_batches(5, &[8, 4]);
    }

    #[test]
    fn dispatch_on_full_batch() {
        let p = BatchPolicy::default();
        assert!(should_dispatch(&p, 8, Duration::ZERO));
        assert!(!should_dispatch(&p, 7, Duration::ZERO));
    }

    #[test]
    fn dispatch_on_deadline() {
        let p = BatchPolicy::default();
        assert!(should_dispatch(&p, 1, Duration::from_millis(3)));
        assert!(!should_dispatch(&p, 0, Duration::from_secs(1)));
    }
}
