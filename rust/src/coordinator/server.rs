//! The inference server: sharded ingress → dynamic batcher → sharded
//! per-worker lanes, with per-batch energy accounting in the workers.
//!
//! ```text
//! infer()  ──shard 0──▶            ──spsc lane 0──▶ worker 0 (Executor
//! infer()  ──shard 1──▶ dispatcher ────lane 1────▶ worker 1  + Metrics
//!   ⋮           ⋮        (round-robin   ⋮    ⋮        ⋮        shard +
//! infer()  ──shard N──▶  drain, plans batches,          per-batch
//!  (admission: sharded    least-loaded lane)            EnergyReport)
//!   counter, max_pending)
//! ```
//!
//! * **Sharded ingress** — `infer` picks an ingress shard from a
//!   per-thread hint ([`shard::thread_shard_hint`]): each client's
//!   requests land on "its" bounded FIFO, falling over to the next
//!   shard when full, so concurrent clients no longer serialize on a
//!   single channel's cache line. The dispatcher drains shards
//!   round-robin with a rotating start, so no shard gets persistent
//!   priority.
//! * **Sharded admission** — [`ServerConfig::max_pending`] bounds
//!   admitted-but-unanswered requests via a [`shard::ShardedCounter`]
//!   (adds on the client's cell, subs on the worker's): beyond the
//!   bound `infer` rejects immediately instead of queueing without
//!   bound. The check-then-add pair is racy across concurrent callers,
//!   so the bound can overshoot by the number of racing threads — fine
//!   for a load-shedding knob.
//! * **Sharded handoff** — every worker owns the consumer half of a
//!   bounded [`spsc`] lane; the dispatcher hands each planned batch to
//!   the least-loaded live lane. Workers never contend on a shared
//!   mutexed receiver.
//! * **Heterogeneous fleet routing** — with [`ServerConfig::fleet`]
//!   each worker lane is backed by a [`BackendSpec`] (machine family ×
//!   node × bits); the server resolves one [`BackendQuote`] per lane at
//!   startup (fitted surrogate when it covers the resident network,
//!   co-simulation through the shared cache otherwise) and the
//!   dispatcher routes each planned batch to the live closed-breaker
//!   lane minimizing predicted µJ/inference — or nominal ns/inference
//!   under [`ServerConfig::slo_ns`] — falling back to least-loaded
//!   among equal-cost (or quote-less) lanes. Liveness and exactly-once
//!   outrank routing: a full or tripped preferred lane spills to the
//!   next-cheapest, counted as a reroute in [`Metrics`]. Per-backend
//!   stats (µJ/inf, batches, latency percentiles, breaker trips) shard
//!   into the worker's labelled metrics and render as a table.
//! * **Sharded metrics + per-batch energy** — each worker records
//!   latencies into a private [`Metrics`] shard returned from its
//!   thread on join, and accounts every executed batch's projected
//!   energy into the same shard: the layer schedule is priced once per
//!   worker ([`co_simulate_cached`] through one shared [`SweepCache`],
//!   which dedups the cold simulation across workers) and the
//!   batch-invariant report is replayed per batch from a worker-local
//!   memo — no shared lock on the steady-state path. The dispatcher
//!   shards batch-size stats the same way; shards merge once at
//!   shutdown. No `Mutex<Metrics>` on the request path.
//! * **Surrogate pricing + energy-budget admission** — with
//!   [`ServerConfig::surrogate`] the resident network is priced *once*
//!   at startup through the fitted closed-form table
//!   ([`SurrogateTable::quote_network`]) and workers account each batch
//!   with a multiply — no simulator anywhere in the steady-state loop.
//!   The same quote powers per-request µJ attribution
//!   ([`Server::request_quote`]) and, with
//!   [`ServerConfig::max_uj_per_inf`], an admission policy that rejects
//!   requests whose predicted energy exceeds the budget (counted
//!   separately from backpressure rejections).
//! * **Drain-barrier lifecycle** — admission increments the completion
//!   counter, answering a request (result *or* error) decrements it;
//!   `shutdown()` closes the ingress and parks on a condvar until the
//!   counter hits zero instead of sleep-polling. Dropping the server
//!   without calling `shutdown()` runs the same drain, so pending
//!   requests are answered, never stranded.
//! * **Failure semantics** — every batch execution gets bounded retries
//!   with jittered exponential backoff ([`ServerConfig::max_retries`],
//!   [`ServerConfig::retry_backoff`]) and an optional per-attempt
//!   execution deadline ([`ServerConfig::batch_deadline`]; an overrun
//!   counts as a timeout but its results are still delivered — slow
//!   answers beat dropped ones). Each lane carries a circuit breaker:
//!   after [`ServerConfig::breaker_threshold`] consecutive failed
//!   batches the worker opens it for
//!   [`ServerConfig::breaker_cooldown`] and the dispatcher routes
//!   around the lane — unless every breaker is open, in which case it
//!   dispatches anyway (liveness and the exactly-once answer guarantee
//!   outrank the breaker). A startup pricing co-simulation that misses
//!   [`ServerConfig::startup_quote_deadline`] degrades to per-batch
//!   pricing instead of blocking startup. Every recovery action is
//!   counted in [`Metrics`]: retries, timeouts, breaker trips,
//!   degraded pricing.
//!
//! PJRT client handles are `Rc`-based (not `Send`), so the engine cannot
//! be shared across threads; each worker builds its own [`Executor`] via
//! a factory called *inside* the worker thread. [`Server::start`] wires
//! the real PJRT engine; [`Server::start_sim`] wires the deterministic
//! [`SimExecutor`] so serving tests and benches run without artifacts.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{plan_batches, should_dispatch, BatchPolicy};
use super::energy::{co_simulate_cached, co_simulate_kind, BackendQuote, EnergyReport};
use super::exec::{Executor, SimExecutor};
use super::metrics::Metrics;
use super::{ConvPath, IMAGE_ELEMS, LOGITS};
use crate::energy::surrogate::{EnergyQuote, MachineKind, SurrogateTable};
use crate::runtime::Engine;
use crate::simulator::{OperatingPoint, SweepCache};
use crate::util::rng::Rng;
use crate::util::shard::{self, PushError, ShardedCounter, ShardedQueue};
use crate::util::spsc;

/// Longest the dispatcher blocks in one park: long enough that an idle
/// server wakes ~100×/s, short enough that ingress-close is honoured
/// promptly.
const IDLE_PARK: Duration = Duration::from_millis(10);

/// Batches buffered per worker lane before the dispatcher prefers
/// another lane (and ultimately blocks). Kept small: a deep lane only
/// adds queueing latency in front of a busy worker.
const LANE_CAP: usize = 8;

/// Default bound on the shutdown drain (see
/// [`ServerConfig::drain_deadline`]): a wedged executor must not hang
/// `shutdown()` forever.
const DEFAULT_DRAIN_DEADLINE: Duration = Duration::from_secs(30);

/// One inference request travelling through the server.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>>>,
}

/// A planned batch ready for execution.
struct Batch {
    artifact: String,
    batch: usize,
    requests: Vec<Request>,
}

/// Completion counter + condvar. `add` on admission, `sub` once a
/// request has been *answered*; `wait_zero` parks until fully drained.
/// The counter is sharded ([`ShardedCounter`]), so admission from many
/// client threads never contends on one cache line; the mutex/condvar
/// pair is touched only on the reached-zero edge and by the (single)
/// waiter. A sharded sum can transiently misread while add/sub pairs
/// race, so the waiter re-polls on a bounded interval instead of
/// trusting a single notify; once the ingress is closed the count
/// decreases monotonically and the zero edge is detected exactly.
struct DrainBarrier {
    count: ShardedCounter,
    lock: Mutex<()>,
    cv: Condvar,
}

impl DrainBarrier {
    fn new(shards: usize) -> Self {
        DrainBarrier {
            count: ShardedCounter::new(shards),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn count(&self) -> usize {
        self.count.value()
    }

    fn add(&self, hint: usize, n: usize) {
        self.count.add(hint, n);
    }

    fn sub(&self, hint: usize, n: usize) {
        if n == 0 {
            return;
        }
        if self.count.sub(hint, n) {
            // Hit zero. Taking the lock before notifying closes the race
            // with a waiter that has read a non-zero count but not yet
            // parked: it holds the lock until it waits, so this notify
            // cannot slip into that window.
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Park until the count reaches zero; `false` on deadline.
    fn wait_zero(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut guard = self.lock.lock().unwrap();
        while self.count.value() > 0 {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            // Bounded park: a notify lost to a racy sharded-sum read
            // costs one re-poll interval, not the whole deadline.
            let park = (deadline - now).min(Duration::from_millis(50));
            let (g, _) = self.cv.wait_timeout(guard, park).unwrap();
            guard = g;
        }
        true
    }
}

/// Per-lane circuit-breaker state, shared between the worker that owns
/// the lane (records batch outcomes, trips the breaker) and the
/// dispatcher (skips lanes whose breaker is open). Times are millis
/// since the server's epoch `Instant`, so the whole state fits in
/// lock-free atomics.
struct LaneHealth {
    /// Consecutive failed batches; reset on any success or on a trip.
    consecutive_failures: AtomicUsize,
    /// Breaker-open horizon, millis since the server epoch (0 = closed).
    open_until_ms: AtomicU64,
    /// Times this lane's breaker has tripped.
    trips: AtomicUsize,
}

impl LaneHealth {
    fn new() -> Self {
        LaneHealth {
            consecutive_failures: AtomicUsize::new(0),
            open_until_ms: AtomicU64::new(0),
            trips: AtomicUsize::new(0),
        }
    }
}

/// Dispatcher-side handle to one worker's lane.
struct Lane {
    tx: spsc::Producer<Batch>,
    /// Requests handed to this lane and not yet retired by its worker —
    /// the least-loaded signal. Written by the dispatcher (add) and the
    /// worker (sub) only.
    depth: Arc<AtomicUsize>,
    /// Circuit-breaker state written by the lane's worker.
    health: Arc<LaneHealth>,
    /// Routing cost from the lane's startup [`BackendQuote`]: predicted
    /// µJ/inference (or nominal ns/inference under an SLO). `None`
    /// outside fleet mode — routing is then pure least-loaded.
    cost: Option<f64>,
}

/// One backend of a heterogeneous fleet: a machine family at an
/// operating point, replicated over `count` worker lanes. Parsed from
/// `KIND@NODE[/BXxBW][:COUNT]` (`aimc serve --fleet
/// systolic@45:2,optical4f@22:2,reram@45:2`); bits default to the
/// server's [`ServerConfig::energy_bits`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackendSpec {
    pub kind: MachineKind,
    pub node_nm: f64,
    /// `(bits_x, bits_w)` override for this backend; `None` inherits
    /// the server-wide precision.
    pub bits: Option<(u32, u32)>,
    /// Worker lanes backed by this spec (≥ 1).
    pub count: usize,
}

impl BackendSpec {
    /// Metrics/table label: `systolic@45`, or `reram@45/8x4` with a
    /// per-backend precision override.
    pub fn label(&self) -> String {
        match self.bits {
            Some((x, w)) => format!("{}@{}/{}x{}", self.kind.name(), self.node_nm, x, w),
            None => format!("{}@{}", self.kind.name(), self.node_nm),
        }
    }
}

/// Parse a `--fleet` spec: comma-separated `KIND@NODE[/BXxBW][:COUNT]`
/// entries, e.g. `systolic@45:2,optical4f@22:2,reram@45:2`. Every
/// malformed entry is a loud error, never a silent default.
pub fn parse_fleet(spec: &str) -> Result<Vec<BackendSpec>, String> {
    let mut out = Vec::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (kind_s, rest) = entry
            .split_once('@')
            .ok_or_else(|| format!("fleet entry {entry:?} is not KIND@NODE[/BXxBW][:COUNT]"))?;
        let kind = MachineKind::parse(kind_s.trim()).ok_or_else(|| {
            format!("unknown fleet backend {kind_s:?} (systolic | reram | photonic | optical4f)")
        })?;
        let (rest, count) = match rest.rsplit_once(':') {
            Some((r, c)) => match c.trim().parse::<usize>() {
                Ok(n) if n >= 1 => (r, n),
                _ => return Err(format!("fleet count must be ≥ 1, got {c:?} in {entry:?}")),
            },
            None => (rest, 1),
        };
        let (node_s, bits) = match rest.split_once('/') {
            Some((n, b)) => {
                let b = b.trim();
                let (x, w) = match b.split_once(['x', 'X']) {
                    Some((x, w)) => (x.trim().parse::<u32>(), w.trim().parse::<u32>()),
                    None => {
                        let v = b.parse::<u32>();
                        (v.clone(), v)
                    }
                };
                let bits = match (x, w) {
                    (Ok(x), Ok(w)) if (1..=32).contains(&x) && (1..=32).contains(&w) => (x, w),
                    _ => {
                        return Err(format!(
                            "bad fleet bits {b:?} in {entry:?} (want e.g. 8 or 8x4, widths 1..=32)"
                        ))
                    }
                };
                (n, Some(bits))
            }
            None => (rest, None),
        };
        let node_nm = match node_s.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => v,
            _ => return Err(format!("bad fleet node {node_s:?} in {entry:?}")),
        };
        out.push(BackendSpec {
            kind,
            node_nm,
            bits,
            count,
        });
    }
    if out.is_empty() {
        return Err("fleet spec needs at least one KIND@NODE entry".to_string());
    }
    Ok(out)
}

/// Per-lane plan resolved at startup for one fleet worker: its metrics
/// label, operating point and backend quote.
#[derive(Clone, Debug)]
struct LanePlan {
    label: String,
    quote: BackendQuote,
    /// True when a surrogate table was configured but did not cover the
    /// resident network on this backend (quote fell back to co-sim).
    surrogate_missed: bool,
}

/// Per-batch retry/timeout policy handed to every worker.
#[derive(Clone, Copy, Debug)]
struct RetryPolicy {
    max_retries: u32,
    backoff: Duration,
    batch_deadline: Option<Duration>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub path: ConvPath,
    pub policy: BatchPolicy,
    pub workers: usize,
    /// Artifacts directory (None = auto-discover). Only used by
    /// [`Server::start`]; backends from other factories ignore it.
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Pre-compile every batch variant in every worker before serving
    /// (keeps PJRT compilation off the request path). Disable in tests
    /// that don't care about steady-state latency.
    pub warm_start: bool,
    /// Admission bound: requests admitted but not yet answered. Beyond
    /// it `infer` rejects immediately instead of queueing without bound.
    pub max_pending: usize,
    /// Ingress shards (0 = auto: scales with `workers`, clamped to
    /// [4, 16]). More shards spread client admission over more cache
    /// lines; the dispatcher drains them all either way.
    pub ingress_shards: usize,
    /// Price every executed batch on the cycle simulators into the
    /// executing worker's metrics shard (see [`co_simulate_cached`]).
    /// After the first batch the layer schedule is fully cached, so the
    /// steady-state cost is a handful of map lookups per batch.
    pub energy: bool,
    /// Technology node (nm) for the per-batch energy pricing.
    pub energy_node_nm: f64,
    /// Bit widths `(bits_x, bits_w)` for the per-batch energy pricing —
    /// together with [`ServerConfig::energy_node_nm`] they form the
    /// serving [`OperatingPoint`] (`--bits` on `aimc serve`).
    pub energy_bits: (u32, u32),
    /// Fitted closed-form energy models (see
    /// [`crate::energy::surrogate`]). When present and covering the
    /// resident network, the quote is computed once at startup and the
    /// workers price batches with a multiply — no simulator on the
    /// steady-state path. Falls back to co-simulation (with a warning)
    /// if the table lacks coverage.
    pub surrogate: Option<Arc<SurrogateTable>>,
    /// Energy-budget admission policy: reject any request whose
    /// predicted worst-case energy ([`EnergyQuote::worst_uj`]) exceeds
    /// this many µJ per inference. The quote comes from the surrogate
    /// when available, else from one startup co-simulation. `None`
    /// disables the policy.
    pub max_uj_per_inf: Option<f64>,
    /// Network whose energy prices every batch (`aimc serve --network`)
    /// — e.g. a transformer decode stream. Pricing only: the compiled
    /// executor datapaths stay SmallCNN-shaped (the only AOT artifacts),
    /// so request/response tensor shapes are unchanged. `None` means the
    /// resident SmallCNN.
    pub resident: Option<crate::networks::Network>,
    /// Bound on the shutdown drain: how long `shutdown()` waits for
    /// admitted requests to be answered before detaching the serving
    /// threads (logging which lanes still held work).
    pub drain_deadline: Duration,
    /// Per-attempt execution deadline for one batch. An attempt that
    /// overruns it is counted as a timeout in [`Metrics`]; any results
    /// it produced are still delivered (never dropped). `None` disables
    /// the accounting.
    pub batch_deadline: Option<Duration>,
    /// Failed batch executions (backend error or wrong-shaped output)
    /// are retried up to this many times before the error fans out to
    /// the batch's requests. Each retry is counted in [`Metrics`].
    pub max_retries: u32,
    /// Base delay of the jittered exponential backoff between retries:
    /// retry *k* sleeps `retry_backoff × 2^(k-1) × [1, 2)`.
    pub retry_backoff: Duration,
    /// Consecutive failed batches (after retries) on one lane before its
    /// circuit breaker opens and the dispatcher routes around it.
    pub breaker_threshold: usize,
    /// How long a tripped lane breaker stays open.
    pub breaker_cooldown: Duration,
    /// Bound on the startup pricing co-simulation forced by an energy
    /// budget without a covering surrogate. On expiry the server starts
    /// anyway with pricing degraded to per-batch co-simulation (and the
    /// budget unenforced, with a warning) instead of blocking startup.
    pub startup_quote_deadline: Duration,
    /// Heterogeneous fleet: each [`BackendSpec`] expands to `count`
    /// worker lanes backed by that machine family × node × bits, each
    /// carrying its own startup [`BackendQuote`]; the dispatcher routes
    /// batches by predicted cost (see [`ServerConfig::slo_ns`]).
    /// Overrides [`ServerConfig::workers`]. `None` = homogeneous
    /// serving, exactly as before fleets existed.
    pub fleet: Option<Vec<BackendSpec>>,
    /// Routing objective under a latency SLO (`aimc serve --slo-ns`):
    /// when set, the dispatcher minimizes each lane's *nominal*
    /// ns/inference (co-simulated `time_units` × a per-machine
    /// step-time constant, see [`super::energy::nominal_step_ns`])
    /// instead of µJ/inference. A routing signal only — the repo has no
    /// cycle-time model, so the value is an objective switch and a
    /// target, not an enforced deadline.
    pub slo_ns: Option<f64>,
}

impl ServerConfig {
    /// Expand [`ServerConfig::fleet`] to one [`BackendSpec`] per worker
    /// lane (spec repeated `count` times), in lane order — the mapping
    /// executor factories use to target a backend by worker index
    /// ([`super::exec::FaultPlan::for_backend`]).
    pub fn fleet_workers(&self) -> Option<Vec<BackendSpec>> {
        self.fleet.as_ref().map(|specs| {
            specs
                .iter()
                .flat_map(|s| std::iter::repeat(*s).take(s.count.max(1)))
                .collect()
        })
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            path: ConvPath::Exact,
            policy: BatchPolicy::default(),
            workers: 2,
            artifacts_dir: None,
            warm_start: true,
            max_pending: 1024,
            ingress_shards: 0,
            energy: true,
            energy_node_nm: 45.0,
            energy_bits: (8, 8),
            surrogate: None,
            max_uj_per_inf: None,
            resident: None,
            drain_deadline: DEFAULT_DRAIN_DEADLINE,
            batch_deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_millis(1),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            startup_quote_deadline: Duration::from_secs(10),
            fleet: None,
            slo_ns: None,
        }
    }
}

/// Handle to a running server.
pub struct Server {
    /// Sharded ingress; closing it is the stop signal: the dispatcher
    /// drains the shards, then closes the worker lanes.
    ingress: Arc<ShardedQueue<Request>>,
    barrier: Arc<DrainBarrier>,
    rejected: Arc<ShardedCounter>,
    budget_rejected: Arc<ShardedCounter>,
    max_pending: usize,
    /// Per-request energy quote (surrogate-priced when a table was
    /// given, else the startup co-simulation backing the budget check).
    quote: Option<EnergyQuote>,
    /// Admission energy budget, µJ per inference.
    max_uj_per_inf: Option<f64>,
    /// Shape families the surrogate could not price at startup (0 when
    /// fully covered or no table) — folded into the final metrics on
    /// shutdown so the co-simulation fallback is visible post-hoc.
    surrogate_misses: usize,
    /// 1 when the startup pricing co-simulation missed its deadline and
    /// pricing degraded to per-batch co-simulation — folded into the
    /// final metrics on shutdown.
    degraded_pricing: usize,
    /// Bound on the shutdown drain (from [`ServerConfig`]).
    drain_deadline: Duration,
    /// Depth counters of every worker lane (the dispatcher owns the
    /// producing halves) — read at drain expiry to name the lanes that
    /// still hold work.
    lane_depths: Vec<Arc<AtomicUsize>>,
    started: Instant,
    dispatcher: Option<JoinHandle<Metrics>>,
    workers: Vec<JoinHandle<Metrics>>,
}

impl Server {
    /// Start over the PJRT engine (requires compiled artifacts).
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        // Resolve the artifacts dir once so workers don't race discovery.
        let dir = match &cfg.artifacts_dir {
            Some(d) => d.clone(),
            None => crate::runtime::find_artifacts_dir().ok_or_else(|| {
                anyhow::anyhow!("artifacts not found — run `make artifacts`")
            })?,
        };
        Server::start_with(cfg, move |_worker| Engine::new(&dir))
    }

    /// Start over the deterministic in-process backend — no artifacts or
    /// PJRT needed, so serving behaviour is testable offline.
    pub fn start_sim(cfg: ServerConfig, sim: SimExecutor) -> Result<Server> {
        // Clones share the fault script's dispatch counter value at
        // clone time, so every worker replays the same `FaultPlan`.
        Server::start_with(cfg, move |_worker| Ok(sim.clone()))
    }

    /// Start with a custom executor factory. The factory runs once
    /// *inside* each worker thread (executors need not be `Send`).
    pub fn start_with<E, F>(cfg: ServerConfig, factory: F) -> Result<Server>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        // A fleet overrides the worker count: one lane per expanded spec.
        let fleet_specs = cfg.fleet_workers();
        let workers_n = match &fleet_specs {
            Some(specs) => specs.len().max(1),
            None => cfg.workers.max(1),
        };
        let shards_n = if cfg.ingress_shards == 0 {
            (workers_n * 2).clamp(4, 16)
        } else {
            cfg.ingress_shards
        };
        let max_pending = cfg.max_pending.max(1);
        // Per-shard capacity sized so the shards together hold exactly
        // the admission bound: `max_pending` stays the binding limit and
        // a full-ingress `Full` reject means the server really is at it.
        let cap_per_shard = max_pending.div_ceil(shards_n);
        let ingress = Arc::new(ShardedQueue::<Request>::new(shards_n, cap_per_shard));
        let barrier = Arc::new(DrainBarrier::new(shards_n));
        // One layer-dedup cache shared by every worker's per-batch
        // energy pricing: the first batch anywhere simulates the layer
        // schedule, every later batch replays it.
        let energy_cache = Arc::new(SweepCache::new());
        let factory = Arc::new(factory);

        // Resolve the resident network's energy quote once, up front.
        // With a covering surrogate table this is the only pricing work
        // the whole server ever does; without one the workers keep the
        // per-batch co-simulation path (memoized, see below) and only an
        // energy-budget policy forces a single startup co-simulation.
        let resident = cfg.resident.clone().unwrap_or_else(super::smallcnn_network);
        let serving_op = OperatingPoint::node(cfg.energy_node_nm)
            .bits(cfg.energy_bits.0, cfg.energy_bits.1);
        let mut surrogate_misses = 0usize;
        // Fleet lanes are priced per backend below; the legacy pair
        // quote (systolic + optical-4F at the global operating point)
        // then only backs the energy-budget admission policy, so its
        // coverage warnings/misses are suppressed in fleet mode.
        let want_pair_quote = fleet_specs.is_none() || cfg.max_uj_per_inf.is_some();
        let surrogate_quote: Option<EnergyQuote> = cfg
            .surrogate
            .as_ref()
            .filter(|_| want_pair_quote)
            .and_then(|table| {
                let q = table.quote_network_op(&resident, &serving_op);
                if q.is_none() {
                    // Name each uncovered shape family once, so a
                    // fallback to co-simulation is actionable, not just
                    // visible.
                    let missing = table.uncovered_families(&resident, &serving_op);
                    for fam in &missing {
                        eprintln!(
                            "warn: surrogate table has no {}×{} stride-{} model for {} at \
                             {} nm {}b; falling back to per-batch co-simulation",
                            fam.kh,
                            fam.kw,
                            fam.stride,
                            resident.name,
                            serving_op.node_nm,
                            serving_op.bits_label()
                        );
                    }
                    surrogate_misses = missing.len().max(1);
                }
                q
            });
        let mut degraded_pricing = 0usize;
        let admission_quote: Option<EnergyQuote> = match (cfg.max_uj_per_inf, surrogate_quote) {
            (None, q) => q,
            (Some(_), Some(q)) => Some(q),
            (Some(_), None) => {
                // An energy budget without a covering surrogate forces
                // one startup co-simulation — but "startup" must not
                // mean "unbounded": run it on a helper thread and give
                // up after the deadline, degrading to per-batch pricing
                // (budget unenforced) instead of blocking the start. A
                // late helper is harmless: its send fails and its work
                // lands in the shared cache for the workers to reuse.
                let (quote_tx, quote_rx) = channel();
                let net = resident.clone();
                let cache = energy_cache.clone();
                let op = serving_op;
                std::thread::spawn(move || {
                    let _ = quote_tx.send(co_simulate_cached(&net, &op, &cache));
                });
                match quote_rx.recv_timeout(cfg.startup_quote_deadline) {
                    Ok(r) => Some(EnergyQuote {
                        systolic_j: r.systolic_joules(),
                        optical_j: r.optical_joules(),
                        node_nm: r.op.node_nm,
                        bits_x: r.op.bits_x,
                        bits_w: r.op.bits_w,
                    }),
                    Err(_) => {
                        eprintln!(
                            "warn: startup energy quote did not finish within {:?}; \
                             pricing degraded to per-batch cosim and max_uj_per_inf \
                             is not enforced",
                            cfg.startup_quote_deadline
                        );
                        degraded_pricing = 1;
                        None
                    }
                }
            }
        };

        // Fleet mode: resolve one BackendQuote per worker lane, up
        // front, so the dispatcher can route by predicted cost from the
        // first batch. Joules come from the fitted surrogate when it
        // covers (resident × kind × operating point); otherwise — and
        // always for the nominal-ns SLO signal — from one co-simulation
        // through the shared cache (deduped across lanes of the same
        // backend).
        let lane_plans: Option<Vec<LanePlan>> = fleet_specs.as_ref().map(|specs| {
            specs
                .iter()
                .map(|spec| {
                    let (bx, bw) = spec.bits.unwrap_or(cfg.energy_bits);
                    let op = OperatingPoint::node(spec.node_nm).bits(bx, bw);
                    let surro_j = cfg
                        .surrogate
                        .as_ref()
                        .and_then(|t| t.predict_network_op(spec.kind, &op, &resident));
                    let quote = match surro_j {
                        Some(j) if cfg.slo_ns.is_none() => BackendQuote {
                            kind: spec.kind,
                            j_per_inf: j,
                            ns_per_inf: None,
                            source: "surrogate",
                        },
                        Some(j) => {
                            // SLO routing needs the nominal-ns signal,
                            // which only the cycle simulators carry; the
                            // surrogate still prices the joules.
                            let mut q = co_simulate_kind(spec.kind, &resident, &op, &energy_cache);
                            q.j_per_inf = j;
                            q.source = "surrogate";
                            q
                        }
                        None => co_simulate_kind(spec.kind, &resident, &op, &energy_cache),
                    };
                    LanePlan {
                        label: spec.label(),
                        quote,
                        surrogate_missed: cfg.surrogate.is_some() && surro_j.is_none(),
                    }
                })
                .collect()
        });

        // Workers: each owns the consumer half of its lane, a private
        // executor (compilation is per-worker and lazy unless warmed),
        // and a private metrics shard returned on join.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        // Epoch for breaker timestamps: lane-health horizons are millis
        // since this instant, shared by workers (writers) and the
        // dispatcher (reader).
        let epoch = Instant::now();
        let retry = RetryPolicy {
            max_retries: cfg.max_retries,
            backoff: cfg.retry_backoff,
            batch_deadline: cfg.batch_deadline,
        };
        let breaker_threshold = cfg.breaker_threshold.max(1);
        let breaker_cooldown = cfg.breaker_cooldown;
        let mut lanes = Vec::with_capacity(workers_n);
        let mut lane_depths = Vec::with_capacity(workers_n);
        let mut workers = Vec::with_capacity(workers_n);
        for w in 0..workers_n {
            let (lane_tx, mut lane_rx) = spsc::channel::<Batch>(LANE_CAP);
            let depth = Arc::new(AtomicUsize::new(0));
            let health = Arc::new(LaneHealth::new());
            let lane_plan: Option<LanePlan> =
                lane_plans.as_ref().map(|plans| plans[w].clone());
            // Routing cost: what the dispatcher minimizes when picking a
            // lane. Joules by default; the nominal-ns signal under an
            // SLO (missing ns sorts last rather than wins).
            let cost = lane_plan.as_ref().map(|p| {
                if cfg.slo_ns.is_some() {
                    p.quote.ns_per_inf.unwrap_or(f64::INFINITY)
                } else {
                    p.quote.j_per_inf
                }
            });
            lane_depths.push(depth.clone());
            lanes.push(Lane {
                tx: lane_tx,
                depth: depth.clone(),
                health: health.clone(),
                cost,
            });
            let factory = factory.clone();
            let barrier = barrier.clone();
            let energy_cache = energy_cache.clone();
            let ready_tx = ready_tx.clone();
            let path = cfg.path;
            let warm = cfg.warm_start;
            let energy = cfg.energy;
            let worker_op = serving_op;
            let worker_net = resident.clone();
            workers.push(std::thread::spawn(move || {
                let exec = match (*factory)(w) {
                    Ok(e) => e,
                    Err(err) => {
                        let _ = ready_tx.send(Err(err));
                        return Metrics::new();
                    }
                };
                if warm {
                    let names: Vec<String> = path
                        .available_batches()
                        .iter()
                        .map(|&b| path.artifact_for_batch(b))
                        .collect();
                    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    if let Err(err) = exec.warm_up(&name_refs) {
                        let _ = ready_tx.send(Err(err));
                        return Metrics::new();
                    }
                }
                let _ = ready_tx.send(Ok(()));
                let mut shard = Metrics::new();
                if let Some(plan) = &lane_plan {
                    shard.set_backend(&plan.label);
                    if plan.surrogate_missed {
                        // The fitted table didn't cover this backend ×
                        // shape × operating point; the lane fell back to
                        // co-simulated pricing. Counted per backend so
                        // the fleet table shows which lanes degraded.
                        shard.record_surrogate_miss(1);
                    }
                }
                let net = worker_net;
                // The energy model is batch-size-independent today, so
                // each worker prices the schedule once (the shared cache
                // still dedups that cold simulation across workers) and
                // replays the report per batch — zero shared-lock
                // traffic in steady state. Drop the memo and re-price
                // per batch if a batch-aware energy model lands.
                let mut energy_memo: Option<EnergyReport> = None;
                // Jitter source for the retry backoff — seeded per
                // worker so lanes don't retry in lockstep.
                let mut retry_rng = Rng::new(0xFA17_5EED ^ w as u64);
                // Exit when the dispatcher drops the lane producer and
                // the ring has drained.
                while let Ok(job) = lane_rx.recv() {
                    let retired = job.requests.len();
                    let delivered_ok = run_batch(&exec, job, &mut shard, &retry, &mut retry_rng);
                    // run_batch answered every request, so retire them
                    // from the in-flight accounting BEFORE the energy
                    // pricing — admission and the least-loaded lane pick
                    // must not see already-answered requests as pending
                    // while the co-simulation runs.
                    depth.fetch_sub(retired, SeqCst);
                    barrier.sub(w, retired);
                    if delivered_ok {
                        health.consecutive_failures.store(0, SeqCst);
                    } else {
                        // Batch failed even after retries: one more
                        // strike against this lane; at the threshold the
                        // breaker opens and the dispatcher routes around
                        // it for the cooldown.
                        let strikes = health.consecutive_failures.fetch_add(1, SeqCst) + 1;
                        if strikes >= breaker_threshold {
                            health.consecutive_failures.store(0, SeqCst);
                            let until = (epoch.elapsed() + breaker_cooldown).as_millis() as u64;
                            health.open_until_ms.store(until, SeqCst);
                            health.trips.fetch_add(1, SeqCst);
                            shard.record_breaker_trip(1);
                        }
                    }
                    match &lane_plan {
                        // Fleet lane: account the batch against this
                        // lane's backend shard. The startup BackendQuote
                        // already priced the lane (surrogate or one
                        // co-simulation), so per-batch accounting is a
                        // multiply regardless of pricing path.
                        Some(plan) => {
                            shard.record_backend_batch(retired);
                            if energy {
                                shard.record_backend_energy(
                                    retired,
                                    plan.quote.j_per_inf,
                                    plan.quote.source,
                                );
                            }
                        }
                        None if energy => match surrogate_quote {
                            // Closed-form fast path: the quote was
                            // computed once at startup; accounting a
                            // batch is a handful of adds.
                            Some(q) => shard.record_priced_energy(
                                retired,
                                q.systolic_j,
                                q.optical_j,
                                q.node_nm,
                                (q.bits_x, q.bits_w),
                                "surrogate",
                            ),
                            None => {
                                let report = energy_memo.get_or_insert_with(|| {
                                    co_simulate_cached(&net, &worker_op, &energy_cache)
                                });
                                shard.record_energy(retired, report);
                            }
                        },
                        None => {}
                    }
                }
                shard
            }));
        }

        // Block until every worker has built (and warmed) its executor.
        // On failure the error propagates here, `lanes` drops its
        // producers, and the already-spawned workers exit via lane
        // disconnect — no orphaned threads.
        drop(ready_tx);
        for _ in 0..workers_n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => anyhow::bail!("worker warm-up failed: {e:#}"),
                Err(_) => anyhow::bail!("worker died during warm-up"),
            }
        }

        // Dispatcher: drains the ingress shards, owns all lane producers.
        let dispatcher = {
            let ingress = ingress.clone();
            let policy = cfg.policy;
            let path = cfg.path;
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                dispatcher_loop(&ingress, lanes, policy, path, &barrier, epoch)
            })
        };

        Ok(Server {
            ingress,
            barrier,
            rejected: Arc::new(ShardedCounter::new(shards_n)),
            budget_rejected: Arc::new(ShardedCounter::new(shards_n)),
            max_pending,
            quote: admission_quote,
            max_uj_per_inf: cfg.max_uj_per_inf,
            surrogate_misses,
            degraded_pricing,
            drain_deadline: cfg.drain_deadline,
            lane_depths,
            started: Instant::now(),
            dispatcher: Some(dispatcher),
            workers,
        })
    }

    /// Submit one image; returns a receiver for the logits. Every
    /// admitted request receives exactly one response (result or error).
    pub fn infer(&self, image: Vec<f32>) -> Receiver<Result<Vec<f32>>> {
        let (resp_tx, resp_rx) = channel();
        if image.len() != IMAGE_ELEMS {
            let _ = resp_tx.send(Err(anyhow::anyhow!(
                "image must have {IMAGE_ELEMS} elements, got {}",
                image.len()
            )));
            return resp_rx;
        }
        let hint = shard::thread_shard_hint();
        // Energy-budget admission: every request runs the resident
        // network, so its predicted cost is the startup quote. Checked
        // before the load-shedding bound — an over-budget request is
        // refused even on an idle server.
        if let (Some(max_uj), Some(q)) = (self.max_uj_per_inf, self.quote) {
            if q.worst_uj() > max_uj {
                self.budget_rejected.add(hint, 1);
                let _ = resp_tx.send(Err(anyhow::anyhow!(
                    "request over energy budget: predicted {:.2} µJ/inf exceeds \
                     max_uj_per_inf {:.2}",
                    q.worst_uj(),
                    max_uj
                )));
                return resp_rx;
            }
        }
        // Admission control. The check-then-add pair is racy across
        // concurrent callers, so the bound can overshoot by the number
        // of racing threads — fine for a load-shedding knob.
        if self.barrier.count() >= self.max_pending {
            self.rejected.add(hint, 1);
            let _ = resp_tx.send(Err(anyhow::anyhow!(
                "server overloaded: {} requests in flight (max_pending {})",
                self.barrier.count(),
                self.max_pending
            )));
            return resp_rx;
        }
        self.barrier.add(hint, 1);
        let req = Request {
            image,
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        match self.ingress.push(hint, req) {
            Ok(()) => {}
            Err(PushError::Full(req)) => {
                // Every shard at capacity — the queues together hold
                // max_pending, so this is the admission bound asserting
                // itself through the ingress.
                self.rejected.add(hint, 1);
                let _ = req.resp.send(Err(anyhow::anyhow!(
                    "server overloaded: ingress full (max_pending {})",
                    self.max_pending
                )));
                self.barrier.sub(hint, 1);
            }
            Err(PushError::Closed(req)) => {
                // Shutdown raced us: answer here.
                let _ = req.resp.send(Err(anyhow::anyhow!("server stopped")));
                self.barrier.sub(hint, 1);
            }
        }
        resp_rx
    }

    /// Submit and wait.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.infer(image)
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    /// Requests refused at admission so far (backpressure only; budget
    /// refusals are counted separately, see [`Server::budget_rejected`]).
    pub fn rejected(&self) -> usize {
        self.rejected.value()
    }

    /// Requests refused by the energy-budget admission policy so far.
    pub fn budget_rejected(&self) -> usize {
        self.budget_rejected.value()
    }

    /// Predicted per-request energy: the quote every admitted inference
    /// is attributed (surrogate-priced when the server was started with
    /// a covering table, else the startup co-simulation backing an
    /// energy budget; `None` when neither applies).
    pub fn request_quote(&self) -> Option<EnergyQuote> {
        self.quote
    }

    /// Requests admitted and not yet answered.
    pub fn in_flight(&self) -> usize {
        self.barrier.count()
    }

    /// Graceful shutdown: close the ingress, drain every admitted
    /// request, join all threads, return the merged metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Metrics {
        // Closing the ingress is the stop signal: the dispatcher flushes
        // the shards and its pending set, drops the lane producers, and
        // each worker drains its ring before exiting.
        self.ingress.close();
        let drained = self.barrier.wait_zero(self.drain_deadline);
        let mut agg = Metrics::new();
        if drained {
            // Zero unanswered requests means no batch is in flight
            // anywhere (dispatch and execution both hold unanswered
            // requests), so these joins complete promptly.
            if let Some(d) = self.dispatcher.take() {
                if let Ok(shard) = d.join() {
                    agg.merge(&shard);
                }
            }
            for w in self.workers.drain(..) {
                if let Ok(shard) = w.join() {
                    agg.merge(&shard);
                }
            }
        } else {
            // A wedged executor holds its worker thread hostage; joining
            // would hang shutdown()/Drop past the promised bound. Detach
            // instead (dropping a JoinHandle leaks no memory beyond the
            // thread itself) and forfeit those shards. Name the lanes
            // that still hold work so the wedge is attributable.
            let stuck: Vec<String> = self
                .lane_depths
                .iter()
                .enumerate()
                .filter(|(_, d)| d.load(SeqCst) > 0)
                .map(|(i, d)| format!("lane {i} holds {}", d.load(SeqCst)))
                .collect();
            eprintln!(
                "warn: server drain deadline ({:?}) hit with {} requests unanswered ({}); \
                 detaching serving threads",
                self.drain_deadline,
                self.barrier.count(),
                if stuck.is_empty() {
                    "none attributable to a worker lane".to_string()
                } else {
                    stuck.join(", ")
                }
            );
            self.dispatcher.take();
            self.workers.clear();
        }
        agg.record_rejected(self.rejected.value());
        agg.record_budget_rejected(self.budget_rejected.value());
        agg.record_surrogate_miss(self.surrogate_misses);
        agg.record_degraded_pricing(self.degraded_pricing);
        agg.set_window(self.started, Instant::now());
        agg
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Dropping without `shutdown()` still drains: every admitted
        // request is answered before the threads are joined.
        if self.dispatcher.is_some() || !self.workers.is_empty() {
            let _ = self.shutdown_inner();
        }
    }
}

/// Dispatcher thread body: drain the ingress shards round-robin, apply
/// the batching policy, hand plans to the cheapest live lane (fleet
/// mode) or the least-loaded one. Returns its metrics shard (batch-size
/// histogram plus reroute count).
fn dispatcher_loop(
    ingress: &ShardedQueue<Request>,
    mut lanes: Vec<Lane>,
    policy: BatchPolicy,
    path: ConvPath,
    barrier: &DrainBarrier,
    epoch: Instant,
) -> Metrics {
    let mut shard = Metrics::new();
    let mut pending: Vec<Request> = Vec::new();
    let mut rr = 0usize;
    loop {
        // Read the close flag BEFORE draining: if this drain then comes
        // up empty, no request can be stranded — pushes serialize with
        // the drain on the shard locks, and any push that lost that race
        // observes the (earlier) close and hands the request back.
        let closed = ingress.is_closed();
        ingress.drain_rotating(&mut rr, &mut pending);
        let oldest = pending
            .first()
            .map(|r| r.enqueued.elapsed())
            .unwrap_or(Duration::ZERO);
        // Closed ingress flushes immediately: there is nothing to wait
        // for once no new request can arrive.
        if should_dispatch(&policy, pending.len(), oldest) || (closed && !pending.is_empty()) {
            let take = pending.len().min(policy.max_batch);
            let mut round: Vec<Request> = pending.drain(..take).collect();
            for b in plan_batches(round.len(), path.available_batches()) {
                let reqs: Vec<Request> = round.drain(..b).collect();
                shard.record_batch(b);
                dispatch(
                    &mut lanes,
                    Batch {
                        artifact: path.artifact_for_batch(b),
                        batch: b,
                        requests: reqs,
                    },
                    barrier,
                    epoch,
                    &mut shard,
                );
            }
        } else if closed && pending.is_empty() {
            // Drained and the server is shutting down: dropping the
            // lane producers tells the workers to finish and exit.
            return shard;
        } else {
            // Park until new work arrives or the oldest pending
            // request's batching deadline fires.
            let park = if pending.is_empty() {
                IDLE_PARK
            } else {
                policy
                    .max_wait
                    .saturating_sub(oldest)
                    .clamp(Duration::from_micros(50), IDLE_PARK)
            };
            ingress.wait_nonempty(park);
        }
    }
}

/// Hand one batch to the cheapest live lane — by startup-quoted cost in
/// fleet mode (predicted µJ/inf, or nominal ns under `--slo-ns`), by
/// depth alone in a homogeneous fleet — falling back across lanes when
/// full and blocking briefly when all are. Lanes whose circuit breaker
/// is open are skipped — unless every breaker is open, in which case the
/// batch is dispatched anyway: liveness and the exactly-once answer
/// guarantee outrank both the breaker and the routing policy. Any
/// successful send to a lane pricier than the cheapest live lane counts
/// as a reroute in the dispatcher shard (breaker detours and lane-full
/// spills alike). Lanes whose worker died are retired; with no lanes
/// left the batch is failed out, so each request still receives exactly
/// one response and the drain barrier still retires it.
fn dispatch(
    lanes: &mut Vec<Lane>,
    job: Batch,
    barrier: &DrainBarrier,
    epoch: Instant,
    shard: &mut Metrics,
) {
    let n = job.requests.len();
    let mut job = job;
    'outer: loop {
        if lanes.is_empty() {
            for r in &job.requests {
                let _ = r
                    .resp
                    .send(Err(anyhow::anyhow!("no live workers to serve request")));
            }
            barrier.sub(0, n);
            return;
        }
        // Cheapest cost over ALL live lanes (breakers included): the
        // reroute yardstick. Recomputed per pass — dead lanes retire.
        let min_cost = lanes
            .iter()
            .filter_map(|l| l.cost)
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rerouted = |lane: &Lane| -> bool {
            matches!((lane.cost, min_cost), (Some(c), Some(mc)) if c > mc)
        };
        // Try closed-breaker lanes in cost-then-load order. Depth is
        // incremented *before* the send so a fast worker can never
        // retire the batch before the increment lands (which would
        // underflow the counter).
        let now_ms = epoch.elapsed().as_millis() as u64;
        let mut order: Vec<usize> = (0..lanes.len())
            .filter(|&i| lanes[i].health.open_until_ms.load(SeqCst) <= now_ms)
            .collect();
        if order.is_empty() {
            // Every breaker open: dispatch anyway rather than strand or
            // fail work that a recovering lane could still serve.
            order = (0..lanes.len()).collect();
        }
        order.sort_by(|&a, &b| {
            let ca = lanes[a].cost.unwrap_or(f64::INFINITY);
            let cb = lanes[b].cost.unwrap_or(f64::INFINITY);
            ca.partial_cmp(&cb)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| lanes[a].depth.load(SeqCst).cmp(&lanes[b].depth.load(SeqCst)))
        });
        for &i in &order {
            lanes[i].depth.fetch_add(n, SeqCst);
            match lanes[i].tx.try_send(job) {
                Ok(()) => {
                    if rerouted(&lanes[i]) {
                        shard.record_reroute(1);
                    }
                    return;
                }
                Err(spsc::TrySendError::Full(j)) => {
                    lanes[i].depth.fetch_sub(n, SeqCst);
                    job = j;
                }
                Err(spsc::TrySendError::Disconnected(j)) => {
                    lanes[i].depth.fetch_sub(n, SeqCst);
                    job = j;
                    lanes.swap_remove(i);
                    continue 'outer; // indices shifted — restart
                }
            }
        }
        // Every candidate lane is full: block on the least-loaded until
        // space frees, re-evaluating load on each timeout.
        let i = order
            .into_iter()
            .min_by_key(|&i| lanes[i].depth.load(SeqCst))
            .expect("lanes checked non-empty");
        lanes[i].depth.fetch_add(n, SeqCst);
        match lanes[i].tx.send_timeout(job, Duration::from_millis(5)) {
            Ok(()) => {
                if rerouted(&lanes[i]) {
                    shard.record_reroute(1);
                }
                return;
            }
            Err(spsc::SendTimeoutError::Timeout(j)) => {
                lanes[i].depth.fetch_sub(n, SeqCst);
                job = j;
            }
            Err(spsc::SendTimeoutError::Disconnected(j)) => {
                lanes[i].depth.fetch_sub(n, SeqCst);
                job = j;
                lanes.swap_remove(i);
            }
        }
    }
}

/// Execute one planned batch on a worker's executor and fan results out,
/// recording latencies into the worker-private shard (one clock read per
/// batch, no lock).
///
/// Failed attempts (backend error or wrong-shaped output) are retried up
/// to `policy.max_retries` times with jittered exponential backoff; only
/// after exhaustion does the error fan out, so every request is still
/// answered exactly once. An attempt that overruns
/// `policy.batch_deadline` is counted as a timeout but its results are
/// delivered regardless — a slow answer beats a dropped one. Returns
/// whether the batch was ultimately delivered `Ok` (the lane-health
/// signal for the circuit breaker).
fn run_batch<E: Executor>(
    exec: &E,
    job: Batch,
    shard: &mut Metrics,
    policy: &RetryPolicy,
    rng: &mut Rng,
) -> bool {
    let Batch {
        artifact,
        batch,
        requests,
    } = job;
    debug_assert_eq!(batch, requests.len());

    // Pack once; retries replay the same input.
    let packed: Vec<f32> = if batch == 1 {
        Vec::new()
    } else {
        let mut p = Vec::with_capacity(batch * IMAGE_ELEMS);
        for r in &requests {
            p.extend_from_slice(&r.image);
        }
        p
    };

    let mut attempt = 0u32;
    let outcome = loop {
        let t0 = Instant::now();
        let result = if batch == 1 {
            exec.execute(&artifact, std::slice::from_ref(&requests[0].image))
        } else {
            exec.execute(&artifact, std::slice::from_ref(&packed))
        };
        if let Some(deadline) = policy.batch_deadline {
            if t0.elapsed() > deadline {
                // Deadline overrun is an observability event, not a
                // cancellation: whatever this attempt produced is still
                // delivered below.
                shard.record_timeout(1);
            }
        }
        // Fold wrong-shaped success into the one failure path so the
        // retry loop treats it like any other transient fault.
        let result = match result {
            Ok(out) if out.len() == batch * LOGITS => Ok(out),
            Ok(out) => Err(anyhow::anyhow!(
                "backend returned {} values, expected {}",
                out.len(),
                batch * LOGITS
            )),
            Err(e) => Err(e),
        };
        match result {
            Ok(out) => break Ok(out),
            Err(_) if attempt < policy.max_retries => {
                attempt += 1;
                shard.record_retry(1);
                // Jittered exponential backoff: base × 2^(k-1) × [1, 2).
                // The shift is clamped so a huge max_retries cannot
                // overflow the multiplier.
                let exp = 1u64 << (attempt - 1).min(16) as u64;
                let wait = policy.backoff.mul_f64(exp as f64 * (1.0 + rng.f64()));
                if !wait.is_zero() {
                    std::thread::sleep(wait);
                }
            }
            Err(e) => break Err(e),
        }
    };

    match outcome {
        Ok(out) => {
            let now = Instant::now();
            for (i, r) in requests.iter().enumerate() {
                let logits = out[i * LOGITS..(i + 1) * LOGITS].to_vec();
                shard.record_request(now.saturating_duration_since(r.enqueued));
                let _ = r.resp.send(Ok(logits));
            }
            true
        }
        Err(e) => {
            for r in &requests {
                let _ = r.resp.send(Err(anyhow::anyhow!("{artifact}: {e:#}")));
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sim_server(workers: usize, max_pending: usize, sim: SimExecutor) -> Server {
        Server::start_sim(
            ServerConfig {
                workers,
                warm_start: false,
                max_pending,
                ..Default::default()
            },
            sim,
        )
        .unwrap()
    }

    #[test]
    fn drain_barrier_counts_and_wakes() {
        let b = Arc::new(DrainBarrier::new(4));
        b.add(0, 3);
        assert_eq!(b.count(), 3);
        assert!(!b.wait_zero(Duration::from_millis(10)));
        let waiter = {
            let b = b.clone();
            std::thread::spawn(move || b.wait_zero(Duration::from_secs(10)))
        };
        // Subs on different cells than the add: the sharded sum must
        // still detect the zero edge.
        b.sub(1, 1);
        b.sub(2, 2);
        assert!(waiter.join().unwrap(), "waiter must wake on zero");
        assert!(b.wait_zero(Duration::ZERO));
    }

    #[test]
    fn rejects_bad_image_size() {
        let s = sim_server(1, 64, SimExecutor::instant());
        let err = s.infer_blocking(vec![0.0; 5]);
        assert!(err.is_err());
        s.shutdown();
    }

    #[test]
    fn serves_single_request_sim() {
        let s = sim_server(1, 64, SimExecutor::instant());
        let mut rng = Rng::new(1);
        let out = s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        assert_eq!(out.len(), LOGITS);
        let m = s.shutdown();
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn every_batch_is_priced_for_energy() {
        let s = sim_server(2, 64, SimExecutor::instant());
        let mut rng = Rng::new(21);
        let rxs: Vec<_> = (0..12)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.energy_images(), 12, "every served image priced");
        assert!(m.energy_batches() >= 1);
        let sys = m.systolic_uj_per_inference().expect("energy priced");
        let opt = m.optical_uj_per_inference().expect("energy priced");
        assert!(sys > 0.0);
        assert!(opt > 0.0);
        assert_eq!(m.energy_source(), "co-simulation");
        assert!(m.summary().contains("µJ/inf"), "{}", m.summary());
        // Per-inference energy must equal the standalone co-simulation:
        // accumulation is (per-inference × images) / images.
        let reference = super::super::energy::co_simulate(
            &super::super::smallcnn_network(),
            &OperatingPoint::node(45.0),
        );
        assert_eq!(m.energy_bits(), (8, 8), "default serving precision");
        let tol = 1e-9;
        assert!(
            (sys - reference.systolic_joules() * 1e6).abs() < tol,
            "{} vs {}",
            sys,
            reference.systolic_joules() * 1e6
        );
    }

    #[test]
    fn reduced_precision_serving_prices_cheaper_and_tags_bits() {
        let serve_at = |bits: (u32, u32)| {
            let s = Server::start_sim(
                ServerConfig {
                    workers: 1,
                    warm_start: false,
                    max_pending: 64,
                    energy_bits: bits,
                    ..Default::default()
                },
                SimExecutor::instant(),
            )
            .unwrap();
            let mut rng = Rng::new(35);
            s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
            s.shutdown()
        };
        let full = serve_at((8, 8));
        let quant = serve_at((4, 4));
        assert_eq!(quant.energy_bits(), (4, 4));
        assert!(quant.summary().contains("4x4b"), "{}", quant.summary());
        let full_uj = full.systolic_uj_per_inference().unwrap();
        let quant_uj = quant.systolic_uj_per_inference().unwrap();
        assert!(quant_uj < full_uj, "{quant_uj} vs {full_uj}");
    }

    /// Fit a surrogate whose coverage includes SmallCNN's (3, 3, 1)
    /// family, padded with a few same-family shapes so the least-squares
    /// systems are well-conditioned.
    fn smallcnn_surrogate() -> SurrogateTable {
        use crate::energy::surrogate::MachineKind;
        use crate::networks::ConvLayer;
        let mut layers = super::super::smallcnn_network().layers;
        layers.push(ConvLayer::square(32, 16, 64, 3, 1));
        layers.push(ConvLayer::square(16, 64, 8, 3, 1));
        layers.push(ConvLayer::square(96, 8, 24, 3, 1));
        layers.push(ConvLayer::square(12, 48, 48, 3, 1));
        SurrogateTable::fit(
            &SweepCache::new(),
            &[MachineKind::Systolic, MachineKind::Optical4F],
            &[45.0],
            &layers,
        )
        .unwrap()
    }

    #[test]
    fn surrogate_pricing_matches_cosim_and_tags_source() {
        let s = Server::start_sim(
            ServerConfig {
                workers: 2,
                warm_start: false,
                max_pending: 64,
                surrogate: Some(Arc::new(smallcnn_surrogate())),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let q = s.request_quote().expect("surrogate covers the resident network");
        let mut rng = Rng::new(31);
        let rxs: Vec<_> = (0..10)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.energy_images(), 10);
        assert_eq!(m.energy_source(), "surrogate");
        assert_eq!(m.surrogate_miss(), 0, "full coverage, no fallback");
        let sys = m.systolic_uj_per_inference().expect("priced");
        let opt = m.optical_uj_per_inference().expect("priced");
        // Per-request attribution is the startup quote...
        assert!((sys - q.systolic_uj()).abs() < 1e-9);
        assert!((opt - q.optical_uj()).abs() < 1e-9);
        // ...and the closed-form prediction agrees with the cycle
        // simulators on the resident network.
        let reference = super::super::energy::co_simulate(
            &super::super::smallcnn_network(),
            &OperatingPoint::node(45.0),
        );
        let sys_rel = (sys - reference.systolic_joules() * 1e6).abs()
            / (reference.systolic_joules() * 1e6);
        let opt_rel =
            (opt - reference.optical_joules() * 1e6).abs() / (reference.optical_joules() * 1e6);
        assert!(sys_rel < 0.01, "systolic surrogate off by {sys_rel}");
        assert!(opt_rel < 0.01, "optical surrogate off by {opt_rel}");
    }

    #[test]
    fn uncovered_surrogate_falls_back_to_cosim() {
        // A fitted table that lacks the resident family (5×5 kernels
        // only) must not break serving: pricing falls back to the
        // co-simulation path.
        use crate::energy::surrogate::MachineKind;
        use crate::networks::ConvLayer;
        let off_family = [
            ConvLayer::square(64, 3, 8, 5, 1),
            ConvLayer::square(32, 8, 16, 5, 1),
            ConvLayer::square(16, 16, 32, 5, 1),
            ConvLayer::square(48, 4, 12, 5, 1),
            ConvLayer::square(24, 24, 24, 5, 1),
            ConvLayer::square(12, 32, 8, 5, 1),
        ];
        let table = SurrogateTable::fit(
            &SweepCache::new(),
            &[MachineKind::Systolic, MachineKind::Optical4F],
            &[45.0],
            &off_family,
        )
        .unwrap();
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 64,
                surrogate: Some(Arc::new(table)),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        assert!(s.request_quote().is_none(), "no quote without coverage");
        let mut rng = Rng::new(32);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        let m = s.shutdown();
        assert_eq!(m.energy_images(), 1);
        assert_eq!(m.energy_source(), "co-simulation");
        // The fallback is counted, not just warned about.
        assert!(m.surrogate_miss() >= 1, "miss must surface in metrics");
        assert!(m.summary().contains("surrogate miss"), "{}", m.summary());
    }

    #[test]
    fn transformer_decode_resident_prices_batches() {
        // `aimc serve --network tfm-tiny@decode`: the decode stream
        // replaces SmallCNN on the pricing path while the executor keeps
        // its SmallCNN-shaped tensors; a GEMM-covering surrogate prices
        // it closed-form with zero misses.
        use crate::energy::surrogate::MachineKind;
        use crate::networks::transformer::TransformerConfig;
        let decode = TransformerConfig::tiny().decode(1, 64);
        let table = SurrogateTable::fit(
            &SweepCache::new(),
            &[MachineKind::Systolic, MachineKind::Optical4F],
            &[45.0],
            &crate::energy::surrogate::training_corpus(300),
        )
        .unwrap();
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 64,
                surrogate: Some(Arc::new(table)),
                resident: Some(decode.clone()),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let q = s.request_quote().expect("corpus covers GEMM streams");
        let mut rng = Rng::new(36);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        let m = s.shutdown();
        assert_eq!(m.energy_images(), 1);
        assert_eq!(m.energy_source(), "surrogate");
        assert_eq!(m.surrogate_miss(), 0);
        // The quote prices the decode stream, not SmallCNN: it must
        // agree with the cycle simulators on the transformer layers.
        let reference =
            super::super::energy::co_simulate(&decode, &OperatingPoint::node(45.0));
        let sys_rel = (q.systolic_uj() - reference.systolic_joules() * 1e6).abs()
            / (reference.systolic_joules() * 1e6);
        assert!(sys_rel < 0.05, "decode quote off by {sys_rel}");
    }

    #[test]
    fn energy_budget_rejects_over_budget_requests() {
        // SmallCNN costs a few µJ on either machine; a 1e-3 µJ budget
        // must shed everything, distinctly from backpressure.
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 64,
                surrogate: Some(Arc::new(smallcnn_surrogate())),
                max_uj_per_inf: Some(1e-3),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(33);
        for _ in 0..5 {
            let err = s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap_err();
            assert!(err.to_string().contains("energy budget"), "{err:#}");
        }
        assert_eq!(s.budget_rejected(), 5);
        assert_eq!(s.rejected(), 0, "budget refusals are not backpressure");
        let m = s.shutdown();
        assert_eq!(m.budget_rejected(), 5);
        assert_eq!(m.count(), 0);
        assert!(m.summary().contains("over-budget"), "{}", m.summary());
    }

    #[test]
    fn generous_energy_budget_admits_and_cosim_backs_the_quote() {
        // Budget without a surrogate: one startup co-simulation supplies
        // the quote; a generous bound admits everything.
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 64,
                max_uj_per_inf: Some(1e9),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let q = s.request_quote().expect("co-simulation backs the budget");
        assert!(q.worst_uj() > 0.0);
        let mut rng = Rng::new(34);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        assert_eq!(s.budget_rejected(), 0);
        let m = s.shutdown();
        assert_eq!(m.count(), 1);
        assert_eq!(m.energy_source(), "co-simulation");
    }

    #[test]
    fn energy_accounting_can_be_disabled() {
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 64,
                energy: false,
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(22);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        let m = s.shutdown();
        assert_eq!(m.energy_images(), 0);
        assert!(!m.summary().contains("µJ/inf"));
    }

    #[test]
    fn batches_form_and_match_batch1_sim() {
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(20),
                },
                warm_start: false,
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(2);
        let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
        let rxs: Vec<_> = images.iter().map(|im| s.infer(im.clone())).collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        let m = s.shutdown();
        assert!(m.mean_batch() > 1.0, "batching should engage: {}", m.summary());

        // Batched results must equal per-image execution.
        let exec = SimExecutor::instant();
        for (im, out) in images.iter().zip(&outs) {
            let single = exec.execute("smallcnn_exact", &[im.clone()]).unwrap();
            assert_eq!(&single, out, "batched vs single must be bit-identical");
        }
    }

    #[test]
    fn backpressure_rejects_beyond_max_pending() {
        // One slow worker, tiny admission bound: most of a burst must be
        // shed, and everything admitted must still be answered.
        let s = sim_server(
            1,
            4,
            SimExecutor::new(Duration::from_millis(20), Duration::ZERO),
        );
        let mut rng = Rng::new(3);
        let rxs: Vec<_> = (0..32)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let mut served = 0;
        let mut shed = 0;
        for rx in rxs {
            match rx.recv().expect("exactly one response per request") {
                Ok(_) => served += 1,
                Err(e) => {
                    assert!(e.to_string().contains("overloaded"), "{e:#}");
                    shed += 1;
                }
            }
        }
        assert_eq!(served + shed, 32);
        assert!(shed > 0, "a 32-burst against max_pending=4 must shed");
        let m = s.shutdown();
        assert_eq!(m.rejected(), shed);
        assert_eq!(m.count(), served);
    }

    #[test]
    fn lanes_spread_load_across_workers() {
        // With several workers and many single-request batches, more
        // than one lane must actually execute work.
        let s = Server::start_sim(
            ServerConfig {
                workers: 4,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::ZERO,
                },
                warm_start: false,
                ..Default::default()
            },
            SimExecutor::new(Duration::from_millis(2), Duration::ZERO),
        )
        .unwrap();
        let mut rng = Rng::new(4);
        let rxs: Vec<_> = (0..64)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.count(), 64);
        // 64 × 2 ms on one lane would take 128 ms of work; with 4 lanes
        // the batch histogram alone can't prove spreading, but the drain
        // finishing with every response delivered does prove no lane
        // deadlocked while others idled.
    }

    #[test]
    fn explicit_ingress_shard_count_is_honoured() {
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                max_pending: 8,
                ingress_shards: 3,
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        assert_eq!(s.ingress.shards(), 3);
        let mut rng = Rng::new(23);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        s.shutdown();
    }

    #[test]
    fn shutdown_with_zero_requests_is_instant() {
        let s = sim_server(2, 64, SimExecutor::instant());
        let t0 = Instant::now();
        s.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn retries_recover_transient_faults() {
        // Every second executor call fails; with retries enabled every
        // request must still be answered Ok, and the recovery work must
        // be visible as retry counts.
        let plan = crate::coordinator::exec::FaultPlan::parse("error=2").unwrap();
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                energy: false,
                ..Default::default()
            },
            SimExecutor::instant().with_plan(plan),
        )
        .unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..8 {
            s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.count(), 8, "every request answered Ok: {}", m.summary());
        assert!(m.retries() > 0, "injected faults must surface as retries");
        assert_eq!(m.breaker_trips(), 0, "recovered batches must not trip the breaker");
        assert!(m.summary().contains("retries"), "{}", m.summary());
    }

    #[test]
    fn breaker_trips_on_persistent_faults_without_losing_answers() {
        // Every executor call fails and retries are off: lanes trip
        // their breakers, the dispatcher routes around them (and through
        // them once all are open — liveness), and every request still
        // gets exactly one (error) response.
        let plan = crate::coordinator::exec::FaultPlan::parse("error=1").unwrap();
        let s = Server::start_sim(
            ServerConfig {
                workers: 2,
                warm_start: false,
                energy: false,
                max_retries: 0,
                breaker_threshold: 1,
                breaker_cooldown: Duration::from_millis(50),
                ..Default::default()
            },
            SimExecutor::instant().with_plan(plan),
        )
        .unwrap();
        let mut rng = Rng::new(12);
        let rxs: Vec<_> = (0..8)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        for rx in rxs {
            let err = rx.recv().expect("exactly one response").unwrap_err();
            assert!(err.to_string().contains("injected transient fault"), "{err:#}");
        }
        let m = s.shutdown();
        assert!(m.breaker_trips() >= 1, "persistent faults must trip: {}", m.summary());
        assert!(m.summary().contains("breaker trip"), "{}", m.summary());
    }

    #[test]
    fn batch_deadline_overruns_count_but_still_deliver() {
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                energy: false,
                batch_deadline: Some(Duration::from_micros(100)),
                ..Default::default()
            },
            SimExecutor::new(Duration::from_millis(5), Duration::ZERO),
        )
        .unwrap();
        let mut rng = Rng::new(13);
        for _ in 0..3 {
            s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.count(), 3, "slow batches still deliver: {}", m.summary());
        assert!(m.timeouts() >= 1, "overruns must be counted: {}", m.summary());
        assert!(m.summary().contains("batch timeout"), "{}", m.summary());
    }

    #[test]
    fn drain_deadline_is_config_driven_and_detaches() {
        // A stalled executor must not hold shutdown() past the
        // configured drain deadline — and the detached worker still
        // answers the admitted request (never stranded).
        let plan = crate::coordinator::exec::FaultPlan::parse("stall=1:300ms").unwrap();
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                energy: false,
                drain_deadline: Duration::from_millis(30),
                ..Default::default()
            },
            SimExecutor::instant().with_plan(plan),
        )
        .unwrap();
        let mut rng = Rng::new(14);
        let rx = s.infer(rng.normal_vec(IMAGE_ELEMS));
        let t0 = Instant::now();
        let _ = s.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "drain deadline must bound shutdown"
        );
        let out = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("detached worker still answers");
        assert!(out.is_ok());
    }

    #[test]
    fn startup_quote_deadline_degrades_pricing_instead_of_blocking() {
        // Energy budget + no surrogate forces a startup co-simulation; a
        // zero deadline forces the degraded path: the server starts,
        // serves, reports the degradation, and enforces no phantom
        // budget.
        let s = Server::start_sim(
            ServerConfig {
                workers: 1,
                warm_start: false,
                energy: false,
                max_uj_per_inf: Some(1.0),
                startup_quote_deadline: Duration::ZERO,
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        assert!(s.request_quote().is_none(), "degraded startup must not invent a quote");
        let mut rng = Rng::new(15);
        s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        let m = s.shutdown();
        assert_eq!(m.degraded_pricing(), 1);
        assert_eq!(m.budget_rejected(), 0, "unenforceable budget must not reject");
        assert!(m.summary().contains("degraded-pricing"), "{}", m.summary());
    }

    #[test]
    fn fault_free_serving_reports_no_recovery_actions() {
        // The zero-fault path must look exactly like it did before the
        // failure semantics landed: no counters, no summary fragments.
        let s = sim_server(2, 64, SimExecutor::instant());
        let mut rng = Rng::new(16);
        for _ in 0..4 {
            s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.retries(), 0);
        assert_eq!(m.timeouts(), 0);
        assert_eq!(m.breaker_trips(), 0);
        assert_eq!(m.degraded_pricing(), 0);
        let sum = m.summary();
        assert!(
            !sum.contains("retries")
                && !sum.contains("timeout")
                && !sum.contains("breaker")
                && !sum.contains("degraded"),
            "{sum}"
        );
    }

    #[test]
    fn fleet_spec_grammar_parses_and_rejects() {
        let fleet = parse_fleet("systolic@45:2, optical4f@22:2,reram@45/8x4").unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0].kind, MachineKind::Systolic);
        assert_eq!(fleet[0].count, 2);
        assert_eq!(fleet[0].bits, None);
        assert_eq!(fleet[0].label(), "systolic@45");
        assert_eq!(fleet[1].kind, MachineKind::Optical4F);
        assert_eq!(fleet[1].node_nm, 22.0);
        assert_eq!(fleet[2].kind, MachineKind::Reram);
        assert_eq!(fleet[2].bits, Some((8, 4)));
        assert_eq!(fleet[2].count, 1);
        assert_eq!(fleet[2].label(), "reram@45/8x4");
        // Shorthand bits + aliases.
        let fleet = parse_fleet("memristor@28/4:3").unwrap();
        assert_eq!(fleet[0].kind, MachineKind::Reram);
        assert_eq!(fleet[0].bits, Some((4, 4)));
        assert_eq!(fleet[0].count, 3);
        for bad in [
            "",
            "systolic",
            "abacus@45",
            "systolic@zero",
            "systolic@-45",
            "systolic@45:0",
            "systolic@45/0x8",
            "systolic@45/33",
            "systolic@45/8y8",
        ] {
            assert!(parse_fleet(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn fleet_workers_expand_replica_counts() {
        let cfg = ServerConfig {
            fleet: Some(parse_fleet("systolic@45:2,reram@45").unwrap()),
            ..Default::default()
        };
        let specs = cfg.fleet_workers().unwrap();
        assert_eq!(specs.len(), 3, "2 systolic lanes + 1 reram lane");
        assert_eq!(specs[0].kind, MachineKind::Systolic);
        assert_eq!(specs[1].kind, MachineKind::Systolic);
        assert_eq!(specs[2].kind, MachineKind::Reram);
        assert!(ServerConfig::default().fleet_workers().is_none());
    }

    #[test]
    fn heterogeneous_fleet_prices_per_backend_and_answers_exactly_once() {
        let s = Server::start_sim(
            ServerConfig {
                warm_start: false,
                max_pending: 64,
                fleet: Some(parse_fleet("systolic@45:1,reram@45:1").unwrap()),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(47);
        let rxs: Vec<_> = (0..16)
            .map(|_| s.infer(rng.normal_vec(IMAGE_ELEMS)))
            .collect();
        let mut ok = 0;
        for rx in rxs {
            // Exactly-once: every admitted request yields one answer.
            if rx.recv().unwrap().is_ok() {
                ok += 1;
            }
        }
        assert_eq!(ok, 16);
        let m = s.shutdown();
        assert_eq!(m.count(), 16);
        let table = m.backend_table().expect("fleet mode must shard metrics");
        assert!(table.contains("systolic@45"), "{table}");
        assert!(table.contains("reram@45"), "{table}");
        let images: usize = m.backends().values().map(|b| b.images()).sum();
        assert_eq!(images, 16, "per-backend shards must cover every image");
        for (label, b) in m.backends() {
            if b.images() > 0 {
                let uj = b.uj_per_inf().expect("served backends must be priced");
                assert!(uj > 0.0, "{label}: {uj}");
                assert_eq!(b.source(), "co-simulation");
            }
        }
    }

    #[test]
    fn routing_sends_serial_load_to_the_cheapest_backend() {
        // At SmallCNN scale the systolic array prices far below the 4F
        // optical machine (`small_images_favor_systolic`), so a serial
        // stream — no lane ever full — must route every batch to the
        // systolic lane and count zero reroutes.
        let s = Server::start_sim(
            ServerConfig {
                warm_start: false,
                max_pending: 64,
                fleet: Some(parse_fleet("systolic@45:1,optical4f@45:1").unwrap()),
                ..Default::default()
            },
            SimExecutor::instant(),
        )
        .unwrap();
        let mut rng = Rng::new(48);
        for _ in 0..6 {
            s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        }
        let m = s.shutdown();
        assert_eq!(m.rerouted(), 0, "{}", m.summary());
        assert_eq!(m.backends()["systolic@45"].images(), 6);
        assert_eq!(m.backends()["optical4f@45"].images(), 0);
    }
}
