//! The inference server: request queue → dynamic batcher → worker pool.
//!
//! PJRT client handles are `Rc`-based (not `Send`), so the engine cannot
//! be shared across threads; instead each worker thread owns a private
//! [`Engine`] (compilation is per-worker and lazy) and workers pull
//! batches from a shared queue. The dispatcher thread implements the
//! [`BatchPolicy`]: it drains the request queue, forms execution plans
//! via [`plan_batches`], and hands concatenated image tensors to workers.
//! Between rounds it parks in a bounded `recv_timeout` (new work or the
//! oldest request's deadline wakes it), so an idle server does not burn
//! a core polling.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{plan_batches, should_dispatch, BatchPolicy};
use super::metrics::Metrics;
use super::{ConvPath, IMAGE_ELEMS, LOGITS};
use crate::runtime::Engine;

/// Longest the dispatcher blocks in one park: long enough that an idle
/// server wakes ~100×/s (instead of the 5000×/s the old 200 µs poll
/// cost a core for), short enough that `stop` is honoured promptly.
const IDLE_PARK: Duration = Duration::from_millis(10);

/// One inference request travelling through the server.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    resp: Sender<Result<Vec<f32>>>,
}

/// A planned batch ready for execution.
struct Batch {
    artifact: String,
    batch: usize,
    requests: Vec<Request>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub path: ConvPath,
    pub policy: BatchPolicy,
    pub workers: usize,
    /// Artifacts directory (None = auto-discover).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Pre-compile every batch variant in every worker before serving
    /// (keeps PJRT compilation off the request path). Disable in tests
    /// that don't care about steady-state latency.
    pub warm_start: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            path: ConvPath::Exact,
            policy: BatchPolicy::default(),
            workers: 2,
            artifacts_dir: None,
            warm_start: true,
        }
    }
}

/// Handle to a running server.
pub struct Server {
    tx: Sender<Request>,
    stop: Arc<AtomicBool>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Mutex<Metrics>>,
    in_flight: Arc<AtomicUsize>,
}

impl Server {
    /// Start dispatcher + workers.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = channel::<Request>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let in_flight = Arc::new(AtomicUsize::new(0));

        // Resolve the artifacts dir once so workers don't race discovery.
        let dir = match &cfg.artifacts_dir {
            Some(d) => d.clone(),
            None => crate::runtime::find_artifacts_dir().ok_or_else(|| {
                anyhow::anyhow!("artifacts not found — run `make artifacts`")
            })?,
        };

        // Dispatcher: drain queue, apply batching policy, emit plans.
        let dispatcher = {
            let stop = stop.clone();
            let policy = cfg.policy;
            let path = cfg.path;
            let metrics = metrics.clone();
            let in_flight = in_flight.clone();
            std::thread::spawn(move || {
                let mut pending: Vec<Request> = Vec::new();
                loop {
                    // Pull everything immediately available.
                    while let Ok(r) = rx.try_recv() {
                        pending.push(r);
                    }
                    let oldest = pending
                        .first()
                        .map(|r| r.enqueued.elapsed())
                        .unwrap_or(Duration::ZERO);
                    if should_dispatch(&policy, pending.len(), oldest) {
                        let take = pending.len().min(policy.max_batch);
                        let round: Vec<Request> = pending.drain(..take).collect();
                        let mut round = round;
                        for b in plan_batches(round.len(), path.available_batches()) {
                            let reqs: Vec<Request> = round.drain(..b).collect();
                            metrics.lock().unwrap().record_batch(b);
                            if let Err(send_err) = batch_tx.send(Batch {
                                artifact: path.artifact_for_batch(b),
                                batch: b,
                                requests: reqs,
                            }) {
                                // All workers are gone; the batch (and
                                // anything still pending) will never be
                                // served — retire its accounting so
                                // shutdown() doesn't burn its deadline.
                                let dropped = send_err.0.requests.len()
                                    + round.len()
                                    + pending.len();
                                in_flight.fetch_sub(dropped, Ordering::AcqRel);
                                return;
                            }
                        }
                    } else if stop.load(Ordering::Acquire) && pending.is_empty() {
                        // Drained and asked to stop: close the batch queue.
                        return;
                    } else {
                        // Park until new work arrives or the oldest
                        // pending request's batching deadline fires. An
                        // idle server blocks for the full bound instead
                        // of spinning at poll granularity; a non-empty
                        // queue wakes exactly when `should_dispatch`
                        // could flip to true.
                        let park = if pending.is_empty() {
                            IDLE_PARK
                        } else {
                            policy
                                .max_wait
                                .saturating_sub(oldest)
                                .clamp(Duration::from_micros(50), IDLE_PARK)
                        };
                        match rx.recv_timeout(park) {
                            Ok(r) => pending.push(r),
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                                if pending.is_empty() {
                                    return;
                                }
                                // Senders are gone but requests remain:
                                // sleep out the deadline (recv would
                                // return Disconnected immediately and
                                // busy-spin otherwise), then the
                                // dispatch branch flushes them.
                                std::thread::sleep(park);
                            }
                        }
                    }
                }
            })
        };

        // Workers: each owns a private engine, pre-compiled for every
        // batch variant of the serving path so compilation (tens of
        // seconds for the larger graphs) never lands on the request path.
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let mut workers = Vec::new();
        for _w in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let dir = dir.clone();
            let metrics = metrics.clone();
            let in_flight = in_flight.clone();
            let path = cfg.path;
            let warm = cfg.warm_start;
            let ready_tx = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                let engine = match Engine::new(&dir) {
                    Ok(e) => e,
                    Err(err) => {
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                };
                if warm {
                    let names: Vec<String> = path
                        .available_batches()
                        .iter()
                        .map(|&b| path.artifact_for_batch(b))
                        .collect();
                    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
                    if let Err(err) = engine.warm_up(&name_refs) {
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                }
                let _ = ready_tx.send(Ok(()));
                loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    let Ok(job) = job else { return };
                    // `infer` counts per request; a batch retires all of
                    // its requests at once.
                    let retired = job.requests.len();
                    run_batch(&engine, job, &metrics);
                    in_flight.fetch_sub(retired, Ordering::AcqRel);
                }
            }));
        }

        // Block until every worker has compiled its executables.
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => anyhow::bail!("worker warm-up failed: {e:#}"),
                Err(_) => anyhow::bail!("worker died during warm-up"),
            }
        }

        Ok(Server {
            tx,
            stop,
            dispatcher: Some(dispatcher),
            workers,
            metrics,
            in_flight,
        })
    }

    /// Submit one image; returns a receiver for the logits.
    pub fn infer(&self, image: Vec<f32>) -> Receiver<Result<Vec<f32>>> {
        let (resp_tx, resp_rx) = channel();
        if image.len() != IMAGE_ELEMS {
            let _ = resp_tx.send(Err(anyhow::anyhow!(
                "image must have {IMAGE_ELEMS} elements, got {}",
                image.len()
            )));
            return resp_rx;
        }
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        let req = Request {
            image,
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        if self.tx.send(req).is_err() {
            // Server stopped; the receiver will see a disconnect.
            self.in_flight.fetch_sub(1, Ordering::AcqRel);
        }
        resp_rx
    }

    /// Submit and wait.
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        self.infer(image)
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped the request"))?
    }

    /// Graceful shutdown: drain, then join all threads.
    pub fn shutdown(mut self) -> Metrics {
        // Wait for in-flight work (bounded).
        let deadline = Instant::now() + Duration::from_secs(30);
        while self.in_flight.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        self.stop.store(true, Ordering::Release);
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let m = self.metrics.lock().unwrap().clone();
        m
    }
}

/// Execute one planned batch on a worker's engine and fan results out.
fn run_batch(engine: &Engine, job: Batch, metrics: &Arc<Mutex<Metrics>>) {
    let Batch {
        artifact,
        batch,
        requests,
    } = job;
    debug_assert_eq!(batch, requests.len());

    let result = if batch == 1 {
        engine.execute(&artifact, &[requests[0].image.clone()])
    } else {
        let mut packed = Vec::with_capacity(batch * IMAGE_ELEMS);
        for r in &requests {
            packed.extend_from_slice(&r.image);
        }
        engine.execute(&artifact, &[packed])
    };

    match result {
        Ok(out) => {
            debug_assert_eq!(out.len(), batch * LOGITS);
            for (i, r) in requests.iter().enumerate() {
                let logits = out[i * LOGITS..(i + 1) * LOGITS].to_vec();
                metrics
                    .lock()
                    .unwrap()
                    .record_request(r.enqueued.elapsed());
                let _ = r.resp.send(Ok(logits));
            }
        }
        Err(e) => {
            for r in requests {
                let _ = r.resp.send(Err(anyhow::anyhow!("{artifact}: {e:#}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn have_artifacts() -> bool {
        crate::runtime::find_artifacts_dir().is_some()
    }

    #[test]
    fn rejects_bad_image_size() {
        if !have_artifacts() {
            return;
        }
        let s = Server::start(ServerConfig {
            workers: 1,
            warm_start: false,
            ..Default::default()
        })
        .unwrap();
        let err = s.infer_blocking(vec![0.0; 5]);
        assert!(err.is_err());
        s.shutdown();
    }

    #[test]
    fn serves_single_request() {
        if !have_artifacts() {
            return;
        }
        let s = Server::start(ServerConfig {
            workers: 1,
            warm_start: false,
            ..Default::default()
        })
        .unwrap();
        let mut rng = Rng::new(1);
        let out = s.infer_blocking(rng.normal_vec(IMAGE_ELEMS)).unwrap();
        assert_eq!(out.len(), LOGITS);
        let m = s.shutdown();
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn batches_under_load_and_matches_batch1() {
        if !have_artifacts() {
            return;
        }
        let s = Server::start(ServerConfig {
            workers: 1,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(20),
            },
            warm_start: false,
            ..Default::default()
        })
        .unwrap();
        let mut rng = Rng::new(2);
        let images: Vec<Vec<f32>> =
            (0..8).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
        // Fire all 8 concurrently so the batcher can pack them.
        let rxs: Vec<_> = images.iter().map(|im| s.infer(im.clone())).collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        let m = s.shutdown();
        assert!(m.mean_batch() > 1.0, "batching should engage: {}", m.summary());

        // Batched results must equal per-image execution.
        let engine = Engine::discover().unwrap();
        for (im, out) in images.iter().zip(&outs) {
            let single = engine.execute("smallcnn_exact", &[im.clone()]).unwrap();
            for (a, b) in single.iter().zip(out) {
                assert!((a - b).abs() < 1e-4, "batched {b} vs single {a}");
            }
        }
    }
}
