//! Execution backends for the serving path.
//!
//! The server is generic over [`Executor`], so the same dispatcher /
//! lane / drain machinery runs against the real PJRT
//! [`Engine`](crate::runtime::Engine) (when artifacts and the `pjrt`
//! feature are present) or the deterministic in-process [`SimExecutor`].
//! The latter is what lets the serving integration tests and
//! `cargo bench -- serve` exercise batching, backpressure and shutdown
//! in the offline build environment, where no AOT artifacts exist.

use std::time::Duration;

use anyhow::Result;

use super::{IMAGE_ELEMS, LOGITS};
use crate::runtime::Engine;

/// A batch-execution backend owned by one worker thread.
///
/// Implementations need not be `Send`: the server constructs one
/// executor *inside* each worker thread via a factory (PJRT client
/// handles are `Rc`-based).
pub trait Executor {
    /// Pre-compile the named artifacts; a no-op for backends without a
    /// compilation step.
    fn warm_up(&self, _artifacts: &[&str]) -> Result<()> {
        Ok(())
    }

    /// Execute one batch. `inputs` matches the artifact's input arity
    /// (the CNN serving artifacts take a single tensor holding `batch`
    /// images concatenated); returns `batch * LOGITS` values.
    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>>;
}

impl Executor for Engine {
    fn warm_up(&self, artifacts: &[&str]) -> Result<()> {
        Engine::warm_up(self, artifacts)
    }

    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        Engine::execute(self, artifact, inputs)
    }
}

/// Deterministic stand-in for the PJRT engine.
///
/// Computes a fixed sparse linear readout per image (batch-invariant:
/// the same image yields bit-identical logits at any batch size, which
/// is what the batched-equals-single tests rely on) and then sleeps
/// `base_cost + per_image_cost × batch` to model a device whose fixed
/// dispatch overhead is amortized by batching — the same shape as the
/// paper's efficiency-at-scale argument, eq. 22's channel packing in
/// miniature.
#[derive(Clone, Copy, Debug)]
pub struct SimExecutor {
    /// Fixed per-dispatch cost (kernel launch, readout).
    pub base_cost: Duration,
    /// Incremental cost per image in the batch.
    pub per_image_cost: Duration,
}

impl Default for SimExecutor {
    fn default() -> Self {
        // base/per-image ≈ 10: batch 8 serves ~5× more images per second
        // than batch 1, so batching visibly pays in the serve bench.
        SimExecutor {
            base_cost: Duration::from_micros(300),
            per_image_cost: Duration::from_micros(30),
        }
    }
}

impl SimExecutor {
    pub fn new(base_cost: Duration, per_image_cost: Duration) -> Self {
        SimExecutor {
            base_cost,
            per_image_cost,
        }
    }

    /// Zero-cost variant for tests that don't time anything.
    pub fn instant() -> Self {
        SimExecutor::new(Duration::ZERO, Duration::ZERO)
    }
}

/// Batch size encoded in an artifact name (`…_b8` → 8, otherwise 1),
/// mirroring [`super::ConvPath::artifact_for_batch`].
fn batch_of(artifact: &str) -> usize {
    artifact
        .rsplit_once("_b")
        .and_then(|(_, n)| n.parse().ok())
        .unwrap_or(1)
}

/// Fixed sparse readout: pseudo-weights in {+1, −1}/64 derived from the
/// element index only, so the map is deterministic and batch-invariant.
fn logits_of(img: &[f32]) -> [f32; LOGITS] {
    let mut l = [0.0f32; LOGITS];
    for (i, &v) in img.iter().enumerate() {
        let sign = if (i / LOGITS) & 1 == 0 { 1.0 } else { -1.0 };
        l[i % LOGITS] += sign * v;
    }
    for v in &mut l {
        *v /= 64.0;
    }
    l
}

impl Executor for SimExecutor {
    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let batch = batch_of(artifact);
        anyhow::ensure!(
            inputs.len() == 1,
            "{artifact}: got {} inputs, expects 1",
            inputs.len()
        );
        let packed = &inputs[0];
        anyhow::ensure!(
            packed.len() == batch * IMAGE_ELEMS,
            "{artifact}: {} elements, expects {}",
            packed.len(),
            batch * IMAGE_ELEMS
        );
        let mut out = Vec::with_capacity(batch * LOGITS);
        for b in 0..batch {
            let img = &packed[b * IMAGE_ELEMS..(b + 1) * IMAGE_ELEMS];
            out.extend_from_slice(&logits_of(img));
        }
        let cost = self.base_cost + self.per_image_cost * batch as u32;
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn batch_parsed_from_artifact_name() {
        assert_eq!(batch_of("smallcnn_exact"), 1);
        assert_eq!(batch_of("smallcnn_exact_b8"), 8);
        assert_eq!(batch_of("smallcnn_systolic_b4"), 4);
        assert_eq!(batch_of("smallcnn_fft"), 1);
    }

    #[test]
    fn deterministic_and_finite() {
        let e = SimExecutor::instant();
        let mut rng = Rng::new(3);
        let img = rng.normal_vec(IMAGE_ELEMS);
        let a = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
        let b = e.execute("smallcnn_exact", &[img]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), LOGITS);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_equals_single() {
        let e = SimExecutor::instant();
        let mut rng = Rng::new(4);
        let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
        let packed: Vec<f32> = images.iter().flatten().copied().collect();
        let batched = e.execute("smallcnn_exact_b8", &[packed]).unwrap();
        for (i, img) in images.iter().enumerate() {
            let single = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
            assert_eq!(&batched[i * LOGITS..(i + 1) * LOGITS], &single[..]);
        }
    }

    #[test]
    fn wrong_input_len_rejected() {
        let e = SimExecutor::instant();
        assert!(e.execute("smallcnn_exact", &[vec![0.0; 5]]).is_err());
        assert!(e.execute("smallcnn_exact_b8", &[vec![0.0; IMAGE_ELEMS]]).is_err());
        assert!(e.execute("smallcnn_exact", &[]).is_err());
    }
}
