//! Execution backends for the serving path.
//!
//! The server is generic over [`Executor`], so the same dispatcher /
//! lane / drain machinery runs against the real PJRT
//! [`Engine`](crate::runtime::Engine) (when artifacts and the `pjrt`
//! feature are present) or the deterministic in-process [`SimExecutor`].
//! The latter is what lets the serving integration tests and
//! `cargo bench -- serve` exercise batching, backpressure and shutdown
//! in the offline build environment, where no AOT artifacts exist.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

use anyhow::Result;

use super::{IMAGE_ELEMS, LOGITS};
use crate::energy::surrogate::MachineKind;
use crate::runtime::Engine;

/// A batch-execution backend owned by one worker thread.
///
/// Implementations need not be `Send`: the server constructs one
/// executor *inside* each worker thread via a factory (PJRT client
/// handles are `Rc`-based).
pub trait Executor {
    /// Pre-compile the named artifacts; a no-op for backends without a
    /// compilation step.
    fn warm_up(&self, _artifacts: &[&str]) -> Result<()> {
        Ok(())
    }

    /// Execute one batch. `inputs` matches the artifact's input arity
    /// (the CNN serving artifacts take a single tensor holding `batch`
    /// images concatenated); returns `batch * LOGITS` values.
    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>>;
}

impl Executor for Engine {
    fn warm_up(&self, artifacts: &[&str]) -> Result<()> {
        Engine::warm_up(self, artifacts)
    }

    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        Engine::execute(self, artifact, inputs)
    }
}

/// Scripted executor-fault injection: deterministic cadences of
/// transient errors, stalls and slow batches, for chaos-testing the
/// server's retry/timeout/breaker machinery without any real hardware
/// misbehaving. The `Default` plan is clear — no clause fires, and the
/// executor behaves exactly as before the plan existed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Every `error_every`-th batch fails with a transient error
    /// (0 = never).
    pub error_every: u64,
    /// Every `stall_every`-th batch sleeps `stall_for` before executing
    /// (0 = never).
    pub stall_every: u64,
    /// Stall duration for the `stall_every` cadence.
    pub stall_for: Duration,
    /// Every `slow_every`-th batch costs `slow_factor` × the normal
    /// sleep (0 = never).
    pub slow_every: u64,
    /// Cost multiplier for the `slow_every` cadence.
    pub slow_factor: u32,
    /// Restrict the plan to fleet workers backed by this machine kind
    /// (`None` = every worker). Resolved by [`FaultPlan::for_backend`]
    /// when the server expands a heterogeneous fleet, so chaos can
    /// degrade one backend while the rest of the fleet stays healthy.
    pub backend: Option<MachineKind>,
}

impl FaultPlan {
    /// No clause armed — the executor is fault-free.
    pub fn is_clear(&self) -> bool {
        self.error_every == 0 && self.stall_every == 0 && self.slow_every == 0
    }

    /// Specialize the plan for one fleet worker: the full plan when the
    /// `backend` clause is absent or names `kind`, the clear plan
    /// otherwise — so a targeted plan leaves every other backend's
    /// executor behaviourally untouched.
    pub fn for_backend(self, kind: MachineKind) -> FaultPlan {
        match self.backend {
            None => self,
            Some(target) if target == kind => self,
            Some(_) => FaultPlan::default(),
        }
    }

    /// Parse a `--chaos` spec: comma-separated clauses out of
    /// `error=N` (every Nth batch errors), `stall=N:DUR` (every Nth
    /// batch sleeps DUR — `50ms`, `2s`, `300us`, or bare milliseconds),
    /// `slow=N:F` (every Nth batch costs F×) and `backend=NAME`
    /// (restrict the plan to fleet workers on that machine kind).
    /// `"error=5,stall=7:50ms,slow=3:4"` arms the first three;
    /// `"error=3,backend=reram"` degrades only the ReRAM lanes.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        fn cadence(s: &str) -> Result<u64, String> {
            match s.trim().parse::<u64>() {
                Ok(0) | Err(_) => Err(format!("cadence must be a positive integer, got {s:?}")),
                Ok(n) => Ok(n),
            }
        }
        fn duration(s: &str) -> Result<Duration, String> {
            let s = s.trim();
            let bad = || format!("bad duration {s:?} (want e.g. 50ms, 2s, 300us)");
            if let Some(us) = s.strip_suffix("us") {
                us.parse::<u64>().map(Duration::from_micros).map_err(|_| bad())
            } else if let Some(ms) = s.strip_suffix("ms") {
                ms.parse::<u64>().map(Duration::from_millis).map_err(|_| bad())
            } else if let Some(sec) = s.strip_suffix('s') {
                sec.parse::<u64>().map(Duration::from_secs).map_err(|_| bad())
            } else {
                s.parse::<u64>().map(Duration::from_millis).map_err(|_| bad())
            }
        }
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("chaos clause {clause:?} is not key=value"))?;
            match key.trim() {
                "error" => plan.error_every = cadence(val)?,
                "stall" => {
                    let (n, d) = val
                        .split_once(':')
                        .ok_or_else(|| format!("stall wants N:DURATION, got {val:?}"))?;
                    plan.stall_every = cadence(n)?;
                    plan.stall_for = duration(d)?;
                }
                "slow" => {
                    let (n, f) = val
                        .split_once(':')
                        .ok_or_else(|| format!("slow wants N:FACTOR, got {val:?}"))?;
                    plan.slow_every = cadence(n)?;
                    plan.slow_factor = match f.trim().parse::<u32>() {
                        Ok(0) | Err(_) => {
                            return Err(format!("slow factor must be ≥ 1, got {f:?}"))
                        }
                        Ok(x) => x,
                    };
                }
                "backend" => {
                    plan.backend = Some(MachineKind::parse(val.trim()).ok_or_else(|| {
                        format!(
                            "unknown chaos backend {val:?} \
                             (systolic | reram | photonic | optical4f)"
                        )
                    })?);
                }
                other => return Err(format!("unknown chaos clause {other:?}")),
            }
        }
        Ok(plan)
    }
}

/// Deterministic stand-in for the PJRT engine.
///
/// Computes a fixed sparse linear readout per image (batch-invariant:
/// the same image yields bit-identical logits at any batch size, which
/// is what the batched-equals-single tests rely on) and then sleeps
/// `base_cost + per_image_cost × batch` to model a device whose fixed
/// dispatch overhead is amortized by batching — the same shape as the
/// paper's efficiency-at-scale argument, eq. 22's channel packing in
/// miniature. A [`FaultPlan`] arms scripted stalls, transient errors
/// and slow batches on deterministic per-instance cadences; each worker
/// clones its own executor, so cadences count per lane.
#[derive(Debug)]
pub struct SimExecutor {
    /// Fixed per-dispatch cost (kernel launch, readout).
    pub base_cost: Duration,
    /// Incremental cost per image in the batch.
    pub per_image_cost: Duration,
    /// Scripted fault injection; clear by default.
    pub plan: FaultPlan,
    /// Batches dispatched through THIS instance (fault cadences count
    /// against it, so every clone runs the same deterministic script).
    dispatched: AtomicU64,
}

impl Clone for SimExecutor {
    fn clone(&self) -> Self {
        SimExecutor {
            base_cost: self.base_cost,
            per_image_cost: self.per_image_cost,
            plan: self.plan,
            dispatched: AtomicU64::new(self.dispatched.load(Relaxed)),
        }
    }
}

impl Default for SimExecutor {
    fn default() -> Self {
        // base/per-image ≈ 10: batch 8 serves ~5× more images per second
        // than batch 1, so batching visibly pays in the serve bench.
        SimExecutor::new(Duration::from_micros(300), Duration::from_micros(30))
    }
}

impl SimExecutor {
    pub fn new(base_cost: Duration, per_image_cost: Duration) -> Self {
        SimExecutor {
            base_cost,
            per_image_cost,
            plan: FaultPlan::default(),
            dispatched: AtomicU64::new(0),
        }
    }

    /// Zero-cost variant for tests that don't time anything.
    pub fn instant() -> Self {
        SimExecutor::new(Duration::ZERO, Duration::ZERO)
    }

    /// Arm a scripted fault plan (builder style).
    pub fn with_plan(mut self, plan: FaultPlan) -> Self {
        self.plan = plan;
        self
    }
}

/// Batch size encoded in an artifact name (`…_b8` → 8, otherwise 1),
/// mirroring [`super::ConvPath::artifact_for_batch`].
fn batch_of(artifact: &str) -> usize {
    artifact
        .rsplit_once("_b")
        .and_then(|(_, n)| n.parse().ok())
        .unwrap_or(1)
}

/// Fixed sparse readout: pseudo-weights in {+1, −1}/64 derived from the
/// element index only, so the map is deterministic and batch-invariant.
fn logits_of(img: &[f32]) -> [f32; LOGITS] {
    let mut l = [0.0f32; LOGITS];
    for (i, &v) in img.iter().enumerate() {
        let sign = if (i / LOGITS) & 1 == 0 { 1.0 } else { -1.0 };
        l[i % LOGITS] += sign * v;
    }
    for v in &mut l {
        *v /= 64.0;
    }
    l
}

impl Executor for SimExecutor {
    fn execute(&self, artifact: &str, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let batch = batch_of(artifact);
        anyhow::ensure!(
            inputs.len() == 1,
            "{artifact}: got {} inputs, expects 1",
            inputs.len()
        );
        let packed = &inputs[0];
        anyhow::ensure!(
            packed.len() == batch * IMAGE_ELEMS,
            "{artifact}: {} elements, expects {}",
            packed.len(),
            batch * IMAGE_ELEMS
        );
        // Scripted faults count well-formed dispatches only, so caller
        // bugs (rejected above) never consume a cadence slot.
        let ordinal = self.dispatched.fetch_add(1, Relaxed) + 1;
        let hits = |every: u64| every > 0 && ordinal % every == 0;
        if hits(self.plan.stall_every) && !self.plan.stall_for.is_zero() {
            std::thread::sleep(self.plan.stall_for);
        }
        if hits(self.plan.error_every) {
            anyhow::bail!("injected transient fault (batch #{ordinal})");
        }
        let mut out = Vec::with_capacity(batch * LOGITS);
        for b in 0..batch {
            let img = &packed[b * IMAGE_ELEMS..(b + 1) * IMAGE_ELEMS];
            out.extend_from_slice(&logits_of(img));
        }
        let mut cost = self.base_cost + self.per_image_cost * batch as u32;
        if hits(self.plan.slow_every) {
            cost *= self.plan.slow_factor.max(1);
        }
        if !cost.is_zero() {
            std::thread::sleep(cost);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn batch_parsed_from_artifact_name() {
        assert_eq!(batch_of("smallcnn_exact"), 1);
        assert_eq!(batch_of("smallcnn_exact_b8"), 8);
        assert_eq!(batch_of("smallcnn_systolic_b4"), 4);
        assert_eq!(batch_of("smallcnn_fft"), 1);
    }

    #[test]
    fn deterministic_and_finite() {
        let e = SimExecutor::instant();
        let mut rng = Rng::new(3);
        let img = rng.normal_vec(IMAGE_ELEMS);
        let a = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
        let b = e.execute("smallcnn_exact", &[img]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), LOGITS);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_equals_single() {
        let e = SimExecutor::instant();
        let mut rng = Rng::new(4);
        let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
        let packed: Vec<f32> = images.iter().flatten().copied().collect();
        let batched = e.execute("smallcnn_exact_b8", &[packed]).unwrap();
        for (i, img) in images.iter().enumerate() {
            let single = e.execute("smallcnn_exact", &[img.clone()]).unwrap();
            assert_eq!(&batched[i * LOGITS..(i + 1) * LOGITS], &single[..]);
        }
    }

    #[test]
    fn wrong_input_len_rejected() {
        let e = SimExecutor::instant();
        assert!(e.execute("smallcnn_exact", &[vec![0.0; 5]]).is_err());
        assert!(e.execute("smallcnn_exact_b8", &[vec![0.0; IMAGE_ELEMS]]).is_err());
        assert!(e.execute("smallcnn_exact", &[]).is_err());
    }

    #[test]
    fn fault_plan_parses_the_chaos_grammar() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("").unwrap().is_clear());
        let p = FaultPlan::parse("error=5,stall=7:50ms,slow=3:4").unwrap();
        assert_eq!(p.error_every, 5);
        assert_eq!(p.stall_every, 7);
        assert_eq!(p.stall_for, Duration::from_millis(50));
        assert_eq!(p.slow_every, 3);
        assert_eq!(p.slow_factor, 4);
        assert!(!p.is_clear());
        // Duration suffixes: us / ms / s / bare-ms.
        assert_eq!(
            FaultPlan::parse("stall=1:300us").unwrap().stall_for,
            Duration::from_micros(300)
        );
        assert_eq!(
            FaultPlan::parse("stall=1:2s").unwrap().stall_for,
            Duration::from_secs(2)
        );
        assert_eq!(
            FaultPlan::parse("stall=1:25").unwrap().stall_for,
            Duration::from_millis(25)
        );
        // Every malformed clause is a loud error, never a silent no-op.
        for bad in [
            "error=0",
            "error=x",
            "stall=3",
            "stall=3:banana",
            "slow=2:0",
            "warp=9",
            "error",
            "backend=abacus",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn backend_clause_targets_one_machine_kind() {
        let p = FaultPlan::parse("error=3,backend=reram").unwrap();
        assert_eq!(p.backend, Some(MachineKind::Reram));
        assert!(!p.is_clear());
        // Specialization: the targeted kind keeps the full plan, every
        // other kind gets the clear plan.
        assert_eq!(p.for_backend(MachineKind::Reram), p);
        assert!(p.for_backend(MachineKind::Systolic).is_clear());
        // An untargeted plan applies to every backend unchanged.
        let any = FaultPlan::parse("error=2").unwrap();
        assert_eq!(any.for_backend(MachineKind::Optical4F), any);
    }

    #[test]
    fn injected_errors_fire_on_their_cadence_only() {
        let e = SimExecutor::instant().with_plan(FaultPlan {
            error_every: 3,
            ..Default::default()
        });
        let img = vec![0.5; IMAGE_ELEMS];
        for ordinal in 1..=12u64 {
            let r = e.execute("smallcnn_exact", &[img.clone()]);
            if ordinal % 3 == 0 {
                let err = r.expect_err("cadence batch must fail").to_string();
                assert!(err.contains("injected transient fault"), "{err}");
            } else {
                assert_eq!(r.unwrap().len(), LOGITS);
            }
        }
    }

    #[test]
    fn cloned_executors_replay_the_same_fault_script() {
        let plan = FaultPlan {
            error_every: 2,
            ..Default::default()
        };
        let a = SimExecutor::instant().with_plan(plan);
        let b = a.clone();
        let img = vec![1.0; IMAGE_ELEMS];
        let script = |e: &SimExecutor| -> Vec<bool> {
            (0..6)
                .map(|_| e.execute("smallcnn_exact", &[img.clone()]).is_ok())
                .collect()
        };
        assert_eq!(script(&a), script(&b), "clones start from the same ordinal");
    }

    #[test]
    fn clear_plan_is_behaviourally_invisible() {
        let faulty = SimExecutor::instant().with_plan(FaultPlan::default());
        let plain = SimExecutor::instant();
        let mut rng = Rng::new(7);
        let img = rng.normal_vec(IMAGE_ELEMS);
        assert_eq!(
            faulty.execute("smallcnn_exact", &[img.clone()]).unwrap(),
            plain.execute("smallcnn_exact", &[img]).unwrap()
        );
    }
}
