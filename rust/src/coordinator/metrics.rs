//! Serving metrics: latency percentiles, throughput, batch-size histogram.

use std::time::{Duration, Instant};

/// Accumulates per-request and per-batch observations.
///
/// The server keeps one `Metrics` *shard* per worker thread (plus one in
/// the dispatcher for batch sizes), each owned `&mut` by its thread so
/// recording never takes a lock; shards are [`Metrics::merge`]d into one
/// aggregate when the server shuts down.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    rejected: usize,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_micros() as u64);
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batch_sizes.push(size);
    }

    /// Count requests refused at admission (backpressure).
    pub fn record_rejected(&mut self, n: usize) {
        self.rejected += n;
    }

    /// Set the throughput window explicitly (the server stamps serving
    /// start → shutdown on the merged aggregate).
    pub fn set_window(&mut self, started: Instant, finished: Instant) {
        self.started = Some(started);
        self.finished = Some(finished);
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.rejected += other.rejected;
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Latency percentile in microseconds (nearest-rank).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
        v[rank.min(v.len()) - 1]
    }

    /// Mean batch size actually executed.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Requests per second over the start→stop window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, {:.1} req/s",
            self.count(),
            self.percentile_us(50.0) as f64 / 1e3,
            self.percentile_us(95.0) as f64 / 1e3,
            self.percentile_us(99.0) as f64 / 1e3,
            self.mean_batch(),
            self.throughput()
        );
        if self.rejected > 0 {
            s.push_str(&format!(", {} rejected", self.rejected));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_request(Duration::from_micros(us));
        }
        assert_eq!(m.percentile_us(50.0), 500);
        assert_eq!(m.percentile_us(95.0), 1000);
        assert_eq!(m.percentile_us(10.0), 100);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let mut m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.mean_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.record_request(Duration::from_micros(10));
        a.record_rejected(1);
        let mut b = Metrics::new();
        b.record_request(Duration::from_micros(20));
        b.record_batch(4);
        b.record_rejected(2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_batch(), 4.0);
        assert_eq!(a.rejected(), 3);
        assert!(a.summary().contains("3 rejected"));
    }

    #[test]
    fn set_window_drives_throughput() {
        let mut m = Metrics::new();
        let t0 = Instant::now();
        for _ in 0..100 {
            m.record_request(Duration::from_micros(5));
        }
        std::thread::sleep(Duration::from_millis(20));
        m.set_window(t0, Instant::now());
        let t = m.throughput();
        assert!(t > 0.0 && t < 100.0 / 0.02, "throughput {t}");
    }

    #[test]
    fn summary_contains_fields() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_millis(1));
        let s = m.summary();
        assert!(s.contains("p50") && s.contains("req/s"));
    }
}
