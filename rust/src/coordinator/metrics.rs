//! Serving metrics: latency percentiles, throughput, batch-size
//! histogram, per-batch energy accounting — and, for heterogeneous
//! fleets, a per-backend breakdown ([`BackendStats`]) keyed by the
//! lane's backend label (`systolic@45` …).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use super::energy::EnergyReport;

/// Latency percentile in microseconds (nearest-rank) over a raw sample.
fn percentile_of(samples: &[u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let rank = ((p / 100.0) * v.len() as f64).ceil().max(1.0) as usize;
    v[rank.min(v.len()) - 1]
}

/// Per-backend serving observations for one fleet label. Accumulated in
/// the owning worker's shard (the shard's `set_backend` label routes
/// every request/trip/energy record here too) and unioned across shards
/// by [`Metrics::merge`].
#[derive(Clone, Debug, Default)]
pub struct BackendStats {
    latencies_us: Vec<u64>,
    batches: usize,
    images: usize,
    energy_images: usize,
    joules: f64,
    breaker_trips: usize,
    surrogate_misses: usize,
    source: &'static str,
}

impl BackendStats {
    fn merge(&mut self, other: &BackendStats) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batches += other.batches;
        self.images += other.images;
        self.energy_images += other.energy_images;
        self.joules += other.joules;
        self.breaker_trips += other.breaker_trips;
        self.surrogate_misses += other.surrogate_misses;
        if !other.source.is_empty() {
            self.source = other.source;
        }
    }

    /// Batches this backend executed.
    pub fn batches(&self) -> usize {
        self.batches
    }

    /// Images (inferences) this backend served.
    pub fn images(&self) -> usize {
        self.images
    }

    /// Projected µJ per inference on this backend; `None` when no batch
    /// was priced (absence, never 0.0).
    pub fn uj_per_inf(&self) -> Option<f64> {
        if self.energy_images == 0 {
            return None;
        }
        Some(self.joules * 1e6 / self.energy_images as f64)
    }

    /// p50 request latency (µs) for requests answered by this backend.
    pub fn p50_us(&self) -> u64 {
        percentile_of(&self.latencies_us, 50.0)
    }

    /// p99 request latency (µs) for requests answered by this backend.
    pub fn p99_us(&self) -> u64 {
        percentile_of(&self.latencies_us, 99.0)
    }

    /// Circuit-breaker openings on this backend's lanes.
    pub fn breaker_trips(&self) -> usize {
        self.breaker_trips
    }

    /// Startup surrogate misses attributed to this backend.
    pub fn surrogate_misses(&self) -> usize {
        self.surrogate_misses
    }

    /// Pricing source for this backend's quote ("surrogate" /
    /// "co-simulation"); empty when unpriced.
    pub fn source(&self) -> &'static str {
        self.source
    }
}

/// Accumulates per-request and per-batch observations.
///
/// The server keeps one `Metrics` *shard* per worker thread (plus one in
/// the dispatcher for batch sizes), each owned `&mut` by its thread so
/// recording never takes a lock; shards are [`Metrics::merge`]d into one
/// aggregate when the server shuts down.
///
/// Energy fields accumulate the per-batch co-simulation each worker runs
/// after executing a batch ([`Metrics::record_energy`]): total projected
/// joules on the systolic and optical-4F machines, over how many images
/// and batches they were accumulated.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    rejected: usize,
    started: Option<Instant>,
    finished: Option<Instant>,
    energy_images: usize,
    energy_batches: usize,
    systolic_joules: f64,
    optical_joules: f64,
    /// Node the energy was priced at; 0.0 until the first record.
    energy_node_nm: f64,
    /// `(bits_x, bits_w)` the energy was priced at; (0, 0) until the
    /// first record.
    energy_bits: (u32, u32),
    /// How the energy numbers were produced ("co-simulation" or
    /// "surrogate"); empty until the first record.
    energy_source: &'static str,
    /// Requests refused by the energy-budget admission policy
    /// (`ServerConfig::max_uj_per_inf`), counted separately from
    /// backpressure rejections.
    budget_rejected: usize,
    /// Layer families of the resident network a configured surrogate
    /// table could NOT price (so pricing fell back to co-simulation).
    /// 0 when no surrogate was configured or coverage was complete.
    surrogate_miss: usize,
    /// Batch executions re-attempted after a failure (backend error or
    /// wrong-shaped output). One failed batch can contribute several.
    retries: usize,
    /// Batch attempts that overran the per-attempt execution deadline
    /// (`ServerConfig::batch_deadline`). Counted for observability; the
    /// attempt's results are still delivered.
    timeouts: usize,
    /// Times a worker lane's circuit breaker opened after consecutive
    /// failed batches.
    breaker_trips: usize,
    /// 1 when the startup pricing co-simulation missed its deadline and
    /// per-request quoting (and any energy budget) was abandoned in
    /// favour of per-batch co-simulation.
    degraded_pricing: usize,
    /// Batches the fleet dispatcher routed AWAY from the quote-preferred
    /// backend (open breaker or full lane there). 0 in homogeneous
    /// deployments, where no lane carries a quote.
    rerouted: usize,
    /// Per-backend breakdown for heterogeneous fleets, keyed by backend
    /// label (`systolic@45` …). Empty outside fleet mode.
    backends: BTreeMap<String, BackendStats>,
    /// This shard's backend label (fleet worker shards only): routes
    /// request/trip/energy records into `backends` as well.
    backend_label: Option<String>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&mut self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.latencies_us.push(us);
        if let Some(label) = self.backend_label.as_deref() {
            if let Some(b) = self.backends.get_mut(label) {
                b.latencies_us.push(us);
            }
        }
    }

    /// Tag this shard with its lane's backend label (fleet workers):
    /// from here on, requests / breaker trips / surrogate misses /
    /// energy recorded on the shard also accumulate under the label.
    pub fn set_backend(&mut self, label: &str) {
        self.backends.entry(label.to_string()).or_default();
        self.backend_label = Some(label.to_string());
    }

    /// Count batches executed by this shard's backend lane (fleet mode).
    pub fn record_backend_batch(&mut self, images: usize) {
        if let Some(label) = self.backend_label.as_deref() {
            if let Some(b) = self.backends.get_mut(label) {
                b.batches += 1;
                b.images += images;
            }
        }
    }

    /// Accumulate priced energy for one batch under this shard's
    /// backend label (fleet mode) — per-inference joules × images,
    /// tagged with the pricing source.
    pub fn record_backend_energy(&mut self, images: usize, j_per_inf: f64, source: &'static str) {
        if let Some(label) = self.backend_label.as_deref() {
            if let Some(b) = self.backends.get_mut(label) {
                b.energy_images += images;
                b.joules += j_per_inf * images as f64;
                b.source = source;
            }
        }
    }

    /// Count batches routed away from the quote-preferred backend.
    pub fn record_reroute(&mut self, n: usize) {
        self.rerouted += n;
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batch_sizes.push(size);
    }

    /// Count requests refused at admission (backpressure).
    pub fn record_rejected(&mut self, n: usize) {
        self.rejected += n;
    }

    /// Accumulate the energy projection for one executed batch of
    /// `images` inferences: `report` prices a *single* inference, so the
    /// batch's projected joules are `per-inference × images`. Recorded
    /// whether or not the batch's results were usable — the (projected)
    /// hardware burns the energy either way.
    pub fn record_energy(&mut self, images: usize, report: &EnergyReport) {
        self.record_priced_energy(
            images,
            report.systolic_joules(),
            report.optical_joules(),
            report.op.node_nm,
            (report.op.bits_x, report.op.bits_w),
            "co-simulation",
        );
    }

    /// [`Metrics::record_energy`] with explicit per-inference joules,
    /// the priced operating point (node + bit widths) and a
    /// pricing-source label — the surrogate fast path records through
    /// this without materializing an [`EnergyReport`].
    pub fn record_priced_energy(
        &mut self,
        images: usize,
        systolic_j_per_inf: f64,
        optical_j_per_inf: f64,
        node_nm: f64,
        bits: (u32, u32),
        source: &'static str,
    ) {
        self.energy_images += images;
        self.energy_batches += 1;
        self.systolic_joules += systolic_j_per_inf * images as f64;
        self.optical_joules += optical_j_per_inf * images as f64;
        self.energy_node_nm = node_nm;
        self.energy_bits = bits;
        self.energy_source = source;
    }

    /// Count requests refused by the energy-budget admission policy.
    pub fn record_budget_rejected(&mut self, n: usize) {
        self.budget_rejected += n;
    }

    /// Count layer families a configured surrogate table failed to
    /// cover (each forces the co-simulation fallback).
    pub fn record_surrogate_miss(&mut self, n: usize) {
        self.surrogate_miss += n;
        if let Some(label) = self.backend_label.as_deref() {
            if let Some(b) = self.backends.get_mut(label) {
                b.surrogate_misses += n;
            }
        }
    }

    /// Count batch executions re-attempted after a failure.
    pub fn record_retry(&mut self, n: usize) {
        self.retries += n;
    }

    /// Count batch attempts that overran the execution deadline.
    pub fn record_timeout(&mut self, n: usize) {
        self.timeouts += n;
    }

    /// Count circuit-breaker openings on worker lanes.
    pub fn record_breaker_trip(&mut self, n: usize) {
        self.breaker_trips += n;
        if let Some(label) = self.backend_label.as_deref() {
            if let Some(b) = self.backends.get_mut(label) {
                b.breaker_trips += n;
            }
        }
    }

    /// Record that startup pricing degraded to per-batch co-simulation.
    pub fn record_degraded_pricing(&mut self, n: usize) {
        self.degraded_pricing += n;
    }

    /// Set the throughput window explicitly (the server stamps serving
    /// start → shutdown on the merged aggregate).
    pub fn set_window(&mut self, started: Instant, finished: Instant) {
        self.started = Some(started);
        self.finished = Some(finished);
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.rejected += other.rejected;
        self.energy_images += other.energy_images;
        self.energy_batches += other.energy_batches;
        self.systolic_joules += other.systolic_joules;
        self.optical_joules += other.optical_joules;
        if other.energy_node_nm > 0.0 {
            self.energy_node_nm = other.energy_node_nm;
        }
        if other.energy_bits != (0, 0) {
            self.energy_bits = other.energy_bits;
        }
        if !other.energy_source.is_empty() {
            self.energy_source = other.energy_source;
        }
        self.budget_rejected += other.budget_rejected;
        self.surrogate_miss += other.surrogate_miss;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.breaker_trips += other.breaker_trips;
        self.degraded_pricing += other.degraded_pricing;
        self.rerouted += other.rerouted;
        for (label, stats) in &other.backends {
            self.backends.entry(label.clone()).or_default().merge(stats);
        }
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn rejected(&self) -> usize {
        self.rejected
    }

    /// Inferences covered by the per-batch energy accounting.
    pub fn energy_images(&self) -> usize {
        self.energy_images
    }

    /// Batches priced by the per-batch energy accounting.
    pub fn energy_batches(&self) -> usize {
        self.energy_batches
    }

    /// Node (nm) the energy was priced at; 0.0 when nothing was priced.
    pub fn energy_node_nm(&self) -> f64 {
        self.energy_node_nm
    }

    /// `(bits_x, bits_w)` the energy was priced at; (0, 0) when nothing
    /// was priced.
    pub fn energy_bits(&self) -> (u32, u32) {
        self.energy_bits
    }

    /// Pricing-source label ("co-simulation" or "surrogate"); empty when
    /// nothing was priced.
    pub fn energy_source(&self) -> &'static str {
        self.energy_source
    }

    /// Requests refused by the energy-budget admission policy.
    pub fn budget_rejected(&self) -> usize {
        self.budget_rejected
    }

    /// Layer families a configured surrogate table could not price
    /// (0 = full coverage or no surrogate configured).
    pub fn surrogate_miss(&self) -> usize {
        self.surrogate_miss
    }

    /// Batch executions re-attempted after a failure.
    pub fn retries(&self) -> usize {
        self.retries
    }

    /// Batch attempts that overran the execution deadline.
    pub fn timeouts(&self) -> usize {
        self.timeouts
    }

    /// Circuit-breaker openings on worker lanes.
    pub fn breaker_trips(&self) -> usize {
        self.breaker_trips
    }

    /// 1 when startup pricing degraded to per-batch co-simulation.
    pub fn degraded_pricing(&self) -> usize {
        self.degraded_pricing
    }

    /// Batches the fleet dispatcher routed away from the quote-preferred
    /// backend.
    pub fn rerouted(&self) -> usize {
        self.rerouted
    }

    /// Per-backend breakdown (heterogeneous fleets); empty otherwise.
    pub fn backends(&self) -> &BTreeMap<String, BackendStats> {
        &self.backends
    }

    /// Render the per-backend breakdown as an aligned table; `None`
    /// outside fleet mode so homogeneous output stays untouched.
    pub fn backend_table(&self) -> Option<String> {
        if self.backends.is_empty() {
            return None;
        }
        let mut s = format!(
            "{:<18} {:>7} {:>7} {:>10} {:>8} {:>8} {:>6} {:>7}  {}",
            "backend",
            "batches",
            "images",
            "µJ/inf",
            "p50 ms",
            "p99 ms",
            "trips",
            "misses",
            "source"
        );
        for (label, b) in &self.backends {
            let uj = match b.uj_per_inf() {
                Some(uj) => format!("{uj:.2}"),
                None => "n/a".to_string(),
            };
            s.push_str(&format!(
                "\n{:<18} {:>7} {:>7} {:>10} {:>8.2} {:>8.2} {:>6} {:>7}  {}",
                label,
                b.batches,
                b.images,
                uj,
                b.p50_us() as f64 / 1e3,
                b.p99_us() as f64 / 1e3,
                b.breaker_trips,
                b.surrogate_misses,
                if b.source.is_empty() { "-" } else { b.source },
            ));
        }
        Some(s)
    }

    /// Projected µJ per inference on the systolic machine. `None` when
    /// no batch was priced — callers must render "n/a" / omit the field
    /// rather than report a meaningless 0.0.
    pub fn systolic_uj_per_inference(&self) -> Option<f64> {
        if self.energy_images == 0 {
            return None;
        }
        Some(self.systolic_joules * 1e6 / self.energy_images as f64)
    }

    /// Projected µJ per inference on the optical-4F machine. `None` when
    /// no batch was priced.
    pub fn optical_uj_per_inference(&self) -> Option<f64> {
        if self.energy_images == 0 {
            return None;
        }
        Some(self.optical_joules * 1e6 / self.energy_images as f64)
    }

    /// Latency percentile in microseconds (nearest-rank).
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_of(&self.latencies_us, p)
    }

    /// Mean batch size actually executed.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Requests per second over the start→stop window.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, mean batch {:.2}, {:.1} req/s",
            self.count(),
            self.percentile_us(50.0) as f64 / 1e3,
            self.percentile_us(95.0) as f64 / 1e3,
            self.percentile_us(99.0) as f64 / 1e3,
            self.mean_batch(),
            self.throughput()
        );
        if self.rejected > 0 {
            s.push_str(&format!(", {} rejected", self.rejected));
        }
        if self.budget_rejected > 0 {
            s.push_str(&format!(", {} over-budget", self.budget_rejected));
        }
        if self.surrogate_miss > 0 {
            s.push_str(&format!(
                ", {} surrogate miss(es) → co-simulation",
                self.surrogate_miss
            ));
        }
        // Recovery counters surface only when non-zero, so fault-free
        // summaries stay byte-identical to the pre-fault format.
        if self.retries > 0 {
            s.push_str(&format!(", {} retries", self.retries));
        }
        if self.timeouts > 0 {
            s.push_str(&format!(", {} batch timeout(s)", self.timeouts));
        }
        if self.breaker_trips > 0 {
            s.push_str(&format!(", {} breaker trip(s)", self.breaker_trips));
        }
        if self.rerouted > 0 {
            s.push_str(&format!(", {} rerouted", self.rerouted));
        }
        if self.degraded_pricing > 0 {
            s.push_str(", degraded-pricing startup");
        }
        if let (Some(sys), Some(opt)) = (
            self.systolic_uj_per_inference(),
            self.optical_uj_per_inference(),
        ) {
            s.push_str(&format!(
                ", energy ({}) @{:.0} nm {}x{}b: {:.2} µJ/inf systolic | {:.2} µJ/inf \
                 optical-4F ({} batches priced)",
                self.energy_source,
                self.energy_node_nm,
                self.energy_bits.0,
                self.energy_bits.1,
                sys,
                opt,
                self.energy_batches
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500, 600, 700, 800, 900, 1000] {
            m.record_request(Duration::from_micros(us));
        }
        assert_eq!(m.percentile_us(50.0), 500);
        assert_eq!(m.percentile_us(95.0), 1000);
        assert_eq!(m.percentile_us(10.0), 100);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.percentile_us(99.0), 0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn mean_batch() {
        let mut m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.mean_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.record_request(Duration::from_micros(10));
        a.record_rejected(1);
        let mut b = Metrics::new();
        b.record_request(Duration::from_micros(20));
        b.record_batch(4);
        b.record_rejected(2);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_batch(), 4.0);
        assert_eq!(a.rejected(), 3);
        assert!(a.summary().contains("3 rejected"));
    }

    #[test]
    fn set_window_drives_throughput() {
        let mut m = Metrics::new();
        let t0 = Instant::now();
        for _ in 0..100 {
            m.record_request(Duration::from_micros(5));
        }
        std::thread::sleep(Duration::from_millis(20));
        m.set_window(t0, Instant::now());
        let t = m.throughput();
        assert!(t > 0.0 && t < 100.0 / 0.02, "throughput {t}");
    }

    #[test]
    fn summary_contains_fields() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_millis(1));
        let s = m.summary();
        assert!(s.contains("p50") && s.contains("req/s"));
        assert!(!s.contains("µJ/inf"), "no energy without record_energy");
    }

    #[test]
    fn energy_accumulates_and_merges() {
        let report = crate::coordinator::energy::co_simulate(
            &crate::coordinator::smallcnn_network(),
            &crate::simulator::OperatingPoint::node(45.0),
        );
        let per_sys = report.systolic_joules() * 1e6;
        let per_opt = report.optical_joules() * 1e6;

        let mut a = Metrics::new();
        a.record_energy(8, &report);
        let mut b = Metrics::new();
        b.record_energy(4, &report);
        b.record_energy(1, &report);
        a.merge(&b);

        assert_eq!(a.energy_images(), 13);
        assert_eq!(a.energy_batches(), 3);
        assert_eq!(a.energy_node_nm(), 45.0);
        assert_eq!(a.energy_bits(), (8, 8));
        assert_eq!(a.energy_source(), "co-simulation");
        // (8 + 4 + 1) × per-inference / 13 == per-inference.
        let sys = a.systolic_uj_per_inference().unwrap();
        let opt = a.optical_uj_per_inference().unwrap();
        assert!((sys - per_sys).abs() < per_sys * 1e-12);
        assert!((opt - per_opt).abs() < per_opt * 1e-12);
        let s = a.summary();
        assert!(s.contains("µJ/inf") && s.contains("@45 nm"), "{s}");
        assert!(s.contains("8x8b"), "{s}");
        assert!(s.contains("(co-simulation)"), "{s}");
    }

    #[test]
    fn empty_energy_is_absent_not_zero() {
        let m = Metrics::new();
        assert_eq!(m.energy_images(), 0);
        assert_eq!(m.systolic_uj_per_inference(), None);
        assert_eq!(m.optical_uj_per_inference(), None);
        assert_eq!(m.energy_source(), "");
        assert!(!m.summary().contains("µJ/inf"));
    }

    #[test]
    fn surrogate_source_and_budget_rejections_surface() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(10));
        m.record_priced_energy(4, 2e-6, 5e-6, 45.0, (8, 4), "surrogate");
        m.record_budget_rejected(3);
        assert_eq!(m.budget_rejected(), 3);
        assert_eq!(m.energy_source(), "surrogate");
        let sys = m.systolic_uj_per_inference().unwrap();
        assert!((sys - 2.0).abs() < 1e-9, "{sys}");
        let s = m.summary();
        assert!(s.contains("(surrogate)"), "{s}");
        assert!(s.contains("3 over-budget"), "{s}");

        // Merge keeps both counters and the label.
        let mut other = Metrics::new();
        other.record_budget_rejected(2);
        m.merge(&other);
        assert_eq!(m.budget_rejected(), 5);
        assert_eq!(m.energy_source(), "surrogate");
    }

    #[test]
    fn recovery_counters_merge_and_surface_only_when_nonzero() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(10));
        let clean = m.summary();
        assert!(
            !clean.contains("retries")
                && !clean.contains("timeout")
                && !clean.contains("breaker")
                && !clean.contains("degraded"),
            "{clean}"
        );
        m.record_retry(2);
        m.record_timeout(1);
        m.record_breaker_trip(1);
        m.record_degraded_pricing(1);
        let mut other = Metrics::new();
        other.record_retry(3);
        other.record_breaker_trip(2);
        m.merge(&other);
        assert_eq!(m.retries(), 5);
        assert_eq!(m.timeouts(), 1);
        assert_eq!(m.breaker_trips(), 3);
        assert_eq!(m.degraded_pricing(), 1);
        let s = m.summary();
        assert!(s.contains("5 retries"), "{s}");
        assert!(s.contains("1 batch timeout(s)"), "{s}");
        assert!(s.contains("3 breaker trip(s)"), "{s}");
        assert!(s.contains("degraded-pricing startup"), "{s}");
    }

    #[test]
    fn backend_shards_accumulate_and_merge() {
        // Two fleet worker shards on different backends, as the server
        // would own them: requests, batches, energy and a breaker trip
        // all land under the shard's label and union at merge time.
        let mut sys = Metrics::new();
        sys.set_backend("systolic@45");
        sys.record_request(Duration::from_micros(100));
        sys.record_request(Duration::from_micros(300));
        sys.record_backend_batch(2);
        sys.record_backend_energy(2, 3e-6, "surrogate");

        let mut opt = Metrics::new();
        opt.set_backend("optical4f@22");
        opt.record_request(Duration::from_micros(900));
        opt.record_backend_batch(1);
        opt.record_breaker_trip(1);

        let mut m = Metrics::new();
        m.record_reroute(2);
        m.merge(&sys);
        m.merge(&opt);

        assert_eq!(m.rerouted(), 2);
        assert_eq!(m.backends().len(), 2);
        let s = &m.backends()["systolic@45"];
        assert_eq!(s.batches(), 1);
        assert_eq!(s.images(), 2);
        let uj = s.uj_per_inf().unwrap();
        assert!((uj - 3.0).abs() < 1e-9, "{uj}");
        assert_eq!(s.source(), "surrogate");
        assert_eq!(s.p50_us(), 100);
        assert_eq!(s.p99_us(), 300);
        let o = &m.backends()["optical4f@22"];
        assert_eq!(o.breaker_trips(), 1);
        assert_eq!(o.uj_per_inf(), None, "unpriced backend is n/a, not 0");
        // The merged aggregate still carries the global counters too.
        assert_eq!(m.count(), 3);
        assert_eq!(m.breaker_trips(), 1);
        let table = m.backend_table().unwrap();
        assert!(table.contains("systolic@45"), "{table}");
        assert!(table.contains("optical4f@22"), "{table}");
        assert!(table.contains("n/a"), "{table}");
        assert!(m.summary().contains("2 rerouted"), "{}", m.summary());
    }

    #[test]
    fn homogeneous_metrics_have_no_backend_table() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(10));
        assert!(m.backend_table().is_none());
        assert!(m.backends().is_empty());
        assert!(!m.summary().contains("rerouted"));
    }

    #[test]
    fn surrogate_miss_counts_and_surfaces() {
        let mut m = Metrics::new();
        m.record_request(Duration::from_micros(10));
        assert_eq!(m.surrogate_miss(), 0);
        assert!(!m.summary().contains("surrogate miss"));
        m.record_surrogate_miss(2);
        assert_eq!(m.surrogate_miss(), 2);
        assert!(m.summary().contains("2 surrogate miss(es)"), "{}", m.summary());
        let mut other = Metrics::new();
        other.record_surrogate_miss(1);
        m.merge(&other);
        assert_eq!(m.surrogate_miss(), 3);
    }
}
