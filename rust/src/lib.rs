//! `aimc` — Analog, In-memory Compute Architectures for AI.
//!
//! Reproduction of Bowen, Regev, Regev, Pedroni, Hanson & Chen,
//! *"Analog, In-memory Compute Architectures for Artificial Intelligence"*
//! (cs.AR, 2023): analytic energy-efficiency models and cycle-accurate
//! simulators for four classes of inference processors — SISD CPUs,
//! digital in-memory (systolic) arrays, planar analog processors
//! (silicon-photonic / ReRAM crossbars), and optical 4F convolution
//! machines — plus a Rust/PJRT serving runtime whose convolution datapaths
//! are the *functional* models of the same machines (AOT-compiled from
//! JAX + Pallas, see `python/compile/`).
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`energy`] — Appendix-A energy parameter models (SRAM, MAC, ADC/DAC,
//!   line loads, laser, ReRAM), precision-aware through
//!   [`energy::EnergyParams::at_op`] (mixed activation × weight bit
//!   widths), plus [`energy::surrogate`]: closed-form
//!   per-(machine × operating point × layer-family) energy models
//!   least-squares fitted from cycle-accurate [`simulator::SweepCache`]
//!   results (`aimc fit-surrogate`), serialized via [`util::json`], so
//!   the serving path can price batches in nanoseconds instead of
//!   re-simulating (cross-validated against the simulators to
//!   [`energy::surrogate::ERR_BOUND`]).
//! * [`technode`] — CMOS technology-node energy scaling (Stillmaker & Baas).
//! * [`networks`] — conv-layer shape zoo for the eight CNNs of Table I,
//!   plus [`networks::transformer`]: decoder-family prefill/decode layer
//!   streams (GEMMs/GEMVs as 1×1 convs, selected by `name@phase`) and
//!   [`networks::stats`] FLOPs/bytes arithmetic-intensity accounting
//!   behind the `aimc intensity` crossover trace.
//! * [`analytic`] — closed-form efficiency models (eqs. 3, 5, 14, 24).
//! * [`simulator`] — cycle-accurate machines for all four processor
//!   classes (systolic, ReRAM, planar photonic, optical 4F), unified
//!   behind the [`simulator::Machine`] trait and priced at a full
//!   [`simulator::OperatingPoint`] (technology node × activation/weight
//!   bit widths × [`simulator::NoiseModel`]; the default reproduces the
//!   paper's 45 nm / 8-bit / noiseless setting exactly), with
//!   layer-dedup memoization ([`simulator::SweepCache`], persistable to
//!   disk keyed by (config fingerprint, operating point, layer)), the
//!   parallel (machine × network × operating point) grid runner
//!   [`simulator::sweep::sweep`], the deterministic seeded-RNG
//!   effective-SNR/accuracy estimator [`simulator::accuracy`] behind
//!   the `aimc pareto` energy × latency × accuracy frontier, and the
//!   seeded fault-injection layer [`simulator::faults`] (stuck cells,
//!   conductance drift, ADC clipping, IR drop) that degrades both the
//!   energy coefficients and the accuracy channel behind `aimc faults`.
//! * [`runtime`] — PJRT loading/execution of the AOT HLO artifacts
//!   (behind the `pjrt` cargo feature; a stub engine otherwise).
//! * [`coordinator`] — the serving path on top of [`runtime`], sharded
//!   end to end: N bounded ingress shards picked per client thread
//!   ([`util::shard`]) behind a sharded `max_pending` admission
//!   counter, a dispatcher draining the shards round-robin into
//!   per-worker [`util::spsc`] batch lanes — least-loaded for a
//!   homogeneous pool, cheapest-by-quote (predicted µJ/inf, or nominal
//!   ns/inf under `ServerConfig::slo_ns`) across a heterogeneous
//!   fleet (`ServerConfig::fleet`, `aimc serve --fleet
//!   systolic@45:2,reram@45:2`, each lane owning its backend's
//!   executor, operating point and startup
//!   [`coordinator::energy::BackendQuote`], metrics sharded per
//!   backend label with a rerouted counter) — per-worker
//!   metrics shards with per-batch energy pricing (fitted surrogate
//!   quote when configured, co-simulation otherwise — misses are
//!   logged per shape family and counted in the metrics) against a
//!   configurable resident network (`aimc serve --network`, e.g. a
//!   transformer decode stream) merged at
//!   shutdown, optional energy-budget admission
//!   (`ServerConfig::max_uj_per_inf`), a condvar drain barrier for the
//!   lifecycle (bounded by a configurable drain deadline), real failure
//!   semantics — bounded retries with jittered backoff, per-batch
//!   execution-deadline accounting, per-lane circuit breakers, and
//!   degraded-pricing startup, all surfaced as metrics counters — and
//!   an executor abstraction ([`coordinator::exec`]) so serving runs
//!   against PJRT or a deterministic in-process backend
//!   (with scripted fault injection via [`coordinator::exec`]'s
//!   `FaultPlan`, `aimc serve --synthetic --chaos …`).
//! * [`report`] — the Scenario → Dataset → sink pipeline: every table,
//!   figure and sweep of the paper's evaluation section is a declarative
//!   [`report::Scenario`] (machines × networks × nodes × derived
//!   columns) evaluated by one engine through a shared [`util::pool`]
//!   `Pool` + [`simulator::SweepCache`] into a typed
//!   [`report::Dataset`], rendered by pluggable text / CSV / JSON
//!   sinks.
//! * [`util`] — in-tree CLI/property-test/bench/PRNG mini-frameworks plus
//!   the [`util::pool`] work-stealing thread pool, the [`util::spsc`]
//!   bounded SPSC channel, the [`util::shard`] sharded counter/queue
//!   behind the serving ingress, and the [`util::json`] dependency-free
//!   JSON tree behind the report layer's `--format json` sink (the
//!   build environment is offline; only `xla` + `anyhow` are
//!   available).

pub mod analytic;
pub mod coordinator;
pub mod energy;
pub mod networks;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod technode;
pub mod util;

/// 1 tera-operation per watt, expressed in ops per joule.
pub const TOPS_PER_WATT: f64 = 1e12;

/// Convert ops-per-joule into the paper's TOPS/W unit.
pub fn tops_per_watt(ops_per_joule: f64) -> f64 {
    ops_per_joule / TOPS_PER_WATT
}

#[cfg(test)]
mod tests {
    #[test]
    fn tops_conversion() {
        // 1 op per pJ == 1 TOPS/W.
        assert!((super::tops_per_watt(1e12) - 1.0).abs() < 1e-12);
    }
}
