//! VGG-16 / VGG-19 (Simonyan & Zisserman 2014): plain 3×3 stacks with
//! 2×2 max-pools. The highest-arithmetic-intensity networks in Table I
//! (median a ≈ 2262 / 2527) because of their large spatial maps.

use super::{Builder, Network};

fn vgg(input: usize, blocks: &[(usize, usize)]) -> Builder {
    // blocks: (convs_in_block, out_channels)
    let mut b = Builder::new(input);
    let mut c_in = 3;
    for &(convs, width) in blocks {
        for _ in 0..convs {
            b.conv(c_in, width, 3, 1);
            c_in = width;
        }
        b.pool(2);
    }
    b
}

/// VGG-16: 13 conv layers (2,2,3,3,3) × (64,128,256,512,512).
pub fn vgg16(input: usize) -> Network {
    vgg(
        input,
        &[(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)],
    )
    .finish("VGG16")
}

/// VGG-19: 16 conv layers (2,2,4,4,4).
pub fn vgg19(input: usize) -> Network {
    vgg(
        input,
        &[(2, 64), (2, 128), (4, 256), (4, 512), (4, 512)],
    )
    .finish("VGG19")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::median;

    #[test]
    fn vgg16_layer_count() {
        assert_eq!(vgg16(1000).num_layers(), 13); // Table I: 13
    }

    #[test]
    fn vgg19_layer_count() {
        assert_eq!(vgg19(1000).num_layers(), 16); // Table I: 16
    }

    #[test]
    fn all_kernels_are_3x3() {
        for l in &vgg19(1000).layers {
            assert_eq!((l.kh, l.kw), (3, 3));
        }
    }

    #[test]
    fn vgg16_median_n_close_to_paper() {
        // Table I: median n = 249 (we track same-padded sizes: 250).
        let net = vgg16(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 249.0).abs() <= 6.0, "median n = {m}");
    }

    #[test]
    fn vgg16_median_channels() {
        // Table I: median Cᵢ = 256, median Cᵢ₊₁ = 256.
        let net = vgg16(1000);
        let ci: Vec<f64> = net.layers.iter().map(|l| l.c_in as f64).collect();
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        assert_eq!(median(&ci), 256.0);
        assert_eq!(median(&co), 256.0);
    }

    #[test]
    fn vgg16_total_weights_1_5e7() {
        // Table I: total K = 1.5e7 (conv layers only).
        let k = vgg16(1000).total_weights();
        assert!((k - 1.47e7).abs() / 1.5e7 < 0.05, "K = {k:.3e}");
    }

    #[test]
    fn vgg16_max_input_size() {
        // Table I: max N = 6.4e7 = 1000²·64.
        let net = vgg16(1000);
        let max_n = net
            .layers
            .iter()
            .map(|l| l.input_size())
            .fold(0.0, f64::max);
        assert!((max_n - 6.4e7).abs() / 6.4e7 < 0.02, "max N = {max_n:.3e}");
    }

    #[test]
    fn vgg16_median_intensity_matches_table1() {
        // Table I: median a = 2262. Band: ±15% (spatial bookkeeping
        // differs by a couple pixels from the paper's).
        let net = vgg16(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 2262.0).abs() / 2262.0 < 0.15, "median a = {m}");
    }

    #[test]
    fn vgg19_median_intensity_matches_table1() {
        // Table I: median a = 2527.
        let net = vgg19(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 2527.0).abs() / 2527.0 < 0.15, "median a = {m}");
    }

    #[test]
    fn vgg16_table2_l_prime() {
        // Table II: median L' = 62001 (=249²); ours (250-3+1)² = 61504.
        let net = vgg16(1000);
        let lp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().0).collect();
        let m = median(&lp);
        assert!((m - 62001.0).abs() / 62001.0 < 0.05, "median L' = {m}");
    }

    #[test]
    fn vgg16_table2_n_m_prime() {
        // Table II: median N' = 2304 (=9·256), median M' = 256.
        let net = vgg16(1000);
        let np: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().1).collect();
        let mp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().2).collect();
        assert_eq!(median(&np), 2304.0);
        assert_eq!(median(&mp), 256.0);
    }
}
