//! Convolutional-layer shape zoo for the eight CNNs of the paper's
//! Tables I–III (DenseNet201, GoogLeNet, InceptionResNetV2, InceptionV3,
//! ResNet152, VGG16, VGG19, YOLOv3).
//!
//! The paper consumes only layer *shape statistics* — spatial size n,
//! channel counts Cᵢ/Cᵢ₊₁, kernel size k, and the derived arithmetic
//! intensity / matrix dimensions — "considering a 1-Mpixel (per channel)
//! input image". Each architecture here is generated programmatically
//! from its published structure at a configurable input resolution
//! (default 1000×1000 = 1 Mpx), tracking spatial size through
//! stride-2 stages exactly as the paper does.
//!
//! The [`transformer`] module grows the zoo beyond CNNs: decoder-family
//! prefill/decode layer streams (GEMMs/GEMVs as 1×1 convs) expressed in
//! the same [`ConvLayer`] vocabulary, selected by `name@phase` (e.g.
//! `gpt2-small@decode`) via [`transformer::resolve`].

pub mod densenet;
pub mod googlenet;
pub mod inception;
pub mod resnet;
pub mod stats;
pub mod transformer;
pub mod vgg;
pub mod yolov3;

/// One convolutional layer's shape. Non-square kernels (Inception's 1×7
/// factorizations) carry distinct `kh`/`kw`. `Hash`/`Eq` make the shape
/// directly usable as a [`crate::simulator::SweepCache`] memo key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvLayer {
    /// Input spatial size (square feature map, n × n).
    pub n: usize,
    /// Input channels Cᵢ.
    pub c_in: usize,
    /// Output channels Cᵢ₊₁.
    pub c_out: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride (same in both dims).
    pub stride: usize,
}

impl ConvLayer {
    pub fn square(n: usize, c_in: usize, c_out: usize, k: usize, stride: usize) -> Self {
        ConvLayer {
            n,
            c_in,
            c_out,
            kh: k,
            kw: k,
            stride,
        }
    }

    /// Output spatial size (same-padding bookkeeping, matching how the
    /// architectures are actually built).
    pub fn n_out(&self) -> usize {
        self.n.div_ceil(self.stride)
    }

    /// Effective k² (= kh·kw for rectangular kernels).
    pub fn k2(&self) -> f64 {
        (self.kh * self.kw) as f64
    }

    /// Effective (geometric-mean) kernel edge, for Table I's "avg. k".
    pub fn k_eff(&self) -> f64 {
        self.k2().sqrt()
    }

    /// Number of kernel weights K = k²·Cᵢ·Cᵢ₊₁.
    pub fn weights(&self) -> f64 {
        self.k2() * (self.c_in * self.c_out) as f64
    }

    /// MAC count: n_out²·k²·Cᵢ·Cᵢ₊₁.
    pub fn macs(&self) -> f64 {
        let no = self.n_out() as f64;
        no * no * self.k2() * (self.c_in * self.c_out) as f64
    }

    /// Operation count (paper convention: multiply and add are separate
    /// ops, N_op = 2·MACs).
    pub fn ops(&self) -> f64 {
        2.0 * self.macs()
    }

    /// Input activation size n²·Cᵢ (Table I's N).
    pub fn input_size(&self) -> f64 {
        (self.n * self.n * self.c_in) as f64
    }

    /// eq. (9): native arithmetic intensity of the layer,
    /// a = 2n²k²CᵢCᵢ₊₁ / (n²(Cᵢ+Cᵢ₊₁) + k²CᵢCᵢ₊₁),
    /// generalized to strided layers by using the output size for the
    /// output-traffic term.
    pub fn arithmetic_intensity(&self) -> f64 {
        let n2 = (self.n * self.n) as f64;
        let no2 = {
            let no = self.n_out() as f64;
            no * no
        };
        let mem = n2 * self.c_in as f64 + no2 * self.c_out as f64 + self.weights();
        self.ops() / mem
    }

    /// eq. (16): conv-as-matmul dimensions (L', N', M') for a
    /// weight-stationary scheme.
    pub fn matmul_dims(&self) -> (f64, f64, f64) {
        let l = {
            // (n-k+1)² for stride 1; ((n-k)/s+1)² generally.
            let span = self.n.saturating_sub(self.kh.max(self.kw)) / self.stride + 1;
            (span * span) as f64
        };
        let n = self.k2() * self.c_in as f64;
        let m = self.c_out as f64;
        (l, n, m)
    }

    /// eq. (8): arithmetic intensity when implemented as a general
    /// matrix multiplication (Toeplitz input, k²-duplicated activations).
    pub fn matmul_arithmetic_intensity(&self) -> f64 {
        let (l, n, m) = self.matmul_dims();
        2.0 * l * n * m / (l * n + n * m + l * m)
    }
}

/// A named network: an ordered list of conv layers.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub layers: Vec<ConvLayer>,
}

impl Network {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_macs(&self) -> f64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_ops(&self) -> f64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    pub fn total_weights(&self) -> f64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

/// Default input resolution: 1 Mpixel per channel, as in Tables I–III.
pub const DEFAULT_INPUT: usize = 1000;

/// All eight networks of Table I at the given input resolution.
pub fn zoo(input: usize) -> Vec<Network> {
    vec![
        densenet::densenet201(input),
        googlenet::googlenet(input),
        inception::inception_resnet_v2(input),
        inception::inception_v3(input),
        resnet::resnet152(input),
        vgg::vgg16(input),
        vgg::vgg19(input),
        yolov3::yolov3(input),
    ]
}

/// Look up one network by (case-insensitive) name.
pub fn by_name(name: &str, input: usize) -> Option<Network> {
    let lower = name.to_ascii_lowercase();
    zoo(input)
        .into_iter()
        .find(|n| n.name.to_ascii_lowercase() == lower)
}

/// Internal helper for the builders: tracks spatial size while pushing
/// layers, mirroring how the reference implementations are written.
pub(crate) struct Builder {
    pub n: usize,
    pub layers: Vec<ConvLayer>,
}

impl Builder {
    pub fn new(input: usize) -> Self {
        Builder {
            n: input,
            layers: Vec::new(),
        }
    }

    /// Push a conv at the current spatial size; advance size by stride.
    pub fn conv(&mut self, c_in: usize, c_out: usize, k: usize, stride: usize) {
        self.layers.push(ConvLayer::square(self.n, c_in, c_out, k, stride));
        self.n = self.n.div_ceil(stride);
    }

    /// Push a conv that does NOT advance the tracked spatial size
    /// (parallel branch of an inception module).
    pub fn branch_conv(
        &mut self,
        n: usize,
        c_in: usize,
        c_out: usize,
        kh: usize,
        kw: usize,
        stride: usize,
    ) {
        self.layers.push(ConvLayer {
            n,
            c_in,
            c_out,
            kh,
            kw,
            stride,
        });
    }

    /// Pooling: just advance the spatial tracker.
    pub fn pool(&mut self, stride: usize) {
        self.n = self.n.div_ceil(stride);
    }

    pub fn finish(self, name: &'static str) -> Network {
        Network {
            name,
            layers: self.layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_layer_basics() {
        let l = ConvLayer::square(100, 16, 32, 3, 1);
        assert_eq!(l.n_out(), 100);
        assert_eq!(l.k2(), 9.0);
        assert_eq!(l.weights(), 9.0 * 16.0 * 32.0);
        assert_eq!(l.macs(), 100.0 * 100.0 * 9.0 * 512.0);
        assert_eq!(l.ops(), 2.0 * l.macs());
    }

    #[test]
    fn stride_halves_output() {
        let l = ConvLayer::square(101, 8, 8, 3, 2);
        assert_eq!(l.n_out(), 51);
    }

    #[test]
    fn rectangular_kernel() {
        let l = ConvLayer {
            n: 50,
            c_in: 4,
            c_out: 4,
            kh: 1,
            kw: 7,
            stride: 1,
        };
        assert_eq!(l.k2(), 7.0);
        assert!((l.k_eff() - 7f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn eq9_matches_hand_computation() {
        // Table V layer: n=512, Ci=Co=128, k=3. eq. (9) *native* intensity:
        // 2·512²·9·128² / (512²·256 + 9·128²) ≈ 1149.
        let l = ConvLayer::square(512, 128, 128, 3, 1);
        let a = l.arithmetic_intensity();
        assert!((a - 1149.0).abs() < 5.0, "a = {a}");
    }

    #[test]
    fn table_v_a_230_is_the_matmul_intensity() {
        // Table V quotes a = 230 for the same layer, citing eq. (9) — but
        // 230 is exactly eq. (8), the conv-as-matmul intensity with the
        // k²-duplicated Toeplitz input. (Paper typo; we reproduce 230 via
        // eq. 8 and use it wherever the paper uses Table V's a.)
        let l = ConvLayer::square(512, 128, 128, 3, 1);
        let a = l.matmul_arithmetic_intensity();
        assert!((a - 230.0).abs() < 2.0, "a_mm = {a}");
    }

    #[test]
    fn eq8_lower_than_eq9() {
        // Matmul implementation duplicates activations k² times, so its
        // arithmetic intensity must be lower for n² >> k²Cᵢ.
        let l = ConvLayer::square(512, 16, 16, 3, 1);
        assert!(l.matmul_arithmetic_intensity() < l.arithmetic_intensity());
    }

    #[test]
    fn matmul_dims_eq16() {
        let l = ConvLayer::square(64, 8, 16, 3, 1);
        let (lp, np, mp) = l.matmul_dims();
        assert_eq!(lp, 62.0 * 62.0);
        assert_eq!(np, 9.0 * 8.0);
        assert_eq!(mp, 16.0);
    }

    #[test]
    fn zoo_has_eight_networks() {
        let z = zoo(DEFAULT_INPUT);
        assert_eq!(z.len(), 8);
        let names: Vec<_> = z.iter().map(|n| n.name).collect();
        assert!(names.contains(&"VGG16") && names.contains(&"YOLOv3"));
    }

    #[test]
    fn by_name_case_insensitive() {
        assert!(by_name("vgg16", 1000).is_some());
        assert!(by_name("YOLOV3", 1000).is_some());
        assert!(by_name("nope", 1000).is_none());
    }

    #[test]
    fn builder_tracks_spatial() {
        let mut b = Builder::new(100);
        b.conv(3, 8, 3, 1);
        assert_eq!(b.n, 100);
        b.conv(8, 16, 3, 2);
        assert_eq!(b.n, 50);
        b.pool(2);
        assert_eq!(b.n, 25);
        let net = b.finish("t");
        assert_eq!(net.num_layers(), 2);
    }
}
