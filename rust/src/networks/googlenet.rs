//! GoogLeNet / Inception-v1 (Szegedy et al. 2014): 9 inception modules of
//! 6 convs each + 3 stem convs + 2 auxiliary-classifier 1×1s = 59 conv
//! layers (Table I).

use super::{Builder, Network};

/// One inception module: (#1×1, #3×3 reduce, #3×3, #5×5 reduce, #5×5,
/// pool-proj). Returns the concatenated output width.
fn inception(
    b: &mut Builder,
    c_in: usize,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pp: usize,
) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, c1, 1, 1, 1);
    b.branch_conv(n, c_in, c3r, 1, 1, 1);
    b.branch_conv(n, c3r, c3, 3, 3, 1);
    b.branch_conv(n, c_in, c5r, 1, 1, 1);
    b.branch_conv(n, c5r, c5, 5, 5, 1);
    b.branch_conv(n, c_in, pp, 1, 1, 1);
    c1 + c3 + c5 + pp
}

/// GoogLeNet at the given input resolution.
pub fn googlenet(input: usize) -> Network {
    let mut b = Builder::new(input);
    // Stem.
    b.conv(3, 64, 7, 2);
    b.pool(2);
    b.conv(64, 64, 1, 1);
    b.conv(64, 192, 3, 1);
    b.pool(2);
    // Inception 3a/3b.
    let c = inception(&mut b, 192, 64, 96, 128, 16, 32, 32); // 256
    let c = inception(&mut b, c, 128, 128, 192, 32, 96, 64); // 480
    b.pool(2);
    // Inception 4a–4e (+ two auxiliary heads off 4a and 4d).
    let c = inception(&mut b, c, 192, 96, 208, 16, 48, 64); // 512
    // aux1: 5×5/3 avg-pool then 1×1 conv 512→128.
    b.branch_conv((b.n + 2) / 3, 512, 128, 1, 1, 1);
    let c = inception(&mut b, c, 160, 112, 224, 24, 64, 64); // 512
    let c = inception(&mut b, c, 128, 128, 256, 24, 64, 64); // 512
    let c = inception(&mut b, c, 112, 144, 288, 32, 64, 64); // 528
    // aux2 off 4d.
    b.branch_conv((b.n + 2) / 3, 528, 128, 1, 1, 1);
    let c = inception(&mut b, c, 256, 160, 320, 32, 128, 128); // 832
    b.pool(2);
    // Inception 5a/5b.
    let c = inception(&mut b, c, 256, 160, 320, 32, 128, 128); // 832
    let _ = inception(&mut b, c, 384, 192, 384, 48, 128, 128); // 1024
    b.finish("GoogLeNet")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, median};

    #[test]
    fn layer_count() {
        assert_eq!(googlenet(1000).num_layers(), 59); // Table I: 59
    }

    #[test]
    fn median_n_about_61() {
        // Table I: median n = 61 (most modules sit at 1000/16 ≈ 62).
        let net = googlenet(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 61.0).abs() <= 3.0, "median n = {m}");
    }

    #[test]
    fn median_ci_480() {
        // Table I: median Cᵢ = 480.
        let net = googlenet(1000);
        let ci: Vec<f64> = net.layers.iter().map(|l| l.c_in as f64).collect();
        let m = median(&ci);
        assert!((m - 480.0).abs() <= 96.0, "median Cᵢ = {m}");
    }

    #[test]
    fn median_co_128() {
        // Table I: median Cᵢ₊₁ = 128.
        let net = googlenet(1000);
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        assert_eq!(median(&co), 128.0);
    }

    #[test]
    fn avg_k_about_2_1() {
        // Table I: avg k = 2.1.
        let net = googlenet(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        let m = mean(&ks);
        assert!((m - 2.1).abs() < 0.2, "avg k = {m}");
    }

    #[test]
    fn total_weights_6_1e6() {
        // Table I: total K = 6.1e6.
        let k = googlenet(1000).total_weights();
        assert!((k - 6.1e6).abs() / 6.1e6 < 0.15, "K = {k:.3e}");
    }

    #[test]
    fn median_intensity_matches_table1() {
        // Table I: median a = 200.
        let net = googlenet(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 200.0).abs() / 200.0 < 0.25, "median a = {m}");
    }

    #[test]
    fn table2_dims() {
        // Table II: median L' = 3721 (61²), N' = 528, M' = 128.
        let net = googlenet(1000);
        let lp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().0).collect();
        let np: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().1).collect();
        let mp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().2).collect();
        assert!((median(&lp) - 3721.0).abs() / 3721.0 < 0.1);
        assert!((median(&np) - 528.0).abs() / 528.0 < 0.3, "N' {}", median(&np));
        assert_eq!(median(&mp), 128.0);
    }
}
