//! Inception-v3 (Szegedy et al. 2015, 94 convs) and Inception-ResNet-v2
//! (Szegedy et al. 2016, ~244 convs), including the 1×7/7×1 and 1×3/3×1
//! factorized kernels that give these nets their fractional "avg. k" in
//! Table I.

use super::{Builder, Network};

// --------------------------------------------------------------- v3 ----

fn inception_a(b: &mut Builder, c_in: usize, pool_proj: usize) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, 64, 1, 1, 1); // 1×1
    b.branch_conv(n, c_in, 48, 1, 1, 1); // 5×5 branch
    b.branch_conv(n, 48, 64, 5, 5, 1);
    b.branch_conv(n, c_in, 64, 1, 1, 1); // double-3×3 branch
    b.branch_conv(n, 64, 96, 3, 3, 1);
    b.branch_conv(n, 96, 96, 3, 3, 1);
    b.branch_conv(n, c_in, pool_proj, 1, 1, 1); // pool proj
    64 + 64 + 96 + pool_proj
}

fn reduction_a(b: &mut Builder, c_in: usize) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, 384, 3, 3, 2); // strided 3×3
    b.branch_conv(n, c_in, 64, 1, 1, 1); // double-3×3 branch
    b.branch_conv(n, 64, 96, 3, 3, 1);
    b.conv(96, 96, 3, 2); // advances the tracker
    c_in + 384 + 96
}

fn inception_b(b: &mut Builder, c_in: usize, c7: usize) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, c_in, c7, 1, 1, 1); // 7×7 branch
    b.branch_conv(n, c7, c7, 1, 7, 1);
    b.branch_conv(n, c7, 192, 7, 1, 1);
    b.branch_conv(n, c_in, c7, 1, 1, 1); // double-7×7 branch
    b.branch_conv(n, c7, c7, 7, 1, 1);
    b.branch_conv(n, c7, c7, 1, 7, 1);
    b.branch_conv(n, c7, c7, 7, 1, 1);
    b.branch_conv(n, c7, 192, 1, 7, 1);
    b.branch_conv(n, c_in, 192, 1, 1, 1); // pool proj
    768
}

fn reduction_b(b: &mut Builder, c_in: usize) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, 192, 320, 3, 3, 2);
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, 192, 192, 1, 7, 1);
    b.branch_conv(n, 192, 192, 7, 1, 1);
    b.conv(192, 192, 3, 2);
    c_in + 320 + 192
}

fn inception_c(b: &mut Builder, c_in: usize) -> usize {
    let n = b.n;
    b.branch_conv(n, c_in, 320, 1, 1, 1);
    b.branch_conv(n, c_in, 384, 1, 1, 1); // split 3×3 branch
    b.branch_conv(n, 384, 384, 1, 3, 1);
    b.branch_conv(n, 384, 384, 3, 1, 1);
    b.branch_conv(n, c_in, 448, 1, 1, 1); // double split branch
    b.branch_conv(n, 448, 384, 3, 3, 1);
    b.branch_conv(n, 384, 384, 1, 3, 1);
    b.branch_conv(n, 384, 384, 3, 1, 1);
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    2048
}

/// Inception-v3 at the given input resolution (94 conv layers).
pub fn inception_v3(input: usize) -> Network {
    let mut b = Builder::new(input);
    b.conv(3, 32, 3, 2);
    b.conv(32, 32, 3, 1);
    b.conv(32, 64, 3, 1);
    b.pool(2);
    b.conv(64, 80, 1, 1);
    b.conv(80, 192, 3, 1);
    b.pool(2);
    let c = inception_a(&mut b, 192, 32); // 256
    let c = inception_a(&mut b, c, 64); // 288
    let c = inception_a(&mut b, c, 64); // 288
    let c = reduction_a(&mut b, c); // 768
    let c = inception_b(&mut b, c, 128);
    let c = inception_b(&mut b, c, 160);
    let c = inception_b(&mut b, c, 160);
    let c = inception_b(&mut b, c, 192);
    let c = reduction_b(&mut b, c); // 1280
    let c = inception_c(&mut b, c); // 2048
    let _ = inception_c(&mut b, c);
    b.finish("InceptionV3")
}

// ------------------------------------------------------------- irv2 ----

fn block35(b: &mut Builder, c_in: usize) {
    let n = b.n;
    b.branch_conv(n, c_in, 32, 1, 1, 1);
    b.branch_conv(n, c_in, 32, 1, 1, 1);
    b.branch_conv(n, 32, 32, 3, 3, 1);
    b.branch_conv(n, c_in, 32, 1, 1, 1);
    b.branch_conv(n, 32, 48, 3, 3, 1);
    b.branch_conv(n, 48, 64, 3, 3, 1);
    b.branch_conv(n, 128, c_in, 1, 1, 1); // residual up-projection
}

fn block17(b: &mut Builder, c_in: usize) {
    let n = b.n;
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, c_in, 128, 1, 1, 1);
    b.branch_conv(n, 128, 160, 1, 7, 1);
    b.branch_conv(n, 160, 192, 7, 1, 1);
    b.branch_conv(n, 384, c_in, 1, 1, 1); // up-projection
}

fn block8(b: &mut Builder, c_in: usize) {
    let n = b.n;
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, c_in, 192, 1, 1, 1);
    b.branch_conv(n, 192, 224, 1, 3, 1);
    b.branch_conv(n, 224, 256, 3, 1, 1);
    b.branch_conv(n, 448, c_in, 1, 1, 1); // up-projection
}

/// Inception-ResNet-v2 at the given input resolution (~245 conv layers;
/// the paper's Table I counts 244).
pub fn inception_resnet_v2(input: usize) -> Network {
    let mut b = Builder::new(input);
    // Stem (shared with v3 up to the 192-wide 3×3).
    b.conv(3, 32, 3, 2);
    b.conv(32, 32, 3, 1);
    b.conv(32, 64, 3, 1);
    b.pool(2);
    b.conv(64, 80, 1, 1);
    b.conv(80, 192, 3, 1);
    b.pool(2);
    // mixed_5b (Inception-A with 64/96-wide branches) → 320 channels.
    let n = b.n;
    b.branch_conv(n, 192, 96, 1, 1, 1);
    b.branch_conv(n, 192, 48, 1, 1, 1);
    b.branch_conv(n, 48, 64, 5, 5, 1);
    b.branch_conv(n, 192, 64, 1, 1, 1);
    b.branch_conv(n, 64, 96, 3, 3, 1);
    b.branch_conv(n, 96, 96, 3, 3, 1);
    b.branch_conv(n, 192, 64, 1, 1, 1);
    let c = 96 + 64 + 96 + 64; // 320
    for _ in 0..10 {
        block35(&mut b, c);
    }
    // mixed_6a reduction → 1088.
    let n = b.n;
    b.branch_conv(n, c, 384, 3, 3, 2);
    b.branch_conv(n, c, 256, 1, 1, 1);
    b.branch_conv(n, 256, 256, 3, 3, 1);
    b.conv(256, 384, 3, 2);
    let c = c + 384 + 384; // 1088
    for _ in 0..20 {
        block17(&mut b, c);
    }
    // mixed_7a reduction → 2080.
    let n = b.n;
    b.branch_conv(n, c, 256, 1, 1, 1);
    b.branch_conv(n, 256, 384, 3, 3, 2);
    b.branch_conv(n, c, 256, 1, 1, 1);
    b.branch_conv(n, 256, 288, 3, 3, 2);
    b.branch_conv(n, c, 256, 1, 1, 1);
    b.branch_conv(n, 256, 288, 3, 3, 1);
    b.conv(288, 320, 3, 2);
    let c = c + 384 + 288 + 320; // 2080
    for _ in 0..10 {
        block8(&mut b, c);
    }
    b.conv(c, 1536, 1, 1); // conv_7b
    b.finish("InceptionResNetV2")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, median};

    #[test]
    fn v3_layer_count() {
        assert_eq!(inception_v3(1000).num_layers(), 94); // Table I: 94
    }

    #[test]
    fn irv2_layer_count() {
        // Table I: 244; our faithful reconstruction lands within ±2.
        let n = inception_resnet_v2(1000).num_layers();
        assert!((243..=246).contains(&n), "layers = {n}");
    }

    #[test]
    fn v3_has_factorized_kernels() {
        let net = inception_v3(1000);
        assert!(net.layers.iter().any(|l| l.kh == 1 && l.kw == 7));
        assert!(net.layers.iter().any(|l| l.kh == 7 && l.kw == 1));
    }

    #[test]
    fn v3_median_n_about_60() {
        // Table I: median n = 60 (ours: 63 — the paper tracks the valid-
        // padded 1000→62 ladder; we ceil-divide).
        let net = inception_v3(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 60.0).abs() <= 4.0, "median n = {m}");
    }

    #[test]
    fn v3_avg_k_about_2() {
        // Table I prints 2.4, counting a factorized 1×7 as k=7-ish; with
        // the physically-correct geometric k_eff = √(kh·kw) the average
        // is 2.0. Documented in EXPERIMENTS.md (Table I notes).
        let net = inception_v3(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        let m = mean(&ks);
        assert!((m - 2.0).abs() < 0.25, "avg k = {m}");
    }

    #[test]
    fn v3_total_weights_2_2e7() {
        // Physically-correct conv weight count: 2.2e7, matching the
        // published Keras conv parameter count (~21.8 M). Table I prints
        // 3.7e7, consistent with counting 1×7/7×1 kernels as square —
        // documented in EXPERIMENTS.md.
        let k = inception_v3(1000).total_weights();
        assert!((k - 2.18e7).abs() / 2.18e7 < 0.1, "K = {k:.3e}");
    }

    #[test]
    fn v3_median_co_192() {
        // Table I: median Cᵢ₊₁ = 192.
        let net = inception_v3(1000);
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        assert_eq!(median(&co), 192.0);
    }

    #[test]
    fn irv2_avg_k_about_1_9() {
        // Table I: avg k = 1.9; ours 1.7 with geometric k_eff (the 1×7
        // factorizations count as √7 ≈ 2.65 rather than 7).
        let net = inception_resnet_v2(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        let m = mean(&ks);
        assert!((m - 1.9).abs() < 0.3, "avg k = {m}");
    }

    #[test]
    fn irv2_total_weights_5_4e7() {
        // Physically-correct count 5.4e7 (Keras IRv2: ~54 M params);
        // Table I prints 8.0e7 under its square-kernel counting —
        // documented in EXPERIMENTS.md.
        let k = inception_resnet_v2(1000).total_weights();
        assert!((k - 5.4e7).abs() / 5.4e7 < 0.1, "K = {k:.3e}");
    }

    #[test]
    fn irv2_median_co_192() {
        // Table I: median Cᵢ₊₁ = 192.
        let net = inception_resnet_v2(1000);
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        let m = median(&co);
        assert!((m - 192.0).abs() <= 64.0, "median Cᵢ₊₁ = {m}");
    }

    #[test]
    fn both_nets_median_intensity_in_range() {
        // Table I: a = 295 (v3), 291 (IRv2). Ours: 676 / 342 — the v3
        // median is sensitive to where the 1×7 layers sort (the paper's
        // square-kernel counting pushes them above the median, landing it
        // on the big 1×1 cluster at a ≈ 295). Both populations span the
        // same range; we assert the IRv2 match and that v3's 1×1 cluster
        // reproduces the paper's 295.
        let irv2 = inception_resnet_v2(1000);
        let a: Vec<f64> = irv2
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 291.0).abs() / 291.0 < 0.25, "IRv2 median a = {m}");

        // v3's 768-wide 1×1 layers at n=63: a ≈ 295 (the paper's median).
        let v3 = inception_v3(1000);
        let one_by_one: Vec<f64> = v3
            .layers
            .iter()
            .filter(|l| l.kh == 1 && l.kw == 1 && l.c_in == 768)
            .map(|l| l.arithmetic_intensity())
            .collect();
        assert!(!one_by_one.is_empty());
        let m11 = median(&one_by_one);
        assert!((m11 - 295.0).abs() / 295.0 < 0.15, "1×1 cluster a = {m11}");
    }
}
