//! YOLOv3 (Redmon & Farhadi 2018): Darknet-53 backbone (52 convs) + the
//! three-scale detection head (23 convs) = 75 conv layers (Table I).
//! This is the workload of the paper's cycle-accurate Figures 8–10.

use super::{Builder, Network};

/// YOLOv3 at the given input resolution.
pub fn yolov3(input: usize) -> Network {
    let mut b = Builder::new(input);
    // ---- Darknet-53 backbone ----
    b.conv(3, 32, 3, 1);
    let mut stage = |b: &mut Builder, c_in: usize, c_out: usize, blocks: usize| {
        b.conv(c_in, c_out, 3, 2); // downsample
        for _ in 0..blocks {
            b.branch_conv(b.n, c_out, c_out / 2, 1, 1, 1);
            b.branch_conv(b.n, c_out / 2, c_out, 3, 3, 1);
        }
    };
    stage(&mut b, 32, 64, 1); // 500
    stage(&mut b, 64, 128, 2); // 250
    stage(&mut b, 128, 256, 8); // 125  (route to scale-3 head)
    let n_route2 = b.n;
    stage(&mut b, 256, 512, 8); // 63   (route to scale-2 head)
    let n_route1 = b.n;
    stage(&mut b, 512, 1024, 4); // 32

    // ---- Detection heads ----
    // Scale 1 (deepest): 5-conv block + 3×3 + 1×1 detection.
    let n = b.n;
    let head = |b: &mut Builder, n: usize, c_in: usize, c: usize| {
        b.branch_conv(n, c_in, c, 1, 1, 1);
        b.branch_conv(n, c, 2 * c, 3, 3, 1);
        b.branch_conv(n, 2 * c, c, 1, 1, 1);
        b.branch_conv(n, c, 2 * c, 3, 3, 1);
        b.branch_conv(n, 2 * c, c, 1, 1, 1);
        b.branch_conv(n, c, 2 * c, 3, 3, 1);
        b.branch_conv(n, 2 * c, 255, 1, 1, 1); // 3·(80+5) anchors
    };
    head(&mut b, n, 1024, 512);
    // Upsample branch to scale 2: 1×1 512→256, concat with 512-wide route.
    b.branch_conv(n, 512, 256, 1, 1, 1);
    head(&mut b, n_route1, 256 + 512, 256);
    // Upsample branch to scale 3.
    b.branch_conv(n_route1, 256, 128, 1, 1, 1);
    head(&mut b, n_route2, 128 + 256, 128);
    b.finish("YOLOv3")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, median};

    #[test]
    fn layer_count() {
        assert_eq!(yolov3(1000).num_layers(), 75); // Table I: 75
    }

    #[test]
    fn spatial_ladder() {
        let net = yolov3(1000);
        assert_eq!(net.layers[0].n, 1000);
        // Backbone bottoms out at 1000/32 ≈ 32.
        let min_n = net.layers.iter().map(|l| l.n).min().unwrap();
        assert!((31..=32).contains(&min_n), "min n = {min_n}");
    }

    #[test]
    fn median_n_about_62() {
        // Table I: median n = 62.
        let net = yolov3(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 62.0).abs() <= 4.0, "median n = {m}");
    }

    #[test]
    fn median_channels_256() {
        // Table I: median Cᵢ = 256, median Cᵢ₊₁ = 256.
        let net = yolov3(1000);
        let ci: Vec<f64> = net.layers.iter().map(|l| l.c_in as f64).collect();
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        assert_eq!(median(&ci), 256.0);
        assert_eq!(median(&co), 256.0);
    }

    #[test]
    fn avg_k_about_2() {
        // Table I: avg k = 2.0 (alternating 1×1 / 3×3).
        let net = yolov3(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        let m = mean(&ks);
        assert!((m - 2.0).abs() < 0.2, "avg k = {m}");
    }

    #[test]
    fn total_weights_6_2e7() {
        // Table I: total K = 6.2e7.
        let k = yolov3(1000).total_weights();
        assert!((k - 6.2e7).abs() / 6.2e7 < 0.1, "K = {k:.3e}");
    }

    #[test]
    fn max_input_size_3_2e7() {
        // Table I: max N = 3.2e7 (= 500²·128 at the stage-2 entry).
        let net = yolov3(1000);
        let max_n = net
            .layers
            .iter()
            .map(|l| l.input_size())
            .fold(0.0, f64::max);
        assert!((max_n - 3.2e7).abs() / 3.2e7 < 0.05, "max N = {max_n:.3e}");
    }

    #[test]
    fn median_intensity_matches_table1() {
        // Table I: median a = 504.
        let net = yolov3(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 504.0).abs() / 504.0 < 0.2, "median a = {m}");
    }

    #[test]
    fn table2_dims() {
        // Table II: median L' = 3844, N' = 1024, M' = 256.
        let net = yolov3(1000);
        let lp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().0).collect();
        let np: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().1).collect();
        let mp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().2).collect();
        assert!((median(&lp) - 3844.0).abs() / 3844.0 < 0.1, "L' {}", median(&lp));
        assert!((median(&np) - 1024.0).abs() / 1024.0 < 0.3, "N' {}", median(&np));
        assert_eq!(median(&mp), 256.0);
    }

    #[test]
    fn total_macs_reasonable() {
        // ~190 GMAC at 1 Mpx (65.9 GFLOP ≈ 33 GMAC at 416², scaled ×5.8).
        let macs = yolov3(1000).total_macs();
        assert!(macs > 1.0e11 && macs < 4.0e11, "MACs = {macs:.3e}");
    }
}
