//! Network-level statistics: the rows of Tables I, II and III, plus the
//! FLOPs/byte arithmetic-intensity accounting behind `aimc intensity`.

use super::{ConvLayer, Network};
use crate::util::stats::{mean, median};

/// FLOPs of one layer forward pass (paper convention: 2·MACs).
pub fn layer_flops(l: &ConvLayer) -> f64 {
    l.ops()
}

/// Off-chip traffic of one layer forward pass in bytes at
/// `bytes_per_elem` bytes per tensor element: input activations, output
/// activations and weights each moved once — exactly eq. (9)'s memory
/// term, so `flops_per_byte(l, 1.0) == l.arithmetic_intensity()`.
pub fn layer_bytes(l: &ConvLayer, bytes_per_elem: f64) -> f64 {
    let no = l.n_out() as f64;
    let input = l.input_size();
    let output = no * no * l.c_out as f64;
    (input + output + l.weights()) * bytes_per_elem
}

/// Arithmetic intensity of one layer in FLOPs per byte.
pub fn flops_per_byte(l: &ConvLayer, bytes_per_elem: f64) -> f64 {
    layer_flops(l) / layer_bytes(l, bytes_per_elem)
}

/// Total FLOPs of one network forward pass.
pub fn network_flops(net: &Network) -> f64 {
    net.layers.iter().map(layer_flops).sum()
}

/// Total bytes moved by one network forward pass.
pub fn network_bytes(net: &Network, bytes_per_elem: f64) -> f64 {
    net.layers.iter().map(|l| layer_bytes(l, bytes_per_elem)).sum()
}

/// Whole-network arithmetic intensity: total FLOPs over total bytes.
/// This is the x-axis of the `aimc intensity` crossover trace.
pub fn network_intensity(net: &Network, bytes_per_elem: f64) -> f64 {
    network_flops(net) / network_bytes(net, bytes_per_elem)
}

/// Table I row: conv-layer shape statistics of one network.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub name: &'static str,
    pub num_layers: usize,
    pub median_n: f64,
    pub median_ci: f64,
    pub max_input: f64,
    pub avg_k: f64,
    pub total_weights: f64,
    pub median_co: f64,
    pub median_a: f64,
}

pub fn table1_row(net: &Network) -> Table1Row {
    let ls = &net.layers;
    Table1Row {
        name: net.name,
        num_layers: ls.len(),
        median_n: median(&ls.iter().map(|l| l.n as f64).collect::<Vec<_>>()),
        median_ci: median(&ls.iter().map(|l| l.c_in as f64).collect::<Vec<_>>()),
        max_input: ls.iter().map(|l| l.input_size()).fold(0.0, f64::max),
        avg_k: mean(&ls.iter().map(|l| l.k_eff()).collect::<Vec<_>>()),
        total_weights: net.total_weights(),
        median_co: median(&ls.iter().map(|l| l.c_out as f64).collect::<Vec<_>>()),
        median_a: median(
            &ls.iter()
                .map(|l| l.arithmetic_intensity())
                .collect::<Vec<_>>(),
        ),
    }
}

/// Table II row: median conv-as-matmul dimensions (eq. 16).
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub name: &'static str,
    pub num_layers: usize,
    pub median_l: f64,
    pub median_n: f64,
    pub median_m: f64,
}

pub fn table2_row(net: &Network) -> Table2Row {
    let dims: Vec<(f64, f64, f64)> =
        net.layers.iter().map(|l| l.matmul_dims()).collect();
    Table2Row {
        name: net.name,
        num_layers: net.layers.len(),
        median_l: median(&dims.iter().map(|d| d.0).collect::<Vec<_>>()),
        median_n: median(&dims.iter().map(|d| d.1).collect::<Vec<_>>()),
        median_m: median(&dims.iter().map(|d| d.2).collect::<Vec<_>>()),
    }
}

/// eq. (23): the energy-amortization factors (L, N, M) of a conv layer on
/// an optical 4F machine with `slm_pixels` of SLM area. `None` pixels
/// means an infinitely large metasurface (Table III's C' → ∞).
pub fn optical4f_dims(layer: &ConvLayer, slm_pixels: Option<usize>) -> (f64, f64, f64) {
    let n2 = (layer.n * layer.n) as f64;
    let k2 = layer.k2();
    let co = layer.c_out as f64;
    let c_prime = match slm_pixels {
        None => f64::INFINITY,
        Some(px) => ((px as f64 / n2).floor()).max(1.0).min(layer.c_in as f64),
    };
    let l = n2; // eq. (23a)
    let n = if c_prime.is_infinite() {
        k2 * co // lim_{C'→∞} k²C'Cₒ/(C'+Cₒ) = k²Cₒ
    } else {
        k2 * c_prime * co / (c_prime + co) // eq. (23b)
    };
    let m = k2 * co / 2.0; // eq. (23c)
    (l, n, m)
}

/// Table III row: median optical-4F amortization dims of one network.
#[derive(Clone, Debug)]
pub struct Table3Row {
    pub name: &'static str,
    pub num_layers: usize,
    pub median_l: f64,
    pub median_n: f64,
    pub median_m: f64,
}

pub fn table3_row(net: &Network, slm_pixels: Option<usize>) -> Table3Row {
    let dims: Vec<(f64, f64, f64)> = net
        .layers
        .iter()
        .map(|l| optical4f_dims(l, slm_pixels))
        .collect();
    Table3Row {
        name: net.name,
        num_layers: net.layers.len(),
        median_l: median(&dims.iter().map(|d| d.0).collect::<Vec<_>>()),
        median_n: median(&dims.iter().map(|d| d.1).collect::<Vec<_>>()),
        median_m: median(&dims.iter().map(|d| d.2).collect::<Vec<_>>()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::{vgg::vgg16, yolov3::yolov3, zoo, ConvLayer};

    #[test]
    fn table1_row_fields_populated() {
        let r = table1_row(&vgg16(1000));
        assert_eq!(r.num_layers, 13);
        assert!(r.median_a > 1000.0);
        assert!(r.max_input > 1e7);
    }

    #[test]
    fn table3_infinite_slm_n_equals_2m() {
        // In the C'→∞ limit N = k²Cₒ and M = k²Cₒ/2, so N = 2M for every
        // layer — visible in every row of the paper's Table III.
        for net in zoo(1000) {
            let r = table3_row(&net, None);
            assert!(
                (r.median_n - 2.0 * r.median_m).abs() < 1e-9,
                "{}: N {} != 2M {}",
                net.name,
                r.median_n,
                r.median_m
            );
        }
    }

    #[test]
    fn table3_yolo_matches_paper() {
        // Table III YOLOv3: L = 3844, N = 512, M = 256.
        let r = table3_row(&yolov3(1000), None);
        assert!((r.median_l - 3844.0).abs() / 3844.0 < 0.1, "L {}", r.median_l);
        assert!((r.median_n - 512.0).abs() / 512.0 < 0.3, "N {}", r.median_n);
        assert!((r.median_m - 256.0).abs() / 256.0 < 0.3, "M {}", r.median_m);
    }

    #[test]
    fn finite_slm_reduces_n() {
        let l = ConvLayer::square(512, 128, 128, 3, 1);
        let (_, n_inf, _) = optical4f_dims(&l, None);
        let (_, n_4m, _) = optical4f_dims(&l, Some(4 * 1024 * 1024));
        assert!(n_4m < n_inf, "finite SLM must reduce amortization");
        // C' = floor(4Mi/512²) = 16 → N = 9·16·128/144 = 128.
        assert!((n_4m - 128.0).abs() < 1.0, "N = {n_4m}");
    }

    #[test]
    fn c_prime_clamped_to_ci() {
        // Tiny image: C' would be huge but can't exceed the actual
        // channel count.
        let l = ConvLayer::square(10, 4, 8, 3, 1);
        let (_, n, _) = optical4f_dims(&l, Some(4 * 1024 * 1024));
        let expect = 9.0 * 4.0 * 8.0 / (4.0 + 8.0);
        assert!((n - expect).abs() < 1e-9);
    }

    #[test]
    fn c_prime_floor_at_one() {
        // Image bigger than the SLM: C' clamps to 1 (spatial tiling is
        // the simulator's job, the analytic factor keeps C' ≥ 1).
        let l = ConvLayer::square(4000, 16, 8, 3, 1);
        let (_, n, _) = optical4f_dims(&l, Some(1024 * 1024));
        let expect = 9.0 * 1.0 * 8.0 / (1.0 + 8.0);
        assert!((n - expect).abs() < 1e-9);
    }

    #[test]
    fn conv_flops_and_bytes_pin() {
        // 32×32×16 → 32 channels, 3×3: FLOPs = 2·32²·9·16·32 and
        // bytes = 32²·16 + 32²·32 + 9·16·32 at one byte per element.
        let l = ConvLayer::square(32, 16, 32, 3, 1);
        assert_eq!(layer_flops(&l), 9_437_184.0);
        assert_eq!(layer_bytes(&l, 1.0), 16_384.0 + 32_768.0 + 4_608.0);
        let a = flops_per_byte(&l, 1.0);
        assert!((a - 175.5476).abs() < 1e-3, "a = {a}");
    }

    #[test]
    fn gemm_flops_and_bytes_pin() {
        // GEMM [256×128]·[128×64] via the 1×1-conv mapping.
        let l = crate::networks::transformer::gemm(256, 128, 64);
        assert_eq!(layer_flops(&l), 2.0 * 256.0 * 128.0 * 64.0);
        assert_eq!(layer_bytes(&l, 1.0), 32_768.0 + 16_384.0 + 8_192.0);
        let a = flops_per_byte(&l, 1.0);
        assert!((a - 73.1428).abs() < 1e-3, "a = {a}");
    }

    #[test]
    fn batch1_gemv_is_memory_bound() {
        // GEMV [1×512]·[512×512]: weights dominate traffic, so the
        // intensity pins just under 2 FLOPs/elem — the decode regime.
        let l = crate::networks::transformer::gemm(1, 512, 512);
        assert_eq!(layer_flops(&l), 524_288.0);
        assert_eq!(layer_bytes(&l, 1.0), 512.0 + 512.0 + 262_144.0);
        let a = flops_per_byte(&l, 1.0);
        assert!(a < 2.0 && a > 1.9, "a = {a}");
    }

    #[test]
    fn flops_per_byte_matches_eq9_at_unit_bytes() {
        for l in [
            ConvLayer::square(100, 16, 32, 3, 1),
            ConvLayer::square(64, 8, 16, 3, 2),
            crate::networks::transformer::gemm(256, 768, 768),
        ] {
            assert_eq!(flops_per_byte(&l, 1.0), l.arithmetic_intensity());
            // Wider elements scale traffic linearly.
            assert_eq!(layer_bytes(&l, 2.0), 2.0 * layer_bytes(&l, 1.0));
        }
    }

    #[test]
    fn network_intensity_is_flops_over_bytes() {
        let net = crate::networks::transformer::TransformerConfig::tiny().decode(1, 64);
        let f = network_flops(&net);
        let b = network_bytes(&net, 1.0);
        assert_eq!(f, 2.0 * net.total_macs());
        assert_eq!(network_intensity(&net, 1.0), f / b);
        // Decode streams sit deep in the memory-bound regime.
        assert!(network_intensity(&net, 1.0) < 2.0);
    }

    #[test]
    fn all_rows_emit_for_zoo() {
        for net in zoo(1000) {
            let r1 = table1_row(&net);
            let r2 = table2_row(&net);
            let r3 = table3_row(&net, None);
            assert_eq!(r1.num_layers, r2.num_layers);
            assert_eq!(r2.num_layers, r3.num_layers);
            assert!(r1.median_a > 0.0 && r2.median_l > 0.0 && r3.median_m > 0.0);
        }
    }
}
