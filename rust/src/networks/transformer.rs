//! Decoder-transformer workload generator: prefill and decode streams.
//!
//! The paper's central claim is that analog in-memory efficiency scales
//! with problem size and *arithmetic intensity*; transformers exercise
//! both extremes of that axis in one model. A decoder forward pass runs
//! in two regimes:
//!
//! * **prefill** — the whole prompt at once: every projection is a GEMM
//!   with `batch·seq` rows, high intensity (weights amortize over many
//!   activations), the regime where the digital machine is comfortable;
//! * **decode** — one token per sequence per step: the same projections
//!   collapse to `batch`-row GEMVs against the resident weights plus a
//!   KV-cache-length attention, the low-intensity memory-wall regime
//!   where in-memory compute should dominate.
//!
//! Both streams are emitted as plain [`Network`]s of 1×1 stride-1
//! [`ConvLayer`]s: a GEMM `[rows × d_in]·[d_in × d_out]` maps exactly
//! onto a 1×1 conv with spatial side `n = √rows` — `macs() =
//! rows·d_in·d_out` and `matmul_dims() = (rows, d_in, d_out)` — so the
//! four cycle simulators, the analytic models, [`SweepCache`], the
//! surrogate fitter and the serving path all consume transformers
//! unchanged. `rows` values that are not perfect squares are padded up
//! to the next square grid (the defaults below are chosen so no padding
//! ever happens in shipped grids).
//!
//! Attention is emitted with heads folded: `n_heads·d_head = d_model`,
//! so the per-head score/AV batches fold into one `d_model`-wide GEMM
//! with an identical MAC count. Causal masking is *not* discounted
//! (full-`seq` scores), matching the usual roofline-accounting
//! convention.
//!
//! [`SweepCache`]: crate::simulator::SweepCache

use std::collections::HashSet;
use std::sync::{Mutex, OnceLock};

use super::{ConvLayer, Network};

/// Which half of the serving loop a layer stream models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Prompt ingestion: `batch·seq`-row GEMMs, high intensity.
    Prefill,
    /// Token generation: `batch`-row GEMVs, low intensity.
    Decode,
}

impl Phase {
    pub fn parse(s: &str) -> Option<Phase> {
        match s.to_ascii_lowercase().as_str() {
            "prefill" => Some(Phase::Prefill),
            "decode" => Some(Phase::Decode),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    /// Tokens produced by ONE forward pass of the stream: prefill
    /// ingests the whole prompt, decode emits one token per sequence.
    pub fn tokens(self, batch: usize, seq: usize) -> usize {
        match self {
            Phase::Prefill => batch * seq,
            Phase::Decode => batch,
        }
    }
}

/// A decoder-family configuration (GPT-2-class or Llama-class).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: &'static str,
    /// Model width (`n_heads · d_head`).
    pub d_model: usize,
    /// Number of decoder blocks.
    pub n_layers: usize,
    /// Attention heads (folded into `d_model`-wide GEMMs; kept for
    /// documentation and the `d_head` invariant).
    pub n_heads: usize,
    /// MLP hidden width.
    pub ff_dim: usize,
    /// Output vocabulary (LM-head width).
    pub vocab: usize,
    /// Llama-style gated MLP (SwiGLU): the up-projection carries a
    /// fused gate, doubling its output width.
    pub gated_mlp: bool,
}

impl TransformerConfig {
    /// GPT-2 small (124M): 12 × d768, GELU MLP ×4, tied 50257 vocab.
    pub fn gpt2_small() -> Self {
        TransformerConfig {
            name: "gpt2-small",
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            ff_dim: 3072,
            vocab: 50257,
            gated_mlp: false,
        }
    }

    /// TinyLlama-1.1B, the Llama-class config: 22 × d2048, SwiGLU
    /// ff 5632, 32000 vocab.
    pub fn tinyllama() -> Self {
        TransformerConfig {
            name: "tinyllama",
            d_model: 2048,
            n_layers: 22,
            n_heads: 32,
            ff_dim: 5632,
            vocab: 32000,
            gated_mlp: true,
        }
    }

    /// Deliberately tiny config for CI smoke runs and unit tests.
    pub fn tiny() -> Self {
        TransformerConfig {
            name: "tfm-tiny",
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            ff_dim: 128,
            vocab: 256,
            gated_mlp: false,
        }
    }

    /// Every shipped config, for name lookup and corpus generation.
    pub fn all() -> [TransformerConfig; 3] {
        [Self::gpt2_small(), Self::tinyllama(), Self::tiny()]
    }

    /// Case-insensitive config lookup by name.
    pub fn by_name(name: &str) -> Option<TransformerConfig> {
        let lower = name.to_ascii_lowercase();
        Self::all().into_iter().find(|c| c.name == lower)
    }

    /// One decoder block's six GEMMs at `rows` activation rows against
    /// a `kv`-long key/value context.
    fn push_block(&self, rows: usize, kv: usize, layers: &mut Vec<ConvLayer>) {
        let d = self.d_model;
        // Fused QKV projection (GPT-2's c_attn; Llama's separate Q/K/V
        // have the identical MAC count).
        layers.push(gemm(rows, d, 3 * d));
        // Attention scores QKᵀ, heads folded: Σ_heads rows·d_head·kv
        // = rows·d_model·kv.
        layers.push(gemm(rows, d, kv));
        // Attention·V, heads folded likewise.
        layers.push(gemm(rows, kv, d));
        // Output projection.
        layers.push(gemm(rows, d, d));
        // MLP up (gated configs fuse gate+up into one double-width GEMM).
        let up = if self.gated_mlp { 2 * self.ff_dim } else { self.ff_dim };
        layers.push(gemm(rows, d, up));
        // MLP down.
        layers.push(gemm(rows, self.ff_dim, d));
    }

    /// Emit one layer stream: the full stack of decoder blocks plus the
    /// LM head (logits for the last position of each sequence only).
    ///
    /// For [`Phase::Prefill`], `seq` is the prompt length (rows =
    /// `batch·seq`, scores span `seq`). For [`Phase::Decode`], `seq` is
    /// the resident KV-cache length (rows = `batch`).
    pub fn stream(&self, phase: Phase, batch: usize, seq: usize) -> Network {
        assert!(batch > 0 && seq > 0, "batch and seq must be positive");
        let rows = match phase {
            Phase::Prefill => batch * seq,
            Phase::Decode => batch,
        };
        let mut layers = Vec::with_capacity(6 * self.n_layers + 1);
        for _ in 0..self.n_layers {
            self.push_block(rows, seq, &mut layers);
        }
        layers.push(gemm(batch, self.d_model, self.vocab));
        let name = intern(format!(
            "{}@{} b{} s{}",
            self.name,
            phase.label(),
            batch,
            seq
        ));
        Network { name, layers }
    }

    /// Prompt-ingestion stream: `batch` prompts of `seq` tokens.
    pub fn prefill(&self, batch: usize, seq: usize) -> Network {
        self.stream(Phase::Prefill, batch, seq)
    }

    /// Token-generation stream: one step for `batch` sequences against
    /// a `ctx`-long KV cache.
    pub fn decode(&self, batch: usize, ctx: usize) -> Network {
        self.stream(Phase::Decode, batch, ctx)
    }
}

/// Default batch grid for intensity sweeps. Perfect squares, so both
/// the decode rows (`batch`) and the prefill rows (`batch·seq`) map
/// onto the n×n conv grid with zero padding.
pub const DEFAULT_BATCHES: [usize; 3] = [1, 4, 16];

/// Default sequence/context grid (perfect squares, see above).
pub const DEFAULT_SEQS: [usize; 3] = [64, 256, 1024];

/// Map a GEMM `[rows × d_in] · [d_in × d_out]` onto the 1×1-conv layer
/// vocabulary. Exact when `rows` is a perfect square; otherwise the
/// row count pads up to the next square grid (accelerators pad tiles
/// the same way).
pub fn gemm(rows: usize, d_in: usize, d_out: usize) -> ConvLayer {
    ConvLayer::square(rows_side(rows), d_in, d_out, 1, 1)
}

/// Smallest n with n² ≥ rows.
fn rows_side(rows: usize) -> usize {
    let mut n = (rows as f64).sqrt() as usize;
    while n * n < rows {
        n += 1;
    }
    while n > 1 && (n - 1) * (n - 1) >= rows {
        n -= 1;
    }
    n.max(1)
}

/// Parse a `name[@phase]` selector: `"gpt2-small@decode"` →
/// `(config, Some(Decode))`, `"gpt2-small"` → `(config, None)`.
pub fn parse_selector(sel: &str) -> Option<(TransformerConfig, Option<Phase>)> {
    match sel.split_once('@') {
        Some((name, phase)) => Some((
            TransformerConfig::by_name(name)?,
            Some(Phase::parse(phase)?),
        )),
        None => Some((TransformerConfig::by_name(sel)?, None)),
    }
}

/// Resolve a `name[@phase]` selector into one concrete stream (phase
/// defaults to decode — the stream serving actually runs per step).
pub fn resolve(sel: &str, batch: usize, seq: usize) -> Option<Network> {
    let (cfg, phase) = parse_selector(sel)?;
    Some(cfg.stream(phase.unwrap_or(Phase::Decode), batch, seq))
}

/// Representative transformer streams for the surrogate training
/// corpus: anchor the GEMM/GEMV (1×1 stride-1) family across the full
/// rows × width range transformers exercise. After layer dedup this
/// costs only a handful of extra shapes per machine × node.
pub fn corpus_networks() -> Vec<Network> {
    let gpt2 = TransformerConfig::gpt2_small();
    let tiny = TransformerConfig::tiny();
    vec![
        gpt2.prefill(1, 64),
        gpt2.decode(4, 256),
        tiny.prefill(1, 64),
        tiny.decode(1, 64),
    ]
}

/// Leak-once string interner so generated stream names satisfy
/// `Network.name: &'static str`. Repeated streams reuse one allocation.
fn intern(s: String) -> &'static str {
    static POOL: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let pool = POOL.get_or_init(|| Mutex::new(HashSet::new()));
    let mut set = pool.lock().unwrap();
    if let Some(&existing) = set.get(s.as_str()) {
        return existing;
    }
    let leaked: &'static str = Box::leak(s.into_boxed_str());
    set.insert(leaked);
    leaked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_maps_rows_exactly_for_perfect_squares() {
        let l = gemm(256, 768, 3 * 768);
        assert_eq!(l.n, 16);
        assert_eq!((l.kh, l.kw, l.stride), (1, 1, 1));
        // macs() = rows·d_in·d_out, matmul_dims() = (rows, d_in, d_out).
        assert_eq!(l.macs(), 256.0 * 768.0 * 2304.0);
        assert_eq!(l.matmul_dims(), (256.0, 768.0, 2304.0));
    }

    #[test]
    fn gemm_pads_non_square_rows_up() {
        assert_eq!(gemm(5, 8, 8).n, 3);
        assert_eq!(gemm(1, 8, 8).n, 1);
        assert_eq!(gemm(2, 8, 8).n, 2);
        assert_eq!(gemm(1024, 8, 8).n, 32);
    }

    #[test]
    fn tiny_decode_mac_count_pins() {
        // tfm-tiny, decode b1 s64: per block (d=64, ff=128, kv=64):
        // qkv 64·192 + scores 64·64 + av 64·64 + out 64·64 + up 64·128
        // + down 128·64 = 40960; ×2 blocks + lm head 64·256 = 98304.
        let net = TransformerConfig::tiny().decode(1, 64);
        assert_eq!(net.num_layers(), 13);
        assert_eq!(net.total_macs(), 98304.0);
    }

    #[test]
    fn prefill_folds_batch_and_seq_into_rows() {
        let net = TransformerConfig::gpt2_small().prefill(4, 64);
        // rows = 256 → n = 16 on every projection.
        assert_eq!(net.layers[0].n, 16);
        // Scores span the sequence, AV contracts over it.
        assert_eq!(net.layers[1].c_out, 64);
        assert_eq!(net.layers[2].c_in, 64);
    }

    #[test]
    fn decode_is_batch_rows_against_kv_context() {
        let net = TransformerConfig::gpt2_small().decode(1, 1024);
        assert_eq!(net.layers[0].n, 1); // batch-1 GEMV
        assert_eq!(net.layers[1].c_out, 1024); // KV-cache-length scores
    }

    #[test]
    fn gated_mlp_doubles_up_projection() {
        let llama = TransformerConfig::tinyllama().decode(1, 64);
        let gpt2 = TransformerConfig::gpt2_small().decode(1, 64);
        assert_eq!(llama.layers[4].c_out, 2 * 5632);
        assert_eq!(gpt2.layers[4].c_out, 3072);
    }

    #[test]
    fn decode_intensity_below_prefill() {
        let cfg = TransformerConfig::gpt2_small();
        let pre = cfg.prefill(4, 256);
        let dec = cfg.decode(4, 256);
        let ai = |n: &Network| {
            n.total_ops()
                / n.layers
                    .iter()
                    .map(|l| l.ops() / l.arithmetic_intensity())
                    .sum::<f64>()
        };
        assert!(ai(&dec) < ai(&pre) / 10.0, "decode must be low-intensity");
    }

    #[test]
    fn selector_parses_phase_and_rejects_unknown() {
        let (cfg, phase) = parse_selector("GPT2-Small@decode").unwrap();
        assert_eq!(cfg.name, "gpt2-small");
        assert_eq!(phase, Some(Phase::Decode));
        let (_, none) = parse_selector("tfm-tiny").unwrap();
        assert_eq!(none, None);
        assert!(parse_selector("gpt2-small@train").is_none());
        assert!(parse_selector("nope@decode").is_none());
        assert!(parse_selector("nope").is_none());
    }

    #[test]
    fn resolve_defaults_to_decode() {
        let net = resolve("tfm-tiny", 1, 64).unwrap();
        assert!(net.name.contains("@decode"));
        assert!(resolve("vgg16", 1, 64).is_none());
    }

    #[test]
    fn interner_dedups_stream_names() {
        let a = TransformerConfig::tiny().decode(1, 64);
        let b = TransformerConfig::tiny().decode(1, 64);
        assert_eq!(a.name, b.name);
        assert_eq!(a.name.as_ptr(), b.name.as_ptr());
    }

    #[test]
    fn tokens_per_forward_pass() {
        assert_eq!(Phase::Prefill.tokens(4, 256), 1024);
        assert_eq!(Phase::Decode.tokens(4, 256), 4);
    }

    #[test]
    fn corpus_networks_are_all_gemm_family() {
        for net in corpus_networks() {
            for l in &net.layers {
                assert_eq!((l.kh, l.kw, l.stride), (1, 1, 1), "{}", net.name);
            }
        }
    }
}
