//! DenseNet-201 (Huang et al. 2016): dense blocks [6, 12, 48, 32] with
//! growth rate 32 and bottleneck width 4·growth = 128. 200 conv layers
//! (Table I): 1 stem + 2·98 dense-layer convs + 3 transitions.

use super::{Builder, Network};

const GROWTH: usize = 32;
const BOTTLENECK: usize = 4 * GROWTH; // 128

/// DenseNet-201 at the given input resolution.
pub fn densenet201(input: usize) -> Network {
    densenet(input, &[6, 12, 48, 32], "DenseNet201")
}

/// DenseNet-121 (ablation benches).
pub fn densenet121(input: usize) -> Network {
    densenet(input, &[6, 12, 24, 16], "DenseNet121")
}

fn densenet(input: usize, blocks: &[usize], name: &'static str) -> Network {
    let mut b = Builder::new(input);
    b.conv(3, 64, 7, 2); // stem
    b.pool(2); // max-pool
    let mut c = 64;
    for (bi, &layers) in blocks.iter().enumerate() {
        for _ in 0..layers {
            // Dense layer: 1×1 bottleneck (c → 128) then 3×3 (128 → 32);
            // the 32 new features concatenate onto the running c.
            b.branch_conv(b.n, c, BOTTLENECK, 1, 1, 1);
            b.branch_conv(b.n, BOTTLENECK, GROWTH, 3, 3, 1);
            c += GROWTH;
        }
        if bi + 1 < blocks.len() {
            // Transition: 1×1 halving channels, then 2×2 avg-pool.
            b.branch_conv(b.n, c, c / 2, 1, 1, 1);
            c /= 2;
            b.pool(2);
        }
    }
    b.finish(name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, median};

    #[test]
    fn densenet201_layer_count() {
        assert_eq!(densenet201(1000).num_layers(), 200); // Table I: 200
    }

    #[test]
    fn densenet121_layer_count() {
        assert_eq!(densenet121(1000).num_layers(), 120); // 1 + 2·58 + 3
    }

    #[test]
    fn channel_accumulation() {
        // Block 3 ends at 256 + 48·32 = 1792 channels before transition.
        let net = densenet201(1000);
        let max_cin = net.layers.iter().map(|l| l.c_in).max().unwrap();
        assert_eq!(max_cin, 896 + 32 * 31); // deepest dense layer of block 4
    }

    #[test]
    fn median_n_is_62() {
        // Table I: median n = 62 (1000/16 = 62 after stem+3 transitions).
        let net = densenet201(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 62.0).abs() <= 2.0, "median n = {m}");
    }

    #[test]
    fn median_ci_is_128() {
        // Table I: median Cᵢ = 128 (half the convs are the 128-in 3×3s).
        let net = densenet201(1000);
        let ci: Vec<f64> = net.layers.iter().map(|l| l.c_in as f64).collect();
        assert_eq!(median(&ci), 128.0);
    }

    #[test]
    fn avg_k_is_2() {
        // Table I: avg k = 2.0 (half 1×1, half 3×3).
        let net = densenet201(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        assert!((mean(&ks) - 2.0).abs() < 0.05);
    }

    #[test]
    fn total_weights_1_8e7() {
        // Table I: total K = 1.8e7.
        let k = densenet201(1000).total_weights();
        assert!((k - 1.8e7).abs() / 1.8e7 < 0.15, "K = {k:.3e}");
    }

    #[test]
    fn median_intensity_matches_table1() {
        // Table I: median a = 292.
        let net = densenet201(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 292.0).abs() / 292.0 < 0.2, "median a = {m}");
    }

    #[test]
    fn table2_dims() {
        // Table II: median L' = 3844 (62²), N' = 1152, M' = 128.
        let net = densenet201(1000);
        let lp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().0).collect();
        let np: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().1).collect();
        let mp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().2).collect();
        assert!((median(&lp) - 3844.0).abs() / 3844.0 < 0.1);
        assert!((median(&np) - 1152.0).abs() / 1152.0 < 0.35, "N' {}", median(&np));
        assert_eq!(median(&mp), 128.0);
    }
}
