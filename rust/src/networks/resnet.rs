//! ResNet-152 (He et al. 2015): bottleneck residual blocks,
//! stages [3, 8, 36, 3]. 155 conv layers (Table I): 1 stem +
//! 150 bottleneck convs + 4 downsample projections.

use super::{Builder, Network};

struct Stage {
    blocks: usize,
    mid: usize,
    out: usize,
    stride: usize,
}

/// General bottleneck ResNet generator.
fn resnet(input: usize, stages: &[Stage], name: &'static str) -> Network {
    let mut b = Builder::new(input);
    b.conv(3, 64, 7, 2); // stem
    b.pool(2); // 3×3 max-pool
    let mut c_in = 64;
    for st in stages {
        for blk in 0..st.blocks {
            let stride = if blk == 0 { st.stride } else { 1 };
            let n = b.n;
            if blk == 0 {
                // Downsample projection shortcut (1×1, strided).
                b.branch_conv(n, c_in, st.out, 1, 1, stride);
            }
            // Bottleneck: 1×1 reduce → 3×3 (strided on the first block)
            // → 1×1 expand. (v1.5 convention: stride on the 3×3.)
            b.branch_conv(n, c_in, st.mid, 1, 1, 1);
            b.conv(st.mid, st.mid, 3, stride);
            b.branch_conv(b.n, st.mid, st.out, 1, 1, 1);
            c_in = st.out;
        }
    }
    b.finish(name)
}

/// ResNet-152 at the given input resolution.
pub fn resnet152(input: usize) -> Network {
    resnet(
        input,
        &[
            Stage { blocks: 3, mid: 64, out: 256, stride: 1 },
            Stage { blocks: 8, mid: 128, out: 512, stride: 2 },
            Stage { blocks: 36, mid: 256, out: 1024, stride: 2 },
            Stage { blocks: 3, mid: 512, out: 2048, stride: 2 },
        ],
        "ResNet152",
    )
}

/// ResNet-50 (used by the ablation benches, not in the paper's tables).
pub fn resnet50(input: usize) -> Network {
    resnet(
        input,
        &[
            Stage { blocks: 3, mid: 64, out: 256, stride: 1 },
            Stage { blocks: 4, mid: 128, out: 512, stride: 2 },
            Stage { blocks: 6, mid: 256, out: 1024, stride: 2 },
            Stage { blocks: 3, mid: 512, out: 2048, stride: 2 },
        ],
        "ResNet50",
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, median};

    #[test]
    fn resnet152_layer_count() {
        assert_eq!(resnet152(1000).num_layers(), 155); // Table I: 155
    }

    #[test]
    fn resnet50_layer_count() {
        assert_eq!(resnet50(1000).num_layers(), 53); // 1 + 48 + 4
    }

    #[test]
    fn spatial_ladder() {
        let net = resnet152(1000);
        // Stem at 1000, first stage at 250, last blocks at 32.
        assert_eq!(net.layers[0].n, 1000);
        assert_eq!(net.layers[1].n, 250);
        assert!(net.layers.last().unwrap().n <= 32);
    }

    #[test]
    fn median_n_matches_table1() {
        // Table I: median n = 63 (ours: 63 with ceil-div tracking).
        let net = resnet152(1000);
        let ns: Vec<f64> = net.layers.iter().map(|l| l.n as f64).collect();
        let m = median(&ns);
        assert!((m - 63.0).abs() <= 2.0, "median n = {m}");
    }

    #[test]
    fn median_channels_match_table1() {
        // Table I: median Cᵢ = 256, Cᵢ₊₁ = 256.
        let net = resnet152(1000);
        let ci: Vec<f64> = net.layers.iter().map(|l| l.c_in as f64).collect();
        let co: Vec<f64> = net.layers.iter().map(|l| l.c_out as f64).collect();
        assert_eq!(median(&ci), 256.0);
        assert_eq!(median(&co), 256.0);
    }

    #[test]
    fn avg_k_about_1_7() {
        // Table I: avg k = 1.7 (mostly 1×1 with one 3×3 per block).
        let net = resnet152(1000);
        let ks: Vec<f64> = net.layers.iter().map(|l| l.k_eff()).collect();
        let m = mean(&ks);
        assert!((m - 1.7).abs() < 0.15, "avg k = {m}");
    }

    #[test]
    fn total_weights_5_8e7() {
        // Table I: total K = 5.8e7.
        let k = resnet152(1000).total_weights();
        assert!((k - 5.8e7).abs() / 5.8e7 < 0.1, "K = {k:.3e}");
    }

    #[test]
    fn median_intensity_matches_table1() {
        // Table I: median a = 390.
        let net = resnet152(1000);
        let a: Vec<f64> = net
            .layers
            .iter()
            .map(|l| l.arithmetic_intensity())
            .collect();
        let m = median(&a);
        assert!((m - 390.0).abs() / 390.0 < 0.2, "median a = {m}");
    }

    #[test]
    fn table2_dims() {
        // Table II: median L' = 3969 (=63²), N' = 1024, M' = 256.
        let net = resnet152(1000);
        let lp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().0).collect();
        let np: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().1).collect();
        let mp: Vec<f64> = net.layers.iter().map(|l| l.matmul_dims().2).collect();
        assert!((median(&lp) - 3969.0).abs() / 3969.0 < 0.1);
        assert!((median(&np) - 1024.0).abs() / 1024.0 < 0.26);
        assert_eq!(median(&mp), 256.0);
    }
}
