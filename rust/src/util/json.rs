//! Dependency-free JSON tree: build, render (compact or pretty) and
//! parse. The report layer's JSON sink ([`crate::report::Dataset::to_json`])
//! emits through this module; the parser exists so tests (and the CI
//! smoke step's local twin) can validate round-trips without pulling
//! serde into the offline build.
//!
//! Numbers are `f64` and render through Rust's shortest-round-trip
//! `Display` (which never uses exponent notation, so every rendering is
//! a valid JSON number). Non-finite numbers render as `null` — JSON has
//! no NaN/∞ — and the parser never produces them.

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (no map: key order is part of the
    /// emitted document and tests pin it).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Compact rendering (no whitespace).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering: 2-space indent, one element per line.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, depth: usize| {
            if let Some(w) = indent {
                out.push('\n');
                for _ in 0..w * depth {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => out.push_str(&render_number(*v)),
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str(if indent.is_some() { "\": " } else { "\":" });
                    v.write(out, indent, depth + 1);
                }
                pad(out, depth);
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage is an error). Nesting is bounded at
    /// [`MAX_DEPTH`] so hostile input errors instead of blowing the
    /// recursion stack.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(value)
    }
}

/// Maximum container nesting the parser accepts (the emitter produces
/// depth ≤ 4; 128 leaves generous headroom while keeping the recursive
/// descent far from the thread stack limit).
pub const MAX_DEPTH: usize = 128;

/// Render a finite f64 as a JSON number (`null` otherwise).
fn render_number(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Escape a string body for embedding between JSON quotes.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {}", *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", *pos));
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'n') => expect(b, pos, "null").map(|_| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|_| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                let value = parse_value(b, pos, depth + 1)?;
                fields.push((key, value));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                        // Surrogates (paired or lone) are rejected: the
                        // emitter never produces them.
                        out.push(
                            char::from_u32(code)
                                .ok_or(format!("\\u{hex} is not a scalar value"))?,
                        );
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => {
                return Err(format!("raw control byte {c:#04x} in string"));
            }
            Some(_) => {
                // Copy one UTF-8 scalar (b is valid UTF-8: it came from &str).
                let s = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let ch = s.chars().next().unwrap();
                out.push(ch);
                *pos += ch.len_utf8();
            }
        }
    }
}

/// RFC 8259 `number` grammar: `-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?`.
/// `f64::from_str` alone is laxer ("+1", "01", "1.", ".5") — accepting
/// those would make this parser a weaker validator than the CI smoke
/// step's `python -m json.tool`, which it mirrors.
fn is_json_number(t: &[u8]) -> bool {
    let mut i = 0;
    if t.first() == Some(&b'-') {
        i += 1;
    }
    let int_start = i;
    while i < t.len() && t[i].is_ascii_digit() {
        i += 1;
    }
    let int_len = i - int_start;
    if int_len == 0 || (int_len > 1 && t[int_start] == b'0') {
        return false;
    }
    if i < t.len() && t[i] == b'.' {
        i += 1;
        let frac_start = i;
        while i < t.len() && t[i].is_ascii_digit() {
            i += 1;
        }
        if i == frac_start {
            return false;
        }
    }
    if i < t.len() && (t[i] == b'e' || t[i] == b'E') {
        i += 1;
        if i < t.len() && (t[i] == b'+' || t[i] == b'-') {
            i += 1;
        }
        let exp_start = i;
        while i < t.len() && t[i].is_ascii_digit() {
            i += 1;
        }
        if i == exp_start {
            return false;
        }
    }
    i == t.len()
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    if !is_json_number(text.as_bytes()) {
        return Err(format!("bad number {text:?} at byte {start}"));
    }
    text.parse::<f64>()
        .ok()
        // `f64::from_str` saturates overflow to ±inf; JSON has no such
        // value and this module's contract is that the parser never
        // produces non-finite numbers.
        .filter(|v| v.is_finite())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number {text:?} at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compact_and_pretty() {
        let j = Json::Obj(vec![
            ("a".into(), Json::Num(1.5)),
            ("b".into(), Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())])),
        ]);
        assert_eq!(j.render(), r#"{"a":1.5,"b":[1,"x"]}"#);
        let p = j.pretty();
        assert!(p.contains("\"a\": 1.5"));
        assert!(p.starts_with('{') && p.ends_with('}'));
    }

    #[test]
    fn integers_render_without_decimal_point() {
        assert_eq!(Json::Num(59.0).render(), "59");
        assert_eq!(Json::Num(-3.0).render(), "-3");
    }

    #[test]
    fn non_finite_renders_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn escapes_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
        // Commas and unicode pass through untouched.
        assert_eq!(escape("Fig. 8 — systolic, YOLOv3"), "Fig. 8 — systolic, YOLOv3");
    }

    #[test]
    fn parse_round_trips_both_renderings() {
        let j = Json::Obj(vec![
            ("title".into(), Json::Str("a, \"quoted\" title\nline2".into())),
            ("n".into(), Json::Num(-1.25e-3)),
            ("flags".into(), Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("empty_arr".into(), Json::Arr(vec![])),
            ("empty_obj".into(), Json::Obj(vec![])),
        ]);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nulL").is_err());
        // Overflowing literals must not saturate to ±inf.
        assert!(Json::parse("1e309").is_err());
        assert!(Json::parse("-1e309").is_err());
    }

    #[test]
    fn parse_enforces_rfc8259_number_grammar() {
        for bad in ["+1", "01", "1.", ".5", "-", "1e", "1e+", "--1", "0x10"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} must be rejected");
        }
        for good in ["0", "-0", "1.5", "-0.00125", "1e3", "1E-3", "12.5e+2"] {
            assert!(Json::parse(good).is_ok(), "{good:?} must parse");
        }
    }

    #[test]
    fn parse_bounds_nesting_depth() {
        let deep_ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&deep_ok).is_ok());
        let hostile = "[".repeat(200_000);
        assert!(
            Json::parse(&hostile).is_err(),
            "deep nesting must error, not overflow the stack"
        );
    }

    #[test]
    fn parse_handles_nested_and_ws() {
        let j = Json::parse(" { \"a\" : [ 1 , { \"b\" : \"c\" } ] } ").unwrap();
        match j {
            Json::Obj(f) => {
                assert_eq!(f[0].0, "a");
                match &f[0].1 {
                    Json::Arr(items) => assert_eq!(items.len(), 2),
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
        assert!(Json::parse("\"\\ud800\"").is_err(), "lone surrogate rejected");
    }
}
