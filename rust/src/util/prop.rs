//! Minimal property-based testing runner (offline stand-in for proptest).
//!
//! Usage:
//! ```ignore
//! check(100, |g| {
//!     let n = g.usize(1, 64);
//!     let xs = g.vec_f64(n, -1.0, 1.0);
//!     prop_assert(xs.len() == n, "length preserved")
//! });
//! ```
//!
//! Each case gets a fresh deterministic generator; on failure the runner
//! retries the failing seed with progressively simpler draws (shrinking is
//! size-based: the generator halves its upper bounds) and reports the
//! smallest failing seed + message.

use super::rng::Rng;

/// Draw source handed to properties. Wraps [`Rng`] and records a size
/// multiplier used during shrinking.
pub struct Gen {
    rng: Rng,
    /// 0..=16, scales upper bounds down when shrinking (16 = full size).
    size: u32,
    pub seed: u64,
}

impl Gen {
    fn new(seed: u64, size: u32) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
            seed,
        }
    }

    fn scaled(&self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        let span = hi - lo;
        let scaled = (span as u64 * self.size as u64 / 16).max(0) as usize;
        lo + scaled
    }

    /// Integer in `[lo, hi]` (hi shrinks with the size parameter).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let hi = self.scaled(lo, hi);
        self.rng.range_usize(lo, hi.max(lo))
    }

    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.usize(lo as usize, hi as usize) as u32
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.f32_range(lo, hi)).collect()
    }
}

/// Result of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two floats are within relative tolerance.
pub fn prop_close(a: f64, b: f64, rtol: f64, what: &str) -> PropResult {
    let denom = a.abs().max(b.abs()).max(1e-300);
    if (a - b).abs() / denom <= rtol {
        Ok(())
    } else {
        Err(format!("{what}: {a} !~ {b} (rtol {rtol})"))
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing
/// `#[test]`) with the seed and message of the smallest failure found.
pub fn check<F>(cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    // Deterministic base seed: stable CI, and failures are reproducible by
    // construction. Derive per-case seeds from it.
    let base = 0xA1C_C0DE_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut g = Gen::new(seed, 16);
        if let Err(msg) = prop(&mut g) {
            // Shrink: same seed, smaller sizes.
            let mut best = (16u32, msg);
            for size in (0..16).rev() {
                let mut g = Gen::new(seed, size);
                if let Err(m) = prop(&mut g) {
                    best = (size, m);
                } else {
                    break;
                }
            }
            panic!(
                "property failed (seed {seed:#x}, case {case}, shrunk to size {}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |g| {
            let n = g.usize(0, 100);
            prop_assert(n <= 100, "bound")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |g| {
            let n = g.usize(0, 100);
            prop_assert(n < 95, "must fail for large draws")
        });
    }

    #[test]
    fn prop_close_tolerates() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(prop_close(1.0, 1.1, 1e-6, "x").is_err());
    }

    #[test]
    fn gen_respects_bounds() {
        check(100, |g| {
            let v = g.f64(-2.0, 3.0);
            prop_assert((-2.0..=3.0).contains(&v), "f64 range")
        });
    }

    #[test]
    fn shrinking_reduces_size() {
        let mut g_full = Gen::new(1, 16);
        let mut g_small = Gen::new(1, 1);
        // With size 1, the upper bound collapses toward lo.
        assert!(g_small.usize(0, 1000) <= 63);
        assert!(g_full.usize(0, 1000) <= 1000);
    }
}
