//! In-tree work-stealing thread pool with scoped `par_map` /
//! `par_for_each` (the offline build has no rayon).
//!
//! Design: the input slice is split into one contiguous range per worker;
//! each range carries an atomic cursor. A worker drains its own range
//! front-to-back with a `fetch_add` claim, and when its range is empty it
//! *steals* from the cursor of whichever victim has the most work left —
//! so a skewed grid (VGG16's 62001-row layers next to SmallCNN) still
//! keeps every core busy. Claims are per-item and idempotent-safe: a
//! cursor past its range end simply yields no work.
//!
//! Guarantees the sweep engine relies on:
//!
//! * **Deterministic ordering** — `par_map` returns results in input
//!   order regardless of which thread computed what (each worker tags
//!   results with their input index; the merge sorts by it).
//! * **Scoped borrows** — built on [`std::thread::scope`], so closures
//!   may borrow the items, configs and caches of the calling frame.
//! * **Panic transparency** — a panic in the closure is re-raised on the
//!   caller (after all workers stop claiming work), so `util::prop`
//!   failures inside a parallel property surface normally.
//!
//! Thread count: `AIMC_THREADS` env override, else
//! [`std::thread::available_parallelism`].

use std::sync::atomic::{AtomicUsize, Ordering};

/// A (size-only) handle describing how many worker threads to use.
/// Workers are spawned per call and scoped to it — the pool holds no
/// long-lived threads, so there is nothing to shut down and `Pool` is
/// freely copyable.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Pool {
            threads: threads.max(1),
        }
    }

    /// The default pool: `AIMC_THREADS` if set, else the machine's
    /// available parallelism, else 1.
    pub fn auto() -> Self {
        let threads = std::env::var("AIMC_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        Pool::new(threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `items` in parallel; results come back in input
    /// order. Falls back to a plain serial map for 1 thread / ≤ 1 item
    /// (identical results by construction — `f` runs once per item
    /// either way).
    pub fn par_map<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(&T) -> U + Sync,
    {
        let n = items.len();
        if self.threads == 1 || n <= 1 {
            return items.iter().map(&f).collect();
        }
        let workers = self.threads.min(n);
        let chunk = n.div_ceil(workers);
        // Per-worker range [w·chunk, min((w+1)·chunk, n)) with an atomic
        // claim cursor.
        let cursors: Vec<AtomicUsize> =
            (0..workers).map(|w| AtomicUsize::new(w * chunk)).collect();
        let ends: Vec<usize> = (0..workers)
            .map(|w| ((w + 1) * chunk).min(n))
            .collect();

        let mut tagged: Vec<(usize, U)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let cursors = &cursors;
                    let ends = &ends;
                    let f = &f;
                    s.spawn(move || {
                        let mut out: Vec<(usize, U)> = Vec::new();
                        let mut victim = w;
                        loop {
                            let i = cursors[victim].fetch_add(1, Ordering::Relaxed);
                            if i < ends[victim] {
                                out.push((i, f(&items[i])));
                                continue;
                            }
                            // Own/current range drained: steal from the
                            // victim with the most remaining work.
                            let next = (0..cursors.len())
                                .filter(|&v| v != victim)
                                .map(|v| {
                                    let cur = cursors[v].load(Ordering::Relaxed);
                                    (v, ends[v].saturating_sub(cur))
                                })
                                .max_by_key(|&(_, rem)| rem)
                                .filter(|&(_, rem)| rem > 0);
                            match next {
                                Some((v, _)) => victim = v,
                                None => break,
                            }
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok(part) => tagged.extend(part),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });

        debug_assert_eq!(tagged.len(), n, "every item claimed exactly once");
        tagged.sort_unstable_by_key(|&(i, _)| i);
        tagged.into_iter().map(|(_, u)| u).collect()
    }

    /// Run `f` on every item in parallel (no result collection beyond
    /// completion — the call returns once every item has been visited).
    pub fn par_for_each<T, F>(&self, items: &[T], f: F)
    where
        T: Sync,
        F: Fn(&T) + Sync,
    {
        self.par_map(items, |x| f(x));
    }
}

/// [`Pool::par_map`] on the default ([`Pool::auto`]) pool.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    Pool::auto().par_map(items, f)
}

/// [`Pool::par_for_each`] on the default ([`Pool::auto`]) pool.
pub fn par_for_each<T, F>(items: &[T], f: F)
where
    T: Sync,
    F: Fn(&T) + Sync,
{
    Pool::auto().par_for_each(items, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_matches_serial_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for threads in [1, 2, 3, 8, 64] {
            let par = Pool::new(threads).par_map(&items, |x| x * x + 1);
            assert_eq!(par, serial, "threads = {threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let p = Pool::new(4);
        assert_eq!(p.par_map(&[] as &[u32], |x| *x), Vec::<u32>::new());
        assert_eq!(p.par_map(&[7u32], |x| x + 1), vec![8]);
    }

    #[test]
    fn every_item_visited_exactly_once() {
        let n = 4096;
        let visits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let idx: Vec<usize> = (0..n).collect();
        Pool::new(7).par_for_each(&idx, |&i| {
            visits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(visits.iter().all(|v| v.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn skewed_work_is_stolen() {
        // One pathological item 1000× heavier than the rest: with
        // stealing, the light items must not wait behind it. We can't
        // assert wall-clock reliably, but we can assert completion and
        // order with heavy skew present.
        let items: Vec<usize> = (0..64).collect();
        let out = Pool::new(4).par_map(&items, |&i| {
            let spins = if i == 0 { 200_000 } else { 200 };
            let mut acc = 0u64;
            for k in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            (i, acc != 1) // acc consumed so the loop isn't optimized out
        });
        assert_eq!(out.len(), 64);
        assert!(out.iter().enumerate().all(|(i, &(j, _))| i == j));
    }

    #[test]
    fn borrows_calling_frame() {
        let offset = 10u64;
        let items: Vec<u64> = (0..100).collect();
        let out = Pool::new(3).par_map(&items, |x| x + offset);
        assert_eq!(out[99], 109);
    }

    #[test]
    fn threads_clamped_to_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert!(Pool::auto().threads() >= 1);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn closure_panic_propagates() {
        let items: Vec<u32> = (0..32).collect();
        Pool::new(4).par_for_each(&items, |&i| {
            if i == 17 {
                panic!("boom");
            }
        });
    }
}
