//! Sharded concurrency helpers for the serving ingress.
//!
//! Two building blocks, both designed around the same observation: a
//! single atomic (or a single channel) written by every client thread
//! serializes the whole admission path on one cache line, which is
//! exactly where the paper says scaling should *not* stop.
//!
//! * [`ShardedCounter`] — a counter split over cache-line-padded cells.
//!   Writers pick a cell from a per-thread hint, so concurrent
//!   increments land on different lines; reads sum the cells. The sum
//!   is *approximate while writers race* (a reader can observe a
//!   matched add/sub pair half-applied), which is fine for the two
//!   consumers here: a load-shedding admission check, and a drain
//!   waiter that re-polls after the ingress has closed (once adds
//!   cease the sum decreases monotonically and zero detection is
//!   exact — see [`ShardedCounter::sub`]).
//! * [`ShardedQueue`] — N bounded FIFO shards with one consumer.
//!   Producers pick a shard from the same per-thread hint and fall
//!   over to the next shard when theirs is full; the consumer drains
//!   shards round-robin, rotating the starting shard so none gets
//!   persistent priority. Closing the queue is race-free against
//!   in-flight pushes: `closed` is checked *under the shard lock*, so
//!   a push either lands where a post-close drain must find it, or
//!   observes the close and hands the value back.
//!
//! [`thread_shard_hint`] derives the per-thread hint from the thread id
//! (hashed once, cached in a thread-local), so one client's requests
//! stay on one shard — cheap affinity without registration.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicUsize, Ordering::SeqCst};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-thread shard hint: the thread id hashed once and cached. Any
/// number of shards can take `hint % shards`.
pub fn thread_shard_hint() -> usize {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    thread_local! {
        static HINT: usize = {
            let mut h = DefaultHasher::new();
            std::thread::current().id().hash(&mut h);
            h.finish() as usize
        };
    }
    HINT.with(|h| *h)
}

/// One counter cell on its own cache line, so concurrent writers on
/// different cells never false-share.
#[repr(align(64))]
struct Cell(AtomicIsize);

/// A counter sharded over padded cells (a LongAdder, not a semaphore).
///
/// Cells hold *signed* counts: an `add` and its matching `sub` may run
/// on different threads and therefore different cells, so individual
/// cells go negative even though the logical count never does.
pub struct ShardedCounter {
    cells: Box<[Cell]>,
}

impl ShardedCounter {
    pub fn new(shards: usize) -> ShardedCounter {
        let cells: Box<[Cell]> = (0..shards.max(1))
            .map(|_| Cell(AtomicIsize::new(0)))
            .collect();
        ShardedCounter { cells }
    }

    pub fn shards(&self) -> usize {
        self.cells.len()
    }

    /// Add `n` on the cell picked by `hint`.
    pub fn add(&self, hint: usize, n: usize) {
        self.cells[hint % self.cells.len()]
            .0
            .fetch_add(n as isize, SeqCst);
    }

    /// Subtract `n` on the cell picked by `hint`; returns `true` when
    /// the post-subtraction sum reads zero or less — the caller's cue to
    /// notify a drain waiter. Once adds have ceased (ingress closed),
    /// the cue is reliable: every decrement precedes the last one in the
    /// `SeqCst` total order, so the last decrementer's sum reads the
    /// final (zero) value.
    pub fn sub(&self, hint: usize, n: usize) -> bool {
        self.cells[hint % self.cells.len()]
            .0
            .fetch_sub(n as isize, SeqCst);
        self.sum() <= 0
    }

    fn sum(&self) -> isize {
        self.cells.iter().map(|c| c.0.load(SeqCst)).sum()
    }

    /// Current logical count (clamped at zero; approximate while
    /// writers race — see the module docs).
    pub fn value(&self) -> usize {
        self.sum().max(0) as usize
    }
}

/// Error from [`ShardedQueue::push`]; the value is handed back.
#[derive(Debug)]
pub enum PushError<T> {
    /// Every shard is at capacity.
    Full(T),
    /// [`ShardedQueue::close`] has been called.
    Closed(T),
}

/// Bounded multi-producer / single-consumer queue sharded over N
/// independently locked FIFOs (see the module docs for the protocol).
pub struct ShardedQueue<T> {
    shards: Box<[Mutex<VecDeque<T>>]>,
    cap_per_shard: usize,
    closed: AtomicBool,
    /// Total buffered, maintained under the shard locks (increment
    /// before the push's unlock, decrement before the drain's), so it
    /// never underflows.
    len: AtomicUsize,
    /// Consumers currently parked (0 or 1); producers skip the park
    /// mutex entirely while this is 0.
    sleepers: AtomicUsize,
    park: Mutex<()>,
    cv: Condvar,
}

impl<T> ShardedQueue<T> {
    pub fn new(shards: usize, cap_per_shard: usize) -> ShardedQueue<T> {
        let shards = shards.max(1);
        let queues: Box<[Mutex<VecDeque<T>>]> = (0..shards)
            .map(|_| Mutex::new(VecDeque::new()))
            .collect();
        ShardedQueue {
            shards: queues,
            cap_per_shard: cap_per_shard.max(1),
            closed: AtomicBool::new(false),
            len: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            park: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Total values buffered across all shards (racy snapshot).
    pub fn len(&self) -> usize {
        self.len.load(SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(SeqCst)
    }

    /// Push onto the shard picked by `hint`, falling over to the next
    /// shard when that one is full. `Full` only when every shard is at
    /// capacity; `Closed` after [`ShardedQueue::close`].
    pub fn push(&self, hint: usize, v: T) -> Result<(), PushError<T>> {
        let n = self.shards.len();
        for probe in 0..n {
            let idx = (hint.wrapping_add(probe)) % n;
            let mut q = self.shards[idx].lock().unwrap();
            // Checked under the shard lock: serialized against a
            // closing consumer's final drain of this shard.
            if self.closed.load(SeqCst) {
                return Err(PushError::Closed(v));
            }
            if q.len() < self.cap_per_shard {
                q.push_back(v);
                self.len.fetch_add(1, SeqCst);
                drop(q);
                self.wake();
                return Ok(());
            }
        }
        Err(PushError::Full(v))
    }

    /// Drain every shard into `out`, visiting shards round-robin from
    /// `*start` and rotating the start for the next call. Returns the
    /// number of values moved.
    pub fn drain_rotating(&self, start: &mut usize, out: &mut Vec<T>) -> usize {
        let n = self.shards.len();
        let mut moved = 0;
        for probe in 0..n {
            let idx = (start.wrapping_add(probe)) % n;
            let mut q = self.shards[idx].lock().unwrap();
            let k = q.len();
            if k > 0 {
                out.extend(q.drain(..));
                self.len.fetch_sub(k, SeqCst);
                moved += k;
            }
        }
        *start = (start.wrapping_add(1)) % n;
        moved
    }

    /// Park the (single) consumer until a value is buffered, the queue
    /// closes, or `timeout` elapses. Returns `true` when woken for
    /// work/close, `false` on a pure timeout.
    pub fn wait_nonempty(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.len.load(SeqCst) > 0 || self.closed.load(SeqCst) {
                return true;
            }
            self.sleepers.fetch_add(1, SeqCst);
            {
                let guard = self.park.lock().unwrap();
                // Re-check under the park lock: a push between the
                // failed check and registering as a sleeper must not
                // leave us parked with work available.
                if self.len.load(SeqCst) == 0 && !self.closed.load(SeqCst) {
                    let now = Instant::now();
                    if now >= deadline {
                        self.sleepers.fetch_sub(1, SeqCst);
                        return false;
                    }
                    let _unused = self.cv.wait_timeout(guard, deadline - now).unwrap();
                }
            }
            self.sleepers.fetch_sub(1, SeqCst);
            if Instant::now() >= deadline {
                return self.len.load(SeqCst) > 0 || self.closed.load(SeqCst);
            }
        }
    }

    /// Close the queue: subsequent pushes return `Closed`; a parked
    /// consumer is woken. Values already buffered stay drainable.
    pub fn close(&self) {
        self.closed.store(true, SeqCst);
        let _g = self.park.lock().unwrap();
        self.cv.notify_all();
    }

    fn wake(&self) {
        if self.sleepers.load(SeqCst) > 0 {
            // Taking the park lock orders this notify after the
            // sleeper's registered-but-not-yet-waiting window closes.
            let _g = self.park.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_add_sub_across_cells() {
        let c = ShardedCounter::new(4);
        c.add(0, 3);
        c.add(7, 2); // cell 3
        assert_eq!(c.value(), 5);
        // Matched sub on a *different* cell than the add: logical count
        // still right even though individual cells go negative.
        assert!(!c.sub(1, 3));
        assert_eq!(c.value(), 2);
        assert!(c.sub(2, 2), "last sub must report the zero edge");
        assert_eq!(c.value(), 0);
    }

    #[test]
    fn counter_concurrent_balanced_ops_net_zero() {
        let c = Arc::new(ShardedCounter::new(8));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000 {
                    c.add(t, 1);
                    c.sub(t + i, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.value(), 0);
    }

    #[test]
    fn queue_fifo_within_a_shard() {
        let q = ShardedQueue::<u32>::new(1, 8);
        for i in 0..5 {
            q.push(0, i).unwrap();
        }
        let mut out = Vec::new();
        let mut rr = 0;
        assert_eq!(q.drain_rotating(&mut rr, &mut out), 5);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_full_falls_over_then_rejects() {
        let q = ShardedQueue::<u32>::new(2, 2);
        // Same hint for all four: two land on shard 0, two fall over to
        // shard 1, the fifth finds every shard full.
        for i in 0..4 {
            q.push(0, i).unwrap();
        }
        match q.push(0, 99) {
            Err(PushError::Full(v)) => assert_eq!(v, 99),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn queue_close_rejects_pushes_keeps_buffered() {
        let q = ShardedQueue::<u32>::new(4, 4);
        q.push(1, 10).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.push(1, 11) {
            Err(PushError::Closed(v)) => assert_eq!(v, 11),
            other => panic!("expected Closed, got {other:?}"),
        }
        let mut out = Vec::new();
        let mut rr = 0;
        assert_eq!(q.drain_rotating(&mut rr, &mut out), 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn queue_drain_rotates_start_shard() {
        let q = ShardedQueue::<u32>::new(3, 4);
        q.push(0, 0).unwrap();
        q.push(1, 1).unwrap();
        q.push(2, 2).unwrap();
        let mut out = Vec::new();
        let mut rr = 0;
        q.drain_rotating(&mut rr, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(rr, 1, "start shard must advance");
        out.clear();
        q.push(0, 0).unwrap();
        q.push(1, 1).unwrap();
        q.push(2, 2).unwrap();
        q.drain_rotating(&mut rr, &mut out);
        assert_eq!(out, vec![1, 2, 0], "second drain starts at shard 1");
    }

    #[test]
    fn queue_wakes_parked_consumer_on_push() {
        let q = Arc::new(ShardedQueue::<u64>::new(4, 4));
        let qc = q.clone();
        let h = std::thread::spawn(move || qc.wait_nonempty(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(30)); // let it park
        q.push(3, 42).unwrap();
        assert!(h.join().unwrap(), "consumer must wake on push");
    }

    #[test]
    fn queue_wakes_parked_consumer_on_close() {
        let q = Arc::new(ShardedQueue::<u64>::new(4, 4));
        let qc = q.clone();
        let h = std::thread::spawn(move || qc.wait_nonempty(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert!(h.join().unwrap(), "consumer must wake on close");
    }

    #[test]
    fn queue_wait_times_out_when_idle() {
        let q = ShardedQueue::<u64>::new(2, 2);
        let t0 = Instant::now();
        assert!(!q.wait_nonempty(Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn queue_threaded_producers_nothing_lost() {
        let q = Arc::new(ShardedQueue::<usize>::new(4, 1024));
        let n_threads = 4;
        let per_thread = 5_000;
        let mut producers = Vec::new();
        for t in 0..n_threads {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    let mut v = t * per_thread + i;
                    loop {
                        match q.push(t, v) {
                            Ok(()) => break,
                            Err(PushError::Full(x)) => {
                                v = x;
                                std::thread::yield_now();
                            }
                            Err(PushError::Closed(_)) => panic!("queue closed early"),
                        }
                    }
                }
            }));
        }
        let mut got = Vec::new();
        let mut rr = 0;
        while got.len() < n_threads * per_thread {
            if q.drain_rotating(&mut rr, &mut got) == 0 {
                q.wait_nonempty(Duration::from_millis(5));
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        got.sort_unstable();
        let expect: Vec<usize> = (0..n_threads * per_thread).collect();
        assert_eq!(got, expect, "every pushed value arrives exactly once");
    }

    #[test]
    fn thread_hints_are_stable_per_thread() {
        let a = thread_shard_hint();
        let b = thread_shard_hint();
        assert_eq!(a, b);
        let other = std::thread::spawn(thread_shard_hint).join().unwrap();
        // Different threads *usually* differ; equality would only mean a
        // hash collision, which the queue tolerates. Just sanity-check
        // the call works off the main thread.
        let _ = other;
    }
}
