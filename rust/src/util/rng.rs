//! Deterministic PRNG (splitmix64 + xoshiro256++), no external deps.
//!
//! Used by the property-test runner, the synthetic workload generators and
//! the coordinator's jittered load generator. Not cryptographic.

/// splitmix64 — used to seed the main generator from a single u64.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling to kill modulo bias.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range_u64(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
