//! Tiny declarative CLI argument parser (offline stand-in for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; generates usage text from the declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Declarative command spec.
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Spec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Spec {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = o
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", o.name, o.help));
        }
        s
    }

    /// Parse a token list. Unknown `--options` are errors.
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = o.default {
                out.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n\n{}", self.usage()))?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} is a flag and takes no value");
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?,
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("t", "test")
            .opt("node", "tech node", Some("45"))
            .opt("net", "network", None)
            .flag("csv", "emit csv")
    }

    fn parse(toks: &[&str]) -> anyhow::Result<Args> {
        spec().parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get("node"), Some("45"));
        assert_eq!(a.get("net"), None);
        assert!(!a.flag("csv"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--node", "7", "--net=vgg16"]).unwrap();
        assert_eq!(a.get("node"), Some("7"));
        assert_eq!(a.get("net"), Some("vgg16"));
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["fig8", "--csv"]).unwrap();
        assert!(a.flag("csv"));
        assert_eq!(a.positional, vec!["fig8"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--bogus", "1"]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&["--net"]).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parse(&["--csv=yes"]).is_err());
    }

    #[test]
    fn numeric_getters() {
        let a = parse(&["--node", "32"]).unwrap();
        assert_eq!(a.get_usize("node", 0).unwrap(), 32);
        assert!(a.get_f64("node", 0.0).unwrap() == 32.0);
        let bad = parse(&["--node", "xx"]).unwrap();
        assert!(bad.get_usize("node", 0).is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = spec().usage();
        assert!(u.contains("--node") && u.contains("--csv"));
    }
}
