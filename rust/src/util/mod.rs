//! In-tree mini-frameworks.
//!
//! The build environment is offline and only the `xla` crate's dependency
//! closure is vendored, so the conveniences a crate would normally pull
//! from crates.io live here instead:
//!
//! * [`rng`] — xorshift/splitmix PRNG (deterministic, seedable).
//! * [`prop`] — a property-based test runner with shrinking.
//! * [`cli`] — a small declarative argument parser for the `aimc` binary.
//! * [`table`] — aligned-column text tables + RFC-4180 CSV emission.
//! * [`json`] — dependency-free JSON tree: build/render/parse (the
//!   report layer's `--format json` sink).
//! * [`stats`] — medians/means over layer populations.
//! * [`pool`] — scoped work-stealing thread pool (`par_map` /
//!   `par_for_each`) driving the parallel sweep engine.
//! * [`spsc`] — bounded single-producer/single-consumer channel with a
//!   lock-free fast path (the coordinator's per-worker batch lanes).
//! * [`shard`] — sharded counter + sharded bounded MPSC queue (the
//!   coordinator's ingress shards and admission counter).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod shard;
pub mod spsc;
pub mod stats;
pub mod table;
