//! Bounded single-producer / single-consumer channel with a lock-free
//! fast path.
//!
//! The serving coordinator's per-worker batch lanes need exactly this
//! shape: one dispatcher thread pushing, one worker thread popping,
//! with blocking only when a side would otherwise spin. The ring buffer
//! is wait-free on the hot path (one atomic load + one atomic store per
//! side, no CAS loop); a `Mutex`/`Condvar` pair exists purely so a side
//! can *sleep* — it is touched only when the ring is empty (consumer)
//! or full (producer), never per message under load.
//!
//! SPSC discipline is enforced statically: [`Producer`] and
//! [`Consumer`] are not `Clone`, and every transfer method takes
//! `&mut self`.
//!
//! All atomics use `SeqCst`. The protocol relies on the total order to
//! close the classic lost-wakeup races (publish-then-check-sleepers vs
//! check-empty-then-register-sleeper); the cost is irrelevant next to a
//! batch execution.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Error from [`Producer::try_send`]; the value is handed back.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    Full(T),
    Disconnected(T),
}

/// Error from [`Producer::send_timeout`]; the value is handed back.
#[derive(Debug, PartialEq, Eq)]
pub enum SendTimeoutError<T> {
    Timeout(T),
    Disconnected(T),
}

/// Error from [`Consumer::try_recv`].
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

/// Error from [`Consumer::recv_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

/// Error from [`Consumer::recv`]: producer gone and the ring is empty.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

struct Ring<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Next slot the consumer reads. Monotonic; slot index is `% cap`.
    head: AtomicUsize,
    /// Next slot the producer writes. Monotonic.
    tail: AtomicUsize,
    producer_alive: AtomicBool,
    consumer_alive: AtomicBool,
    /// Threads currently parked (0..=2); the publishing side skips the
    /// mutex entirely while this is 0.
    sleepers: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

// Values move from the producer thread to the consumer thread; head/tail
// hand out exclusive access to disjoint slots.
unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Ring<T> {
    fn wake(&self) {
        if self.sleepers.load(SeqCst) > 0 {
            // Taking the lock orders this notify after the sleeper's
            // registered-but-not-yet-waiting window closes.
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    fn len(&self) -> usize {
        self.tail.load(SeqCst).wrapping_sub(self.head.load(SeqCst))
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // Both handles are gone; drop whatever is still buffered.
        let head = *self.head.get_mut();
        let tail = *self.tail.get_mut();
        let cap = self.buf.len();
        let mut i = head;
        while i != tail {
            unsafe { self.buf[i % cap].get_mut().assume_init_drop() };
            i = i.wrapping_add(1);
        }
    }
}

/// Sending half. Dropping it disconnects: the consumer drains what is
/// buffered, then sees `Disconnected`.
pub struct Producer<T> {
    ring: Arc<Ring<T>>,
}

/// Receiving half. Dropping it disconnects: the producer's next send
/// reports `Disconnected` (already-buffered values are dropped with the
/// ring).
pub struct Consumer<T> {
    ring: Arc<Ring<T>>,
}

/// Create a bounded SPSC channel holding at most `cap` values.
pub fn channel<T: Send>(cap: usize) -> (Producer<T>, Consumer<T>) {
    assert!(cap >= 1, "spsc capacity must be at least 1");
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> = (0..cap)
        .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
        .collect();
    let ring = Arc::new(Ring {
        buf,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        producer_alive: AtomicBool::new(true),
        consumer_alive: AtomicBool::new(true),
        sleepers: AtomicUsize::new(0),
        lock: Mutex::new(()),
        cv: Condvar::new(),
    });
    (Producer { ring: ring.clone() }, Consumer { ring })
}

impl<T> Producer<T> {
    /// Values currently buffered (racy snapshot).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Push without blocking.
    pub fn try_send(&mut self, v: T) -> Result<(), TrySendError<T>> {
        let ring = &*self.ring;
        if !ring.consumer_alive.load(SeqCst) {
            return Err(TrySendError::Disconnected(v));
        }
        let tail = ring.tail.load(SeqCst);
        let head = ring.head.load(SeqCst);
        if tail.wrapping_sub(head) == ring.buf.len() {
            return Err(TrySendError::Full(v));
        }
        unsafe { (*ring.buf[tail % ring.buf.len()].get()).write(v) };
        ring.tail.store(tail.wrapping_add(1), SeqCst);
        ring.wake();
        Ok(())
    }

    /// Push, parking up to `timeout` for the consumer to free a slot.
    pub fn send_timeout(&mut self, v: T, timeout: Duration) -> Result<(), SendTimeoutError<T>> {
        let deadline = Instant::now() + timeout;
        let mut v = v;
        loop {
            match self.try_send(v) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Disconnected(x)) => {
                    return Err(SendTimeoutError::Disconnected(x))
                }
                Err(TrySendError::Full(x)) => v = x,
            }
            let ring = &*self.ring;
            ring.sleepers.fetch_add(1, SeqCst);
            {
                let guard = ring.lock.lock().unwrap();
                // Re-check under the lock: a pop between the failed
                // try_send and registering as a sleeper must not leave
                // us parked with free space.
                let full = ring.len() == ring.buf.len();
                let alive = ring.consumer_alive.load(SeqCst);
                if full && alive {
                    let now = Instant::now();
                    if now >= deadline {
                        ring.sleepers.fetch_sub(1, SeqCst);
                        return Err(SendTimeoutError::Timeout(v));
                    }
                    let _unused = ring.cv.wait_timeout(guard, deadline - now).unwrap();
                }
            }
            ring.sleepers.fetch_sub(1, SeqCst);
            if Instant::now() >= deadline {
                // One last attempt before reporting the timeout.
                return match self.try_send(v) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Disconnected(x)) => {
                        Err(SendTimeoutError::Disconnected(x))
                    }
                    Err(TrySendError::Full(x)) => Err(SendTimeoutError::Timeout(x)),
                };
            }
        }
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.ring.producer_alive.store(false, SeqCst);
        self.ring.wake();
    }
}

impl<T> Consumer<T> {
    /// Values currently buffered (racy snapshot).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pop without blocking.
    pub fn try_recv(&mut self) -> Result<T, TryRecvError> {
        let ring = &*self.ring;
        loop {
            let head = ring.head.load(SeqCst);
            let tail = ring.tail.load(SeqCst);
            if head != tail {
                let v = unsafe { (*ring.buf[head % ring.buf.len()].get()).assume_init_read() };
                ring.head.store(head.wrapping_add(1), SeqCst);
                ring.wake();
                return Ok(v);
            }
            if ring.producer_alive.load(SeqCst) {
                return Err(TryRecvError::Empty);
            }
            // Producer is gone; it may have published right before
            // dying. Its tail store precedes the alive=false store, so
            // one re-read of tail decides.
            if ring.tail.load(SeqCst) == head {
                return Err(TryRecvError::Disconnected);
            }
        }
    }

    /// Pop, parking up to `timeout` for the producer to publish.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.try_recv() {
                Ok(v) => return Ok(v),
                Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => {}
            }
            let ring = &*self.ring;
            ring.sleepers.fetch_add(1, SeqCst);
            {
                let guard = ring.lock.lock().unwrap();
                // Re-check under the lock (mirror of send_timeout).
                let empty = ring.len() == 0;
                let alive = ring.producer_alive.load(SeqCst);
                if empty && alive {
                    let now = Instant::now();
                    if now >= deadline {
                        ring.sleepers.fetch_sub(1, SeqCst);
                        return Err(RecvTimeoutError::Timeout);
                    }
                    let _unused = ring.cv.wait_timeout(guard, deadline - now).unwrap();
                }
            }
            ring.sleepers.fetch_sub(1, SeqCst);
            if Instant::now() >= deadline {
                return match self.try_recv() {
                    Ok(v) => Ok(v),
                    Err(TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
                    Err(TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
                };
            }
        }
    }

    /// Pop, parking until a value arrives or the producer disconnects
    /// (and the ring has drained).
    pub fn recv(&mut self) -> Result<T, RecvError> {
        loop {
            match self.recv_timeout(Duration::from_secs(1)) {
                Ok(v) => return Ok(v),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return Err(RecvError),
            }
        }
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.ring.consumer_alive.store(false, SeqCst);
        self.ring.wake();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn fifo_order_single_thread() {
        let (mut tx, mut rx) = channel::<u32>(4);
        for i in 0..4 {
            tx.try_send(i).unwrap();
        }
        assert_eq!(tx.try_send(99), Err(TrySendError::Full(99)));
        for i in 0..4 {
            assert_eq!(rx.try_recv(), Ok(i));
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
    }

    #[test]
    fn threaded_transfer_through_tiny_ring() {
        let (mut tx, mut rx) = channel::<usize>(2);
        let n = 10_000;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                let mut v = i;
                loop {
                    match tx.send_timeout(v, Duration::from_secs(5)) {
                        Ok(()) => break,
                        Err(SendTimeoutError::Timeout(x)) => v = x,
                        Err(SendTimeoutError::Disconnected(_)) => panic!("consumer died"),
                    }
                }
            }
        });
        for i in 0..n {
            assert_eq!(rx.recv(), Ok(i), "order must be FIFO");
        }
        producer.join().unwrap();
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn producer_drop_drains_then_disconnects() {
        let (mut tx, mut rx) = channel::<u8>(4);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.try_recv(), Ok(2));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn consumer_drop_rejects_sends() {
        let (mut tx, rx) = channel::<u8>(4);
        drop(rx);
        assert_eq!(tx.try_send(7), Err(TrySendError::Disconnected(7)));
        assert!(matches!(
            tx.send_timeout(8, Duration::from_millis(1)),
            Err(SendTimeoutError::Disconnected(8))
        ));
    }

    #[test]
    fn recv_timeout_expires() {
        let (_tx, mut rx) = channel::<u8>(1);
        let t0 = Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(20)),
            Err(RecvTimeoutError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn buffered_values_dropped_with_ring() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Counted;
        impl Drop for Counted {
            fn drop(&mut self) {
                DROPS.fetch_add(1, SeqCst);
            }
        }
        let (mut tx, rx) = channel::<Counted>(4);
        tx.try_send(Counted).unwrap();
        tx.try_send(Counted).unwrap();
        tx.try_send(Counted).unwrap();
        drop(rx);
        drop(tx);
        assert_eq!(DROPS.load(SeqCst), 3, "ring drop must release values");
    }

    #[test]
    fn wakes_parked_consumer() {
        let (mut tx, mut rx) = channel::<u64>(1);
        let h = std::thread::spawn(move || rx.recv_timeout(Duration::from_secs(10)));
        std::thread::sleep(Duration::from_millis(30)); // let it park
        tx.try_send(42).unwrap();
        assert_eq!(h.join().unwrap(), Ok(42));
    }
}
