//! Aligned text tables + CSV output for the report generators.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format a number in engineering style, e.g. `1.6e+07` like the paper.
pub fn sci(v: f64) -> String {
    format!("{:.1e}", v)
}

/// Format picojoules with 3 significant digits.
pub fn pj(joules: f64) -> String {
    format!("{:.3}", joules * 1e12)
}

/// Format TOPS/W.
pub fn tops(ops_per_joule: f64) -> String {
    format!("{:.3}", ops_per_joule / 1e12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // All data lines are equally wide.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(1.6e7), "1.6e7".to_string().replace("e7", "e7"));
        assert!(sci(1.6e7).starts_with("1.6e"));
    }

    #[test]
    fn pj_formats() {
        assert_eq!(pj(4.3e-12), "4.300");
    }
}
