//! Aligned text tables + CSV output for the report generators.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV: header line then data lines, RFC-4180 escaping
    /// (cells containing commas, quotes, CR or LF are quoted; embedded
    /// quotes doubled). The title is deliberately NOT emitted — CSV has
    /// no comment syntax, and a bare title line (figure titles contain
    /// commas: "Fig. 8 — systolic array, YOLOv3 @ 1000 px") would parse
    /// as a ragged data record. Sinks that need the title carry it out
    /// of band (the JSON sink embeds it as a field).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| csv_escape(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// RFC-4180 field escaping: quote when the cell contains a comma, a
/// quote, or a line break; double embedded quotes.
pub fn csv_escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') || cell.contains('\r') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Format a number in engineering style, e.g. `1.6e+07` like the paper.
pub fn sci(v: f64) -> String {
    format!("{:.1e}", v)
}

/// Format picojoules with 3 significant digits.
pub fn pj(joules: f64) -> String {
    format!("{:.3}", joules * 1e12)
}

/// Format TOPS/W.
pub fn tops(ops_per_joule: f64) -> String {
    format!("{:.3}", ops_per_joule / 1e12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        // All data lines are equally wide.
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    /// Minimal RFC-4180 reader for the round-trip regression below:
    /// splits records on unquoted newlines, fields on unquoted commas,
    /// undoubles quotes.
    fn csv_parse(text: &str) -> Vec<Vec<String>> {
        let mut records = vec![vec![String::new()]];
        let mut quoted = false;
        let mut chars = text.chars().peekable();
        while let Some(ch) = chars.next() {
            let rec = records.last_mut().unwrap();
            if quoted {
                match ch {
                    '"' if chars.peek() == Some(&'"') => {
                        chars.next();
                        rec.last_mut().unwrap().push('"');
                    }
                    '"' => quoted = false,
                    c => rec.last_mut().unwrap().push(c),
                }
            } else {
                match ch {
                    '"' => quoted = true,
                    ',' => rec.push(String::new()),
                    '\n' => records.push(vec![String::new()]),
                    c => rec.last_mut().unwrap().push(c),
                }
            }
        }
        assert!(!quoted, "unterminated quoted field");
        // Trailing newline leaves one empty record behind.
        if records.last().map(|r| r == &[String::new()]) == Some(true) {
            records.pop();
        }
        records
    }

    #[test]
    fn csv_round_trips_commas_quotes_and_newlines() {
        // Regression for the report-title case: a comma-laden title must
        // never leak into the CSV body, and comma/quote/newline cells
        // must survive an RFC-4180 read-back bit-for-bit.
        let mut t = Table::new(
            "Fig. 8 — systolic array, YOLOv3 @ 1000 px",
            &["network, resolution", "eta \"best\"", "note"],
        );
        t.row(vec![
            "YOLOv3, 1 Mpx".into(),
            "3.141".into(),
            "line1\nline2".into(),
        ]);
        t.row(vec!["plain".into(), "2".into(), "says \"hi\"".into()]);
        let csv = t.to_csv();
        // The title appears nowhere in the emitted CSV.
        assert!(!csv.contains("Fig. 8"));
        let parsed = csv_parse(&csv);
        assert_eq!(parsed.len(), 3, "header + 2 records: {parsed:?}");
        assert_eq!(
            parsed[0],
            vec!["network, resolution", "eta \"best\"", "note"]
        );
        assert_eq!(parsed[1], vec!["YOLOv3, 1 Mpx", "3.141", "line1\nline2"]);
        assert_eq!(parsed[2], vec!["plain", "2", "says \"hi\""]);
    }

    #[test]
    fn csv_escape_cases() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("a\nb"), "\"a\nb\"");
        assert_eq!(csv_escape("a\rb"), "\"a\rb\"");
    }

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(sci(1.6e7), "1.6e7".to_string().replace("e7", "e7"));
        assert!(sci(1.6e7).starts_with("1.6e"));
    }

    #[test]
    fn pj_formats() {
        assert_eq!(pj(4.3e-12), "4.300");
    }
}
