//! Small statistics helpers used by the network-zoo summaries (Table I-III
//! report *medians* over a network's conv layers) and the dense
//! least-squares solver behind [`crate::energy::surrogate`]'s fitted
//! energy models.

/// Median of a slice (average of the two central elements for even length).
/// Returns 0.0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Maximum (0.0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Geometric mean (0.0 for empty; requires positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Ridge term applied to the equilibrated normal-equation diagonal when
/// the plain solve is rank-deficient. Small enough that a consistent
/// system is still reproduced to ~1e-10 relative.
const RIDGE: f64 = 1e-10;

/// Pivot threshold for the equilibrated (unit-diagonal-scale) normal
/// matrix below which a column is treated as numerically dependent.
const PIVOT_EPS: f64 = 1e-12;

/// Solve the linear least-squares problem `min ‖A·x − b‖₂` (rows of `a`
/// are observations) and return the coefficient vector.
///
/// Strategy: equilibrate columns to unit RMS so the tolerances are
/// scale-free, form the normal equations `AᵀA·x = Aᵀb`, and solve by
/// Gaussian elimination with partial pivoting. A (near-)rank-deficient
/// system — collinear features are routine when a surrogate family has
/// few distinct layer shapes — is retried with a tiny ridge term on the
/// equilibrated diagonal, which picks a small-coefficient solution among
/// the equivalent minimizers instead of failing.
///
/// Returns `None` for empty/ragged input, non-finite values, or when
/// even the ridge-regularized system is numerically singular.
pub fn least_squares(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let m = a.len();
    if m == 0 || m != b.len() {
        return None;
    }
    let k = a[0].len();
    if k == 0 || a.iter().any(|row| row.len() != k) {
        return None;
    }
    if a.iter().flatten().chain(b.iter()).any(|v| !v.is_finite()) {
        return None;
    }

    // Column equilibration: unit-RMS columns. An all-zero column keeps
    // scale 1 and falls out of the solve with coefficient 0 (via ridge).
    let mut scale = vec![0.0f64; k];
    for row in a {
        for (s, v) in scale.iter_mut().zip(row) {
            *s += v * v;
        }
    }
    for s in scale.iter_mut() {
        *s = (*s / m as f64).sqrt();
        if *s == 0.0 {
            *s = 1.0;
        }
    }

    // Normal equations on the equilibrated system, divided by the row
    // count so a well-conditioned system has an O(1) diagonal.
    let mut g = vec![vec![0.0f64; k]; k];
    let mut c = vec![0.0f64; k];
    for (row, &y) in a.iter().zip(b) {
        for i in 0..k {
            let ai = row[i] / scale[i];
            c[i] += ai * y / m as f64;
            for j in i..k {
                g[i][j] += ai * (row[j] / scale[j]) / m as f64;
            }
        }
    }
    for i in 0..k {
        for j in 0..i {
            g[i][j] = g[j][i];
        }
    }

    let solved = solve_dense(g.clone(), c.clone()).or_else(|| {
        let mut ridged = g;
        for (i, row) in ridged.iter_mut().enumerate() {
            row[i] += RIDGE;
        }
        solve_dense(ridged, c)
    })?;
    let x: Vec<f64> = solved.iter().zip(&scale).map(|(v, s)| v / s).collect();
    x.iter().all(|v| v.is_finite()).then_some(x)
}

/// Gaussian elimination with partial pivoting on a small dense system.
/// `None` when a pivot falls under [`PIVOT_EPS`].
fn solve_dense(mut g: Vec<Vec<f64>>, mut c: Vec<f64>) -> Option<Vec<f64>> {
    let k = c.len();
    for col in 0..k {
        let mut piv = col;
        for r in col + 1..k {
            if g[r][col].abs() > g[piv][col].abs() {
                piv = r;
            }
        }
        let pval = g[piv][col].abs();
        if pval.is_nan() || pval < PIVOT_EPS {
            return None;
        }
        g.swap(col, piv);
        c.swap(col, piv);
        let prow = g[col].clone();
        let pc = c[col];
        for row in col + 1..k {
            let f = g[row][col] / prow[col];
            if f == 0.0 {
                continue;
            }
            for (target, p) in g[row].iter_mut().zip(&prow).skip(col) {
                *target -= f * p;
            }
            c[row] -= f * pc;
        }
    }
    let mut x = vec![0.0; k];
    for col in (0..k).rev() {
        let mut v = c[col];
        for j in col + 1..k {
            v -= g[col][j] * x[j];
        }
        x[col] = v / g[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn median_empty() {
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn median_single() {
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn max_basic() {
        assert_eq!(max(&[1.0, 5.0, 3.0]), 5.0);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn least_squares_recovers_exact_coefficients() {
        // Quadratic through 6 points: unique minimizer, zero residual.
        let truth = [2.0, -3.0, 0.5];
        let a: Vec<Vec<f64>> = (0..6)
            .map(|i| {
                let x = i as f64;
                vec![1.0, x, x * x]
            })
            .collect();
        let b: Vec<f64> = a
            .iter()
            .map(|r| r.iter().zip(&truth).map(|(f, c)| f * c).sum())
            .collect();
        let x = least_squares(&a, &b).unwrap();
        for (got, want) in x.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-9, "{x:?} vs {truth:?}");
        }
    }

    #[test]
    fn least_squares_overdetermined_minimizes() {
        // y = 3x with one perturbed observation: slope stays near 3 and
        // beats the perturbed naive estimate in residual.
        let a: Vec<Vec<f64>> = (1..=5).map(|i| vec![i as f64]).collect();
        let b = [3.0, 6.0, 9.6, 12.0, 15.0];
        let x = least_squares(&a, &b).unwrap();
        assert!((x[0] - 3.0).abs() < 0.05, "slope {}", x[0]);
    }

    #[test]
    fn least_squares_rank_deficient_still_fits() {
        // Duplicate column: infinitely many exact solutions; the ridge
        // fallback must return one that reproduces the targets.
        let a: Vec<Vec<f64>> = (1..=4)
            .map(|i| vec![i as f64, 2.0 * i as f64])
            .collect();
        let b: Vec<f64> = (1..=4).map(|i| 5.0 * i as f64).collect();
        let x = least_squares(&a, &b).unwrap();
        for (row, want) in a.iter().zip(&b) {
            let got: f64 = row.iter().zip(&x).map(|(f, c)| f * c).sum();
            assert!((got - want).abs() / want < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn least_squares_zero_column_is_inert() {
        let a: Vec<Vec<f64>> = (1..=4).map(|i| vec![i as f64, 0.0]).collect();
        let b: Vec<f64> = (1..=4).map(|i| 7.0 * i as f64).collect();
        let x = least_squares(&a, &b).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-6);
        assert!(x[1].abs() < 1e-6);
    }

    #[test]
    fn least_squares_rejects_bad_input() {
        assert!(least_squares(&[], &[]).is_none());
        assert!(least_squares(&[vec![1.0]], &[1.0, 2.0]).is_none());
        assert!(least_squares(&[vec![1.0], vec![1.0, 2.0]], &[1.0, 2.0]).is_none());
        assert!(least_squares(&[vec![f64::NAN]], &[1.0]).is_none());
        assert!(least_squares(&[vec![1.0]], &[f64::INFINITY]).is_none());
    }
}
