//! Small statistics helpers used by the network-zoo summaries (Table I-III
//! report *medians* over a network's conv layers).

/// Median of a slice (average of the two central elements for even length).
/// Returns 0.0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Maximum (0.0 for empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Geometric mean (0.0 for empty; requires positive entries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn median_empty() {
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn median_single() {
        assert_eq!(median(&[7.0]), 7.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn max_basic() {
        assert_eq!(max(&[1.0, 5.0, 3.0]), 5.0);
    }

    #[test]
    fn geomean_powers() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}
