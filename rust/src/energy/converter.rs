//! ADC/DAC energy — eqs. (A3)/(A4), the 2^{2B} thermal-noise laws.
//!
//! Distinguishing the levels of a B-bit converter against thermal noise
//! costs energy exponential in precision: e = γ·kT·2^{2B}. The paper's
//! calibrations: γ_adc ≈ 927 (45 nm, from Jonsson's empirical survey),
//! γ_dac ≈ 39 (current-steering DAC), with thermal floors γ_adc > 3.

use super::constants::KT;

/// eq. (A3): ADC energy per sample at calibration.
pub fn adc_energy(gamma_adc: f64, bits: u32) -> f64 {
    gamma_adc * KT * 2f64.powi(2 * bits as i32)
}

/// eq. (A4): DAC circuit energy per sample at calibration (load excluded —
/// see [`super::load`] and eq. (A5)).
pub fn dac_energy(gamma_dac: f64, bits: u32) -> f64 {
    gamma_dac * KT * 2f64.powi(2 * bits as i32)
}

/// Thermal-noise lower bound on any linear-step ADC (γ = 3).
pub fn adc_thermal_floor(bits: u32) -> f64 {
    3.0 * KT * 2f64.powi(2 * bits as i32)
}

/// eq. (A5): full DAC sample cost driving a physical load.
pub fn dac_with_load(gamma_dac: f64, bits: u32, e_load: f64) -> f64 {
    dac_energy(gamma_dac, bits) + e_load
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::constants::{GAMMA_ADC_45NM, GAMMA_DAC};

    #[test]
    fn table_iv_adc() {
        let e = adc_energy(GAMMA_ADC_45NM, 8);
        assert!((e * 1e12 - 0.25).abs() < 0.01, "{} pJ", e * 1e12);
    }

    #[test]
    fn table_iv_dac() {
        let e = dac_energy(GAMMA_DAC, 8);
        assert!((e * 1e12 - 0.0106).abs() < 0.001, "{} pJ", e * 1e12);
    }

    #[test]
    fn exponential_in_bits() {
        let r = adc_energy(GAMMA_ADC_45NM, 10) / adc_energy(GAMMA_ADC_45NM, 8);
        assert!((r - 16.0).abs() < 1e-9, "2 extra bits = 16×");
    }

    #[test]
    fn floor_below_real() {
        assert!(adc_thermal_floor(8) < adc_energy(GAMMA_ADC_45NM, 8));
        let headroom = GAMMA_ADC_45NM / 3.0;
        assert!(headroom > 100.0, "survey says ~300× above floor");
    }

    #[test]
    fn load_adds() {
        let base = dac_energy(GAMMA_DAC, 8);
        assert!((dac_with_load(GAMMA_DAC, 8, 1e-13) - base - 1e-13).abs() < 1e-20);
    }
}
