//! Appendix-A energy parameter models.
//!
//! Everything here is expressed in SI units (joules, meters, volts, farads)
//! with `f64` precision; the report layer converts to pJ for display.
//!
//! The module reproduces every energy law the paper uses:
//!
//! | paper | here |
//! |---|---|
//! | eq. (A1) MAC gate model | [`logic::mac_energy`] |
//! | eq. (A2) SRAM √size law | [`sram::Sram`] |
//! | eq. (A3) ADC 2^2B law | [`converter::adc_energy`] |
//! | eq. (A4)/(A5) DAC + load | [`converter::dac_energy`], [`load`] |
//! | eq. (A6) line-capacitance load | [`load::line_energy`] |
//! | eq. (A8) shot-noise laser floor | [`optical::optical_energy`] |
//! | eqs. (A9)–(A13) ReRAM array | [`reram`] |
//! | Table IV / Table VII constants | [`constants`] |
//!
//! [`surrogate`] sits on top: closed-form energy models fitted from the
//! cycle simulators' outputs, so the serving path can price inferences
//! without a simulator in the hot loop.

pub mod constants;
pub mod converter;
pub mod load;
pub mod logic;
pub mod optical;
pub mod reram;
pub mod sram;
pub mod surrogate;

pub use constants::*;

/// Bundle of the per-operation energies a processor model consumes,
/// evaluated at one technology node and bit precision.
///
/// Produced by [`EnergyParams::at_node`]; every analytic model and both
/// cycle-accurate simulators read from this struct only, so a single
/// source of truth feeds Tables IV/V and Figures 6–10.
#[derive(Clone, Copy, Debug)]
pub struct OpEnergies {
    /// Technology node in nm this was evaluated at.
    pub node_nm: f64,
    /// Bit precision.
    pub bits: u32,
    /// Digital MAC (multiply + accumulate counted as the fused op), J.
    pub e_mac: f64,
    /// ADC conversion (per sample), J.
    pub e_adc: f64,
    /// DAC conversion circuit energy (per sample, excl. load), J.
    pub e_dac: f64,
    /// Laser energy per measured pixel (shot-noise floor, node-independent), J.
    pub e_opt: f64,
}

/// Technology-independent description of the converter/logic stack;
/// evaluate with [`EnergyParams::at_node`] to get node-scaled numbers.
#[derive(Clone, Copy, Debug)]
pub struct EnergyParams {
    pub bits: u32,
    /// Dimensionless γ for the MAC model (paper: 1.225e5 at 45 nm).
    pub gamma_mac: f64,
    /// Dimensionless γ for ADCs (paper Table IV uses 927 at 45 nm).
    pub gamma_adc: f64,
    /// Dimensionless γ for DACs (paper: 39).
    pub gamma_dac: f64,
    /// Optical system efficiency (0..1], paper: 0.8 for Table IV.
    pub eta_opt: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            bits: 8,
            gamma_mac: constants::GAMMA_MAC_45NM,
            gamma_adc: constants::GAMMA_ADC_45NM,
            gamma_dac: constants::GAMMA_DAC,
            eta_opt: constants::ETA_OPT,
        }
    }
}

/// Per-op energies at a full [`crate::simulator::OperatingPoint`]:
/// mixed activation (Bx) / weight (Bw) precision resolved per circuit.
/// Samples crossing a converter carry the activation width; weight
/// writes carry the weight width; the digital MAC is Bx × Bw.
///
/// At the default 8×8 point every field is **bit-identical** to the
/// corresponding [`OpEnergies`] field from [`EnergyParams::at_node`] —
/// the simulators rely on this for the golden-output contract.
#[derive(Clone, Copy, Debug)]
pub struct MixedOpEnergies {
    /// Technology node in nm this was evaluated at.
    pub node_nm: f64,
    /// Activation bit width.
    pub bits_x: u32,
    /// Weight bit width.
    pub bits_w: u32,
    /// Digital Bx × Bw MAC, J.
    pub e_mac: f64,
    /// ADC conversion of one output sample (activation width), J.
    pub e_adc: f64,
    /// DAC conversion of one activation sample (excl. load), J.
    pub e_dac_x: f64,
    /// DAC conversion of one weight sample (excl. load), J.
    pub e_dac_w: f64,
    /// Laser energy per measured pixel (shot-noise floor at the
    /// activation/output width; node-independent), J.
    pub e_opt: f64,
}

impl EnergyParams {
    /// Evaluate all CMOS energies at a technology node (nm). CMOS terms are
    /// scaled from their 45 nm calibration by [`crate::technode::scale`];
    /// the laser term is physics-bound and does not scale with node.
    pub fn at_node(&self, node_nm: f64) -> OpEnergies {
        let s = crate::technode::scale_from_45nm(node_nm);
        OpEnergies {
            node_nm,
            bits: self.bits,
            e_mac: logic::mac_energy(self.gamma_mac, self.bits) * s,
            e_adc: converter::adc_energy(self.gamma_adc, self.bits) * s,
            e_dac: converter::dac_energy(self.gamma_dac, self.bits) * s,
            e_opt: optical::optical_energy(self.eta_opt, self.bits),
        }
    }

    /// Evaluate all energies at a full operating point (node + mixed
    /// precision). `self.bits` is ignored — the operating point's
    /// widths govern. The γ calibrations and node scaling are shared
    /// with [`EnergyParams::at_node`], so at 8×8 the two agree bit for
    /// bit (pinned by `at_op_default_matches_at_node` below).
    pub fn at_op(&self, op: &crate::simulator::OperatingPoint) -> MixedOpEnergies {
        let s = crate::technode::scale_from_45nm(op.node_nm);
        MixedOpEnergies {
            node_nm: op.node_nm,
            bits_x: op.bits_x,
            bits_w: op.bits_w,
            e_mac: logic::mac_energy_xw(self.gamma_mac, op.bits_x, op.bits_w) * s,
            e_adc: converter::adc_energy(self.gamma_adc, op.bits_x) * s,
            e_dac_x: converter::dac_energy(self.gamma_dac, op.bits_x) * s,
            e_dac_w: converter::dac_energy(self.gamma_dac, op.bits_w) * s,
            e_opt: optical::optical_energy(self.eta_opt, op.bits_x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iv_at_45nm() {
        // Reproduce Table IV: e_mac 0.23 pJ, e_adc 0.25 pJ, e_dac 0.01 pJ,
        // e_opt 0.01 pJ (all 8-bit, 45 nm).
        let e = EnergyParams::default().at_node(45.0);
        assert!((e.e_mac * 1e12 - 0.23).abs() < 0.01, "e_mac {}", e.e_mac * 1e12);
        assert!((e.e_adc * 1e12 - 0.25).abs() < 0.01, "e_adc {}", e.e_adc * 1e12);
        assert!((e.e_dac * 1e12 - 0.01).abs() < 0.005, "e_dac {}", e.e_dac * 1e12);
        assert!((e.e_opt * 1e12 - 0.01).abs() < 0.005, "e_opt {}", e.e_opt * 1e12);
    }

    #[test]
    fn smaller_node_cheaper_cmos_same_laser() {
        let p = EnergyParams::default();
        let e45 = p.at_node(45.0);
        let e7 = p.at_node(7.0);
        assert!(e7.e_mac < e45.e_mac);
        assert!(e7.e_adc < e45.e_adc);
        assert_eq!(e7.e_opt, e45.e_opt, "laser floor is node-independent");
    }

    #[test]
    fn at_op_default_matches_at_node() {
        // The keystone of the OperatingPoint refactor: at the default
        // 8×8 precision, the mixed-precision evaluation is bit-identical
        // to the legacy single-width one at every node.
        use crate::simulator::OperatingPoint;
        let p = EnergyParams::default();
        for node in crate::technode::NODES {
            let nm = node.nm;
            let legacy = p.at_node(nm);
            let mixed = p.at_op(&OperatingPoint::node(nm));
            assert_eq!(mixed.e_mac.to_bits(), legacy.e_mac.to_bits(), "e_mac @{nm}");
            assert_eq!(mixed.e_adc.to_bits(), legacy.e_adc.to_bits(), "e_adc @{nm}");
            assert_eq!(mixed.e_dac_x.to_bits(), legacy.e_dac.to_bits(), "e_dac_x @{nm}");
            assert_eq!(mixed.e_dac_w.to_bits(), legacy.e_dac.to_bits(), "e_dac_w @{nm}");
            assert_eq!(mixed.e_opt.to_bits(), legacy.e_opt.to_bits(), "e_opt @{nm}");
        }
    }

    #[test]
    fn at_op_resolves_mixed_widths_per_circuit() {
        use crate::simulator::OperatingPoint;
        let p = EnergyParams::default();
        let e = p.at_op(&OperatingPoint::node(45.0).bits(8, 4));
        // ADC / activation DAC / laser follow the 8-bit activations...
        let e8 = p.at_node(45.0);
        assert_eq!(e.e_adc.to_bits(), e8.e_adc.to_bits());
        assert_eq!(e.e_dac_x.to_bits(), e8.e_dac.to_bits());
        assert_eq!(e.e_opt.to_bits(), e8.e_opt.to_bits());
        // ...the weight DAC follows the 4-bit weights (2^2B law → 256×)...
        assert!(e.e_dac_w < e.e_dac_x / 100.0);
        // ...and the MAC sits between the 4-bit and 8-bit symmetric MACs.
        let lo = EnergyParams { bits: 4, ..p }.at_node(45.0);
        assert!(e.e_mac > lo.e_mac && e.e_mac < e8.e_mac);
    }

    #[test]
    fn more_bits_more_energy() {
        let lo = EnergyParams {
            bits: 4,
            ..Default::default()
        }
        .at_node(45.0);
        let hi = EnergyParams {
            bits: 12,
            ..Default::default()
        }
        .at_node(45.0);
        assert!(hi.e_adc > lo.e_adc * 100.0, "ADC is exponential in B");
        assert!(hi.e_mac > lo.e_mac, "MAC is quadratic in B");
    }
}
