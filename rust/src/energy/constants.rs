//! Physical constants and the paper's calibration values
//! (Tables IV, VI, VII; Appendix A).

/// Boltzmann constant × 300 K, in joules. The paper's γ constants are
/// quoted against kT at room temperature.
pub const KT: f64 = 1.380_649e-23 * 300.0;

/// Reduced Planck constant, J·s.
pub const HBAR: f64 = 1.054_571_817e-34;

/// Speed of light, m/s.
pub const C_LIGHT: f64 = 2.997_924_58e8;

/// Quantum of conductance 2e²/h, in siemens (Appendix A2).
pub const G0: f64 = 7.748_091_729e-5;

/// Supply voltage the paper calibrates everything at (45 nm node).
pub const VDD_45NM: f64 = 0.9;

/// Copper trace capacitance per unit length, F/m (paper: ~0.2 fF/µm).
pub const TRACE_CAP_PER_M: f64 = 0.2e-15 / 1e-6;

/// γ_mac at 45 nm (paper Table VII: 1.2e5; Horowitz-calibrated 122 500).
pub const GAMMA_MAC_45NM: f64 = 122_500.0;

/// γ_adc at 45 nm. NOTE: the paper is internally inconsistent here —
/// Table VII lists 583 but Table IV's e_adc = 0.25 pJ together with the
/// text ("1404 for a 65-nm process, which scales to about 927 at 45 nm")
/// implies 927; we use 927 so Table IV reproduces exactly.
pub const GAMMA_ADC_45NM: f64 = 927.0;

/// γ_adc as printed in Table VII (kept for reference/comparison output).
pub const GAMMA_ADC_TABLE_VII: f64 = 583.0;

/// γ_dac (paper: 39, from a 130 nm current-steering DAC; treated as
/// node-scalable like the other CMOS terms).
pub const GAMMA_DAC: f64 = 39.0;

/// Optical system efficiency assumed for Table IV's e_opt = 0.01 pJ.
pub const ETA_OPT: f64 = 0.8;

/// Laser wavelength, m (1550 nm telecom band).
pub const LAMBDA: f64 = 1550e-9;

/// γ_m: SRAM single-bit-cell Landauer ratio (Appendix A: ~3e6 at 45 nm),
/// equivalent to e_m0 ≈ 5 fJ.
pub const GAMMA_M: f64 = 3.0e6;

/// SRAM per-access energy constant e_m0 (eq. A2), joules. Calibrated so an
/// 8 KB bank costs 1.25 pJ/byte at 45 nm: e_m0·√(8192·8 bits) = 1.25 pJ.
pub const E_M0_45NM: f64 = 1.25e-12 / 256.0; // ≈ 4.88 fJ

/// Horowitz reference: SRAM read/write energy per byte of an 8 KB bank
/// at 45 nm, 0.9 V.
pub const SRAM_8KB_PJ_PER_BYTE: f64 = 1.25e-12;

/// Reference 8 KB bank size in bytes.
pub const SRAM_REF_BYTES: f64 = 8.0 * 1024.0;

// ---------------------------------------------------------------- pitches

/// Table VI: active ReRAM cell pitch (m). (Paper: 1–4 µm; we use 4 µm,
/// the value Table IV's 0.08 pJ load row assumes.)
pub const PITCH_RERAM: f64 = 4e-6;

/// Table VI: thermo-optic / MEMS SLM pitch for planar photonics (m).
pub const PITCH_PHOTONIC: f64 = 250e-6;

/// Table VI: optical Mach-Zehnder interferometer pitch (m).
pub const PITCH_MZI: f64 = 100e-6;

/// SLM / metasurface pixel pitch for the optical 4F system (m).
pub const PITCH_SLM: f64 = 2.5e-6;

// ------------------------------------------------------ machine geometry

/// Systolic array dimension (TPUv1-like 256×256).
pub const SYSTOLIC_DIM: usize = 256;

/// Total on-chip SRAM of every modeled accelerator (24 MiB, TPUv1-like).
pub const TOTAL_SRAM_BYTES: usize = 24 * 1024 * 1024;

/// Photonic array dimension (40×40 typical of published processors).
pub const PHOTONIC_DIM: usize = 40;

/// SLM pixel count of the optical 4F machine (4 Mpx = 2048×2048).
pub const SLM_PIXELS: usize = 2048 * 2048;

/// SLM side length in pixels.
pub const SLM_SIDE: usize = 2048;

/// Electro-optic modulator energy per sample assumed for the *future*
/// silicon-photonic projection (paper §VI: "we assume in our model that
/// this will be improved to 0.5 pJ over time").
pub const E_EO_MODULATOR_FUTURE: f64 = 0.5e-12;

/// State-of-the-art electro-optic modulator energy (paper §A1: ~7 pJ/byte
/// for carrier-dispersion micro-rings).
pub const E_EO_MODULATOR_TODAY: f64 = 7e-12;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kt_room_temperature() {
        assert!((KT - 4.14e-21).abs() / KT < 0.01);
    }

    #[test]
    fn e_m0_is_about_5_fj() {
        assert!((E_M0_45NM - 4.88e-15).abs() < 0.1e-15);
    }

    #[test]
    fn gamma_m_consistent_with_e_m0() {
        // Appendix A: e_m0 = γ_m·kT ⇒ γ_m ≈ 1.2e6…3e6 order of magnitude.
        let gamma = E_M0_45NM / KT;
        assert!(gamma > 5e5 && gamma < 5e6, "γ_m = {gamma}");
    }

    #[test]
    fn photon_energy_1550nm() {
        let omega = 2.0 * std::f64::consts::PI * C_LIGHT / LAMBDA;
        let e_photon = HBAR * omega;
        assert!((e_photon - 1.28e-19).abs() / e_photon < 0.01);
    }
}
