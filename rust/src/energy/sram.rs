//! SRAM access energy — eq. (A2), the √size law.
//!
//! Energy per access scales with the bit/word-line lengths, i.e. with the
//! square root of the bank size: e_m = e_m0 √N_bits. Calibrated against
//! Horowitz's 1.25 pJ/byte for an 8 KB bank at 45 nm; the paper's 96 KB
//! TPU bank then costs 1.25·√(96/8) = 4.33 pJ/byte (Table IV's 4.3 pJ).

use super::constants::{SRAM_8KB_PJ_PER_BYTE, SRAM_REF_BYTES};

/// An SRAM bank model at a given technology node.
#[derive(Clone, Copy, Debug)]
pub struct Sram {
    /// Bank size in bytes.
    pub bank_bytes: usize,
    /// Energy per byte accessed (read or write), joules, node-scaled.
    pub energy_per_byte: f64,
}

impl Sram {
    /// Bank of `bank_bytes` at 45 nm calibration.
    pub fn new_45nm(bank_bytes: usize) -> Self {
        Sram {
            bank_bytes,
            energy_per_byte: energy_per_byte_45nm(bank_bytes),
        }
    }

    /// Bank scaled to a technology node.
    pub fn at_node(bank_bytes: usize, node_nm: f64) -> Self {
        let s = crate::technode::scale_from_45nm(node_nm);
        Sram {
            bank_bytes,
            energy_per_byte: energy_per_byte_45nm(bank_bytes) * s,
        }
    }

    /// Energy to read or write `bytes` bytes.
    pub fn access(&self, bytes: f64) -> f64 {
        bytes * self.energy_per_byte
    }
}

/// eq. (A2): per-byte access energy of a bank, at the 45 nm calibration.
pub fn energy_per_byte_45nm(bank_bytes: usize) -> f64 {
    SRAM_8KB_PJ_PER_BYTE * (bank_bytes as f64 / SRAM_REF_BYTES).sqrt()
}

/// Partition a total SRAM capacity into equal banks (the paper mirrors the
/// TPU floorplan: 24 MiB split across one bank per array port).
pub fn bank_bytes(total_bytes: usize, num_banks: usize) -> usize {
    assert!(num_banks > 0);
    total_bytes / num_banks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::constants::TOTAL_SRAM_BYTES;

    #[test]
    fn table_iv_96kb_bank() {
        // Table IV: 4.3 pJ for the 96 KB TPU bank.
        let e = energy_per_byte_45nm(96 * 1024);
        assert!((e * 1e12 - 4.33).abs() < 0.05, "{} pJ", e * 1e12);
    }

    #[test]
    fn calibration_point() {
        let e = energy_per_byte_45nm(8 * 1024);
        assert!((e * 1e12 - 1.25).abs() < 1e-9);
    }

    #[test]
    fn paper_12kb_slm_bank() {
        // §VII.B: 24 MiB / 2048 = 12 KB banks → 1.55 pJ/byte… the paper
        // says 1.55; √(12/8)·1.25 = 1.53. Accept the computed value.
        let bank = bank_bytes(TOTAL_SRAM_BYTES, 2048);
        assert_eq!(bank, 12 * 1024);
        let e = energy_per_byte_45nm(bank);
        assert!((e * 1e12 - 1.53).abs() < 0.03, "{} pJ", e * 1e12);
    }

    #[test]
    fn paper_600kb_photonic_bank() {
        // §VI: 24 MiB over 40 banks ≈ 600 KB → √(600/8)·1.25 ≈ 10.8 pJ.
        let bank = bank_bytes(TOTAL_SRAM_BYTES, 40);
        let e = energy_per_byte_45nm(bank);
        assert!((e * 1e12 - 10.8).abs() < 0.4, "{} pJ", e * 1e12);
    }

    #[test]
    fn sqrt_scaling() {
        let e1 = energy_per_byte_45nm(16 * 1024);
        let e2 = energy_per_byte_45nm(64 * 1024);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tile_register_energy_31fj() {
        // §VII.A: scaling the 8 KB bank down to a 5-byte accumulator word
        // gives 1.25 pJ·√(5/8192) ≈ 31 fJ/byte.
        let e = energy_per_byte_45nm(5);
        assert!((e * 1e15 - 30.9).abs() < 1.0, "{} fJ", e * 1e15);
    }

    #[test]
    fn access_is_linear_in_bytes() {
        let s = Sram::new_45nm(8 * 1024);
        assert!((s.access(10.0) - 10.0 * s.energy_per_byte).abs() < 1e-30);
    }

    #[test]
    fn node_scaling_applies() {
        let a = Sram::at_node(96 * 1024, 45.0);
        let b = Sram::at_node(96 * 1024, 7.0);
        assert!(b.energy_per_byte < a.energy_per_byte);
    }
}
