//! Surrogate energy-pricing models fitted from cycle-accurate sweeps.
//!
//! The serving path used to put a cycle-accurate co-simulation in the
//! hot loop to price batches. This module replaces that with the LASANA
//! recipe: run the slow simulators once over a training grid (through
//! [`SweepCache`], so sweep results are reused), fit a cheap closed-form
//! model per **machine × operating point × layer-shape family**, and
//! serve every later pricing query as a handful of multiply-adds.
//!
//! The models are *linear* in per-machine shape features. That is not an
//! approximation of convenience: for a fixed machine config and
//! operating point, each cycle simulator's per-layer energy is an exact
//! linear combination of features computable from the layer shape alone
//! (MAC count, Toeplitz/tile traffic terms, converter counts — see
//! [`MachineKind::features`]), so a least-squares fit over a
//! representative corpus recovers the simulator's own coefficients and
//! crossval error sits at floating-point noise, far inside the ≤7%
//! bound the evaluation scenario enforces. Precision and noise enter the
//! key, not the features: the features stay shape-only, and each fitted
//! coefficient vector absorbs the (bits, noise)-dependent energy scale
//! of its own operating point — so the exact-span argument (and the 7%
//! bound) holds at every precision. Fits are solved with
//! [`crate::util::stats::least_squares`] (no external dependencies) and
//! weighted by 1/energy so the minimized quantity is **relative** error.
//!
//! Tables serialize through [`crate::util::json`] (`aimc fit-surrogate`
//! writes one, `aimc serve --surrogate` loads it at startup). Loading is
//! strict: any structural anomaly is an error, and the caller falls back
//! to co-simulation rather than trusting a corrupt model. The v2 format
//! added per-model precision/noise fields; v1 tables predate them and
//! are rejected by the format tag.
//!
//! The plain-`node_nm` entry points (`fit`, `predict_layer`, …) are
//! default-precision conveniences over the `*_op`/`*_ops` variants: they
//! price at [`OperatingPoint::node`]`(node_nm)` — 8×8 bits, noiseless —
//! which is exactly the pre-precision behaviour.

use std::collections::HashMap;
use std::path::Path;

use crate::networks::{zoo, ConvLayer, Network};
use crate::simulator::machine::Machine;
use crate::simulator::optical4f::Optical4FConfig;
use crate::simulator::photonic::PhotonicConfig;
use crate::simulator::reram::ReramConfig;
use crate::simulator::systolic::SystolicConfig;
use crate::simulator::{OpKey, OperatingPoint, SweepCache};
use crate::util::json::Json;
use crate::util::stats::least_squares;

/// Serialization header; bump on any layout change so old tables
/// deliberately fail to load. v2 added bits_x/bits_w and the noise
/// sigmas to every model entry; v3 added the four fault-model fields
/// (stuck rate, drift sigma, ADC clip, IR drop).
pub const SURROGATE_FORMAT: &str = "aimc-surrogate-v3";

/// Acceptance bound on surrogate-vs-cycle-simulator relative energy
/// error: the crossval scenario, its test, and `aimc surrogate-crossval`
/// all fail any (machine × operating point) whose worst layer error
/// exceeds this.
pub const ERR_BOUND: f64 = 0.07;

/// The four cycle-modeled processor classes a surrogate can price.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MachineKind {
    Systolic,
    Reram,
    Photonic,
    Optical4F,
}

impl MachineKind {
    pub const ALL: [MachineKind; 4] = [
        MachineKind::Systolic,
        MachineKind::Reram,
        MachineKind::Photonic,
        MachineKind::Optical4F,
    ];

    /// Stable name, matching [`Machine::name`] for the same class.
    pub fn name(self) -> &'static str {
        match self {
            MachineKind::Systolic => "systolic",
            MachineKind::Reram => "reram",
            MachineKind::Photonic => "photonic",
            MachineKind::Optical4F => "optical4f",
        }
    }

    pub fn parse(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "systolic" => Some(MachineKind::Systolic),
            "reram" | "memristor" => Some(MachineKind::Reram),
            "photonic" | "sp" => Some(MachineKind::Photonic),
            "optical4f" | "optical" | "4f" => Some(MachineKind::Optical4F),
            _ => None,
        }
    }

    /// The default-config cycle machine this kind models. Surrogates are
    /// fitted against (and only valid for) these default configs — the
    /// same ones the coordinator and the report scenarios use.
    pub fn machine(self) -> Box<dyn Machine> {
        match self {
            MachineKind::Systolic => Box::new(SystolicConfig::default()),
            MachineKind::Reram => Box::new(ReramConfig::default()),
            MachineKind::Photonic => Box::new(PhotonicConfig::default()),
            MachineKind::Optical4F => Box::new(Optical4FConfig::default()),
        }
    }

    /// Number of shape features (= fitted coefficients) for this kind.
    pub fn feature_count(self) -> usize {
        match self {
            MachineKind::Systolic => 4,
            MachineKind::Reram => 6,
            MachineKind::Photonic => 5,
            MachineKind::Optical4F => 5,
        }
    }

    /// Shape features whose span contains the machine's per-layer energy
    /// exactly (fixed config + operating point). Derived term-by-term
    /// from the cycle simulators' tile loops:
    ///
    /// * **systolic** — `[L·N·M, L·N·tm, L·M, L·M·(tn−1)]`: MAC/register
    ///   + hop terms are ∝ MACs; activation reads stream N per output
    ///   tile column; partial-sum SRAM traffic is the output surface plus
    ///   a 2·psum_bytes spill per extra contraction pass.
    /// * **reram** — adds `N·M` (amortized weight programming) and an
    ///   indicator `L·M·[tn>1]` (the 5/8-byte psum spill schedule is
    ///   affine in tn only for tn ≥ 2).
    /// * **photonic** — `[L·N, L·M, N·M, L·N·tm, L·M·tn]`: one SRAM read
    ///   per Toeplitz element and write per output, weight reconfig over
    ///   the tile grid, input DACs re-driven per output tile, ADC reads
    ///   per contraction pass.
    /// * **optical-4F** — per-patch/per-group loop of the 4F machine:
    ///   load-phase pixel traffic `P·s̄²·Cᵢ`, kernel writes
    ///   `P·k²·Cᵢ·Cᵢ₊₁`, laser shots `P·g·(1+Cᵢ₊₁)`, and output reads /
    ///   psum spills spanned by `n_out·Cᵢ₊₁·g` and `n_out·Cᵢ₊₁`.
    ///
    /// Precision/noise deliberately do **not** appear here: they rescale
    /// the per-event energies uniformly across a layer, which the fitted
    /// coefficients of that operating point's model absorb exactly.
    ///
    /// Tile counts use the same clamping as the simulators, so the
    /// feature map agrees with them on degenerate shapes too.
    pub fn features(self, layer: &ConvLayer) -> Vec<f64> {
        match self {
            MachineKind::Systolic => {
                let (l, n, m, tn, tm) = tiled_dims(layer, SystolicConfig::default().dim);
                vec![l * n * m, l * n * tm, l * m, l * m * (tn - 1.0)]
            }
            MachineKind::Reram => {
                let (l, n, m, tn, tm) = tiled_dims(layer, ReramConfig::default().dim);
                let spill = if tn > 1.0 { l * m } else { 0.0 };
                vec![l * n * m, n * m, l * n * tm, l * m * tn, l * m, spill]
            }
            MachineKind::Photonic => {
                let (l, n, m, tn, tm) = tiled_dims(layer, PhotonicConfig::default().dim);
                vec![l * n, l * m, n * m, l * n * tm, l * m * tn]
            }
            MachineKind::Optical4F => {
                let cfg = Optical4FConfig::default();
                let n = layer.n;
                let k = layer.kh.max(layer.kw);
                let ci = layer.c_in;
                let co = layer.c_out as f64;
                let n_out = {
                    let span = n.saturating_sub(k) / layer.stride + 1;
                    (span * span) as f64
                };
                let patches = cfg.spatial_patches(n, k);
                let s2 = if patches == 1 {
                    ((n + k - 1) * (n + k - 1)) as f64
                } else {
                    cfg.slm_pixels as f64
                };
                let c_prime = cfg.channels_at_once(s2.sqrt() as usize, ci);
                let groups = ci.div_ceil(c_prime) as f64;
                let p = patches as f64;
                let cif = ci as f64;
                let kk = (k * k) as f64;
                vec![
                    p * s2 * cif,
                    p * kk * cif * co,
                    p * groups * (1.0 + co),
                    n_out * co * groups,
                    n_out * co,
                ]
            }
        }
    }
}

/// Matmul dims + tile counts with the simulators' degenerate-shape
/// clamps applied.
fn tiled_dims(layer: &ConvLayer, dim: usize) -> (f64, f64, f64, f64, f64) {
    let (l, n, m) = layer.matmul_dims();
    let l = l.max(1.0);
    let n = n.max(1.0) as usize;
    let m = m.max(1.0) as usize;
    let tn = n.div_ceil(dim) as f64;
    let tm = m.div_ceil(dim) as f64;
    (l, n as f64, m as f64, tn, tm)
}

/// Layer-shape family a model is fitted for: kernel geometry + stride.
/// Within a family the tile/patch features vary smoothly with (n, Cᵢ,
/// Cᵢ₊₁); keying on the kernel keeps each fit on one scheduling regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Family {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
}

impl Family {
    pub fn of(layer: &ConvLayer) -> Self {
        Family {
            kh: layer.kh,
            kw: layer.kw,
            stride: layer.stride,
        }
    }
}

/// Model key: machine class, exact operating point (bit patterns — same
/// convention as [`SweepCache`] keys, no tolerance games), shape family.
type ModelKey = (MachineKind, OpKey, Family);

/// A fitted table of per-(machine × operating point × family) linear
/// models.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SurrogateTable {
    models: HashMap<ModelKey, Vec<f64>>,
}

/// Predicted per-inference energy for the coordinator's co-simulation
/// pair (systolic + optical-4F), joules, at a stated precision.
#[derive(Clone, Copy, Debug)]
pub struct EnergyQuote {
    pub systolic_j: f64,
    pub optical_j: f64,
    pub node_nm: f64,
    pub bits_x: u32,
    pub bits_w: u32,
}

impl EnergyQuote {
    pub fn systolic_uj(&self) -> f64 {
        self.systolic_j * 1e6
    }

    pub fn optical_uj(&self) -> f64 {
        self.optical_j * 1e6
    }

    /// Conservative per-inference µJ figure for admission control: the
    /// worse of the two priced machines.
    pub fn worst_uj(&self) -> f64 {
        self.systolic_uj().max(self.optical_uj())
    }
}

impl SurrogateTable {
    /// Fit one model per (machine × operating point × family) over the
    /// training `layers`. Energy targets are served through `cache`, so
    /// grid points already simulated by earlier sweeps are replayed
    /// rather than re-simulated. Rows are weighted by 1/energy, making
    /// the solver minimize relative error — the quantity
    /// [`crossval`] bounds.
    pub fn fit_ops(
        cache: &SweepCache,
        kinds: &[MachineKind],
        ops: &[OperatingPoint],
        layers: &[ConvLayer],
    ) -> Result<SurrogateTable, String> {
        if kinds.is_empty() || ops.is_empty() || layers.is_empty() {
            return Err(
                "surrogate fit needs at least one machine, operating point and layer".into(),
            );
        }
        let mut models = HashMap::new();
        for &kind in kinds {
            let machine = kind.machine();
            for op in ops {
                if !op.node_nm.is_finite() || op.node_nm <= 0.0 {
                    return Err(format!("bad node {}", op.node_nm));
                }
                // Deterministic grouping: families in first-seen order.
                let mut order: Vec<Family> = Vec::new();
                let mut by_family: HashMap<Family, (Vec<Vec<f64>>, Vec<f64>)> = HashMap::new();
                for (layer, joules) in cache.training_rows(machine.as_ref(), layers, op) {
                    if !joules.is_finite() || joules <= 0.0 {
                        return Err(format!(
                            "{} @{} nm {}b: non-positive energy for {layer:?}",
                            kind.name(),
                            op.node_nm,
                            op.bits_label()
                        ));
                    }
                    let fam = Family::of(&layer);
                    let entry = by_family.entry(fam).or_insert_with(|| {
                        order.push(fam);
                        (Vec::new(), Vec::new())
                    });
                    let row: Vec<f64> =
                        kind.features(&layer).iter().map(|f| f / joules).collect();
                    entry.0.push(row);
                    entry.1.push(1.0);
                }
                for fam in order {
                    let (a, b) = &by_family[&fam];
                    let coeffs = least_squares(a, b).ok_or_else(|| {
                        format!(
                            "{} @{} nm {}b family {fam:?}: singular fit over {} layers",
                            kind.name(),
                            op.node_nm,
                            op.bits_label(),
                            a.len()
                        )
                    })?;
                    models.insert((kind, op.key(), fam), coeffs);
                }
            }
        }
        Ok(SurrogateTable { models })
    }

    /// [`SurrogateTable::fit_ops`] at default precision (8×8, noiseless)
    /// over a plain node grid — the pre-precision entry point.
    pub fn fit(
        cache: &SweepCache,
        kinds: &[MachineKind],
        nodes: &[f64],
        layers: &[ConvLayer],
    ) -> Result<SurrogateTable, String> {
        let ops: Vec<OperatingPoint> = nodes.iter().map(|&nm| OperatingPoint::node(nm)).collect();
        SurrogateTable::fit_ops(cache, kinds, &ops, layers)
    }

    /// Number of fitted (machine × operating point × family) models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Predicted energy for one layer, joules. `None` when no model
    /// covers this (machine, operating point, family).
    pub fn predict_layer_op(
        &self,
        kind: MachineKind,
        op: &OperatingPoint,
        layer: &ConvLayer,
    ) -> Option<f64> {
        let coeffs = self.models.get(&(kind, op.key(), Family::of(layer)))?;
        let e: f64 = kind
            .features(layer)
            .iter()
            .zip(coeffs)
            .map(|(f, c)| f * c)
            .sum();
        Some(e)
    }

    /// [`SurrogateTable::predict_layer_op`] at default precision.
    pub fn predict_layer(&self, kind: MachineKind, node_nm: f64, layer: &ConvLayer) -> Option<f64> {
        self.predict_layer_op(kind, &OperatingPoint::node(node_nm), layer)
    }

    /// Predicted energy for a whole network, joules. `None` when any
    /// layer lacks a model — partial coverage must not silently
    /// under-price a network.
    pub fn predict_network_op(
        &self,
        kind: MachineKind,
        op: &OperatingPoint,
        net: &Network,
    ) -> Option<f64> {
        let mut total = 0.0;
        for layer in &net.layers {
            total += self.predict_layer_op(kind, op, layer)?;
        }
        Some(total)
    }

    /// [`SurrogateTable::predict_network_op`] at default precision.
    pub fn predict_network(&self, kind: MachineKind, node_nm: f64, net: &Network) -> Option<f64> {
        self.predict_network_op(kind, &OperatingPoint::node(node_nm), net)
    }

    /// Price `net` for the coordinator's co-simulation pair. `None`
    /// unless every layer has a model for both machines at `op`.
    pub fn quote_network_op(&self, net: &Network, op: &OperatingPoint) -> Option<EnergyQuote> {
        Some(EnergyQuote {
            systolic_j: self.predict_network_op(MachineKind::Systolic, op, net)?,
            optical_j: self.predict_network_op(MachineKind::Optical4F, op, net)?,
            node_nm: op.node_nm,
            bits_x: op.bits_x,
            bits_w: op.bits_w,
        })
    }

    /// [`SurrogateTable::quote_network_op`] at default precision.
    pub fn quote_network(&self, net: &Network, node_nm: f64) -> Option<EnergyQuote> {
        self.quote_network_op(net, &OperatingPoint::node(node_nm))
    }

    /// Shape families of `net` that [`SurrogateTable::quote_network_op`]
    /// cannot price at `op` — i.e. families missing a fitted model for
    /// the systolic or optical-4F machine. First-appearance order,
    /// deduplicated; empty means the quote path has full coverage.
    pub fn uncovered_families(&self, net: &Network, op: &OperatingPoint) -> Vec<Family> {
        let mut seen = std::collections::HashSet::new();
        let mut missing = Vec::new();
        for layer in &net.layers {
            let fam = Family::of(layer);
            if !seen.insert(fam) {
                continue;
            }
            let covered = [MachineKind::Systolic, MachineKind::Optical4F]
                .iter()
                .all(|&kind| self.models.contains_key(&(kind, op.key(), fam)));
            if !covered {
                missing.push(fam);
            }
        }
        missing
    }

    // ---- serialization ---------------------------------------------------

    /// Deterministic JSON document (models sorted by key).
    pub fn to_json(&self) -> Json {
        let mut keys: Vec<ModelKey> = self.models.keys().copied().collect();
        keys.sort();
        let models: Vec<Json> = keys
            .iter()
            .map(|key| {
                let (kind, opk, fam) = *key;
                let op = opk.to_op();
                Json::Obj(vec![
                    ("machine".into(), Json::Str(kind.name().into())),
                    ("node_nm".into(), Json::Num(op.node_nm)),
                    ("bits_x".into(), Json::Num(op.bits_x as f64)),
                    ("bits_w".into(), Json::Num(op.bits_w as f64)),
                    ("weight_sigma".into(), Json::Num(op.noise.weight_sigma)),
                    ("output_sigma".into(), Json::Num(op.noise.output_sigma)),
                    ("stuck_rate".into(), Json::Num(op.noise.faults.stuck_rate)),
                    ("drift_sigma".into(), Json::Num(op.noise.faults.drift_sigma)),
                    ("adc_clip".into(), Json::Num(op.noise.faults.adc_clip)),
                    ("ir_drop".into(), Json::Num(op.noise.faults.ir_drop)),
                    ("kh".into(), Json::Num(fam.kh as f64)),
                    ("kw".into(), Json::Num(fam.kw as f64)),
                    ("stride".into(), Json::Num(fam.stride as f64)),
                    (
                        "coeffs".into(),
                        Json::Arr(self.models[key].iter().map(|&c| Json::Num(c)).collect()),
                    ),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("format".into(), Json::Str(SURROGATE_FORMAT.into())),
            ("models".into(), Json::Arr(models)),
        ])
    }

    /// Strict deserialization: wrong format tag, unknown machine,
    /// non-finite numbers, out-of-range bit widths, negative sigmas,
    /// wrong coefficient count, duplicate or empty models all fail.
    /// Callers treat any error as "do not serve with this table".
    pub fn from_json(doc: &Json) -> Result<SurrogateTable, String> {
        let format = as_str(field(doc, "format")?)?;
        if format != SURROGATE_FORMAT {
            return Err(format!(
                "format {format:?} is not {SURROGATE_FORMAT:?}"
            ));
        }
        let Json::Arr(entries) = field(doc, "models")? else {
            return Err("\"models\" is not an array".into());
        };
        if entries.is_empty() {
            return Err("empty model table".into());
        }
        let mut models = HashMap::new();
        for entry in entries {
            let name = as_str(field(entry, "machine")?)?;
            let kind = MachineKind::parse(name)
                .ok_or_else(|| format!("unknown machine {name:?}"))?;
            let node = as_num(field(entry, "node_nm")?)?;
            if node <= 0.0 {
                return Err(format!("bad node_nm {node}"));
            }
            let bits_x = as_usize(field(entry, "bits_x")?)?;
            let bits_w = as_usize(field(entry, "bits_w")?)?;
            if !(1..=32).contains(&bits_x) || !(1..=32).contains(&bits_w) {
                return Err(format!("bit widths out of range: {bits_x}x{bits_w}"));
            }
            let weight_sigma = as_num(field(entry, "weight_sigma")?)?;
            let output_sigma = as_num(field(entry, "output_sigma")?)?;
            if weight_sigma < 0.0 || output_sigma < 0.0 {
                return Err(format!(
                    "negative noise sigma: {weight_sigma} / {output_sigma}"
                ));
            }
            let stuck_rate = as_num(field(entry, "stuck_rate")?)?;
            let drift_sigma = as_num(field(entry, "drift_sigma")?)?;
            let adc_clip = as_num(field(entry, "adc_clip")?)?;
            let ir_drop = as_num(field(entry, "ir_drop")?)?;
            if stuck_rate < 0.0 || drift_sigma < 0.0 || adc_clip < 0.0 || ir_drop < 0.0 {
                return Err(format!(
                    "negative fault field: {stuck_rate} / {drift_sigma} / {adc_clip} / {ir_drop}"
                ));
            }
            let op = OperatingPoint::node(node)
                .bits(bits_x as u32, bits_w as u32)
                .with_noise(crate::simulator::NoiseModel {
                    weight_sigma,
                    output_sigma,
                    faults: crate::simulator::FaultModel {
                        stuck_rate,
                        drift_sigma,
                        adc_clip,
                        ir_drop,
                    },
                });
            let fam = Family {
                kh: as_usize(field(entry, "kh")?)?,
                kw: as_usize(field(entry, "kw")?)?,
                stride: as_usize(field(entry, "stride")?)?,
            };
            if fam.kh == 0 || fam.kw == 0 || fam.stride == 0 {
                return Err(format!("degenerate family {fam:?}"));
            }
            let Json::Arr(raw) = field(entry, "coeffs")? else {
                return Err("\"coeffs\" is not an array".into());
            };
            let coeffs: Vec<f64> = raw
                .iter()
                .map(as_num)
                .collect::<Result<_, _>>()?;
            if coeffs.len() != kind.feature_count() {
                return Err(format!(
                    "{} expects {} coefficients, found {}",
                    kind.name(),
                    kind.feature_count(),
                    coeffs.len()
                ));
            }
            if models.insert((kind, op.key(), fam), coeffs).is_some() {
                return Err(format!(
                    "duplicate model for {} @{node} nm {}b {fam:?}",
                    kind.name(),
                    op.bits_label()
                ));
            }
        }
        Ok(SurrogateTable { models })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut text = self.to_json().pretty();
        text.push('\n');
        std::fs::write(path, text)
    }

    pub fn load(path: &Path) -> Result<SurrogateTable, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let doc =
            Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        SurrogateTable::from_json(&doc).map_err(|e| format!("{}: {e}", path.display()))
    }
}

// ---- JSON field helpers (strict) -----------------------------------------

fn field<'a>(obj: &'a Json, name: &str) -> Result<&'a Json, String> {
    let Json::Obj(pairs) = obj else {
        return Err(format!("expected object while reading {name:?}"));
    };
    pairs
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing field {name:?}"))
}

fn as_str(j: &Json) -> Result<&str, String> {
    match j {
        Json::Str(s) => Ok(s),
        other => Err(format!("expected string, found {other:?}")),
    }
}

fn as_num(j: &Json) -> Result<f64, String> {
    match j {
        Json::Num(v) if v.is_finite() => Ok(*v),
        other => Err(format!("expected number, found {other:?}")),
    }
}

fn as_usize(j: &Json) -> Result<usize, String> {
    let v = as_num(j)?;
    if v < 0.0 || v.fract() != 0.0 || v > usize::MAX as f64 {
        return Err(format!("expected non-negative integer, found {v}"));
    }
    Ok(v as usize)
}

// ---- training corpus & crossval ------------------------------------------

/// Order-preserving shape dedup.
pub fn dedup_layers(layers: impl IntoIterator<Item = ConvLayer>) -> Vec<ConvLayer> {
    let mut seen = std::collections::HashSet::new();
    layers.into_iter().filter(|l| seen.insert(*l)).collect()
}

/// Default training corpus: every unique conv shape of the Table I zoo
/// at `input` resolution, plus the Table V reference layer, plus the
/// transformer prefill/decode exemplar streams — so the shapes the
/// crossval scenario scores (and the GEMM/GEMV rows `aimc intensity`
/// and `serve --network` price) are interpolations of the fit, never
/// extrapolations. Callers append whatever else they serve (e.g. the
/// coordinator's resident CNN) before fitting.
pub fn training_corpus(input: usize) -> Vec<ConvLayer> {
    let mut layers: Vec<ConvLayer> = Vec::new();
    for net in zoo(input) {
        layers.extend(net.layers);
    }
    layers.push(ConvLayer::square(512, 128, 128, 3, 1));
    for net in crate::networks::transformer::corpus_networks() {
        layers.extend(net.layers);
    }
    dedup_layers(layers)
}

/// The full technology ladder, the default node grid for fitting.
pub fn default_nodes() -> Vec<f64> {
    crate::technode::NODES.iter().map(|n| n.nm).collect()
}

/// One crossval verdict: surrogate vs cycle simulator for a machine ×
/// operating point over a layer set.
#[derive(Clone, Copy, Debug)]
pub struct CrossvalPoint {
    pub kind: MachineKind,
    pub node_nm: f64,
    pub bits_x: u32,
    pub bits_w: u32,
    pub layers: usize,
    pub max_rel_err: f64,
    pub mean_rel_err: f64,
}

/// Score `table` against the cycle simulators (through `cache`) for
/// every machine × operating point over the unique shapes of `layers`.
/// A layer with no fitted model counts as 100% error, so a coverage
/// hole can never pass a bound check.
pub fn crossval_ops(
    table: &SurrogateTable,
    cache: &SweepCache,
    kinds: &[MachineKind],
    ops: &[OperatingPoint],
    layers: &[ConvLayer],
) -> Vec<CrossvalPoint> {
    let uniq = dedup_layers(layers.iter().copied());
    let mut out = Vec::with_capacity(kinds.len() * ops.len());
    for &kind in kinds {
        let machine = kind.machine();
        for op in ops {
            let mut max_rel = 0.0f64;
            let mut sum_rel = 0.0f64;
            for layer in &uniq {
                let truth = cache.simulate_layer(machine.as_ref(), layer, op);
                let truth_j = truth.ledger.total().max(f64::MIN_POSITIVE);
                let rel = match table.predict_layer_op(kind, op, layer) {
                    Some(pred) => (pred - truth_j).abs() / truth_j,
                    None => 1.0,
                };
                max_rel = max_rel.max(rel);
                sum_rel += rel;
            }
            out.push(CrossvalPoint {
                kind,
                node_nm: op.node_nm,
                bits_x: op.bits_x,
                bits_w: op.bits_w,
                layers: uniq.len(),
                max_rel_err: max_rel,
                mean_rel_err: sum_rel / uniq.len().max(1) as f64,
            });
        }
    }
    out
}

/// [`crossval_ops`] at default precision over a plain node grid.
pub fn crossval(
    table: &SurrogateTable,
    cache: &SweepCache,
    kinds: &[MachineKind],
    nodes: &[f64],
    layers: &[ConvLayer],
) -> Vec<CrossvalPoint> {
    let ops: Vec<OperatingPoint> = nodes.iter().map(|&nm| OperatingPoint::node(nm)).collect();
    crossval_ops(table, cache, kinds, &ops, layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Small but heterogeneous corpus: zoo at a reduced input resolution
    /// (all kernel families, strides, channel ranges) plus the Table V
    /// reference layer (already appended by `training_corpus`).
    fn test_corpus() -> Vec<ConvLayer> {
        training_corpus(300)
    }

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "aimc-surrogate-{tag}-{}.json",
            std::process::id()
        ))
    }

    #[test]
    fn crossval_error_bounded_on_table_v_shapes() {
        // The acceptance bound: ≤7% relative energy error per machine ×
        // node (LASANA's figure). Because the fitted families are linear
        // in the features, the observed error is FP noise.
        let cache = SweepCache::new();
        let corpus = test_corpus();
        let nodes = [45.0, 7.0];
        let table =
            SurrogateTable::fit(&cache, &MachineKind::ALL, &nodes, &corpus).unwrap();
        // Table V reference layer + resident-CNN-sized shapes + a
        // held-out (not in corpus) same-family shape.
        let eval = vec![
            ConvLayer::square(512, 128, 128, 3, 1),
            ConvLayer::square(64, 3, 8, 3, 1),
            ConvLayer::square(14, 16, 32, 3, 1),
            ConvLayer::square(96, 48, 64, 3, 1),
        ];
        for p in crossval(&table, &cache, &MachineKind::ALL, &nodes, &eval) {
            assert!(
                p.max_rel_err <= ERR_BOUND,
                "{} @{} nm: max rel err {:.4} over {} layers",
                p.kind.name(),
                p.node_nm,
                p.max_rel_err,
                p.layers
            );
        }
    }

    #[test]
    fn crossval_error_bounded_across_precisions() {
        // The exact-span argument holds per operating point: fitting and
        // scoring at 4×4 / 8×4 / 8×8 must stay inside the same bound.
        let cache = SweepCache::new();
        let corpus = test_corpus();
        let ops = [
            OperatingPoint::node(45.0).bits(4, 4),
            OperatingPoint::node(45.0).bits(8, 4),
            OperatingPoint::node(45.0),
            OperatingPoint::node(7.0).bits(6, 6),
        ];
        let table =
            SurrogateTable::fit_ops(&cache, &MachineKind::ALL, &ops, &corpus).unwrap();
        let eval = vec![
            ConvLayer::square(512, 128, 128, 3, 1),
            ConvLayer::square(96, 48, 64, 3, 1),
        ];
        for p in crossval_ops(&table, &cache, &MachineKind::ALL, &ops, &eval) {
            assert!(
                p.max_rel_err <= ERR_BOUND,
                "{} @{} nm {}x{}b: max rel err {:.4}",
                p.kind.name(),
                p.node_nm,
                p.bits_x,
                p.bits_w,
                p.max_rel_err
            );
        }
    }

    #[test]
    fn precision_keys_never_alias() {
        let cache = SweepCache::new();
        let corpus = test_corpus();
        let ops = [
            OperatingPoint::node(45.0),
            OperatingPoint::node(45.0).bits(4, 4),
        ];
        let table =
            SurrogateTable::fit_ops(&cache, &[MachineKind::Systolic], &ops, &corpus).unwrap();
        let layer = ConvLayer::square(96, 48, 64, 3, 1);
        let e8 = table
            .predict_layer_op(MachineKind::Systolic, &ops[0], &layer)
            .unwrap();
        let e4 = table
            .predict_layer_op(MachineKind::Systolic, &ops[1], &layer)
            .unwrap();
        assert!(e4 < e8, "4-bit prediction must price below 8-bit");
        // An operating point that was never fitted has no model.
        assert!(table
            .predict_layer_op(
                MachineKind::Systolic,
                &OperatingPoint::node(45.0).bits(6, 6),
                &layer
            )
            .is_none());
        // And the default-precision wrapper hits the 8×8 model exactly.
        assert_eq!(
            table.predict_layer(MachineKind::Systolic, 45.0, &layer).unwrap().to_bits(),
            e8.to_bits()
        );
    }

    #[test]
    fn network_prediction_matches_cycle_sum() {
        let cache = SweepCache::new();
        let corpus = test_corpus();
        let table =
            SurrogateTable::fit(&cache, &MachineKind::ALL, &[45.0], &corpus).unwrap();
        let net = crate::networks::vgg::vgg16(300);
        let op = OperatingPoint::node(45.0);
        for kind in MachineKind::ALL {
            let truth = cache
                .simulate_network(kind.machine().as_ref(), &net, &op)
                .ledger
                .total();
            let pred = table.predict_network(kind, 45.0, &net).unwrap();
            let rel = (pred - truth).abs() / truth;
            assert!(rel < 0.01, "{}: rel {rel}", kind.name());
        }
    }

    #[test]
    fn uncovered_families_names_the_quote_gap() {
        let cache = SweepCache::new();
        let gemm_fam = Family {
            kh: 1,
            kw: 1,
            stride: 1,
        };
        // Fit everything EXCEPT the 1×1 GEMM family.
        let no_gemm: Vec<ConvLayer> = test_corpus()
            .into_iter()
            .filter(|l| Family::of(l) != gemm_fam)
            .collect();
        let table =
            SurrogateTable::fit(&cache, &MachineKind::ALL, &[45.0], &no_gemm).unwrap();
        let op = OperatingPoint::node(45.0);
        let decode = crate::networks::transformer::TransformerConfig::tiny().decode(1, 64);
        // Every layer of a decode stream is a GEMM/GEMV: exactly one gap.
        assert_eq!(table.uncovered_families(&decode, &op), vec![gemm_fam]);
        assert!(table.quote_network_op(&decode, &op).is_none());
        // A covered network reports no gaps and quotes fine.
        let covered = crate::networks::vgg::vgg16(300);
        assert!(table.uncovered_families(&covered, &op).is_empty());
        assert!(table.quote_network_op(&covered, &op).is_some());
        // An operating point that was never fitted misses everything.
        assert!(!table
            .uncovered_families(&covered, &OperatingPoint::node(7.0))
            .is_empty());
    }

    #[test]
    fn training_corpus_covers_transformer_streams() {
        // The default corpus must let the quote path price transformer
        // prefill AND decode streams without co-simulation fallback.
        let cache = SweepCache::new();
        let table =
            SurrogateTable::fit(&cache, &MachineKind::ALL, &[45.0], &test_corpus()).unwrap();
        let op = OperatingPoint::node(45.0);
        for net in crate::networks::transformer::corpus_networks() {
            assert!(
                table.uncovered_families(&net, &op).is_empty(),
                "{}: gap in default corpus",
                net.name
            );
            assert!(table.quote_network_op(&net, &op).is_some());
        }
    }

    #[test]
    fn fitted_predictions_deterministic_across_runs() {
        // Property: two independent fits over a seeded random corpus
        // produce bit-identical predictions (no HashMap-order leakage
        // into the solver).
        let mut rng = Rng::new(0xA1C0_5EED);
        let mut layers = Vec::new();
        for _ in 0..40 {
            let k = *rng.choose(&[1usize, 3, 5]);
            let stride = *rng.choose(&[1usize, 2]);
            layers.push(ConvLayer::square(
                rng.range_usize(16, 128),
                rng.range_usize(1, 64),
                rng.range_usize(1, 64),
                k,
                stride,
            ));
        }
        let nodes = [45.0, 14.0];
        let t1 =
            SurrogateTable::fit(&SweepCache::new(), &MachineKind::ALL, &nodes, &layers)
                .unwrap();
        let t2 =
            SurrogateTable::fit(&SweepCache::new(), &MachineKind::ALL, &nodes, &layers)
                .unwrap();
        assert_eq!(t1, t2, "fits must be bit-identical");
        for kind in MachineKind::ALL {
            for &node in &nodes {
                for layer in &layers {
                    let a = t1.predict_layer(kind, node, layer).unwrap();
                    let b = t2.predict_layer(kind, node, layer).unwrap();
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let cache = SweepCache::new();
        let ops = [
            OperatingPoint::node(45.0),
            OperatingPoint::node(7.0).bits(4, 8).with_noise(crate::simulator::NoiseModel {
                weight_sigma: 0.01,
                output_sigma: 0.02,
                faults: crate::simulator::FaultModel {
                    stuck_rate: 0.001,
                    drift_sigma: 0.02,
                    adc_clip: 0.5,
                    ir_drop: 0.03,
                },
            }),
        ];
        let table = SurrogateTable::fit_ops(
            &cache,
            &MachineKind::ALL,
            &ops,
            &test_corpus(),
        )
        .unwrap();
        let path = tmp_path("roundtrip");
        table.save(&path).unwrap();
        let back = SurrogateTable::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // `{v}` rendering is shortest-roundtrip, so equality is exact.
        assert_eq!(table, back);
    }

    #[test]
    fn corrupt_tables_are_rejected() {
        let cache = SweepCache::new();
        let table = SurrogateTable::fit(
            &cache,
            &[MachineKind::Systolic],
            &[45.0],
            &test_corpus(),
        )
        .unwrap();
        let path = tmp_path("corrupt");

        // Truncated file.
        let mut text = table.to_json().pretty();
        text.truncate(text.len() / 2);
        std::fs::write(&path, &text).unwrap();
        assert!(SurrogateTable::load(&path).is_err());

        // Wrong format tag (v1 tables land here too — they predate the
        // precision fields).
        std::fs::write(
            &path,
            "{\"format\": \"aimc-surrogate-v1\", \"models\": []}",
        )
        .unwrap();
        assert!(SurrogateTable::load(&path).is_err());

        // Wrong coefficient count for the machine.
        std::fs::write(
            &path,
            format!(
                "{{\"format\": \"{SURROGATE_FORMAT}\", \"models\": [{{\
                 \"machine\": \"systolic\", \"node_nm\": 45.0, \
                 \"bits_x\": 8, \"bits_w\": 8, \
                 \"weight_sigma\": 0.0, \"output_sigma\": 0.0, \
                 \"stuck_rate\": 0.0, \"drift_sigma\": 0.0, \
                 \"adc_clip\": 0.0, \"ir_drop\": 0.0, \
                 \"kh\": 3, \"kw\": 3, \"stride\": 1, \"coeffs\": [1.0]}}]}}"
            ),
        )
        .unwrap();
        assert!(SurrogateTable::load(&path).is_err());

        // Out-of-range bit width.
        std::fs::write(
            &path,
            format!(
                "{{\"format\": \"{SURROGATE_FORMAT}\", \"models\": [{{\
                 \"machine\": \"systolic\", \"node_nm\": 45.0, \
                 \"bits_x\": 0, \"bits_w\": 8, \
                 \"weight_sigma\": 0.0, \"output_sigma\": 0.0, \
                 \"stuck_rate\": 0.0, \"drift_sigma\": 0.0, \
                 \"adc_clip\": 0.0, \"ir_drop\": 0.0, \
                 \"kh\": 3, \"kw\": 3, \"stride\": 1, \
                 \"coeffs\": [1.0, 1.0, 1.0, 1.0]}}]}}"
            ),
        )
        .unwrap();
        assert!(SurrogateTable::load(&path).is_err());

        // Missing file.
        std::fs::remove_file(&path).ok();
        assert!(SurrogateTable::load(&path).is_err());
    }

    #[test]
    fn partial_coverage_returns_none() {
        let cache = SweepCache::new();
        let table = SurrogateTable::fit(
            &cache,
            &[MachineKind::Systolic],
            &[45.0],
            &[ConvLayer::square(64, 8, 8, 3, 1)],
        )
        .unwrap();
        let covered = ConvLayer::square(32, 4, 4, 3, 1); // same family
        let missing_family = ConvLayer::square(32, 4, 4, 5, 1);
        assert!(table.predict_layer(MachineKind::Systolic, 45.0, &covered).is_some());
        assert!(table
            .predict_layer(MachineKind::Systolic, 45.0, &missing_family)
            .is_none());
        assert!(table.predict_layer(MachineKind::Systolic, 7.0, &covered).is_none());
        assert!(table.predict_layer(MachineKind::Reram, 45.0, &covered).is_none());
        let net = Network {
            name: "mixed",
            layers: vec![covered, missing_family],
        };
        assert!(table.predict_network(MachineKind::Systolic, 45.0, &net).is_none());
    }

    #[test]
    fn quote_worst_is_max_of_pair() {
        let q = EnergyQuote {
            systolic_j: 2e-6,
            optical_j: 5e-6,
            node_nm: 45.0,
            bits_x: 8,
            bits_w: 8,
        };
        assert!((q.worst_uj() - 5.0).abs() < 1e-9);
        assert!((q.systolic_uj() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn machine_kind_names_round_trip() {
        let probe = ConvLayer::square(64, 8, 8, 3, 1);
        for kind in MachineKind::ALL {
            assert_eq!(MachineKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.machine().name(), kind.name());
            assert_eq!(kind.feature_count(), kind.features(&probe).len());
        }
        assert!(MachineKind::parse("nope").is_none());
    }
}
