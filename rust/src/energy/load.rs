//! Line-capacitance load energy — eq. (A6).
//!
//! Driving an analog array's row/column addressing lines dissipates
//! e = ½·C·L·V² where C is the trace capacitance per unit length and L the
//! line length. This term is **not** technology-node dependent (wire
//! capacitance per length is roughly constant across nodes), which is why
//! the paper's cycle-accurate curves flatten at small nodes (Figs. 8-10).

use super::constants::{TRACE_CAP_PER_M, VDD_45NM};

/// eq. (A6): energy to charge a line of length `line_m` meters.
pub fn line_energy(line_m: f64, vdd: f64) -> f64 {
    0.5 * TRACE_CAP_PER_M * line_m * vdd * vdd
}

/// Load model for an N-element array addressed by lines of pitch `pitch_m`.
///
/// `segments` models segmented (active-matrix) addressing: the drive
/// only charges 1/segments of the full line per operation. The paper's
/// Table IV SLM row (2.5 µm pitch, N = 2048 → 0.04 pJ) is only consistent
/// with eq. (A6) under segmentation ≈ 10 (see DESIGN.md "Substitutions");
/// the ReRAM and photonic rows use `segments = 1` and match exactly.
#[derive(Clone, Copy, Debug)]
pub struct LoadModel {
    pub pitch_m: f64,
    pub elements: usize,
    pub vdd: f64,
    pub segments: f64,
}

impl LoadModel {
    pub fn new(pitch_m: f64, elements: usize) -> Self {
        LoadModel {
            pitch_m,
            elements,
            vdd: VDD_45NM,
            segments: 1.0,
        }
    }

    pub fn with_segments(mut self, segments: f64) -> Self {
        assert!(segments >= 1.0);
        self.segments = segments;
        self
    }

    /// Full line length in meters.
    pub fn line_length(&self) -> f64 {
        self.pitch_m * self.elements as f64
    }

    /// Energy per drive operation (one element update), joules.
    pub fn energy(&self) -> f64 {
        line_energy(self.line_length() / self.segments, self.vdd)
    }
}

/// The SLM active-matrix segmentation factor calibrated to the paper's
/// quoted 40 fJ load at 2.5 µm pitch, N = 2048 (see DESIGN.md).
pub const SLM_SEGMENTS: f64 = 10.24;

/// Convenience constructors matching Table IV's three rows.
pub mod presets {
    use super::*;
    use crate::energy::constants::{PITCH_PHOTONIC, PITCH_RERAM, PITCH_SLM};

    /// "e_load for 4 µm pitch, N = 256" → 0.08 pJ (ReRAM crossbar).
    pub fn reram_256() -> LoadModel {
        LoadModel::new(PITCH_RERAM, 256)
    }

    /// "e_load for 250 µm pitch, N = 40" → 0.8 pJ (planar photonics).
    pub fn photonic_40() -> LoadModel {
        LoadModel::new(PITCH_PHOTONIC, 40)
    }

    /// "e_load for 2.5 µm pitch, N = 2048" → 0.04 pJ (4F SLM,
    /// segmented active-matrix addressing).
    pub fn slm_2048() -> LoadModel {
        LoadModel::new(PITCH_SLM, 2048).with_segments(SLM_SEGMENTS)
    }

    /// Systolic-array inter-tile hop (§VII.A): 34.8 µm pitch derived from
    /// the 256×256 array occupying 24% of the 331 mm² TPU die. Per *bit*.
    pub fn systolic_hop() -> LoadModel {
        LoadModel::new(34.8e-6, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn copper_trace_0_08_fj_per_um() {
        // Paper: "they typically consume 0.08 fJ/µm per operation".
        let e = line_energy(1e-6, VDD_45NM);
        assert!((e * 1e15 - 0.081).abs() < 0.005, "{} fJ", e * 1e15);
    }

    #[test]
    fn table_iv_reram_row() {
        let e = reram_256().energy();
        assert!((e * 1e12 - 0.08).abs() < 0.005, "{} pJ", e * 1e12);
    }

    #[test]
    fn table_iv_photonic_row() {
        let e = photonic_40().energy();
        assert!((e * 1e12 - 0.8).abs() < 0.05, "{} pJ", e * 1e12);
    }

    #[test]
    fn table_iv_slm_row() {
        let e = slm_2048().energy();
        assert!((e * 1e12 - 0.04).abs() < 0.003, "{} pJ", e * 1e12);
    }

    #[test]
    fn systolic_hop_2_82_fj_per_bit() {
        // §VII.A: "A load energy cost of 2.82 fJ/bit was computed using
        // eq. A6 … a distance of 34.8 µm between tiles."
        let e = systolic_hop().energy();
        assert!((e * 1e15 - 2.82).abs() < 0.05, "{} fJ", e * 1e15);
    }

    #[test]
    fn energy_linear_in_length() {
        let a = LoadModel::new(1e-6, 100).energy();
        let b = LoadModel::new(1e-6, 200).energy();
        assert!((b / a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn segmentation_divides() {
        let full = LoadModel::new(2.5e-6, 2048);
        let seg = full.with_segments(8.0);
        assert!((full.energy() / seg.energy() - 8.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn segments_below_one_rejected() {
        let _ = LoadModel::new(1e-6, 10).with_segments(0.5);
    }
}
