//! ReRAM (memristive) analog array energy — Appendix A2, eqs. (A9)–(A13).
//!
//! In a memristor crossbar the array itself dissipates ⟨G⟩·V²·δt per
//! element per sample. Because the usable conductance window is bounded
//! below by the quantum of conductance G₀, the energy per MAC is a
//! *constant* — it does **not** improve with array size (eq. A11) — which
//! is the paper's core argument for why memristive analog compute has a
//! hard efficiency ceiling (~20 TOPS/W) while optical scales.

use super::constants::{G0, KT};

/// ReRAM array operating point.
#[derive(Clone, Copy, Debug)]
pub struct ReramArray {
    /// Bit precision of the stored conductances.
    pub bits: u32,
    /// RMS drive voltage, volts (practical floor ≈ 70 mV).
    pub v_rms: f64,
    /// Sampling period δt, seconds.
    pub dt: f64,
}

impl Default for ReramArray {
    fn default() -> Self {
        // Paper §A2: V_rms ≈ 70 mV, δt = 1 ns, 8-bit.
        ReramArray {
            bits: 8,
            v_rms: 0.07,
            dt: 1e-9,
        }
    }
}

impl ReramArray {
    /// Mean conductance for B-bit elements uniformly filling [G₀, G₀·2^B]
    /// (paper: ⟨G⟩ = 2^{B-1}·G₀).
    pub fn mean_conductance(&self) -> f64 {
        2f64.powi(self.bits as i32 - 1) * G0
    }

    /// eq. (A11): energy per MAC dissipated in the memristors — size
    /// independent.
    pub fn energy_per_mac(&self) -> f64 {
        self.mean_conductance() * self.v_rms * self.v_rms * self.dt
    }

    /// eq. (A13): the thermal-noise-limited ideal (V driven just hard
    /// enough for B bits against Johnson-Nyquist noise): 3·kT·2^{3B}.
    pub fn thermal_limit_per_mac(&self) -> f64 {
        3.0 * KT * 2f64.powi(3 * self.bits as i32)
    }

    /// Johnson-Nyquist noise voltage (squared) of the minimum-conductance
    /// element over the sampling bandwidth, eq. (A12).
    pub fn v_noise_sq(&self) -> f64 {
        4.0 * KT / (G0 * self.dt)
    }

    /// Efficiency ceiling in ops/J implied by the array energy alone
    /// (2 ops per MAC, matching the paper's op accounting).
    pub fn efficiency_ceiling(&self) -> f64 {
        2.0 / self.energy_per_mac()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_0_05_pj_per_mac() {
        // §A2: "the energy per operation due to the memristors is
        // e_ReRAM ≈ 0.05 pJ".
        let e = ReramArray::default().energy_per_mac();
        assert!((e * 1e12 - 0.0486).abs() < 0.005, "{} pJ", e * 1e12);
    }

    #[test]
    fn paper_20_tops_ceiling() {
        // §A2: "places an upper bound on the efficiency at η ≈ 20 TOPS/W"
        // (per-op accounting: 1 MAC = 2 ops ⇒ 2/0.0486 pJ ≈ 41 ops/pJ…
        // the paper's 20 uses 1 op = 1 MAC; check both are in range).
        let arr = ReramArray::default();
        let tops_per_mac = 1.0 / (arr.energy_per_mac() * 1e12);
        assert!(tops_per_mac > 15.0 && tops_per_mac < 25.0, "{tops_per_mac}");
    }

    #[test]
    fn size_independent() {
        // eq. (A11): e/MAC does not depend on any array dimension — the
        // struct has no size field by construction; verify the mean
        // conductance math instead.
        let arr = ReramArray::default();
        assert!((arr.mean_conductance() / G0 - 128.0).abs() < 1e-9);
    }

    #[test]
    fn thermal_limit_above_70mv_practical() {
        // At 8 bits the Johnson-Nyquist-limited drive voltage is ≈145 mV
        // (eq. A13 → 3kT·2^24 ≈ 0.21 pJ/MAC), so the 70 mV practical
        // operating point — which achieves *fewer* effective bits —
        // dissipates less than the full-8-bit ideal.
        let arr = ReramArray::default();
        assert!(
            (arr.thermal_limit_per_mac() * 1e12 - 0.208).abs() < 0.01,
            "{} pJ",
            arr.thermal_limit_per_mac() * 1e12
        );
        assert!(arr.thermal_limit_per_mac() > arr.energy_per_mac());
    }

    #[test]
    fn higher_bits_exponentially_worse() {
        let b8 = ReramArray::default();
        let b10 = ReramArray {
            bits: 10,
            ..Default::default()
        };
        assert!((b10.energy_per_mac() / b8.energy_per_mac() - 4.0).abs() < 1e-9);
        assert!(
            (b10.thermal_limit_per_mac() / b8.thermal_limit_per_mac() - 64.0).abs()
                < 1e-6
        );
    }

    #[test]
    fn noise_voltage_sane() {
        // 4kT/(G0·1ns) ≈ 2.14e-7 V² → ~0.46 mV rms at the G₀ floor.
        let v2 = ReramArray::default().v_noise_sq();
        assert!((v2 - 2.14e-7).abs() / 2.14e-7 < 0.02, "{v2}");
    }
}
