//! Digital logic energy — eq. (A1), the gate-count MAC model.
//!
//! A serial-parallel multiplier has G = 6B² gates, a full adder adds 9B
//! more, so e_mac = γ_mac (6B² + 9B) kT. γ_mac ≈ 1.225e5 for a 45 nm
//! process (Horowitz), giving the 0.23 pJ 8-bit MAC of Table IV; the
//! Landauer bound is γ_mac = ln 2.

use super::constants::KT;

/// Number of logic gates in a B-bit MAC (multiplier + adder).
pub fn mac_gate_count(bits: u32) -> u64 {
    let b = bits as u64;
    6 * b * b + 9 * b
}

/// Energy of one B-bit MAC at calibration (45 nm), eq. (A1).
pub fn mac_energy(gamma_mac: f64, bits: u32) -> f64 {
    gamma_mac * mac_gate_count(bits) as f64 * KT
}

/// Gate count of a mixed-precision Bx × Bw MAC: the serial-parallel
/// multiplier needs 6·Bx·Bw gates, the accumulator adder is sized by
/// the wider operand (9·max(Bx,Bw)). Collapses to [`mac_gate_count`]
/// when Bx == Bw.
pub fn mac_gate_count_xw(bits_x: u32, bits_w: u32) -> u64 {
    let bx = bits_x as u64;
    let bw = bits_w as u64;
    6 * bx * bw + 9 * bx.max(bw)
}

/// Energy of one mixed-precision Bx × Bw MAC at calibration (45 nm).
/// Bit-identical to [`mac_energy`] at Bx == Bw (same gate count, same
/// multiply order).
pub fn mac_energy_xw(gamma_mac: f64, bits_x: u32, bits_w: u32) -> f64 {
    gamma_mac * mac_gate_count_xw(bits_x, bits_w) as f64 * KT
}

/// The Landauer lower bound for the same gate count (γ = ln 2).
pub fn mac_landauer_bound(bits: u32) -> f64 {
    std::f64::consts::LN_2 * mac_gate_count(bits) as f64 * KT
}

/// Headroom factor between a real MAC and its Landauer bound.
pub fn landauer_headroom(gamma_mac: f64) -> f64 {
    gamma_mac / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::constants::GAMMA_MAC_45NM;

    #[test]
    fn gate_count_8bit() {
        // 6·64 + 72 = 456 gates.
        assert_eq!(mac_gate_count(8), 456);
    }

    #[test]
    fn mac_energy_is_0_23_pj() {
        let e = mac_energy(GAMMA_MAC_45NM, 8);
        assert!((e * 1e12 - 0.23).abs() < 0.005, "{} pJ", e * 1e12);
    }

    #[test]
    fn quadratic_in_bits() {
        let e8 = mac_energy(GAMMA_MAC_45NM, 8);
        let e16 = mac_energy(GAMMA_MAC_45NM, 16);
        let ratio = e16 / e8;
        // (6·256+144)/(6·64+72) ≈ 3.68
        assert!((ratio - 3.68).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn mixed_precision_collapses_to_symmetric() {
        for b in [1u32, 4, 8, 12, 16] {
            assert_eq!(mac_gate_count_xw(b, b), mac_gate_count(b));
            assert_eq!(
                mac_energy_xw(GAMMA_MAC_45NM, b, b).to_bits(),
                mac_energy(GAMMA_MAC_45NM, b).to_bits(),
                "must be bit-identical at Bx == Bw = {b}"
            );
        }
    }

    #[test]
    fn mixed_precision_is_symmetric_and_monotone() {
        assert_eq!(mac_gate_count_xw(8, 4), mac_gate_count_xw(4, 8));
        // 6·32 + 9·8 = 264, between the 4-bit (132) and 8-bit (456) MACs.
        assert_eq!(mac_gate_count_xw(8, 4), 264);
        assert!(mac_gate_count_xw(8, 4) > mac_gate_count(4));
        assert!(mac_gate_count_xw(8, 4) < mac_gate_count(8));
    }

    #[test]
    fn landauer_bound_below_real() {
        assert!(mac_landauer_bound(8) < mac_energy(GAMMA_MAC_45NM, 8));
        // Paper: "several orders of magnitude improvement" available.
        assert!(landauer_headroom(GAMMA_MAC_45NM) > 1e4);
    }
}
