//! Laser / optical energy — eq. (A8), the shot-noise floor.
//!
//! Recovering B bits from a photodetector against shot noise requires
//! 2^{2B} detected photons, so the optical energy per measured value is
//! e_opt = (ħω/η_opt)·2^{2B}. For 1550 nm light at 80% system efficiency
//! this is ≈ 10 fJ — Table IV's 0.01 pJ. Physics-bound: does not scale
//! with CMOS technology node.

use super::constants::{C_LIGHT, HBAR, KT, LAMBDA};

/// Photon energy ħω at the system wavelength, joules.
pub fn photon_energy() -> f64 {
    let omega = 2.0 * std::f64::consts::PI * C_LIGHT / LAMBDA;
    HBAR * omega
}

/// eq. (A8): optical energy per measured pixel for B-bit precision.
pub fn optical_energy(eta_opt: f64, bits: u32) -> f64 {
    assert!(eta_opt > 0.0 && eta_opt <= 1.0);
    photon_energy() / eta_opt * 2f64.powi(2 * bits as i32)
}

/// The equivalent dimensionless γ_opt = ħω/(η·kT), for Table VII output.
pub fn gamma_opt(eta_opt: f64) -> f64 {
    photon_energy() / eta_opt / KT
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::constants::ETA_OPT;

    #[test]
    fn table_iv_e_opt() {
        let e = optical_energy(ETA_OPT, 8);
        assert!((e * 1e15 - 10.5).abs() < 0.5, "{} fJ", e * 1e15);
    }

    #[test]
    fn gamma_opt_about_39_at_80pct() {
        // Paper: "for 1550-nm light and an optical efficiency of 80%, we
        // have γ_opt ≈ 39".
        let g = gamma_opt(0.8);
        assert!((g - 38.7).abs() < 1.0, "γ_opt = {g}");
    }

    #[test]
    fn lower_efficiency_costs_more() {
        assert!(optical_energy(0.5, 8) > optical_energy(0.8, 8));
    }

    #[test]
    fn shot_noise_exponential() {
        let r = optical_energy(0.8, 10) / optical_energy(0.8, 8);
        assert!((r - 16.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn zero_efficiency_rejected() {
        let _ = optical_energy(0.0, 8);
    }
}
