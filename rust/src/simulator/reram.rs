//! Cycle-accurate ReRAM crossbar accelerator (paper Fig. 3b + Appendix
//! A2) — an *extension*: the paper gives this machine an analytic ceiling
//! (eq. A11–A13) but no cycle model; we build one so all four processor
//! classes of Fig. 6 can be cross-validated the same way.
//!
//! Machine: a grid of `dim × dim` 1T1R crossbar tiles. Weights are
//! programmed as conductances (slow, amortized over `reuse` inferences);
//! inputs are applied as pulse-width-modulated rows (one DAC per row per
//! tile pass), outputs integrate on column sense amps (one ADC per column
//! per pass). Signed values cost a ×2 differential-pair factor (§IV.A).
//! The memristor array itself dissipates eq. (A11)'s size-independent
//! e_ReRAM per MAC — the term that caps this architecture at ~20 TOPS/W
//! no matter how large the arrays get.

//!
//! All entry points take an [`OperatingPoint`]: the row DACs / column
//! ADCs follow `bits_x`, the programmed conductance resolution follows
//! `bits_w` (overriding `ReramConfig::array.bits`), and the default 8×8
//! point reproduces the fixed-precision model bit-exactly.

use super::op::OperatingPoint;
use super::{Component, EnergyLedger, SimResult};
use crate::energy::{
    constants::{PITCH_RERAM, TOTAL_SRAM_BYTES},
    load::LoadModel,
    reram::ReramArray,
    sram::{bank_bytes, Sram},
    EnergyParams,
};
use crate::networks::{ConvLayer, Network};

/// Machine description.
#[derive(Clone, Copy, Debug)]
pub struct ReramConfig {
    /// Crossbar tile dimension (typ. 128–256 rows/cols).
    pub dim: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// SRAM banks.
    pub banks: usize,
    /// Memristor array operating point (bits, V_rms, δt).
    pub array: ReramArray,
    /// Inferences a programmed weight set is reused for (weight
    /// programming energy is amortized over this count).
    pub reuse: f64,
    /// Energy to program one memristor cell (SET/RESET pulses), J.
    /// Literature: ~1–100 pJ; default 10 pJ.
    pub e_program: f64,
    /// Signed-value factor (differential pairs), §IV.A.
    pub signed_factor: f64,
}

impl Default for ReramConfig {
    fn default() -> Self {
        ReramConfig {
            dim: 256,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: 256,
            array: ReramArray::default(),
            reuse: 1.0e4,
            e_program: 10e-12,
            signed_factor: 2.0,
        }
    }
}

impl ReramConfig {
    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }
}

struct Coeffs {
    e_dac_row: f64,
    e_adc: f64,
    e_cell_mac: f64,
    e_sram_byte: f64,
    /// SRAM cost of one activation element at bits_x precision.
    e_sram_act: f64,
    e_program_amortized: f64,
}

impl Coeffs {
    fn new(cfg: &ReramConfig, op: &OperatingPoint) -> Self {
        let e = EnergyParams::default().at_op(op);
        // Row drive: DAC circuit + bit-line load (eq. A6 at the ReRAM
        // pitch; node-independent wire term). Inputs are activations.
        let line = LoadModel::new(PITCH_RERAM, cfg.dim).energy();
        let e_sram_byte = Sram::at_node(cfg.bank_bytes(), op.node_nm).energy_per_byte;
        // Fault derates: stuck cells / drift surcharge the analog array
        // (spare columns + refresh reprogramming), IR drop / ADC range
        // pressure surcharge the converters. Both are exactly ×1.0 for
        // the ideal device — the golden bit-identity contract.
        let cell = op.noise.faults.cell_derate();
        let conv = op.noise.faults.converter_derate();
        Coeffs {
            e_dac_row: (e.e_dac_x + line) * conv,
            e_adc: e.e_adc * conv,
            // eq. (A11): per-MAC dissipation in the cells — no node
            // scaling (set by quantum conductance + noise floor), but
            // the mean programmed conductance follows bits_w.
            e_cell_mac: ReramArray {
                bits: op.bits_w,
                ..cfg.array
            }
            .energy_per_mac()
                * cell,
            e_sram_byte,
            e_sram_act: e_sram_byte * op.sx(),
            e_program_amortized: cfg.e_program / cfg.reuse * cell,
        }
    }
}

/// Simulate one conv layer (im2col GEMM mapping, like the systolic array:
/// ReRAM crossbars are matrix machines, so they eat the k² Toeplitz too).
pub fn simulate_layer(cfg: &ReramConfig, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    simulate_layer_with(cfg, layer, &c)
}

fn simulate_layer_with(cfg: &ReramConfig, layer: &ConvLayer, c: &Coeffs) -> SimResult {
    let (l_rows, n_dim, m_dim) = layer.matmul_dims();
    let l_rows = l_rows.max(1.0);
    let n_dim = n_dim.max(1.0) as usize;
    let m_dim = m_dim.max(1.0) as usize;
    let dim = cfg.dim;
    let tn = n_dim.div_ceil(dim);
    let tm = m_dim.div_ceil(dim);

    let mut ledger = EnergyLedger::new();
    let mut macs = 0.0;
    let mut passes = 0.0;

    for ti in 0..tn {
        let tile_n = (n_dim - ti * dim).min(dim) as f64;
        for tj in 0..tm {
            let tile_m = (m_dim - tj * dim).min(dim) as f64;

            // Weight programming, amortized over cfg.reuse inferences.
            ledger.add(
                Component::Dram,
                tile_n * tile_m * c.e_program_amortized * cfg.signed_factor,
            );

            // Stream the L' activation rows through this tile.
            // Per pass: tile_n row DACs, tile_m column ADCs, tile_n×tile_m
            // cell MACs — all ×2 for signed values.
            ledger.add(
                Component::Sram,
                l_rows * tile_n * c.e_sram_act, // activation reads (bits_x)
            );
            ledger.add(
                Component::Dac,
                cfg.signed_factor * l_rows * tile_n * c.e_dac_row,
            );
            ledger.add(
                Component::Adc,
                cfg.signed_factor * l_rows * tile_m * c.e_adc,
            );
            let tile_macs = l_rows * tile_n * tile_m;
            macs += tile_macs;
            ledger.add(
                Component::Mac,
                cfg.signed_factor * tile_macs * c.e_cell_mac,
            );

            // Partial-sum handling across tn passes (digital accumulate).
            let psum = l_rows * tile_m;
            if tn > 1 {
                // 32-bit digital psum spill/fill (bits-independent);
                // boundary passes touch one side only.
                let bytes = if ti == 0 || ti == tn - 1 { 5.0 } else { 8.0 };
                ledger.add(Component::Sram, psum * bytes * c.e_sram_byte);
            } else {
                // Single pass: write the bits_x-wide output directly.
                ledger.add(Component::Sram, psum * c.e_sram_act);
            }
            passes += l_rows;
        }
    }

    SimResult {
        macs,
        ops: 2.0 * macs,
        ledger,
        time_units: passes,
    }
}

/// Simulate a whole network.
pub fn simulate_network(cfg: &ReramConfig, net: &Network, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    let mut total = SimResult::default();
    for layer in &net.layers {
        total += &simulate_layer_with(cfg, layer, &c);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn mac_conservation() {
        let cfg = ReramConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (lp, np, mp) = l.matmul_dims();
        assert!((r.macs - lp * np * mp).abs() < 1.0);
    }

    #[test]
    fn ceiling_respected() {
        // Appendix A2: the array term alone caps ReRAM at ~20 TOPS/W
        // (per-MAC accounting). The full machine with converters sits
        // below that ceiling at every node.
        let cfg = ReramConfig::default();
        let net = yolov3(1000);
        let ceiling = 1.0 / (cfg.array.energy_per_mac() * 1e12); // TOPS/W per MAC
        for node in [45.0, 7.0] {
            let r = simulate_network(&cfg, &net, &op(node));
            let eta_mac = r.macs / r.ledger.total() / 1e12;
            assert!(
                eta_mac < ceiling,
                "@{node}nm: {eta_mac} !< ceiling {ceiling}"
            );
        }
    }

    #[test]
    fn cell_energy_does_not_scale_with_node() {
        let cfg = ReramConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let a = simulate_layer(&cfg, &l, &op(45.0));
        let b = simulate_layer(&cfg, &l, &op(7.0));
        assert_eq!(
            a.ledger.get(Component::Mac),
            b.ledger.get(Component::Mac),
            "memristor dissipation is physics-bound, not CMOS-bound"
        );
        assert!(b.ledger.get(Component::Adc) < a.ledger.get(Component::Adc));
    }

    #[test]
    fn beats_systolic_at_large_nodes_loses_headroom_at_small() {
        // The analog advantage is largest where CMOS is expensive: at
        // 45 nm ReRAM clearly beats the digital array; by 7 nm digital
        // MACs got ~10× cheaper while the memristor floor stayed put.
        use crate::simulator::systolic::{simulate_network as sys, SystolicConfig};
        let net = yolov3(1000);
        let r45 = simulate_network(&ReramConfig::default(), &net, &op(45.0)).tops_per_watt()
            / sys(&SystolicConfig::default(), &net, &op(45.0)).tops_per_watt();
        let r7 = simulate_network(&ReramConfig::default(), &net, &op(7.0)).tops_per_watt()
            / sys(&SystolicConfig::default(), &net, &op(7.0)).tops_per_watt();
        assert!(r45 > 1.5, "ReRAM should win at 45 nm: ratio {r45}");
        assert!(r7 < r45, "advantage must shrink with node: {r45} -> {r7}");
    }

    #[test]
    fn programming_amortization_matters() {
        // Programming dominates when the weight set is barely reused —
        // a low-arithmetic-intensity layer (tiny spatial extent, so few
        // rows stream past each programmed cell) makes this visible.
        let l = ConvLayer::square(8, 16, 32, 3, 1); // L' = 36 rows only
        let fresh = ReramConfig {
            reuse: 1.0,
            ..Default::default()
        };
        let amortized = ReramConfig::default();
        let ef = simulate_layer(&fresh, &l, &op(45.0)).ledger.total();
        let ea = simulate_layer(&amortized, &l, &op(45.0)).ledger.total();
        assert!(ef > 1.5 * ea, "single-use programming must dominate: {ef} vs {ea}");
        // And with big spatial reuse within one inference the gap closes.
        let big = ConvLayer::square(256, 16, 32, 3, 1);
        let ef_big = simulate_layer(&fresh, &big, &op(45.0)).ledger.total();
        let ea_big = simulate_layer(&amortized, &big, &op(45.0)).ledger.total();
        assert!(ef_big < 1.1 * ea_big);
    }

    #[test]
    fn signed_factor_doubles_converter_terms() {
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let unsigned = ReramConfig {
            signed_factor: 1.0,
            ..Default::default()
        };
        let signed = ReramConfig::default();
        let ru = simulate_layer(&unsigned, &l, &op(45.0));
        let rs = simulate_layer(&signed, &l, &op(45.0));
        let ratio = rs.ledger.get(Component::Dac) / ru.ledger.get(Component::Dac);
        assert!((ratio - 2.0).abs() < 1e-9);
    }

    #[test]
    fn weight_bits_drive_cells_activation_bits_drive_converters() {
        let cfg = ReramConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let r88 = simulate_layer(&cfg, &l, &op(45.0));
        // Halving the conductance resolution halves the mean programmed
        // conductance (eq. A9) but leaves the converters untouched…
        let r84 = simulate_layer(&cfg, &l, &op(45.0).bits(8, 4));
        assert!(r84.ledger.get(Component::Mac) < r88.ledger.get(Component::Mac));
        assert_eq!(
            r84.ledger.get(Component::Adc).to_bits(),
            r88.ledger.get(Component::Adc).to_bits()
        );
        // …while narrower activations collapse the 2^2B ADC law and the
        // cells stay put.
        let r48 = simulate_layer(&cfg, &l, &op(45.0).bits(4, 8));
        assert!(r48.ledger.get(Component::Adc) < r88.ledger.get(Component::Adc) / 100.0);
        assert_eq!(
            r48.ledger.get(Component::Mac).to_bits(),
            r88.ledger.get(Component::Mac).to_bits()
        );
    }

    #[test]
    fn injected_faults_surcharge_cells_and_converters() {
        use crate::simulator::faults::FaultModel;
        use crate::simulator::op::NoiseModel;
        let cfg = ReramConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let clean = simulate_layer(&cfg, &l, &op(45.0));
        let faulty = simulate_layer(
            &cfg,
            &l,
            &op(45.0).with_noise(NoiseModel {
                faults: FaultModel::at_rate(0.01),
                ..Default::default()
            }),
        );
        assert_eq!(clean.macs, faulty.macs, "faults never change work");
        assert!(faulty.ledger.get(Component::Mac) > clean.ledger.get(Component::Mac));
        assert!(faulty.ledger.get(Component::Adc) > clean.ledger.get(Component::Adc));
        assert!(faulty.ledger.get(Component::Dac) > clean.ledger.get(Component::Dac));
        // Digital activation SRAM is untouched by analog-array faults.
        assert_eq!(
            clean.ledger.get(Component::Sram).to_bits(),
            faulty.ledger.get(Component::Sram).to_bits()
        );
    }
}
