//! Cycle-accurate planar silicon-photonic processor (paper Fig. 3c) —
//! an *extension*: the paper models this machine analytically (eqs.
//! 13–14) but builds no cycle model; with one, all four Fig. 6 processor
//! classes are cross-validated identically.
//!
//! Machine: a `dim × dim` mesh of electro-optic elements (MZIs / VOAs).
//! Per conv layer the im2col GEMM (L′×N′)·(N′×M′) is tiled into
//! ⌈N′/dim⌉·⌈M′/dim⌉ weight configurations; each configuration costs
//! tile_n·tile_m weight-DAC writes (2 phases per coupled MZI), then the
//! L′ input rows stream through optically: tile_n input DACs + laser
//! photons in, tile_m coherent ADC reads out, everything ×2 for signed
//! values (§IV.A). No MAC energy — the mesh computes by interference.

//!
//! All entry points take an [`OperatingPoint`]: input DACs / output
//! ADCs / the shot-noise laser budget follow `bits_x`, weight-reconfig
//! DACs follow `bits_w`, and the default 8×8 point reproduces the
//! fixed-precision model bit-exactly.

use super::op::OperatingPoint;
use super::{Component, EnergyLedger, SimResult};
use crate::energy::{
    constants::{E_EO_MODULATOR_FUTURE, PHOTONIC_DIM, PITCH_PHOTONIC, TOTAL_SRAM_BYTES},
    load::LoadModel,
    sram::{bank_bytes, Sram},
    EnergyParams,
};
use crate::networks::{ConvLayer, Network};

/// Machine description.
#[derive(Clone, Copy, Debug)]
pub struct PhotonicConfig {
    /// Mesh dimension (40×40 typical of published processors).
    pub dim: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// SRAM banks (§VI: one 600 KB bank per port).
    pub banks: usize,
    /// Electro-optic modulator energy per sample, J.
    pub e_modulator: f64,
    /// DAC writes per weight element (2 for coupled-MZI phase pairs).
    pub dacs_per_weight: f64,
    /// Signed-value factor (§IV.A).
    pub signed_factor: f64,
}

impl Default for PhotonicConfig {
    fn default() -> Self {
        PhotonicConfig {
            dim: PHOTONIC_DIM,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: PHOTONIC_DIM,
            e_modulator: E_EO_MODULATOR_FUTURE,
            dacs_per_weight: 2.0,
            signed_factor: 2.0,
        }
    }
}

impl PhotonicConfig {
    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }
}

struct Coeffs {
    e_dac_in: f64,
    e_dac_weight: f64,
    e_adc: f64,
    /// SRAM cost of one activation/output element at bits_x precision.
    e_sram_act: f64,
    /// Small near-converter buffer traffic (row buffer + digital
    /// accumulator registers), 8 KB-class energy scaled to a word.
    e_reg_byte: f64,
}

impl Coeffs {
    fn new(cfg: &PhotonicConfig, op: &OperatingPoint) -> Self {
        let e = EnergyParams::default().at_op(op);
        let line = LoadModel::new(PITCH_PHOTONIC, cfg.dim).energy();
        // Fault derate: the photonic mesh has no conductance cells to
        // stick, but IR-drop-style drive droop and ADC range pressure
        // surcharge every converter event. Exactly ×1.0 when ideal.
        let conv = op.noise.faults.converter_derate();
        Coeffs {
            // Input: DAC + modulator + shot-noise laser budget (eq. A7/A8).
            e_dac_in: (e.e_dac_x + cfg.e_modulator + e.e_opt) * conv,
            // Weight reconfig: DAC + modulator + mesh line load (eq. A5).
            e_dac_weight: (e.e_dac_w + cfg.e_modulator + line) * conv,
            e_adc: e.e_adc * conv,
            e_sram_act: Sram::at_node(cfg.bank_bytes(), op.node_nm).energy_per_byte * op.sx(),
            e_reg_byte: Sram::at_node(5, op.node_nm).energy_per_byte,
        }
    }
}

/// Simulate one conv layer (im2col GEMM mapping).
pub fn simulate_layer(cfg: &PhotonicConfig, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    simulate_layer_with(cfg, layer, &c)
}

fn simulate_layer_with(cfg: &PhotonicConfig, layer: &ConvLayer, c: &Coeffs) -> SimResult {
    // Row-major schedule: each Toeplitz row is read from SRAM ONCE into a
    // near-mesh row buffer, then re-driven through the mesh for every
    // (tn, tm) tile; the tile_m partial sums of a row live in digital
    // accumulator registers across the tn contraction passes (exactly the
    // accumulator-column trick the systolic machine uses). This keeps big-
    // bank SRAM traffic at the in-memory ideal — one read per input, one
    // write per output — while the converter counts stay cycle-exact.
    // A naive tile-major schedule spills l·tile_m 32-bit psums through
    // the 600 KB banks every pass and is ~10× worse (see the
    // `row_major_schedule_beats_tile_major` test).
    let (l_rows, n_dim, m_dim) = layer.matmul_dims();
    let l_rows = l_rows.max(1.0);
    let n_dim = n_dim.max(1.0) as usize;
    let m_dim = m_dim.max(1.0) as usize;
    let dim = cfg.dim;
    let tn = n_dim.div_ceil(dim);
    let tm = m_dim.div_ceil(dim);

    let mut ledger = EnergyLedger::new();
    let mut macs = 0.0;
    let mut reconfigs = 0.0;

    // Activations: one SRAM read per Toeplitz element (row buffer).
    ledger.add(Component::Sram, l_rows * n_dim as f64 * c.e_sram_act);
    // Outputs: one bits_x-wide write per element.
    ledger.add(Component::Sram, l_rows * m_dim as f64 * c.e_sram_act);

    for ti in 0..tn {
        let tile_n = (n_dim - ti * dim).min(dim) as f64;
        for tj in 0..tm {
            let tile_m = (m_dim - tj * dim).min(dim) as f64;

            // Weight reconfiguration (eq. 14's e_dac,2/L term — amortized
            // over this layer's L′ rows, which is exactly why matmul, not
            // vector-matrix, restores the scaling).
            ledger.add(
                Component::Dac,
                cfg.signed_factor
                    * cfg.dacs_per_weight
                    * tile_n
                    * tile_m
                    * c.e_dac_weight,
            );
            reconfigs += 1.0;

            // Stream L′ rows through this tile: row-buffer feed, input
            // DACs, coherent ADC reads, register accumulation.
            ledger.add(
                Component::Load,
                l_rows * (tile_n + 5.0 * tile_m) * c.e_reg_byte,
            );
            ledger.add(
                Component::Dac,
                cfg.signed_factor * l_rows * tile_n * c.e_dac_in,
            );
            ledger.add(
                Component::Adc,
                cfg.signed_factor * l_rows * tile_m * c.e_adc,
            );
            macs += l_rows * tile_n * tile_m;
        }
    }

    SimResult {
        macs,
        ops: 2.0 * macs,
        ledger,
        time_units: reconfigs,
    }
}

/// Simulate a whole network.
pub fn simulate_network(cfg: &PhotonicConfig, net: &Network, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    let mut total = SimResult::default();
    for layer in &net.layers {
        total += &simulate_layer_with(cfg, layer, &c);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;
    use crate::simulator::{optical4f, systolic};

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn mac_conservation() {
        let cfg = PhotonicConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (lp, np, mp) = l.matmul_dims();
        assert!((r.macs - lp * np * mp).abs() < 1.0);
    }

    #[test]
    fn fig6_ordering_cycle_accurate_all_four() {
        // Fig. 6's ordering validated with *cycle models* for all four
        // classes on YOLOv3: systolic < photonic < optical-4F. The
        // photonic margin over the digital array is thinner than the
        // analytic Fig. 6 suggests (the 40×40 mesh re-DACs every input
        // tm times and pays real reconfiguration) — consistent with the
        // paper's §VI warning that photonics "will have a difficult time
        // maintaining an efficiency advantage over digital compute in
        // memory" at practical mesh sizes.
        let net = yolov3(1000);
        let node = op(32.0);
        let s = systolic::simulate_network(&systolic::SystolicConfig::default(), &net, &node)
            .tops_per_watt();
        let p = simulate_network(&PhotonicConfig::default(), &net, &node).tops_per_watt();
        let o = optical4f::simulate_network(
            &optical4f::Optical4FConfig::default(),
            &net,
            &node,
        )
        .tops_per_watt();
        assert!(p > s, "photonic {p} !> systolic {s}");
        assert!(o > p, "optical-4F {o} !> photonic {p}");
    }

    #[test]
    fn no_mac_component() {
        // Interference computes for free; all energy is converters,
        // modulators (in Dac), SRAM and reconfig.
        let r = simulate_layer(
            &PhotonicConfig::default(),
            &ConvLayer::square(64, 16, 32, 3, 1),
            &op(45.0),
        );
        assert_eq!(r.ledger.get(Component::Mac), 0.0);
        assert!(r.ledger.get(Component::Dac) > 0.0);
    }

    #[test]
    fn reconfig_count_is_tile_grid() {
        let cfg = PhotonicConfig::default(); // 40×40
        let l = ConvLayer::square(64, 16, 32, 3, 1); // N′=144, M′=32
        let r = simulate_layer(&cfg, &l, &op(45.0));
        assert_eq!(r.time_units, (144f64 / 40.0).ceil() * 1.0); // 4×1 tiles
    }

    #[test]
    fn small_mesh_pays_more_reconfig_per_mac() {
        let l = ConvLayer::square(128, 64, 64, 3, 1);
        let small = PhotonicConfig {
            dim: 8,
            banks: 8,
            ..Default::default()
        };
        let big = PhotonicConfig {
            dim: 128,
            banks: 128,
            ..Default::default()
        };
        let rs = simulate_layer(&small, &l, &op(45.0));
        let rb = simulate_layer(&big, &l, &op(45.0));
        assert!(
            rs.energy_per_mac() > rb.energy_per_mac(),
            "eq. (11): efficiency grows with processor scale"
        );
    }

    #[test]
    fn modulator_technology_dominates_converter_cost() {
        // §VI: today's 7 pJ modulators vs the assumed 0.5 pJ future —
        // the DAC component (which carries the modulator drive) must
        // shrink by ~an order of magnitude.
        let l = ConvLayer::square(512, 128, 128, 3, 1);
        let today = PhotonicConfig {
            e_modulator: crate::energy::constants::E_EO_MODULATOR_TODAY,
            ..Default::default()
        };
        let future = PhotonicConfig::default();
        let rt = simulate_layer(&today, &l, &op(45.0));
        let rf = simulate_layer(&future, &l, &op(45.0));
        let ratio = rt.ledger.get(Component::Dac) / rf.ledger.get(Component::Dac);
        assert!(ratio > 5.0, "DAC component ratio {ratio}");
        assert!(rt.energy_per_mac() > 1.5 * rf.energy_per_mac());
    }

    #[test]
    fn row_major_schedule_beats_tile_major() {
        // The schedule finding this extension surfaced: spilling 32-bit
        // partial sums through the 600 KB banks every contraction pass (a
        // naive tile-major loop) costs ~10× the row-buffer + register
        // schedule on a deep-contraction layer. Computed side by side.
        let l = ConvLayer::square(512, 128, 128, 3, 1); // N' = 1152 » 40
        let cfg = PhotonicConfig::default();
        let r = simulate_layer(&cfg, &l, &op(45.0));
        // Tile-major psum traffic it would have paid:
        let (lr, nd, md) = l.matmul_dims();
        let tn = (nd as usize).div_ceil(cfg.dim) as f64;
        let tm = (md as usize).div_ceil(cfg.dim) as f64;
        let e_b = crate::energy::sram::energy_per_byte_45nm(cfg.bank_bytes());
        let spill = lr * 40.0 * 8.0 * (tn - 1.0) * tm * e_b;
        assert!(
            spill > 5.0 * r.ledger.total(),
            "spill {spill:.3e} J vs actual total {:.3e} J",
            r.ledger.total()
        );
    }

    #[test]
    fn cycle_tracks_analytic_photonic() {
        use crate::analytic::{photonic, Workload};
        let l = ConvLayer::square(512, 128, 128, 3, 1);
        let w = Workload::from_layer(l);
        let sim = simulate_layer(&PhotonicConfig::default(), &l, &op(45.0)).tops_per_watt();
        let ana = photonic::Config::typical()
            .efficiency(&w, 45.0)
            .tops_per_watt();
        let ratio = sim / ana;
        // The cycle model re-DACs inputs tm times and charges real
        // reconfiguration; the analytic eq. (14) is the optimistic bound.
        assert!((0.15..1.5).contains(&ratio), "sim {sim} vs analytic {ana}");
    }

    #[test]
    fn activation_bits_dominate_converter_scaling() {
        // The 2^2B ADC/laser laws make bits_x the expensive axis here;
        // weight bits only touch the (amortized) reconfig DACs.
        let cfg = PhotonicConfig::default();
        let l = ConvLayer::square(64, 16, 32, 3, 1);
        let r88 = simulate_layer(&cfg, &l, &op(45.0));
        let r48 = simulate_layer(&cfg, &l, &op(45.0).bits(4, 8));
        let r84 = simulate_layer(&cfg, &l, &op(45.0).bits(8, 4));
        assert!(r48.ledger.get(Component::Adc) < r88.ledger.get(Component::Adc) / 100.0);
        assert_eq!(
            r84.ledger.get(Component::Adc).to_bits(),
            r88.ledger.get(Component::Adc).to_bits()
        );
        assert!(r84.ledger.get(Component::Dac) < r88.ledger.get(Component::Dac));
        assert_eq!(r88.time_units, r84.time_units, "reconfig count is shape-only");
    }
}
