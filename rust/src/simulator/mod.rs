//! Cycle-accurate machine models (paper §VII).
//!
//! Two machines, matching the paper's computational-results section:
//!
//! * [`systolic`] — a weight-stationary 256×256 systolic array with
//!   24 MiB of banked activation SRAM and DRAM-resident weights
//!   (the Google-TPUv1-like machine of Fig. 8);
//! * [`optical4f`] — the reflection-mode optical 4F machine of Fig. 5
//!   with 4 Mpx SLMs (Figs. 9–10);
//! * [`reram`], [`photonic`] — *extensions*: cycle models for the two
//!   planar analog machines of Fig. 3 that the paper only treats
//!   analytically, so all four Fig. 6 processor classes cross-validate
//!   the same way.
//!
//! Unlike the analytic models, the simulators walk every layer tile by
//! tile / execution by execution, so finite array capacity, edge tiles,
//! stride effects and partial-sum spilling are all accounted exactly.
//! Every joule is attributed to a [`ledger::Component`] so Fig. 10's
//! energy-distribution stacks fall out directly.

pub mod ledger;
pub mod optical4f;
pub mod photonic;
pub mod reram;
pub mod systolic;

pub use ledger::{Component, EnergyLedger};

/// Result of simulating one network on one machine at one node.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Total MAC count actually performed (useful work only).
    pub macs: f64,
    /// Total operations (2·MACs, the paper's op accounting).
    pub ops: f64,
    /// Energy attribution.
    pub ledger: EnergyLedger,
    /// Machine-specific time proxy: systolic = array cycles,
    /// optical = SLM executions.
    pub time_units: f64,
}

impl SimResult {
    /// Efficiency in ops per joule.
    pub fn ops_per_joule(&self) -> f64 {
        self.ops / self.ledger.total()
    }

    /// Efficiency in TOPS/W.
    pub fn tops_per_watt(&self) -> f64 {
        self.ops_per_joule() / 1e12
    }

    /// Energy per MAC in joules (Fig. 10's y-axis is pJ/MAC).
    pub fn energy_per_mac(&self) -> f64 {
        self.ledger.total() / self.macs
    }

    pub fn merge(&mut self, other: &SimResult) {
        self.macs += other.macs;
        self.ops += other.ops;
        self.ledger.merge(&other.ledger);
        self.time_units += other.time_units;
    }

    pub fn empty() -> Self {
        SimResult {
            macs: 0.0,
            ops: 0.0,
            ledger: EnergyLedger::new(),
            time_units: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = SimResult::empty();
        a.macs = 10.0;
        a.ops = 20.0;
        a.ledger.add(Component::Sram, 1e-12);
        let mut b = SimResult::empty();
        b.macs = 5.0;
        b.ops = 10.0;
        b.ledger.add(Component::Adc, 2e-12);
        a.merge(&b);
        assert_eq!(a.macs, 15.0);
        assert!((a.ledger.total() - 3e-12).abs() < 1e-24);
    }

    #[test]
    fn efficiency_math() {
        let mut r = SimResult::empty();
        r.macs = 1e6;
        r.ops = 2e6;
        r.ledger.add(Component::Mac, 2e-6); // 1 pJ/op
        assert!((r.tops_per_watt() - 1.0).abs() < 1e-9);
        assert!((r.energy_per_mac() - 2e-12).abs() < 1e-24);
    }
}
