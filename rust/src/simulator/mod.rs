//! Cycle-accurate machine models (paper §VII).
//!
//! Four machines, covering the paper's computational-results section:
//!
//! * [`systolic`] — a weight-stationary 256×256 systolic array with
//!   24 MiB of banked activation SRAM and DRAM-resident weights
//!   (the Google-TPUv1-like machine of Fig. 8);
//! * [`optical4f`] — the reflection-mode optical 4F machine of Fig. 5
//!   with 4 Mpx SLMs (Figs. 9–10);
//! * [`reram`], [`photonic`] — *extensions*: cycle models for the two
//!   planar analog machines of Fig. 3 that the paper only treats
//!   analytically, so all four Fig. 6 processor classes cross-validate
//!   the same way.
//!
//! Unlike the analytic models, the simulators walk every layer tile by
//! tile / execution by execution, so finite array capacity, edge tiles,
//! stride effects and partial-sum spilling are all accounted exactly.
//! Every joule is attributed to a [`ledger::Component`] so Fig. 10's
//! energy-distribution stacks fall out directly.
//!
//! Every simulation entry point takes an [`OperatingPoint`] — node,
//! activation/weight bit widths, and a device [`NoiseModel`] — with
//! `OperatingPoint::default()` reproducing the legacy fixed 45 nm / 8×8
//! configuration bit-exactly. The [`accuracy`] module estimates the
//! effective SNR / task-accuracy retention of a point, so the `aimc
//! pareto` scenario can trace the energy × latency × accuracy frontier.
//! The [`faults`] module makes device non-idealities (stuck cells,
//! conductance drift, ADC saturation, IR drop) first-class: a
//! [`FaultModel`] rides inside the `NoiseModel`, derates every cycle
//! simulator's energy coefficients (identity at zero faults), degrades
//! the accuracy estimator's Monte-Carlo channel, and samples
//! deterministic seeded [`faults::FaultMap`]s — the `aimc faults`
//! scenario sweeps the resulting degradation curves.
//!
//! Sweep drivers do not call the machines directly: the [`machine`]
//! module unifies all four (plus the analytic models) behind the
//! [`Machine`] trait, and [`sweep`] adds layer-dedup memoization
//! ([`SweepCache`]) plus the parallel (machine × network ×
//! operating-point) grid runner built on [`crate::util::pool`].

pub mod accuracy;
pub mod faults;
pub mod ledger;
pub mod machine;
pub mod op;
pub mod optical4f;
pub mod photonic;
pub mod reram;
pub mod sweep;
pub mod systolic;

pub use faults::{FaultMap, FaultModel};
pub use ledger::{Component, EnergyLedger};
pub use machine::{all_machines, AnalyticMachine, Machine};
pub use op::{NoiseModel, OpKey, OperatingPoint};
pub use sweep::{SweepCache, SweepRecord};

/// Result of simulating one network on one machine at one node.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    /// Total MAC count actually performed (useful work only).
    pub macs: f64,
    /// Total operations (2·MACs, the paper's op accounting).
    pub ops: f64,
    /// Energy attribution.
    pub ledger: EnergyLedger,
    /// Machine-specific time proxy: systolic = array cycles,
    /// optical = SLM executions.
    pub time_units: f64,
}

impl SimResult {
    /// Efficiency in ops per joule.
    pub fn ops_per_joule(&self) -> f64 {
        self.ops / self.ledger.total()
    }

    /// Efficiency in TOPS/W.
    pub fn tops_per_watt(&self) -> f64 {
        self.ops_per_joule() / 1e12
    }

    /// Energy per MAC in joules (Fig. 10's y-axis is pJ/MAC).
    pub fn energy_per_mac(&self) -> f64 {
        self.ledger.total() / self.macs
    }

    pub fn merge(&mut self, other: &SimResult) {
        self.macs += other.macs;
        self.ops += other.ops;
        self.ledger.merge(&other.ledger);
        self.time_units += other.time_units;
    }
}

impl std::ops::AddAssign<&SimResult> for SimResult {
    fn add_assign(&mut self, rhs: &SimResult) {
        self.merge(rhs);
    }
}

impl std::ops::AddAssign for SimResult {
    fn add_assign(&mut self, rhs: SimResult) {
        self.merge(&rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = SimResult::default();
        a.macs = 10.0;
        a.ops = 20.0;
        a.ledger.add(Component::Sram, 1e-12);
        let mut b = SimResult::default();
        b.macs = 5.0;
        b.ops = 10.0;
        b.ledger.add(Component::Adc, 2e-12);
        a.merge(&b);
        assert_eq!(a.macs, 15.0);
        assert!((a.ledger.total() - 3e-12).abs() < 1e-24);
    }

    #[test]
    fn add_assign_delegates_to_merge() {
        let mut a = SimResult::default();
        a.macs = 1.0;
        a.time_units = 2.0;
        let mut b = SimResult::default();
        b.macs = 3.0;
        b.ledger.add(Component::Dac, 4e-12);
        a += &b;
        a += b.clone();
        assert_eq!(a.macs, 7.0);
        assert_eq!(a.time_units, 2.0);
        assert!((a.ledger.get(Component::Dac) - 8e-12).abs() < 1e-24);
    }

    #[test]
    fn efficiency_math() {
        let mut r = SimResult::default();
        r.macs = 1e6;
        r.ops = 2e6;
        r.ledger.add(Component::Mac, 2e-6); // 1 pJ/op
        assert!((r.tops_per_watt() - 1.0).abs() < 1e-9);
        assert!((r.energy_per_mac() - 2e-12).abs() < 1e-24);
    }
}
