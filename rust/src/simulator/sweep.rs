//! Layer-dedup memoization + the parallel sweep engine.
//!
//! The zoo networks repeat identical conv shapes heavily (DenseNet201's
//! 200 layers collapse to a few dozen unique (n, Cᵢ, Cᵢ₊₁, k, stride)
//! tuples; VGG repeats its expensive 224²-class layers back to back), and
//! the evaluation grids re-simulate every network at 13 nodes. A
//! [`SweepCache`] keyed by (machine-config fingerprint, operating point,
//! layer shape) therefore simulates each unique tuple **once** and
//! replays the stored [`SimResult`] everywhere else. The operating point
//! joins the key as an [`OpKey`] — exact `f64` bit patterns for node and
//! noise sigmas plus the integer bit widths — so precision sweeps never
//! alias with each other or with the default 8×8 point.
//!
//! Correctness contract: [`SweepCache::simulate_network`] merges the
//! per-layer results *in layer order*, exactly like the direct
//! `simulate_network` paths, so cached totals are **bit-identical** to
//! uncached ones — scaling one result by a multiplicity factor would
//! round differently and is deliberately avoided. The property tests in
//! `tests/sweep_engine.rs` pin this down for all four machines.
//!
//! [`sweep`] is the grid runner on top: every (machine × network ×
//! operating point), evaluated through a shared cache by
//! [`crate::util::pool`] workers, with records returned in deterministic
//! machine-major order.
//!
//! The cache also **persists**: [`SweepCache::save`] snapshots every
//! entry to a text file with bit-exact (hex `f64`) values, and
//! [`SweepCache::load`] restores it — keyed by (config fingerprint,
//! operating point, layer shape), so entries never alias across machine
//! configs or processes and a repeated CLI invocation with `--cache-dir`
//! replays instead of re-simulating. A corrupt, truncated or
//! version-mismatched snapshot (including any v1 file, which predates
//! the precision fields) is *ignored in full* (fresh simulation), never
//! trusted in part.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::machine::Machine;
use super::op::{OpKey, OperatingPoint};
use super::{Component, SimResult};
use crate::networks::{ConvLayer, Network};
use crate::util::pool::Pool;

/// Memo key: machine config fingerprint + operating point + layer.
type Key = (u64, OpKey, ConvLayer);

/// Concurrent memo table for (machine, operating point, layer)
/// simulation results.
///
/// Thread-safe by a plain mutex around the map: the hot path is the
/// *simulation*, which runs outside the lock; the lock only guards
/// clone-in/clone-out of small `SimResult`s. Two workers racing on the
/// same miss both simulate (idempotent — results are identical) and one
/// insert wins.
#[derive(Default)]
pub struct SweepCache {
    entries: Mutex<HashMap<Key, SimResult>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl SweepCache {
    pub fn new() -> Self {
        SweepCache::default()
    }

    /// Price one layer through the cache.
    pub fn simulate_layer(
        &self,
        machine: &dyn Machine,
        layer: &ConvLayer,
        op: &OperatingPoint,
    ) -> SimResult {
        let key = (machine.fingerprint(), op.key(), *layer);
        if let Some(hit) = self.entries.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let r = machine.simulate_layer(layer, op);
        self.entries.lock().unwrap().insert(key, r.clone());
        r
    }

    /// Price a whole network through the cache, merging per-layer
    /// results in layer order (bit-identical to the direct path; see
    /// module docs).
    pub fn simulate_network(
        &self,
        machine: &dyn Machine,
        net: &Network,
        op: &OperatingPoint,
    ) -> SimResult {
        let mut total = SimResult::default();
        for layer in &net.layers {
            total += &self.simulate_layer(machine, layer, op);
        }
        total
    }

    /// Unique (machine, operating point, layer) tuples simulated so far.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to simulate.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// "hits/misses (ratio)" one-liner for CLI / bench output.
    pub fn stats(&self) -> String {
        let (h, m) = (self.hits(), self.misses());
        let total = (h + m).max(1);
        format!(
            "{h} hits / {m} misses ({:.1}% reuse)",
            100.0 * h as f64 / total as f64
        )
    }

    /// Price a whole network with the unique layer shapes fanned out
    /// over `pool` first (one worker per unique (shape) tuple), then the
    /// usual in-layer-order merge — so a single-network CLI call uses
    /// every core while the total stays **bit-identical** to the serial
    /// [`SweepCache::simulate_network`] path (the merge never reorders).
    ///
    /// Counter semantics: the warm-up records one lookup per unique
    /// shape and the merge one (hit) per layer, so hits/misses count
    /// both passes' lookups — a higher reuse % than the serial walk of
    /// the same cold network would report.
    pub fn simulate_network_par(
        &self,
        pool: &Pool,
        machine: &dyn Machine,
        net: &Network,
        op: &OperatingPoint,
    ) -> SimResult {
        let mut seen = HashSet::new();
        let uniq: Vec<ConvLayer> = net
            .layers
            .iter()
            .filter(|l| seen.insert(**l))
            .copied()
            .collect();
        pool.par_for_each(&uniq, |l| {
            let _ = self.simulate_layer(machine, l, op);
        });
        // Every shape is now cached: the merge below is pure hits.
        self.simulate_network(machine, net, op)
    }

    /// Training rows for the [`crate::energy::surrogate`] fitter: one
    /// `(layer, total energy in joules)` pair per unique shape in
    /// `layers`, for one machine × operating point. Served through the
    /// cache, so grid points warmed by earlier sweeps are replayed
    /// bit-exactly and anything missing is simulated once and retained
    /// for later callers (the crossval pass reuses the same entries).
    pub fn training_rows(
        &self,
        machine: &dyn Machine,
        layers: &[ConvLayer],
        op: &OperatingPoint,
    ) -> Vec<(ConvLayer, f64)> {
        let mut seen = HashSet::new();
        layers
            .iter()
            .filter(|l| seen.insert(**l))
            .map(|l| (*l, self.simulate_layer(machine, l, op).ledger.total()))
            .collect()
    }

    // ---- persistence -----------------------------------------------------

    /// Snapshot every cache entry to `path`. Entries are sorted by key,
    /// so identical cache contents produce identical files; every `f64`
    /// is written as its IEEE-754 bit pattern in hex, so a reload is
    /// bit-identical to the simulation that produced it. The write is
    /// atomic (temp file + rename), so an interrupted or concurrent
    /// save leaves either the old snapshot or the new one — never a
    /// truncated file that would silently cost a full re-simulation.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let entries = self.entries.lock().unwrap();
        let out = render_snapshot(&entries);
        drop(entries);
        write_atomic(path, &out)
    }

    /// Snapshot the cache into `dir`, **sharded by machine-config
    /// fingerprint**: one `sweep-cache.v3.<fp>.txt` file per fingerprint,
    /// each written atomically (temp + rename) after unioning with
    /// whatever that shard already holds on disk. Concurrent processes
    /// sharing a `--cache-dir` therefore merge instead of losing entries
    /// to last-writer-wins: writers touching *different* configs write
    /// different files outright, and writers racing on the *same* config
    /// re-read the shard and union before renaming (entries are
    /// idempotent simulations, so both sides of any remaining race carry
    /// bit-identical values). Returns the number of shard files written.
    pub fn save_sharded(&self, dir: &Path) -> std::io::Result<usize> {
        let entries = self.entries.lock().unwrap();
        let mut by_fp: HashMap<u64, HashMap<Key, SimResult>> = HashMap::new();
        for (key, r) in entries.iter() {
            by_fp.entry(key.0).or_default().insert(*key, r.clone());
        }
        drop(entries);
        let mut written = 0;
        for (fp, mut group) in by_fp {
            let path = shard_file(dir, fp);
            if let Some(existing) = std::fs::read_to_string(&path)
                .ok()
                .and_then(|text| parse_snapshot(&text))
            {
                for (k, v) in existing {
                    group.entry(k).or_insert(v);
                }
            }
            write_atomic(&path, &render_snapshot(&group))?;
            written += 1;
        }
        Ok(written)
    }

    /// Restore a cache from every snapshot in `dir`: all fingerprint
    /// shards written by [`SweepCache::save_sharded`] plus a legacy
    /// monolithic `sweep-cache.v3.txt` if one is still around (so a
    /// pre-sharding cache directory keeps replaying; the next save
    /// re-homes its entries into shards). Each file is still
    /// all-or-nothing — a corrupt shard is skipped in full — but one bad
    /// shard no longer discards its healthy siblings. A missing or empty
    /// directory loads an empty cache.
    pub fn load_sharded(dir: &Path) -> SweepCache {
        let mut map = HashMap::new();
        let Ok(read_dir) = std::fs::read_dir(dir) else {
            return SweepCache::new();
        };
        let mut paths: Vec<std::path::PathBuf> = read_dir
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("sweep-cache.v3") && n.ends_with(".txt"))
            })
            .collect();
        paths.sort();
        for path in paths {
            if let Some(parsed) = std::fs::read_to_string(&path)
                .ok()
                .and_then(|text| parse_snapshot(&text))
            {
                for (k, v) in parsed {
                    map.entry(k).or_insert(v);
                }
            }
        }
        SweepCache {
            entries: Mutex::new(map),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Restore a cache from a [`SweepCache::save`] snapshot. Any anomaly
    /// — missing file, wrong magic/version (v1 snapshots included), bad
    /// field, truncated or over-long body, negative/NaN energy — discards
    /// the whole snapshot and returns an **empty** cache, so corruption
    /// can only ever cost re-simulation, never wrong numbers.
    pub fn load(path: &Path) -> SweepCache {
        let parsed = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| parse_snapshot(&text));
        match parsed {
            Some(map) => SweepCache {
                entries: Mutex::new(map),
                hits: AtomicUsize::new(0),
                misses: AtomicUsize::new(0),
            },
            None => SweepCache::new(),
        }
    }
}

/// Snapshot header: format name + version. Bump the version on any
/// layout change — old files then deliberately fail to load. v2 added
/// the operating-point precision/noise fields to every line; v3 added
/// the four fault-model fields (stuck rate, drift sigma, ADC clip,
/// IR drop) so fault-derated energies never alias clean ones.
const SNAPSHOT_MAGIC: &str = "aimc-sweepcache-v3";

/// Where one config fingerprint's shard lives inside a cache directory.
/// The fixed-width hex keeps `ls` stable and the prefix greppable next
/// to the legacy monolithic `sweep-cache.v3.txt`.
fn shard_file(dir: &Path, fp: u64) -> std::path::PathBuf {
    dir.join(format!("sweep-cache.v3.{fp:016x}.txt"))
}

/// Render entries in [`SweepCache::save`]'s line format: sorted by key,
/// so identical contents produce identical files; every `f64` as its
/// IEEE-754 bit pattern in hex, so a reload is bit-identical.
fn render_snapshot(entries: &HashMap<Key, SimResult>) -> String {
    let mut keys: Vec<&Key> = entries.keys().collect();
    keys.sort_by_key(|(fp, op, l)| (*fp, *op, l.n, l.c_in, l.c_out, l.kh, l.kw, l.stride));
    let mut out = String::with_capacity(64 + keys.len() * 200);
    out.push_str(&format!("{SNAPSHOT_MAGIC} {}\n", keys.len()));
    for key in keys {
        let (fp, op, l) = key;
        let r = &entries[key];
        out.push_str(&format!(
            "{fp:016x} {:016x} {} {} {:016x} {:016x} {:016x} {:016x} {:016x} {:016x} {} {} {} {} {} {} {:016x} {:016x} {:016x}",
            op.node_bits,
            op.bits_x,
            op.bits_w,
            op.wsig_bits,
            op.osig_bits,
            op.stuck_bits,
            op.drift_bits,
            op.clip_bits,
            op.ir_bits,
            l.n,
            l.c_in,
            l.c_out,
            l.kh,
            l.kw,
            l.stride,
            r.macs.to_bits(),
            r.ops.to_bits(),
            r.time_units.to_bits(),
        ));
        for c in Component::ALL {
            out.push_str(&format!(" {:016x}", r.ledger.get(c).to_bits()));
        }
        out.push('\n');
    }
    out
}

/// Same-directory temp (rename is only atomic within a filesystem);
/// pid-suffixed so concurrent savers never clobber each other's staging
/// file. An interrupted or concurrent write leaves either the old file
/// or the new one — never a truncated snapshot.
fn write_atomic(path: &Path, contents: &str) -> std::io::Result<()> {
    let file = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("sweep-cache");
    let tmp = path.with_file_name(format!("{file}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

/// Strict snapshot parser: `None` on ANY deviation (see
/// [`SweepCache::load`]).
fn parse_snapshot(text: &str) -> Option<HashMap<Key, SimResult>> {
    let mut lines = text.lines();
    let header = lines.next()?;
    let count: usize = header.strip_prefix(SNAPSHOT_MAGIC)?.trim().parse().ok()?;
    // `count` is untrusted input: cap the pre-allocation so a corrupt
    // header can't abort on a huge reserve — the map still grows to any
    // genuine size.
    let mut map = HashMap::with_capacity(count.min(1 << 16));
    for _ in 0..count {
        let line = lines.next()?;
        let tok: Vec<&str> = line.split_whitespace().collect();
        if tok.len() != 19 + Component::ALL.len() {
            return None;
        }
        let fp = u64::from_str_radix(tok[0], 16).ok()?;
        let sigma_at = |i: usize| -> Option<u64> {
            let bits = u64::from_str_radix(tok[i], 16).ok()?;
            let v = f64::from_bits(bits);
            // Noise sigmas and fault fields are finite and non-negative
            // by construction.
            (v.is_finite() && v >= 0.0).then_some(bits)
        };
        let op = OpKey {
            node_bits: u64::from_str_radix(tok[1], 16).ok()?,
            bits_x: tok[2].parse().ok()?,
            bits_w: tok[3].parse().ok()?,
            wsig_bits: sigma_at(4)?,
            osig_bits: sigma_at(5)?,
            stuck_bits: sigma_at(6)?,
            drift_bits: sigma_at(7)?,
            clip_bits: sigma_at(8)?,
            ir_bits: sigma_at(9)?,
        };
        let layer = ConvLayer {
            n: tok[10].parse().ok()?,
            c_in: tok[11].parse().ok()?,
            c_out: tok[12].parse().ok()?,
            kh: tok[13].parse().ok()?,
            kw: tok[14].parse().ok()?,
            stride: tok[15].parse().ok()?,
        };
        let f64_at = |i: usize| -> Option<f64> {
            let v = f64::from_bits(u64::from_str_radix(tok[i], 16).ok()?);
            // Simulation outputs are finite and non-negative; anything
            // else is corruption.
            (v.is_finite() && v >= 0.0).then_some(v)
        };
        let mut r = SimResult {
            macs: f64_at(16)?,
            ops: f64_at(17)?,
            time_units: f64_at(18)?,
            ..SimResult::default()
        };
        for (i, c) in Component::ALL.iter().enumerate() {
            r.ledger.add(*c, f64_at(19 + i)?);
        }
        if map.insert((fp, op, layer), r).is_some() {
            return None; // duplicate key: corrupt writer
        }
    }
    // Exactly `count` entries and nothing but trailing whitespace after.
    if lines.any(|l| !l.trim().is_empty()) {
        return None;
    }
    Some(map)
}

/// One evaluated grid point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub machine: &'static str,
    pub network: &'static str,
    pub op: OperatingPoint,
    pub result: SimResult,
}

/// Evaluate the full (machine × network × operating point) grid in
/// parallel through a shared cache. Records come back machine-major,
/// then network, then operating point — the exact order a serial triple
/// loop would produce — so drivers can index
/// `records[(mi * nets.len() + ni) * ops.len() + ki]` or just iterate.
pub fn sweep(
    machines: &[Box<dyn Machine>],
    nets: &[Network],
    ops: &[OperatingPoint],
    cache: &SweepCache,
) -> Vec<SweepRecord> {
    sweep_on(&Pool::auto(), machines, nets, ops, cache)
}

/// [`sweep`] with an explicit pool (serial baseline: `Pool::new(1)`).
pub fn sweep_on(
    pool: &Pool,
    machines: &[Box<dyn Machine>],
    nets: &[Network],
    ops: &[OperatingPoint],
    cache: &SweepCache,
) -> Vec<SweepRecord> {
    let mut points: Vec<(usize, usize, OperatingPoint)> =
        Vec::with_capacity(machines.len() * nets.len() * ops.len());
    for mi in 0..machines.len() {
        for ni in 0..nets.len() {
            for &op in ops {
                points.push((mi, ni, op));
            }
        }
    }
    pool.par_map(&points, |&(mi, ni, op)| SweepRecord {
        machine: machines[mi].name(),
        network: nets[ni].name,
        op,
        result: cache.simulate_network(machines[mi].as_ref(), &nets[ni], &op),
    })
}

/// Operating points for a plain node sweep at default precision — the
/// bridge from the legacy `&[f64]` node-list call sites.
pub fn ops_at_nodes(nodes: &[f64]) -> Vec<OperatingPoint> {
    nodes.iter().map(|&nm| OperatingPoint::node(nm)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;
    use crate::simulator::machine::all_machines;
    use crate::simulator::{systolic, Component};

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn cache_hits_on_repeated_layers() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let net = yolov3(1000); // plenty of repeated residual-block shapes
        let r = cache.simulate_network(&cfg, &net, &op(45.0));
        assert!(r.macs > 0.0);
        assert!(cache.hits() > 0, "YOLOv3 repeats shapes: {}", cache.stats());
        assert_eq!(cache.hits() + cache.misses(), net.num_layers());
        assert_eq!(cache.len(), cache.misses());
    }

    #[test]
    fn cached_network_bit_identical_to_direct() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let net = yolov3(1000);
        let direct = systolic::simulate_network(&cfg, &net, &op(28.0));
        let cached = cache.simulate_network(&cfg, &net, &op(28.0));
        let again = cache.simulate_network(&cfg, &net, &op(28.0)); // pure hits
        for r in [&cached, &again] {
            assert_eq!(direct.macs, r.macs);
            assert_eq!(direct.ops, r.ops);
            assert_eq!(direct.time_units, r.time_units);
            for c in Component::ALL {
                assert_eq!(direct.ledger.get(c), r.ledger.get(c), "{c:?}");
            }
        }
    }

    #[test]
    fn distinct_configs_never_alias() {
        let cache = SweepCache::new();
        let small = systolic::SystolicConfig {
            dim: 64,
            banks: 64,
            ..Default::default()
        };
        let big = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let a = cache.simulate_layer(&small, &layer, &op(45.0));
        let b = cache.simulate_layer(&big, &layer, &op(45.0));
        assert_eq!(cache.misses(), 2, "two configs → two entries");
        assert!(a.ledger.total() != b.ledger.total());
    }

    #[test]
    fn distinct_nodes_never_alias() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let a = cache.simulate_layer(&cfg, &layer, &op(45.0));
        let b = cache.simulate_layer(&cfg, &layer, &op(7.0));
        assert_eq!(cache.misses(), 2);
        assert!(a.ledger.total() > b.ledger.total());
    }

    #[test]
    fn distinct_precisions_never_alias() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let a = cache.simulate_layer(&cfg, &layer, &op(45.0));
        let b = cache.simulate_layer(&cfg, &layer, &op(45.0).bits(4, 4));
        let c = cache.simulate_layer(&cfg, &layer, &op(45.0).bits(8, 4));
        assert_eq!(cache.misses(), 3, "three operating points → three entries");
        assert!(b.ledger.total() < a.ledger.total());
        assert!(c.ledger.total() < a.ledger.total());
        assert!(b.ledger.total() < c.ledger.total());
    }

    #[test]
    fn sweep_grid_order_is_machine_major() {
        let machines = all_machines();
        let nets = vec![yolov3(200)];
        let ops = ops_at_nodes(&[45.0, 7.0]);
        let cache = SweepCache::new();
        let recs = sweep(&machines, &nets, &ops, &cache);
        assert_eq!(recs.len(), machines.len() * nets.len() * ops.len());
        let mut i = 0;
        for m in &machines {
            for net in &nets {
                for point in &ops {
                    assert_eq!(recs[i].machine, m.name());
                    assert_eq!(recs[i].network, net.name);
                    assert_eq!(recs[i].op, *point);
                    assert!(recs[i].result.macs > 0.0);
                    i += 1;
                }
            }
        }
    }

    /// Fresh temp directory per test so parallel test threads never
    /// collide (pid + tag).
    fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "aimc-sweepcache-shard-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sharded_snapshots_survive_both_writers() {
        // Two "processes" (caches) with different machine configs share
        // one cache dir: after both save, BOTH sets of entries must
        // load back — the last-writer-wins loss mode is gone.
        let dir = temp_cache_dir("two-writers");
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let small = systolic::SystolicConfig {
            dim: 64,
            banks: 64,
            ..Default::default()
        };
        let big = systolic::SystolicConfig::default();

        let a = SweepCache::new();
        let ra = a.simulate_layer(&small, &layer, &op(45.0));
        assert_eq!(a.save_sharded(&dir).unwrap(), 1);
        let b = SweepCache::new();
        let rb = b.simulate_layer(&big, &layer, &op(45.0));
        assert_eq!(b.save_sharded(&dir).unwrap(), 1);

        let shards = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(shards, 2, "one shard file per config fingerprint");
        let merged = SweepCache::load_sharded(&dir);
        assert_eq!(merged.len(), 2, "both writers' entries survive");
        let ra2 = merged.simulate_layer(&small, &layer, &op(45.0));
        let rb2 = merged.simulate_layer(&big, &layer, &op(45.0));
        assert_eq!(merged.misses(), 0, "replay must not simulate");
        assert_eq!(ra.ledger.total(), ra2.ledger.total());
        assert_eq!(rb.ledger.total(), rb2.ledger.total());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_fingerprint_writers_union_their_entries() {
        // Two writers on the SAME config but different operating points
        // race on one shard file: the second save re-reads and unions,
        // so the first writer's entry survives.
        let dir = temp_cache_dir("same-fp");
        let cfg = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);

        let a = SweepCache::new();
        let _ = a.simulate_layer(&cfg, &layer, &op(45.0));
        a.save_sharded(&dir).unwrap();
        let b = SweepCache::new();
        let _ = b.simulate_layer(&cfg, &layer, &op(7.0));
        b.save_sharded(&dir).unwrap();

        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            1,
            "same fingerprint → one shard"
        );
        let merged = SweepCache::load_sharded(&dir);
        assert_eq!(merged.len(), 2, "union, not last-writer-wins");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_monolithic_snapshot_loads_and_migrates() {
        // A pre-sharding cache dir holds the old sweep-cache.v3.txt:
        // load_sharded must replay it, and the next save_sharded re-homes
        // the entries into fingerprint shards.
        let dir = temp_cache_dir("legacy");
        let cfg = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let old = SweepCache::new();
        let _ = old.simulate_layer(&cfg, &layer, &op(45.0));
        old.save(&dir.join("sweep-cache.v3.txt")).unwrap();

        let loaded = SweepCache::load_sharded(&dir);
        assert_eq!(loaded.len(), 1, "legacy snapshot still replays");
        loaded.save_sharded(&dir).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert!(
            names.iter().any(|n| n.len() > "sweep-cache.v3.txt".len()),
            "entries re-homed into a fingerprint shard: {names:?}"
        );
        // A corrupt shard is skipped in full without poisoning siblings.
        std::fs::write(dir.join("sweep-cache.v3.dead.txt"), "garbage\n").unwrap();
        assert_eq!(SweepCache::load_sharded(&dir).len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallel_sweep_matches_serial_sweep() {
        let machines = all_machines();
        let nets = vec![yolov3(200)];
        let ops = [op(45.0), op(28.0), op(7.0).bits(4, 4)];
        let serial = sweep_on(
            &Pool::new(1),
            &machines,
            &nets,
            &ops,
            &SweepCache::new(),
        );
        let parallel = sweep_on(
            &Pool::new(8),
            &machines,
            &nets,
            &ops,
            &SweepCache::new(),
        );
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.machine, b.machine);
            assert_eq!(a.op, b.op);
            assert_eq!(a.result.macs, b.result.macs);
            for c in Component::ALL {
                assert_eq!(a.result.ledger.get(c), b.result.ledger.get(c));
            }
        }
    }
}
