//! Layer-dedup memoization + the parallel sweep engine.
//!
//! The zoo networks repeat identical conv shapes heavily (DenseNet201's
//! 200 layers collapse to a few dozen unique (n, Cᵢ, Cᵢ₊₁, k, stride)
//! tuples; VGG repeats its expensive 224²-class layers back to back), and
//! the evaluation grids re-simulate every network at 13 nodes. A
//! [`SweepCache`] keyed by (machine-config fingerprint, node, layer
//! shape) therefore simulates each unique tuple **once** and replays the
//! stored [`SimResult`] everywhere else.
//!
//! Correctness contract: [`SweepCache::simulate_network`] merges the
//! per-layer results *in layer order*, exactly like the direct
//! `simulate_network` paths, so cached totals are **bit-identical** to
//! uncached ones — scaling one result by a multiplicity factor would
//! round differently and is deliberately avoided. The property tests in
//! `tests/sweep_engine.rs` pin this down for all four machines.
//!
//! [`sweep`] is the grid runner on top: every (machine × network × node)
//! point, evaluated through a shared cache by [`crate::util::pool`]
//! workers, with records returned in deterministic machine-major order.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::machine::Machine;
use super::SimResult;
use crate::networks::{ConvLayer, Network};
use crate::util::pool::Pool;

/// Memo key: machine config fingerprint + node (exact bits) + layer.
type Key = (u64, u64, ConvLayer);

/// Concurrent memo table for (machine, node, layer) simulation results.
///
/// Thread-safe by a plain mutex around the map: the hot path is the
/// *simulation*, which runs outside the lock; the lock only guards
/// clone-in/clone-out of small `SimResult`s. Two workers racing on the
/// same miss both simulate (idempotent — results are identical) and one
/// insert wins.
#[derive(Default)]
pub struct SweepCache {
    entries: Mutex<HashMap<Key, SimResult>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl SweepCache {
    pub fn new() -> Self {
        SweepCache::default()
    }

    /// Price one layer through the cache.
    pub fn simulate_layer(
        &self,
        machine: &dyn Machine,
        layer: &ConvLayer,
        node_nm: f64,
    ) -> SimResult {
        let key = (machine.fingerprint(), node_nm.to_bits(), *layer);
        if let Some(hit) = self.entries.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let r = machine.simulate_layer(layer, node_nm);
        self.entries.lock().unwrap().insert(key, r.clone());
        r
    }

    /// Price a whole network through the cache, merging per-layer
    /// results in layer order (bit-identical to the direct path; see
    /// module docs).
    pub fn simulate_network(
        &self,
        machine: &dyn Machine,
        net: &Network,
        node_nm: f64,
    ) -> SimResult {
        let mut total = SimResult::default();
        for layer in &net.layers {
            total += &self.simulate_layer(machine, layer, node_nm);
        }
        total
    }

    /// Unique (machine, node, layer) tuples simulated so far.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to simulate.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// "hits/misses (ratio)" one-liner for CLI / bench output.
    pub fn stats(&self) -> String {
        let (h, m) = (self.hits(), self.misses());
        let total = (h + m).max(1);
        format!(
            "{h} hits / {m} misses ({:.1}% reuse)",
            100.0 * h as f64 / total as f64
        )
    }
}

/// One evaluated grid point of a sweep.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub machine: &'static str,
    pub network: &'static str,
    pub node_nm: f64,
    pub result: SimResult,
}

/// Evaluate the full (machine × network × node) grid in parallel through
/// a shared cache. Records come back machine-major, then network, then
/// node — the exact order a serial triple loop would produce — so
/// drivers can index `records[(mi * nets.len() + ni) * nodes.len() + ki]`
/// or just iterate.
pub fn sweep(
    machines: &[Box<dyn Machine>],
    nets: &[Network],
    nodes: &[f64],
    cache: &SweepCache,
) -> Vec<SweepRecord> {
    sweep_on(&Pool::auto(), machines, nets, nodes, cache)
}

/// [`sweep`] with an explicit pool (serial baseline: `Pool::new(1)`).
pub fn sweep_on(
    pool: &Pool,
    machines: &[Box<dyn Machine>],
    nets: &[Network],
    nodes: &[f64],
    cache: &SweepCache,
) -> Vec<SweepRecord> {
    let mut points: Vec<(usize, usize, f64)> =
        Vec::with_capacity(machines.len() * nets.len() * nodes.len());
    for mi in 0..machines.len() {
        for ni in 0..nets.len() {
            for &node in nodes {
                points.push((mi, ni, node));
            }
        }
    }
    pool.par_map(&points, |&(mi, ni, node)| SweepRecord {
        machine: machines[mi].name(),
        network: nets[ni].name,
        node_nm: node,
        result: cache.simulate_network(machines[mi].as_ref(), &nets[ni], node),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;
    use crate::simulator::machine::all_machines;
    use crate::simulator::{systolic, Component};

    #[test]
    fn cache_hits_on_repeated_layers() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let net = yolov3(1000); // plenty of repeated residual-block shapes
        let r = cache.simulate_network(&cfg, &net, 45.0);
        assert!(r.macs > 0.0);
        assert!(cache.hits() > 0, "YOLOv3 repeats shapes: {}", cache.stats());
        assert_eq!(cache.hits() + cache.misses(), net.num_layers());
        assert_eq!(cache.len(), cache.misses());
    }

    #[test]
    fn cached_network_bit_identical_to_direct() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let net = yolov3(1000);
        let direct = systolic::simulate_network(&cfg, &net, 28.0);
        let cached = cache.simulate_network(&cfg, &net, 28.0);
        let again = cache.simulate_network(&cfg, &net, 28.0); // pure hits
        for r in [&cached, &again] {
            assert_eq!(direct.macs, r.macs);
            assert_eq!(direct.ops, r.ops);
            assert_eq!(direct.time_units, r.time_units);
            for c in Component::ALL {
                assert_eq!(direct.ledger.get(c), r.ledger.get(c), "{c:?}");
            }
        }
    }

    #[test]
    fn distinct_configs_never_alias() {
        let cache = SweepCache::new();
        let small = systolic::SystolicConfig {
            dim: 64,
            banks: 64,
            ..Default::default()
        };
        let big = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let a = cache.simulate_layer(&small, &layer, 45.0);
        let b = cache.simulate_layer(&big, &layer, 45.0);
        assert_eq!(cache.misses(), 2, "two configs → two entries");
        assert!(a.ledger.total() != b.ledger.total());
    }

    #[test]
    fn distinct_nodes_never_alias() {
        let cache = SweepCache::new();
        let cfg = systolic::SystolicConfig::default();
        let layer = crate::networks::ConvLayer::square(64, 32, 32, 3, 1);
        let a = cache.simulate_layer(&cfg, &layer, 45.0);
        let b = cache.simulate_layer(&cfg, &layer, 7.0);
        assert_eq!(cache.misses(), 2);
        assert!(a.ledger.total() > b.ledger.total());
    }

    #[test]
    fn sweep_grid_order_is_machine_major() {
        let machines = all_machines();
        let nets = vec![yolov3(200)];
        let nodes = [45.0, 7.0];
        let cache = SweepCache::new();
        let recs = sweep(&machines, &nets, &nodes, &cache);
        assert_eq!(recs.len(), machines.len() * nets.len() * nodes.len());
        let mut i = 0;
        for m in &machines {
            for net in &nets {
                for &node in &nodes {
                    assert_eq!(recs[i].machine, m.name());
                    assert_eq!(recs[i].network, net.name);
                    assert_eq!(recs[i].node_nm, node);
                    assert!(recs[i].result.macs > 0.0);
                    i += 1;
                }
            }
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_sweep() {
        let machines = all_machines();
        let nets = vec![yolov3(200)];
        let nodes = [45.0, 28.0, 7.0];
        let serial = sweep_on(
            &Pool::new(1),
            &machines,
            &nets,
            &nodes,
            &SweepCache::new(),
        );
        let parallel = sweep_on(
            &Pool::new(8),
            &machines,
            &nets,
            &nodes,
            &SweepCache::new(),
        );
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.machine, b.machine);
            assert_eq!(a.node_nm, b.node_nm);
            assert_eq!(a.result.macs, b.result.macs);
            for c in Component::ALL {
                assert_eq!(a.result.ledger.get(c), b.result.ledger.get(c));
            }
        }
    }
}
