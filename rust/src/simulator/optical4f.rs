//! Cycle-accurate reflection-mode optical 4F machine (paper §VII.B–C,
//! Figs. 5, 9, 10).
//!
//! The machine of Fig. 5: one lens between two hybrid chips, each holding
//! an SLM/metasurface half and a CMOS image-sensor half. Every layer runs
//! in two phases:
//!
//! * **Load phase** (Fig. 5a): C′ input channels are tiled onto the
//!   object-plane SLM (2 DACs/pixel for the complex write), one laser
//!   shot takes the optical Fourier transform, the CIS reads the spectrum
//!   interferometrically (2 ADCs/pixel) and it is re-written to the
//!   Fourier-plane SLM (2 DACs/pixel) — eq. (18)'s n²Cᵢ(2e_adc + 4e_dac).
//! * **Compute phase** (Fig. 5b): per output channel, the kernel stack is
//!   written to the object SLM (2 DACs per kernel pixel), a laser shot
//!   performs Λ·(Ux) and the second Fourier transform, and the CIS reads
//!   the convolution (2 ADCs per output pixel) — eq. (19).
//!
//! Differences from the analytic eq. (24) — exactly the ones the paper
//! lists in §VII.B: exact execution counts (⌈Cᵢ/C′⌉ groups × Cᵢ₊₁
//! output channels), stride-aware CIS readout, and laser energy charged
//! per shot proportional to the full metasurface size rather than folded
//! into e_dac.

//!
//! All entry points take an [`OperatingPoint`]: activation SLM writes /
//! CIS reads / the laser shot-noise budget follow `bits_x`, kernel SLM
//! writes follow `bits_w`, and the default 8×8 point reproduces the
//! fixed-precision model bit-exactly.

use super::op::OperatingPoint;
use super::{Component, EnergyLedger, SimResult};
use crate::energy::{
    constants::{SLM_PIXELS, TOTAL_SRAM_BYTES},
    load::presets,
    sram::{bank_bytes, Sram},
    EnergyParams,
};
use crate::networks::{ConvLayer, Network};

/// Machine description.
#[derive(Clone, Copy, Debug)]
pub struct Optical4FConfig {
    /// SLM pixel count N̂ (4 Mpx default).
    pub slm_pixels: usize,
    /// Total activation SRAM, bytes.
    pub sram_bytes: usize,
    /// SRAM bank count (2048 × 12 KB default).
    pub banks: usize,
    /// Bytes per stored activation (1 = 8-bit).
    pub act_bytes: f64,
    /// Bytes per partial sum when channel groups accumulate (4 = 32-bit).
    pub psum_bytes: f64,
    /// Laser energy charged per shot per SLM pixel? When `true` (paper's
    /// cycle model) each execution pays e_opt × N̂; when `false` only
    /// active pixels pay (an idealized shuttered illuminator — ablation).
    pub laser_full_aperture: bool,
}

impl Default for Optical4FConfig {
    fn default() -> Self {
        Optical4FConfig {
            slm_pixels: SLM_PIXELS,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: 2048,
            act_bytes: 1.0,
            psum_bytes: 4.0,
            laser_full_aperture: true,
        }
    }
}

impl Optical4FConfig {
    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }

    /// Channels that fit on the SLM at once for a padded tile of s² px
    /// (eq. 22), clamped to [1, Cᵢ].
    pub fn channels_at_once(&self, s: usize, c_in: usize) -> usize {
        ((self.slm_pixels / (s * s)).max(1)).min(c_in.max(1))
    }

    /// Spatial patches needed when one padded channel exceeds the SLM:
    /// the image is split into overlapping patches whose inner (valid)
    /// region tiles the output plane.
    pub fn spatial_patches(&self, n: usize, k: usize) -> usize {
        let s = n + k - 1;
        if s * s <= self.slm_pixels {
            return 1;
        }
        let side = (self.slm_pixels as f64).sqrt().floor() as usize;
        let inner = side.saturating_sub(k - 1).max(1);
        n.div_ceil(inner).pow(2)
    }
}

struct Coeffs {
    /// Activation-pixel SLM write (bits_x DAC + line load).
    e_dac_px: f64,
    /// Kernel-pixel SLM write (bits_w DAC + line load).
    e_dac_kern_px: f64,
    e_adc: f64,
    e_opt_px: f64,
    e_sram_byte: f64,
    /// Bytes per stored activation at this precision.
    act_bytes: f64,
    /// Bytes per stored kernel element at this precision.
    wgt_bytes: f64,
}

impl Coeffs {
    fn new(cfg: &Optical4FConfig, op: &OperatingPoint) -> Self {
        let e = EnergyParams::default().at_op(op);
        // Pixel-wise DAC: converter circuit + segmented active-matrix
        // line load (node-independent wire term).
        let slm_line = presets::slm_2048().energy();
        // Fault derates: dead/stuck SLM pixels behave like stuck analog
        // cells (spare-pixel redundancy + recalibration refresh charge
        // the optical budget), while drive droop and CIS ADC range
        // pressure surcharge the converters. Exactly ×1.0 when ideal.
        let cell = op.noise.faults.cell_derate();
        let conv = op.noise.faults.converter_derate();
        Coeffs {
            e_dac_px: (e.e_dac_x + slm_line) * conv,
            e_dac_kern_px: (e.e_dac_w + slm_line) * conv,
            e_adc: e.e_adc * conv,
            e_opt_px: e.e_opt * cell,
            e_sram_byte: Sram::at_node(cfg.bank_bytes(), op.node_nm).energy_per_byte,
            act_bytes: cfg.act_bytes * op.sx(),
            wgt_bytes: cfg.act_bytes * op.sw(),
        }
    }
}

/// Simulate one conv layer (stride supported; the FFT is computed on the
/// full input, only the CIS readout is stride-decimated).
pub fn simulate_layer(cfg: &Optical4FConfig, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    simulate_layer_with(cfg, layer, &c)
}

fn simulate_layer_with(
    cfg: &Optical4FConfig,
    layer: &ConvLayer,
    c: &Coeffs,
) -> SimResult {
    let n = layer.n;
    let k = layer.kh.max(layer.kw);
    let ci = layer.c_in;
    let co = layer.c_out;
    let n_out = {
        // VALID output, stride-decimated.
        let span = n.saturating_sub(k) / layer.stride + 1;
        span * span
    } as f64;

    let patches = cfg.spatial_patches(n, k);
    // Per-patch spatial extent (padded): whole image if it fits.
    let s2 = if patches == 1 {
        ((n + k - 1) * (n + k - 1)) as f64
    } else {
        cfg.slm_pixels as f64
    };
    let c_prime = cfg.channels_at_once(((s2).sqrt()) as usize, ci);
    let groups = ci.div_ceil(c_prime);

    let laser_px = if cfg.laser_full_aperture {
        cfg.slm_pixels as f64
    } else {
        s2 * c_prime as f64
    };

    let mut ledger = EnergyLedger::new();
    let mut executions = 0.0;

    for _patch in 0..patches {
        let mut remaining = ci;
        for _g in 0..groups {
            let cg = remaining.min(c_prime) as f64;
            remaining -= cg as usize;
            let act_px = s2 * cg; // active pixels this group

            // ---- Load phase (eq. 18) ----
            // Activations out of SRAM to drive the object SLM.
            ledger.add(Component::Sram, act_px * c.act_bytes * c.e_sram_byte);
            // Complex write of the input (2 DACs/px).
            ledger.add(Component::Dac, 2.0 * act_px * c.e_dac_px);
            // One laser shot for the optical FFT.
            ledger.add(Component::Laser, laser_px * c.e_opt_px);
            executions += 1.0;
            // Interferometric CIS read of the spectrum (2 ADCs/px) and
            // complex re-write to the Fourier-plane SLM (2 DACs/px).
            ledger.add(Component::Adc, 2.0 * act_px * c.e_adc);
            ledger.add(Component::Dac, 2.0 * act_px * c.e_dac_px);

            // ---- Compute phase (eq. 19), one execution per out-channel.
            // Every output channel of this group performs identical
            // work, so the Cᵢ₊₁ executions are charged in closed form
            // (hoisting this loop cut the YOLOv3 whole-network sim from
            // 43 µs to ~6 µs — EXPERIMENTS.md §Perf).
            let kern_px = (k * k) as f64 * cg;
            let cof = co as f64;
            // Kernel stacks from SRAM, complex writes to the object SLM.
            ledger.add(
                Component::Sram,
                cof * kern_px * c.wgt_bytes * c.e_sram_byte,
            );
            ledger.add(Component::Dac, cof * 2.0 * kern_px * c.e_dac_kern_px);
            // One laser shot per output channel for Λ·Ux + second FFT.
            ledger.add(Component::Laser, cof * laser_px * c.e_opt_px);
            executions += cof;
            // CIS reads the (stride-decimated) output field.
            let out_px = n_out / patches as f64;
            ledger.add(Component::Adc, cof * 2.0 * out_px * c.e_adc);
            // Output buffering: final group writes the bits_x-wide
            // result; earlier groups spill 32-bit partial fields.
            if groups > 1 && remaining > 0 {
                ledger.add(
                    Component::Sram,
                    cof * 2.0 * out_px * cfg.psum_bytes * c.e_sram_byte,
                );
            } else {
                ledger.add(
                    Component::Sram,
                    cof * out_px * c.act_bytes * c.e_sram_byte,
                );
            }
        }
    }

    // Useful work = the VALID output region the CIS actually measured —
    // the same count the systolic machine's Toeplitz GEMM performs, so
    // cross-machine TOPS/W comparisons are apples-to-apples.
    let macs = n_out * layer.k2() * (ci * co) as f64;
    SimResult {
        macs,
        ops: 2.0 * macs,
        ledger,
        time_units: executions,
    }
}

/// Simulate a whole network at an operating point.
pub fn simulate_network(
    cfg: &Optical4FConfig,
    net: &Network,
    op: &OperatingPoint,
) -> SimResult {
    let c = Coeffs::new(cfg, op);
    let mut total = SimResult::default();
    for layer in &net.layers {
        total += &simulate_layer_with(cfg, layer, &c);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn channels_at_once_eq22() {
        let cfg = Optical4FConfig::default();
        // 4 Mpx / 512² = 16 channels.
        assert_eq!(cfg.channels_at_once(512, 128), 16);
        // Clamped to Cᵢ.
        assert_eq!(cfg.channels_at_once(512, 4), 4);
        // Image fills the SLM: 1 channel at a time.
        assert_eq!(cfg.channels_at_once(2048, 64), 1);
    }

    #[test]
    fn spatial_patches_only_for_huge_inputs() {
        let cfg = Optical4FConfig::default();
        assert_eq!(cfg.spatial_patches(1000, 3), 1);
        assert_eq!(cfg.spatial_patches(2046, 3), 1);
        assert!(cfg.spatial_patches(4000, 3) > 1);
    }

    #[test]
    fn execution_count_exact() {
        // Groups = ⌈Cᵢ/C′⌉; executions = groups·(1 + Cᵢ₊₁).
        let cfg = Optical4FConfig::default();
        let l = ConvLayer::square(512, 128, 64, 3, 1);
        let r = simulate_layer(&cfg, &l, &op(45.0));
        // Padded tile is 514² px → C′ = ⌊4 Mpx/514²⌋ = 15 → 9 groups.
        let c_prime = cfg.channels_at_once(514, 128);
        assert_eq!(c_prime, 15);
        let groups = 128usize.div_ceil(c_prime);
        assert_eq!(r.time_units, (groups * (1 + 64)) as f64);
    }

    #[test]
    fn dac_count_matches_eq18_eq19() {
        // For a single-group layer the DAC op count is exactly
        // 4·n̄²Cᵢ (load) + 2·k²CᵢCᵢ₊₁ (compute), n̄ = n+k-1.
        let cfg = Optical4FConfig::default();
        let l = ConvLayer::square(100, 4, 8, 3, 1);
        let c = Coeffs::new(&cfg, &op(45.0));
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let s2 = (102 * 102) as f64;
        let expect_dacs = 4.0 * s2 * 4.0 + 2.0 * 9.0 * 4.0 * 8.0;
        let got = r.ledger.get(Component::Dac) / c.e_dac_px;
        assert!((got - expect_dacs).abs() / expect_dacs < 1e-9, "{got} vs {expect_dacs}");
    }

    #[test]
    fn adc_count_matches_eq18_eq19() {
        let cfg = Optical4FConfig::default();
        let l = ConvLayer::square(100, 4, 8, 3, 1);
        let c = Coeffs::new(&cfg, &op(45.0));
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let s2 = (102 * 102) as f64;
        let out = (98 * 98) as f64;
        let expect = 2.0 * s2 * 4.0 + 2.0 * out * 8.0;
        let got = r.ledger.get(Component::Adc) / c.e_adc;
        assert!((got - expect).abs() / expect < 1e-9, "{got} vs {expect}");
    }

    #[test]
    fn efficiency_band_45nm_yolo() {
        // Fig. 9: tens of TOPS/W at 45 nm for YOLOv3.
        let cfg = Optical4FConfig::default();
        let r = simulate_network(&cfg, &yolov3(1000), &op(45.0));
        let eta = r.tops_per_watt();
        assert!(eta > 10.0 && eta < 400.0, "η = {eta}");
    }

    #[test]
    fn beats_systolic_by_an_order() {
        // The paper's headline: the 4F machine sits ≳10× above the
        // digital systolic array on the same network and node.
        use crate::simulator::systolic::{simulate_network as sys, SystolicConfig};
        let net = yolov3(1000);
        let o = simulate_network(&Optical4FConfig::default(), &net, &op(32.0));
        let s = sys(&SystolicConfig::default(), &net, &op(32.0));
        assert!(
            o.tops_per_watt() > 5.0 * s.tops_per_watt(),
            "4F {} vs systolic {}",
            o.tops_per_watt(),
            s.tops_per_watt()
        );
    }

    #[test]
    fn laser_energy_flat_across_nodes() {
        let cfg = Optical4FConfig::default();
        let net = yolov3(1000);
        let a = simulate_network(&cfg, &net, &op(45.0));
        let b = simulate_network(&cfg, &net, &op(7.0));
        let la = a.ledger.get(Component::Laser);
        let lb = b.ledger.get(Component::Laser);
        assert!((la - lb).abs() / la < 1e-12, "laser is node-independent");
        // While ADC + SRAM must shrink (Fig. 10's trend).
        assert!(b.ledger.get(Component::Adc) < a.ledger.get(Component::Adc));
        assert!(b.ledger.get(Component::Sram) < a.ledger.get(Component::Sram));
    }

    #[test]
    fn dac_nearly_flat_across_nodes() {
        // Fig. 10: "we see very little reduction in the overall DAC
        // energy cost" — the wire load dominates the converter circuit
        // over the figure's 45 → 7 nm span.
        let cfg = Optical4FConfig::default();
        let net = yolov3(1000);
        let a = simulate_network(&cfg, &net, &op(45.0));
        let b = simulate_network(&cfg, &net, &op(7.0));
        let ratio = b.ledger.get(Component::Dac) / a.ledger.get(Component::Dac);
        assert!(ratio > 0.6, "DAC should be ≳60% flat 45→7 nm, got {ratio}");
        // While SRAM scales nearly fully with CMOS.
        let sr = b.ledger.get(Component::Sram) / a.ledger.get(Component::Sram);
        assert!(sr < 0.15, "SRAM should follow CMOS scaling, got {sr}");
    }

    #[test]
    fn shuttered_laser_ablation_reduces_laser_energy() {
        let full = Optical4FConfig::default();
        let shuttered = Optical4FConfig {
            laser_full_aperture: false,
            ..full
        };
        let l = ConvLayer::square(100, 4, 8, 3, 1); // tiny active area
        let rf = simulate_layer(&full, &l, &op(45.0));
        let rs = simulate_layer(&shuttered, &l, &op(45.0));
        assert!(
            rs.ledger.get(Component::Laser) < rf.ledger.get(Component::Laser) / 10.0
        );
    }

    #[test]
    fn stride_reduces_adc_not_dac() {
        let cfg = Optical4FConfig::default();
        let s1 = ConvLayer::square(200, 8, 8, 3, 1);
        let s2 = ConvLayer::square(200, 8, 8, 3, 2);
        let r1 = simulate_layer(&cfg, &s1, &op(45.0));
        let r2 = simulate_layer(&cfg, &s2, &op(45.0));
        assert!(r2.ledger.get(Component::Adc) < r1.ledger.get(Component::Adc));
        assert_eq!(r2.ledger.get(Component::Dac), r1.ledger.get(Component::Dac));
        // …and stride-2 performs ~1/4 the MACs: efficiency drops (the
        // paper's §VII.B divergence).
        assert!(r2.macs < r1.macs / 3.5);
    }

    #[test]
    fn group_psum_spill_appears_only_with_multiple_groups() {
        let cfg = Optical4FConfig::default();
        // 512²-padded channels: C′=15 < Cᵢ=30 → 2 groups → 32-bit spill.
        let multi = ConvLayer::square(510, 30, 4, 3, 1);
        let single = ConvLayer::square(510, 15, 4, 3, 1);
        let rm = simulate_layer(&cfg, &multi, &op(45.0));
        let rs = simulate_layer(&cfg, &single, &op(45.0));
        // Per MAC, the multi-group layer pays more SRAM.
        let per_mac_m = rm.ledger.get(Component::Sram) / rm.macs;
        let per_mac_s = rs.ledger.get(Component::Sram) / rs.macs;
        assert!(per_mac_m > per_mac_s, "{per_mac_m} !> {per_mac_s}");
    }

    #[test]
    fn kernel_and_activation_precision_split() {
        let cfg = Optical4FConfig::default();
        let l = ConvLayer::square(100, 4, 8, 3, 1);
        let r88 = simulate_layer(&cfg, &l, &op(45.0));
        // Narrower kernels cut only the kernel SLM writes…
        let r84 = simulate_layer(&cfg, &l, &op(45.0).bits(8, 4));
        assert!(r84.ledger.get(Component::Dac) < r88.ledger.get(Component::Dac));
        assert_eq!(
            r84.ledger.get(Component::Adc).to_bits(),
            r88.ledger.get(Component::Adc).to_bits()
        );
        assert_eq!(
            r84.ledger.get(Component::Laser).to_bits(),
            r88.ledger.get(Component::Laser).to_bits()
        );
        // …while narrower activations collapse the 2^2B ADC and
        // shot-noise laser laws.
        let r48 = simulate_layer(&cfg, &l, &op(45.0).bits(4, 8));
        assert!(r48.ledger.get(Component::Adc) < r88.ledger.get(Component::Adc) / 100.0);
        assert!(r48.ledger.get(Component::Laser) < r88.ledger.get(Component::Laser) / 100.0);
        assert_eq!(r48.time_units, r88.time_units, "executions are shape-only");
    }
}
