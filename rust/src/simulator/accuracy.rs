//! Effective-SNR / accuracy estimator for an [`OperatingPoint`].
//!
//! Energy and latency fall monotonically with bit width, so an
//! energy-only sweep always "prefers" the lowest precision — the missing
//! third axis is *how much signal survives*. This module estimates it
//! with a small seeded Monte-Carlo experiment per layer shape: random
//! Gaussian activations and weights are pushed through a quantize +
//! perturb + dot-product pipeline at the operating point's bit widths
//! and [`super::op::NoiseModel`] sigmas, and the resulting output error
//! power yields an effective SNR (dB), an effective number of bits
//! (ENOB) and a logistic accuracy-retention proxy in `[0, 1]`.
//!
//! Everything is **deterministic**: the RNG seed is derived (FNV-1a)
//! from the layer shape and the operating-point key, so the same
//! (layer, op) pair produces bit-identical estimates on every call,
//! thread and platform — the Pareto scenario goldens depend on it.
//! No wall-clock, no global RNG, no platform intrinsics.
//!
//! This is a *proxy*, not a task benchmark: it ranks operating points by
//! signal integrity (quantization + analog noise) without claiming a
//! specific ImageNet top-1. The logistic retention curve maps SNR to a
//! [0, 1] score with its knee near 10 dB, consistent with the precision
//! cliffs reported for analog in-memory inference.

use super::machine::fnv1a;
use super::op::OperatingPoint;
use crate::networks::{ConvLayer, Network};
use crate::util::rng::Rng;

/// Monte-Carlo trials per (layer, op) estimate. 256 keeps the estimator
/// sub-millisecond per unique shape while the seeded RNG makes the
/// variance irrelevant for ranking (the estimate is deterministic).
const TRIALS: usize = 256;

/// Dot-product fan-in is capped so huge layers don't make the estimate
/// arbitrarily slow; SNR per element is what matters, and it has
/// converged long before 512 terms.
const FAN_IN_CAP: usize = 512;

/// Signal-integrity estimate for one (layer, operating point) pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccuracyEstimate {
    /// Effective output signal-to-noise ratio, dB.
    pub snr_db: f64,
    /// Effective number of bits: (SNR_dB − 1.76) / 6.02.
    pub effective_bits: f64,
    /// Logistic accuracy-retention proxy in `[0, 1]` (≈1 when noise and
    /// quantization are negligible, rolling off below ~10 dB SNR).
    pub retention: f64,
}

/// Deterministic seed for one (layer, op) experiment. The fault bits
/// are appended only for a non-ideal [`super::faults::FaultModel`], so
/// every zero-fault seed — and with it every pre-fault estimate — stays
/// exactly what it was before fault injection existed.
fn seed_for(layer: &ConvLayer, op: &OperatingPoint) -> u64 {
    let k = op.key();
    let mut s = format!(
        "accuracy {} {} {} {} {} {} | {:016x} {} {} {:016x} {:016x}",
        layer.n,
        layer.c_in,
        layer.c_out,
        layer.kh,
        layer.kw,
        layer.stride,
        k.node_bits,
        k.bits_x,
        k.bits_w,
        k.wsig_bits,
        k.osig_bits,
    );
    if !op.noise.faults.is_ideal() {
        s.push_str(&format!(
            " {:016x} {:016x} {:016x} {:016x}",
            k.stuck_bits, k.drift_bits, k.clip_bits, k.ir_bits,
        ));
    }
    fnv1a(s.as_bytes())
}

/// Mid-rise uniform quantizer over a ±4σ clipping range (standard-normal
/// inputs): step = 8 / 2ᵇ. Clipping noise is negligible at 4σ and the
/// quantization error power follows the classic step²/12 law, which is
/// what makes `effective_bits` track `bits` closely in the noiseless
/// case (the in-module test pins this).
fn quantize(x: f64, bits: u32) -> f64 {
    let step = 8.0 / (1u64 << bits.min(52)) as f64;
    (x.clamp(-4.0, 4.0) / step).round() * step
}

/// Estimate signal integrity for one layer at `op`.
///
/// Fault composition (all gated on the corresponding
/// [`super::faults::FaultModel`] field being non-zero, so the zero-fault
/// RNG stream — and every pre-fault estimate — is untouched): stuck
/// cells replace the stored weight with Gmin (0) or Gmax (full scale),
/// log-normal drift multiplies it, IR drop scales the analog
/// accumulation by a deterministic per-column factor, and ADC
/// saturation clamps the readout at `adc_clip` output-RMS units.
pub fn estimate_layer(layer: &ConvLayer, op: &OperatingPoint) -> AccuracyEstimate {
    let fan_in = (layer.kh * layer.kw * layer.c_in).clamp(1, FAN_IN_CAP);
    let f = op.noise.faults;
    let mut rng = Rng::new(seed_for(layer, op));
    let mut sig_power = 0.0;
    let mut err_power = 0.0;
    for t in 0..TRIALS {
        let mut exact = 0.0;
        let mut noisy = 0.0;
        for _ in 0..fan_in {
            let x = rng.normal();
            let w = rng.normal();
            // Device-level perturbations: quantize both operands, then
            // add per-device conductance error to the stored weight.
            let qx = quantize(x, op.bits_x);
            let mut qw = quantize(w, op.bits_w) + op.noise.weight_sigma * rng.normal();
            if f.stuck_rate > 0.0 && rng.f64() < f.stuck_rate {
                // Stuck cell: Gmin reads as zero, Gmax as a full-scale
                // weight of the programmed sign.
                qw = if rng.bool() {
                    0.0
                } else if qw >= 0.0 {
                    4.0
                } else {
                    -4.0
                };
            }
            if f.drift_sigma > 0.0 {
                // Log-normal conductance drift since the last refresh.
                qw *= (f.drift_sigma * rng.normal()).exp();
            }
            exact += x * w;
            noisy += qx * qw;
        }
        if f.ir_drop > 0.0 {
            // Per-column IR drop: successive trials read successive
            // columns of the array, scaled 1.0 → 1 − ir_drop (same
            // deterministic ramp as `faults::sample_map`).
            noisy *= 1.0 - f.ir_drop * (t as f64 / (TRIALS - 1) as f64);
        }
        // Output-referred analog noise (ADC / shot / thermal) scales
        // with the accumulation length like an RSS of per-term noise.
        noisy += op.noise.output_sigma * (fan_in as f64).sqrt() * rng.normal();
        if f.adc_clip > 0.0 {
            // ADC saturation at `adc_clip` output-RMS units (the output
            // RMS of a fan_in-term unit-variance accumulation is
            // √fan_in).
            let limit = f.adc_clip * (fan_in as f64).sqrt();
            noisy = noisy.clamp(-limit, limit);
        }
        sig_power += exact * exact;
        err_power += (noisy - exact) * (noisy - exact);
    }
    snr_to_estimate(if err_power == 0.0 {
        // Perfectly clean channel (unreachable with finite bits, but the
        // guard keeps the math total): report the 160 dB ceiling.
        1e16
    } else {
        sig_power / err_power
    })
}

fn snr_to_estimate(snr_linear: f64) -> AccuracyEstimate {
    let snr_db = (10.0 * snr_linear.log10()).min(160.0);
    AccuracyEstimate {
        snr_db,
        effective_bits: (snr_db - 1.76) / 6.02,
        retention: 1.0 / (1.0 + (-(snr_db - 10.0) / 4.0).exp()),
    }
}

/// Network-level estimate: per-unique-shape estimates combined as a
/// MAC-weighted harmonic mean of the *linear* SNR — the layers with the
/// most accumulated work and the worst channels dominate, mirroring how
/// a single noisy bottleneck layer drags end-to-end accuracy.
pub fn estimate_network(net: &Network, op: &OperatingPoint) -> AccuracyEstimate {
    let mut memo: Vec<(ConvLayer, f64)> = Vec::new();
    let mut weight_sum = 0.0;
    let mut inv_sum = 0.0;
    for layer in &net.layers {
        let snr_linear = match memo.iter().find(|(l, _)| l == layer) {
            Some(&(_, s)) => s,
            None => {
                let e = estimate_layer(layer, op);
                let s = 10f64.powf(e.snr_db / 10.0);
                memo.push((*layer, s));
                s
            }
        };
        let w = layer.macs();
        weight_sum += w;
        inv_sum += w / snr_linear;
    }
    if weight_sum == 0.0 || inv_sum == 0.0 {
        return snr_to_estimate(1e16);
    }
    snr_to_estimate(weight_sum / inv_sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;
    use crate::simulator::NoiseModel;

    fn layer() -> ConvLayer {
        ConvLayer::square(64, 128, 128, 3, 1)
    }

    #[test]
    fn deterministic_across_calls_and_threads() {
        let l = layer();
        let op = OperatingPoint::node(45.0).bits(6, 6).with_noise(NoiseModel {
            weight_sigma: 0.01,
            output_sigma: 0.02,
            ..Default::default()
        });
        let here = estimate_layer(&l, &op);
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || estimate_layer(&l, &op)))
            .collect();
        for h in handles {
            let other = h.join().unwrap();
            assert_eq!(here.snr_db.to_bits(), other.snr_db.to_bits());
            assert_eq!(here.retention.to_bits(), other.retention.to_bits());
        }
        // And bit-identical on a plain repeat.
        let again = estimate_layer(&l, &op);
        assert_eq!(here.snr_db.to_bits(), again.snr_db.to_bits());
    }

    #[test]
    fn effective_bits_track_quantizer_bits_when_noiseless() {
        let l = layer();
        for bits in [4u32, 6, 8, 10] {
            let e = estimate_layer(&l, &OperatingPoint::node(45.0).bits(bits, bits));
            // Two quantized operands per product: ENOB lands near the
            // operand width (within ~2 bits), and always below it.
            assert!(
                e.effective_bits > bits as f64 - 2.5 && e.effective_bits < bits as f64 + 0.5,
                "bits={bits} enob={}",
                e.effective_bits
            );
        }
    }

    #[test]
    fn snr_is_monotone_in_bits_and_noise() {
        let l = layer();
        let e4 = estimate_layer(&l, &OperatingPoint::node(45.0).bits(4, 4));
        let e8 = estimate_layer(&l, &OperatingPoint::node(45.0));
        let e12 = estimate_layer(&l, &OperatingPoint::node(45.0).bits(12, 12));
        assert!(e4.snr_db < e8.snr_db && e8.snr_db < e12.snr_db);
        assert!(e4.retention <= e8.retention && e8.retention <= e12.retention);

        let noisy = estimate_layer(
            &l,
            &OperatingPoint::node(45.0).with_noise(NoiseModel {
                weight_sigma: 0.1,
                output_sigma: 0.1,
                ..Default::default()
            }),
        );
        assert!(noisy.snr_db < e8.snr_db);
        assert!(noisy.retention < e8.retention);
    }

    #[test]
    fn node_does_not_change_the_estimate() {
        // Signal integrity is a precision/noise property; the technology
        // node only scales energy.
        let l = layer();
        let a = estimate_layer(&l, &OperatingPoint::node(45.0).bits(6, 6));
        let b = estimate_layer(&l, &OperatingPoint::node(7.0).bits(6, 6));
        // Different node ⇒ different seed, so estimates differ slightly —
        // but by sampling noise only, not systematically.
        assert!((a.snr_db - b.snr_db).abs() < 3.0, "{} vs {}", a.snr_db, b.snr_db);
    }

    #[test]
    fn network_estimate_is_work_weighted_and_deterministic() {
        let net = yolov3(200);
        let op = OperatingPoint::node(45.0).bits(6, 6);
        let a = estimate_network(&net, &op);
        let b = estimate_network(&net, &op);
        assert_eq!(a.snr_db.to_bits(), b.snr_db.to_bits());
        // Harmonic mean sits at or below the best layer's SNR and keeps
        // ordering in bits.
        let lo = estimate_network(&net, &OperatingPoint::node(45.0).bits(4, 4));
        assert!(lo.snr_db < a.snr_db);
        assert!(a.retention > 0.9, "8-ish bit channel retains accuracy");
    }

    #[test]
    fn heavy_noise_floors_retention() {
        let l = layer();
        let e = estimate_layer(
            &l,
            &OperatingPoint::node(45.0).bits(2, 2).with_noise(NoiseModel {
                weight_sigma: 0.5,
                output_sigma: 0.5,
                ..Default::default()
            }),
        );
        assert!(e.retention < 0.5, "retention {}", e.retention);
        assert!(e.snr_db < 10.0);
    }

    #[test]
    fn injected_faults_degrade_snr_monotonically() {
        use crate::simulator::faults::FaultModel;
        let l = layer();
        let at = |rate: f64| {
            estimate_layer(
                &l,
                &OperatingPoint::node(45.0).with_noise(NoiseModel {
                    faults: FaultModel::at_rate(rate),
                    ..Default::default()
                }),
            )
        };
        let clean = at(0.0);
        let mild = at(0.01);
        let harsh = at(0.10);
        assert!(mild.snr_db < clean.snr_db, "{} vs {}", mild.snr_db, clean.snr_db);
        assert!(harsh.snr_db < mild.snr_db, "{} vs {}", harsh.snr_db, mild.snr_db);
        assert!(harsh.retention < mild.retention);
        // A zero-rate fault bundle IS the ideal model: same seed, same
        // stream, bit-identical estimate.
        let plain = estimate_layer(&l, &OperatingPoint::node(45.0));
        assert_eq!(clean.snr_db.to_bits(), plain.snr_db.to_bits());
    }

    #[test]
    fn adc_clipping_alone_degrades_the_channel() {
        use crate::simulator::faults::FaultModel;
        let l = layer();
        let clipped = estimate_layer(
            &l,
            &OperatingPoint::node(45.0).with_noise(NoiseModel {
                faults: FaultModel {
                    adc_clip: 0.5,
                    ..Default::default()
                },
                ..Default::default()
            }),
        );
        let clean = estimate_layer(&l, &OperatingPoint::node(45.0));
        assert!(clipped.snr_db < clean.snr_db);
    }

    #[test]
    fn faulted_estimates_are_deterministic() {
        use crate::simulator::faults::FaultModel;
        let l = layer();
        let op = OperatingPoint::node(45.0).bits(6, 6).with_noise(NoiseModel {
            weight_sigma: 0.01,
            output_sigma: 0.02,
            faults: FaultModel::at_rate(0.02),
        });
        let here = estimate_layer(&l, &op);
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || estimate_layer(&l, &op)))
            .collect();
        for h in handles {
            let other = h.join().unwrap();
            assert_eq!(here.snr_db.to_bits(), other.snr_db.to_bits());
            assert_eq!(here.retention.to_bits(), other.retention.to_bits());
        }
    }
}
