//! Energy ledger: attribute every simulated joule to a hardware component.
//!
//! Fig. 10's stacked "energy cost distribution" (DAC / ADC / SRAM / laser)
//! is a direct read-out of this ledger after a simulation run.

/// Hardware components energy can be charged to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Component {
    /// Digital-to-analog conversion incl. line/modulator loads.
    Dac,
    /// Analog-to-digital conversion.
    Adc,
    /// On-chip SRAM traffic.
    Sram,
    /// Off-chip DRAM traffic (weights).
    Dram,
    /// Laser illumination (optical machines).
    Laser,
    /// Digital MAC array (systolic machine).
    Mac,
    /// Inter-tile data movement (systolic machine).
    Load,
}

impl Component {
    pub const ALL: [Component; 7] = [
        Component::Dac,
        Component::Adc,
        Component::Sram,
        Component::Dram,
        Component::Laser,
        Component::Mac,
        Component::Load,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Component::Dac => "DAC",
            Component::Adc => "ADC",
            Component::Sram => "SRAM",
            Component::Dram => "DRAM",
            Component::Laser => "laser",
            Component::Mac => "MAC",
            Component::Load => "load",
        }
    }

    fn index(&self) -> usize {
        match self {
            Component::Dac => 0,
            Component::Adc => 1,
            Component::Sram => 2,
            Component::Dram => 3,
            Component::Laser => 4,
            Component::Mac => 5,
            Component::Load => 6,
        }
    }
}

/// Per-component energy accumulator (joules).
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    joules: [f64; 7],
}

impl EnergyLedger {
    pub fn new() -> Self {
        EnergyLedger::default()
    }

    /// Charge `joules` to a component. Negative charges are a bug.
    pub fn add(&mut self, c: Component, joules: f64) {
        debug_assert!(joules >= 0.0, "negative energy charged to {c:?}");
        self.joules[c.index()] += joules;
    }

    pub fn get(&self, c: Component) -> f64 {
        self.joules[c.index()]
    }

    pub fn total(&self) -> f64 {
        self.joules.iter().sum()
    }

    pub fn merge(&mut self, other: &EnergyLedger) {
        for i in 0..self.joules.len() {
            self.joules[i] += other.joules[i];
        }
    }

    /// Non-zero (component, joules) pairs, largest first.
    pub fn breakdown(&self) -> Vec<(Component, f64)> {
        let mut v: Vec<(Component, f64)> = Component::ALL
            .iter()
            .map(|&c| (c, self.get(c)))
            .filter(|(_, j)| *j > 0.0)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_total() {
        let mut l = EnergyLedger::new();
        l.add(Component::Dac, 1.0e-12);
        l.add(Component::Dac, 0.5e-12);
        l.add(Component::Laser, 2.0e-12);
        assert!((l.get(Component::Dac) - 1.5e-12).abs() < 1e-24);
        assert!((l.total() - 3.5e-12).abs() < 1e-24);
    }

    #[test]
    fn merge_sums_componentwise() {
        let mut a = EnergyLedger::new();
        a.add(Component::Sram, 1.0);
        let mut b = EnergyLedger::new();
        b.add(Component::Sram, 2.0);
        b.add(Component::Adc, 3.0);
        a.merge(&b);
        assert_eq!(a.get(Component::Sram), 3.0);
        assert_eq!(a.get(Component::Adc), 3.0);
    }

    #[test]
    fn breakdown_sorted_desc() {
        let mut l = EnergyLedger::new();
        l.add(Component::Adc, 1.0);
        l.add(Component::Dac, 5.0);
        l.add(Component::Laser, 3.0);
        let b = l.breakdown();
        assert_eq!(b[0].0, Component::Dac);
        assert_eq!(b[1].0, Component::Laser);
        assert_eq!(b[2].0, Component::Adc);
        assert_eq!(b.len(), 3, "zero components omitted");
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<&str> = Component::ALL.iter().map(|c| c.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 7);
    }
}
