//! Cycle-accurate weight-stationary systolic array (paper §VII.A, Fig. 8).
//!
//! Architecture mirrors the Google TPUv1 as the paper parameterizes it:
//! a 256×256 weight-stationary MAC array, 24 MiB of activation SRAM in
//! 256 banks of 96 KB (one per array port), weights resident in DRAM,
//! 8-bit operands with 32-bit accumulation.
//!
//! Per conv layer, the im2col-mapped GEMM (L′ × N′) · (N′ × M′) is tiled
//! into ⌈N′/256⌉·⌈M′/256⌉ weight tiles; for each tile the full activation
//! column block streams through the array. Energy accounting per §VII.A:
//!
//! * SRAM: activation reads (k²-duplicated Toeplitz), partial-sum
//!   spill/fill when N′ > 256, and output writes — at the 96 KB-bank
//!   energy (4.33 pJ/B at 45 nm), node-scaled;
//! * MAC: 0.23 pJ (45 nm) per 8-bit MAC + 31 fJ/B × 5 B register traffic,
//!   node-scaled;
//! * Load: 2.82 fJ/bit × 40 bits per inter-tile hop — **not** node-scaled
//!   (eq. A6 is wire-dominated), which is why Fig. 8's cycle-accurate
//!   curve flattens at small nodes;
//! * DRAM: weight streaming, default 0 to match the paper's accounting
//!   (§VII.A lists only SRAM/MAC/load/register costs); the ablation bench
//!   turns it on.

//!
//! All entry points take an [`OperatingPoint`]; activation/weight byte
//! widths and the MAC gate model follow its `bits_x`/`bits_w`, and the
//! default 8×8 point reproduces the fixed-precision model bit-exactly.

use super::op::OperatingPoint;
use super::{Component, EnergyLedger, SimResult};
use crate::energy::{
    constants::{SYSTOLIC_DIM, TOTAL_SRAM_BYTES},
    load::presets,
    sram::{bank_bytes, Sram},
    EnergyParams,
};
use crate::networks::{ConvLayer, Network};

/// Machine description.
#[derive(Clone, Copy, Debug)]
pub struct SystolicConfig {
    /// Array dimension (dim × dim processing elements).
    pub dim: usize,
    /// Total activation SRAM in bytes.
    pub sram_bytes: usize,
    /// Number of SRAM banks.
    pub banks: usize,
    /// Bits per inter-tile hop (8-bit operand + 32-bit accumulator).
    pub hop_bits: u32,
    /// Register-file bytes touched per MAC.
    pub reg_bytes_per_mac: f64,
    /// DRAM energy per byte for weight streaming (J/B). Default 0 — the
    /// paper's model does not charge DRAM; see module docs.
    pub e_dram_per_byte: f64,
    /// Bytes per activation / weight element (1 = 8-bit).
    pub act_bytes: f64,
    /// Bytes per partial sum (4 = 32-bit).
    pub psum_bytes: f64,
}

impl Default for SystolicConfig {
    fn default() -> Self {
        SystolicConfig {
            dim: SYSTOLIC_DIM,
            sram_bytes: TOTAL_SRAM_BYTES,
            banks: SYSTOLIC_DIM,
            hop_bits: 40,
            reg_bytes_per_mac: 5.0,
            e_dram_per_byte: 0.0,
            act_bytes: 1.0,
            psum_bytes: 4.0,
        }
    }
}

impl SystolicConfig {
    pub fn bank_bytes(&self) -> usize {
        bank_bytes(self.sram_bytes, self.banks)
    }
}

/// Per-operating-point energy coefficients, precomputed once per
/// simulation. Precision folds in here — `act_bytes`/`wgt_bytes` carry
/// the bits_x/bits_w storage scale so the tile loop keeps its exact
/// expression shape (×1.0 at the default 8×8 point).
struct Coeffs {
    e_mac: f64,
    e_hop: f64,
    e_reg: f64,
    e_sram_byte: f64,
    e_dram_byte: f64,
    /// Bytes per activation element at this precision.
    act_bytes: f64,
    /// Bytes per weight element at this precision.
    wgt_bytes: f64,
}

impl Coeffs {
    fn new(cfg: &SystolicConfig, op: &OperatingPoint) -> Self {
        let e = EnergyParams::default().at_op(op);
        // Digital fault tolerance (ECC over the memory hierarchy when
        // stuck cells are injected) surcharges every byte moved; exactly
        // ×1.0 for the ideal device, preserving the golden bit-identity.
        let dig = op.noise.faults.digital_derate();
        Coeffs {
            e_mac: e.e_mac,
            // Wire load: node-independent.
            e_hop: presets::systolic_hop().energy() * cfg.hop_bits as f64,
            e_reg: Sram::at_node(5, op.node_nm).energy_per_byte * cfg.reg_bytes_per_mac,
            e_sram_byte: Sram::at_node(cfg.bank_bytes(), op.node_nm).energy_per_byte * dig,
            e_dram_byte: cfg.e_dram_per_byte * dig,
            act_bytes: cfg.act_bytes * op.sx(),
            wgt_bytes: cfg.act_bytes * op.sw(),
        }
    }
}

/// Simulate one conv layer. Returns the layer's [`SimResult`].
pub fn simulate_layer(cfg: &SystolicConfig, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    simulate_layer_with(cfg, layer, &c)
}

fn simulate_layer_with(cfg: &SystolicConfig, layer: &ConvLayer, c: &Coeffs) -> SimResult {
    // im2col GEMM dimensions (eq. 16).
    let (l_rows, n_dim, m_dim) = layer.matmul_dims();
    let l_rows = l_rows.max(1.0);
    let n_dim = n_dim.max(1.0) as usize;
    let m_dim = m_dim.max(1.0) as usize;
    let dim = cfg.dim;

    let tn = n_dim.div_ceil(dim);
    let tm = m_dim.div_ceil(dim);

    let mut ledger = EnergyLedger::new();
    let mut macs = 0.0;
    let mut cycles = 0.0;

    for ti in 0..tn {
        let tile_n = (n_dim - ti * dim).min(dim) as f64;
        for tj in 0..tm {
            let tile_m = (m_dim - tj * dim).min(dim) as f64;

            // Weight tile streamed from DRAM into the array.
            ledger.add(
                Component::Dram,
                tile_n * tile_m * c.wgt_bytes * c.e_dram_byte,
            );

            // Activation block streams through: L′ rows of tile_n bytes.
            ledger.add(
                Component::Sram,
                l_rows * tile_n * c.act_bytes * c.e_sram_byte,
            );

            // MACs in this pass.
            let tile_macs = l_rows * tile_n * tile_m;
            macs += tile_macs;
            ledger.add(Component::Mac, tile_macs * (c.e_mac + c.e_reg));
            ledger.add(Component::Load, tile_macs * c.e_hop);

            // Partial-sum traffic: with N′ split across tn passes the
            // running 32-bit psums spill to SRAM between passes.
            let psum = l_rows * tile_m;
            if tn > 1 {
                if ti == 0 {
                    // First pass: write psums.
                    ledger.add(Component::Sram, psum * cfg.psum_bytes * c.e_sram_byte);
                } else if ti < tn - 1 {
                    // Middle passes: read + write.
                    ledger.add(
                        Component::Sram,
                        2.0 * psum * cfg.psum_bytes * c.e_sram_byte,
                    );
                } else {
                    // Last pass: read psums, requantize, write the
                    // bits_x-wide output.
                    ledger.add(
                        Component::Sram,
                        psum * (cfg.psum_bytes + c.act_bytes) * c.e_sram_byte,
                    );
                }
            } else {
                // Single pass: write the bits_x-wide output directly.
                ledger.add(Component::Sram, psum * c.act_bytes * c.e_sram_byte);
            }

            // Cycles: weight fill (dim) + stream (L′) + drain (dim).
            cycles += l_rows + 2.0 * dim as f64;
        }
    }

    SimResult {
        macs,
        ops: 2.0 * macs,
        ledger,
        time_units: cycles,
    }
}

/// Simulate a whole network at an operating point.
pub fn simulate_network(cfg: &SystolicConfig, net: &Network, op: &OperatingPoint) -> SimResult {
    let c = Coeffs::new(cfg, op);
    let mut total = SimResult::default();
    for layer in &net.layers {
        total += &simulate_layer_with(cfg, layer, &c);
    }
    total
}

/// Array utilization: useful MACs / (cycles × array area).
pub fn utilization(cfg: &SystolicConfig, r: &SimResult) -> f64 {
    r.macs / (r.time_units * (cfg.dim * cfg.dim) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;

    fn small_layer() -> ConvLayer {
        ConvLayer::square(64, 8, 16, 3, 1)
    }

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn mac_count_matches_layer() {
        // The simulator must perform exactly the layer's useful MACs —
        // padding/edge tiles add energy, never phantom work.
        let cfg = SystolicConfig::default();
        let l = small_layer();
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (lp, np, mp) = l.matmul_dims();
        assert!((r.macs - lp * np * mp).abs() < 1.0);
    }

    #[test]
    fn efficiency_in_expected_band_45nm() {
        // YOLOv3 at 45 nm should land near the analytic eq. (5) value
        // (~2 TOPS/W with the §VII.A per-MAC bundle).
        let cfg = SystolicConfig::default();
        let r = simulate_network(&cfg, &yolov3(1000), &op(45.0));
        let eta = r.tops_per_watt();
        assert!(eta > 0.8 && eta < 6.0, "η = {eta}");
    }

    #[test]
    fn flattens_at_small_nodes() {
        // Fig. 8: the node-independent e_load dominates at 7 nm, so the
        // 45→7 nm gain is well below pure CMOS scaling (~10.6×).
        let cfg = SystolicConfig::default();
        let net = yolov3(1000);
        let e45 = simulate_network(&cfg, &net, &op(45.0)).tops_per_watt();
        let e7 = simulate_network(&cfg, &net, &op(7.0)).tops_per_watt();
        assert!(e7 > e45, "still improves");
        assert!(e7 / e45 < 6.0, "but sub-CMOS: {}", e7 / e45);
    }

    #[test]
    fn psum_spill_only_when_contraction_tiled() {
        let cfg = SystolicConfig::default();
        // N′ = 9·8 = 72 < 256: single pass, no spill → SRAM traffic =
        // activations + outputs exactly.
        let l = small_layer();
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (lp, np, mp) = l.matmul_dims();
        let e_b = Sram::at_node(cfg.bank_bytes(), 45.0).energy_per_byte;
        let expect = (lp * np + lp * mp) * e_b;
        let got = r.ledger.get(Component::Sram);
        assert!((got - expect).abs() / expect < 1e-9, "{got} vs {expect}");
    }

    #[test]
    fn psum_spill_when_n_exceeds_array() {
        let cfg = SystolicConfig::default();
        // N′ = 9·64 = 576 > 256 → 3 passes → psum spill traffic appears.
        let l = ConvLayer::square(64, 64, 16, 3, 1);
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (lp, np, mp) = l.matmul_dims();
        let e_b = Sram::at_node(cfg.bank_bytes(), 45.0).energy_per_byte;
        let min_no_spill = (lp * np + lp * mp) * e_b;
        assert!(r.ledger.get(Component::Sram) > min_no_spill * 1.05);
    }

    #[test]
    fn dram_off_by_default_matching_paper() {
        let cfg = SystolicConfig::default();
        let r = simulate_layer(&cfg, &small_layer(), &op(45.0));
        assert_eq!(r.ledger.get(Component::Dram), 0.0);
    }

    #[test]
    fn dram_accounting_when_enabled() {
        let cfg = SystolicConfig {
            e_dram_per_byte: 10e-12,
            ..Default::default()
        };
        let l = small_layer();
        let r = simulate_layer(&cfg, &l, &op(45.0));
        let (_, np, mp) = l.matmul_dims();
        let expect = np * mp * 10e-12; // one weight pass, single tile
        assert!((r.ledger.get(Component::Dram) - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn utilization_below_one() {
        let cfg = SystolicConfig::default();
        let r = simulate_network(&cfg, &yolov3(1000), &op(45.0));
        let u = utilization(&cfg, &r);
        assert!(u > 0.05 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn bigger_array_fewer_cycles_lower_utilization_on_small_layers() {
        let small = SystolicConfig {
            dim: 64,
            banks: 64,
            ..Default::default()
        };
        let big = SystolicConfig::default();
        let l = small_layer(); // M′ = 16 « 256
        let r_small = simulate_layer(&small, &l, &op(45.0));
        let r_big = simulate_layer(&big, &l, &op(45.0));
        assert!(
            utilization(&small, &r_small) > utilization(&big, &r_big),
            "small array should be better utilized by a small layer"
        );
    }

    #[test]
    fn energy_independent_of_tiling_for_mac_term() {
        // MAC energy depends only on total MACs, not the tile grid.
        let a = SystolicConfig {
            dim: 64,
            banks: 64,
            ..Default::default()
        };
        let b = SystolicConfig::default();
        let l = ConvLayer::square(32, 128, 128, 3, 1);
        let ra = simulate_layer(&a, &l, &op(45.0));
        let rb = simulate_layer(&b, &l, &op(45.0));
        assert!((ra.macs - rb.macs).abs() < 1.0);
        let ma = ra.ledger.get(Component::Mac);
        let mb = rb.ledger.get(Component::Mac);
        assert!((ma - mb).abs() / mb < 1e-9);
        // …but SRAM traffic is higher for the smaller array (more passes).
        assert!(ra.ledger.get(Component::Sram) > rb.ledger.get(Component::Sram));
    }

    #[test]
    fn default_operating_point_is_bit_identical_to_45nm_8x8() {
        let cfg = SystolicConfig::default();
        let l = ConvLayer::square(64, 64, 16, 3, 1); // tiled contraction
        let a = simulate_layer(&cfg, &l, &OperatingPoint::default());
        let b = simulate_layer(&cfg, &l, &op(45.0).bits(8, 8));
        assert_eq!(a.ledger.total().to_bits(), b.ledger.total().to_bits());
        assert_eq!(a.time_units.to_bits(), b.time_units.to_bits());
    }

    #[test]
    fn injected_faults_raise_energy_never_work() {
        use crate::simulator::faults::FaultModel;
        use crate::simulator::op::NoiseModel;
        let cfg = SystolicConfig::default();
        let l = small_layer();
        let clean = simulate_layer(&cfg, &l, &op(45.0));
        let faulty = simulate_layer(
            &cfg,
            &l,
            &op(45.0).with_noise(NoiseModel {
                faults: FaultModel::at_rate(0.01),
                ..Default::default()
            }),
        );
        assert_eq!(clean.macs, faulty.macs, "faults never change work");
        assert_eq!(clean.time_units, faulty.time_units);
        assert!(faulty.ledger.get(Component::Sram) > clean.ledger.get(Component::Sram));
        // A zero-rate fault model is the ideal device, bit-identically.
        let zero = simulate_layer(
            &cfg,
            &l,
            &op(45.0).with_noise(NoiseModel {
                faults: FaultModel::at_rate(0.0),
                ..Default::default()
            }),
        );
        assert_eq!(clean.ledger.total().to_bits(), zero.ledger.total().to_bits());
    }

    #[test]
    fn lower_precision_cuts_energy_not_work() {
        let cfg = SystolicConfig::default();
        let l = small_layer();
        let r8 = simulate_layer(&cfg, &l, &op(45.0));
        let r4 = simulate_layer(&cfg, &l, &op(45.0).bits(4, 4));
        assert!((r4.macs - r8.macs).abs() < 1.0, "precision never changes work");
        assert!(r4.time_units == r8.time_units, "cycle count is shape-only");
        assert!(r4.ledger.get(Component::Mac) < r8.ledger.get(Component::Mac));
        assert!(r4.ledger.get(Component::Sram) < r8.ledger.get(Component::Sram));
        assert!(r4.ledger.total() < r8.ledger.total());
    }
}
