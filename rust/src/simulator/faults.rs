//! Deterministic fault injection for the analog device layer.
//!
//! Real IMC arrays are not the ideal devices the paper's scaling
//! argument assumes: cells stick at their conductance extremes, stored
//! conductances drift log-normally between refreshes, long summation
//! columns lose current to IR drop, and the ADC saturates. This module
//! makes those non-idealities first-class and **deterministic**:
//!
//! * [`FaultModel`] — the statistical description carried inside
//!   [`super::op::NoiseModel`] (and therefore inside every
//!   [`super::op::OperatingPoint`] and cache key). The energy
//!   simulators consume it through the closed-form expected-overhead
//!   derates ([`FaultModel::cell_derate`] /
//!   [`FaultModel::converter_derate`] / [`FaultModel::digital_derate`]),
//!   all of which are **exactly 1.0** for the ideal model — multiplying
//!   a finite coefficient by 1.0 is an IEEE-754 identity, which is how
//!   the zero-fault golden outputs stay byte-identical. The accuracy
//!   estimator ([`super::accuracy`]) composes the same fields into its
//!   per-draw Monte-Carlo channel.
//! * [`FaultMap`] — one concrete seeded realization of the model over an
//!   R×C array (per-cell stuck state, per-cell drift factor, per-column
//!   IR scale). The same `(model, rows, cols, seed)` produces a
//!   bit-identical map on every call, thread and platform
//!   ([`FaultMap::fingerprint`] pins this in tests and lets callers
//!   assert reproducibility cheaply).

use super::machine::fnv1a;
use crate::util::rng::Rng;

/// Expected energy overhead per unit of stuck-cell rate: spare-column
/// redundancy plus the remap logic that steers around a dead cell.
const STUCK_REDUNDANCY_COST: f64 = 4.0;

/// Expected energy overhead per unit of drift sigma: periodic refresh
/// programming amortized over the reuse window.
const DRIFT_REFRESH_COST: f64 = 0.5;

/// Converter overhead per unit of IR-drop fraction: per-column gain
/// calibration in front of the ADC.
const IR_CAL_COST: f64 = 0.25;

/// Converter overhead when ADC saturation handling is on: auto-ranging
/// margin per unit of 1/clip (a tighter clip needs more ranging work).
const ADC_RANGE_COST: f64 = 0.1;

/// Digital-side overhead per unit of stuck-cell rate: ECC-style
/// detect/correct on memory traffic.
const ECC_COST: f64 = 0.5;

/// Statistical description of the device-level faults injected at an
/// operating point. All-zero (the `Default`) means the ideal device the
/// pre-fault code paths assumed.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct FaultModel {
    /// Fraction of array cells stuck at Gmin or Gmax (split 50/50 by the
    /// sampled map).
    pub stuck_rate: f64,
    /// Sigma of the log-normal multiplicative conductance drift per
    /// stored weight (`g' = g · exp(σ·N(0,1))`).
    pub drift_sigma: f64,
    /// ADC saturation threshold in units of the output RMS (0 = ideal,
    /// no clipping). Smaller values clip harder.
    pub adc_clip: f64,
    /// Fractional current lost to IR drop at the far end of a summation
    /// column (`[0, 1)`; columns scale linearly from 1.0 down to
    /// `1 − ir_drop`).
    pub ir_drop: f64,
}

impl FaultModel {
    /// Is this the ideal (zero-fault) device?
    pub fn is_ideal(&self) -> bool {
        self.stuck_rate == 0.0
            && self.drift_sigma == 0.0
            && self.adc_clip == 0.0
            && self.ir_drop == 0.0
    }

    /// One-knob fault bundle for degradation sweeps (`aimc faults`):
    /// stuck cells at `rate`, drift sigma `rate`, IR-drop fraction
    /// `rate`, ADC clipping off. `at_rate(0.0)` is the ideal model.
    pub fn at_rate(rate: f64) -> FaultModel {
        FaultModel {
            stuck_rate: rate,
            drift_sigma: rate,
            adc_clip: 0.0,
            ir_drop: rate,
        }
    }

    /// Expected energy overhead on analog cell arrays (ReRAM crossbar
    /// MACs and programming, SLM pixels): redundancy for stuck cells
    /// plus refresh programming against drift. Exactly 1.0 when ideal.
    pub fn cell_derate(&self) -> f64 {
        if self.is_ideal() {
            return 1.0;
        }
        (1.0 + self.stuck_rate * STUCK_REDUNDANCY_COST)
            * (1.0 + self.drift_sigma * DRIFT_REFRESH_COST)
    }

    /// Expected energy overhead on the converters (DAC drive, ADC
    /// readout): per-column IR calibration plus ADC auto-ranging margin
    /// when a saturation threshold is configured. Exactly 1.0 when
    /// ideal.
    pub fn converter_derate(&self) -> f64 {
        if self.is_ideal() {
            return 1.0;
        }
        let range = if self.adc_clip > 0.0 {
            1.0 + ADC_RANGE_COST / self.adc_clip
        } else {
            1.0
        };
        (1.0 + self.ir_drop * IR_CAL_COST) * range
    }

    /// Expected energy overhead on digital memory traffic (ECC-style
    /// detect/correct against stuck bits). Exactly 1.0 when ideal.
    pub fn digital_derate(&self) -> f64 {
        if self.is_ideal() {
            return 1.0;
        }
        1.0 + self.stuck_rate * ECC_COST
    }
}

/// State of one array cell in a sampled [`FaultMap`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CellFault {
    /// Cell programs and reads normally.
    Ok,
    /// Stuck at minimum conductance (reads as zero weight).
    StuckMin,
    /// Stuck at maximum conductance (reads as a full-scale weight).
    StuckMax,
}

/// One concrete seeded realization of a [`FaultModel`] over an R×C
/// array. Row-major cell order.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultMap {
    pub rows: usize,
    pub cols: usize,
    /// Per-cell stuck state, row-major (`rows × cols` entries).
    pub cells: Vec<CellFault>,
    /// Per-cell multiplicative drift factor, row-major; all 1.0 when
    /// `drift_sigma == 0`.
    pub drift: Vec<f64>,
    /// Per-column current scale from IR drop: 1.0 at the near column,
    /// `1 − ir_drop` at the far column, linear in between.
    pub column_scale: Vec<f64>,
}

/// Deterministic seed for a `(model, rows, cols, salt)` map draw.
pub fn seed_for(model: &FaultModel, rows: usize, cols: usize, salt: u64) -> u64 {
    let s = format!(
        "faultmap {rows} {cols} {salt} | {:016x} {:016x} {:016x} {:016x}",
        model.stuck_rate.to_bits(),
        model.drift_sigma.to_bits(),
        model.adc_clip.to_bits(),
        model.ir_drop.to_bits(),
    );
    fnv1a(s.as_bytes())
}

/// Sample one fault map. Same inputs ⇒ bit-identical output, on every
/// call, thread and platform (no wall clock, no global RNG).
pub fn sample_map(model: &FaultModel, rows: usize, cols: usize, salt: u64) -> FaultMap {
    let mut rng = Rng::new(seed_for(model, rows, cols, salt));
    let n = rows * cols;
    let mut cells = Vec::with_capacity(n);
    let mut drift = Vec::with_capacity(n);
    for _ in 0..n {
        let cell = if model.stuck_rate > 0.0 && rng.f64() < model.stuck_rate {
            if rng.bool() {
                CellFault::StuckMax
            } else {
                CellFault::StuckMin
            }
        } else {
            CellFault::Ok
        };
        cells.push(cell);
        drift.push(if model.drift_sigma > 0.0 {
            (model.drift_sigma * rng.normal()).exp()
        } else {
            1.0
        });
    }
    let span = (cols.max(2) - 1) as f64;
    let column_scale = (0..cols)
        .map(|c| 1.0 - model.ir_drop * (c as f64 / span))
        .collect();
    FaultMap {
        rows,
        cols,
        cells,
        drift,
        column_scale,
    }
}

impl FaultMap {
    /// Fraction of cells stuck (either polarity).
    pub fn stuck_fraction(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let stuck = self
            .cells
            .iter()
            .filter(|&&c| c != CellFault::Ok)
            .count();
        stuck as f64 / self.cells.len() as f64
    }

    /// FNV-1a digest over the exact bit content of the map — two maps
    /// are bit-identical iff their fingerprints match (modulo hash
    /// collisions), which is what the seeded-determinism tests pin.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(16 + self.cells.len() * 9 + self.column_scale.len() * 8);
        bytes.extend_from_slice(&(self.rows as u64).to_le_bytes());
        bytes.extend_from_slice(&(self.cols as u64).to_le_bytes());
        for c in &self.cells {
            bytes.push(match c {
                CellFault::Ok => 0,
                CellFault::StuckMin => 1,
                CellFault::StuckMax => 2,
            });
        }
        for d in &self.drift {
            bytes.extend_from_slice(&d.to_bits().to_le_bytes());
        }
        for s in &self.column_scale {
            bytes.extend_from_slice(&s.to_bits().to_le_bytes());
        }
        fnv1a(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_model_has_identity_derates() {
        let f = FaultModel::default();
        assert!(f.is_ideal());
        // Bit-exact 1.0: the zero-fault golden contract rests on this.
        assert_eq!(f.cell_derate().to_bits(), 1.0f64.to_bits());
        assert_eq!(f.converter_derate().to_bits(), 1.0f64.to_bits());
        assert_eq!(f.digital_derate().to_bits(), 1.0f64.to_bits());
        assert_eq!(FaultModel::at_rate(0.0), f);
    }

    #[test]
    fn derates_grow_with_fault_severity() {
        let lo = FaultModel::at_rate(0.01);
        let hi = FaultModel::at_rate(0.05);
        assert!(lo.cell_derate() > 1.0);
        assert!(hi.cell_derate() > lo.cell_derate());
        assert!(hi.converter_derate() > lo.converter_derate());
        assert!(hi.digital_derate() > lo.digital_derate());
        let clipped = FaultModel {
            adc_clip: 2.0,
            ..Default::default()
        };
        assert!(clipped.converter_derate() > 1.0);
        assert_eq!(clipped.cell_derate().to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn same_seed_gives_bit_identical_map() {
        let f = FaultModel {
            stuck_rate: 0.02,
            drift_sigma: 0.05,
            adc_clip: 3.0,
            ir_drop: 0.1,
        };
        let a = sample_map(&f, 64, 64, 7);
        let b = sample_map(&f, 64, 64, 7);
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Across threads too.
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || sample_map(&f, 64, 64, 7).fingerprint()))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), a.fingerprint());
        }
    }

    #[test]
    fn different_seed_or_model_changes_the_map() {
        let f = FaultModel::at_rate(0.05);
        let a = sample_map(&f, 32, 32, 1);
        let b = sample_map(&f, 32, 32, 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
        let g = FaultModel::at_rate(0.06);
        let c = sample_map(&g, 32, 32, 1);
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn map_statistics_track_the_model() {
        let f = FaultModel {
            stuck_rate: 0.1,
            drift_sigma: 0.0,
            adc_clip: 0.0,
            ir_drop: 0.2,
        };
        let m = sample_map(&f, 128, 128, 3);
        let frac = m.stuck_fraction();
        assert!((frac - 0.1).abs() < 0.02, "stuck fraction {frac}");
        assert!(m.drift.iter().all(|&d| d == 1.0), "no drift configured");
        assert_eq!(m.column_scale[0], 1.0);
        let last = *m.column_scale.last().unwrap();
        assert!((last - 0.8).abs() < 1e-12, "far column {last}");
    }

    #[test]
    fn ideal_map_is_clean() {
        let m = sample_map(&FaultModel::default(), 16, 16, 0);
        assert_eq!(m.stuck_fraction(), 0.0);
        assert!(m.drift.iter().all(|&d| d == 1.0));
        assert!(m.column_scale.iter().all(|&s| s == 1.0));
    }
}
