//! The operating point: technology node, bit precision and device noise
//! as one value threaded through every simulation entry point.
//!
//! The paper's efficiency claims scale with "the size, arithmetic
//! intensity, and bit precision of the computation", so precision cannot
//! stay a frozen constant inside `energy/constants.rs`. An
//! [`OperatingPoint`] carries everything a simulator needs beyond the
//! layer shape: the CMOS node, separate activation and weight bit
//! widths, and a [`NoiseModel`] for the per-device non-idealities the
//! accuracy estimator ([`crate::simulator::accuracy`]) consumes.
//!
//! **Compatibility contract:** `OperatingPoint::default()` is 45 nm,
//! 8×8-bit, noiseless — and every simulator is written so that results
//! at the default precision are **bit-identical** to the pre-refactor
//! fixed-precision code paths (the golden tests in
//! `tests/scenario_golden.rs` pin this). The precision scale factors
//! [`OperatingPoint::sx`]/[`OperatingPoint::sw`] are exactly 1.0 at
//! 8 bits, and multiplying by 1.0 is an IEEE-754 identity for finite
//! values.

use super::faults::FaultModel;

/// Per-device noise description for the accuracy estimator. Sigmas are
/// relative to unit-variance signals (i.e. a `weight_sigma` of 0.05
/// means 5% rms conductance/phase error per stored weight).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct NoiseModel {
    /// RMS error on each stored weight (programming / drift variation).
    pub weight_sigma: f64,
    /// RMS error added per dot-product readout (ADC / shot noise),
    /// in units of one input element's contribution.
    pub output_sigma: f64,
    /// Injected device faults (stuck cells, drift, ADC saturation,
    /// IR drop — see [`crate::simulator::faults`]). The `Default` is the
    /// ideal device, reproducing every pre-fault code path exactly.
    pub faults: FaultModel,
}

impl NoiseModel {
    pub fn is_noiseless(&self) -> bool {
        self.weight_sigma == 0.0 && self.output_sigma == 0.0 && self.faults.is_ideal()
    }
}

/// One point in the (node × precision × noise) design space.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    /// Technology node, nm.
    pub node_nm: f64,
    /// Activation (input / output sample) bit width.
    pub bits_x: u32,
    /// Weight bit width.
    pub bits_w: u32,
    /// Per-device noise model (only the accuracy estimator reads it;
    /// the energy models are deterministic).
    pub noise: NoiseModel,
}

impl Default for OperatingPoint {
    /// The pre-refactor fixed configuration: 45 nm, 8-bit activations
    /// and weights, no noise.
    fn default() -> Self {
        OperatingPoint {
            node_nm: 45.0,
            bits_x: 8,
            bits_w: 8,
            noise: NoiseModel::default(),
        }
    }
}

impl OperatingPoint {
    /// Default precision at an explicit node — the direct replacement
    /// for every old `(…, node_nm: f64)` call site.
    pub fn node(node_nm: f64) -> Self {
        OperatingPoint {
            node_nm,
            ..Default::default()
        }
    }

    /// Builder: set both bit widths.
    pub fn bits(self, bits_x: u32, bits_w: u32) -> Self {
        OperatingPoint {
            bits_x,
            bits_w,
            ..self
        }
    }

    /// Builder: set the noise model.
    pub fn with_noise(self, noise: NoiseModel) -> Self {
        OperatingPoint { noise, ..self }
    }

    /// Activation storage scale vs the 8-bit calibration (bytes per
    /// element multiplier). Exactly 1.0 at 8 bits.
    pub fn sx(&self) -> f64 {
        self.bits_x as f64 / 8.0
    }

    /// Weight storage scale vs the 8-bit calibration.
    pub fn sw(&self) -> f64 {
        self.bits_w as f64 / 8.0
    }

    /// Does this point reproduce the pre-refactor fixed precision?
    pub fn is_default_precision(&self) -> bool {
        self.bits_x == 8 && self.bits_w == 8 && self.noise.is_noiseless()
    }

    /// Short "BXxBW" label for tables and CLI output ("8x8", "6x4").
    pub fn bits_label(&self) -> String {
        format!("{}x{}", self.bits_x, self.bits_w)
    }

    /// Exact-bits cache key (same convention as `f64::to_bits` node
    /// keys everywhere else in the cache layer — no tolerance games).
    pub fn key(&self) -> OpKey {
        OpKey {
            node_bits: self.node_nm.to_bits(),
            bits_x: self.bits_x,
            bits_w: self.bits_w,
            wsig_bits: self.noise.weight_sigma.to_bits(),
            osig_bits: self.noise.output_sigma.to_bits(),
            stuck_bits: self.noise.faults.stuck_rate.to_bits(),
            drift_bits: self.noise.faults.drift_sigma.to_bits(),
            clip_bits: self.noise.faults.adc_clip.to_bits(),
            ir_bits: self.noise.faults.ir_drop.to_bits(),
        }
    }
}

/// Hashable/orderable identity of an [`OperatingPoint`]: IEEE-754 bit
/// patterns for the floats, so distinct points never alias and equal
/// points always collide. Used by [`crate::simulator::SweepCache`] memo
/// keys, the persistent snapshot format, and the surrogate table key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpKey {
    pub node_bits: u64,
    pub bits_x: u32,
    pub bits_w: u32,
    pub wsig_bits: u64,
    pub osig_bits: u64,
    pub stuck_bits: u64,
    pub drift_bits: u64,
    pub clip_bits: u64,
    pub ir_bits: u64,
}

impl OpKey {
    /// Reconstruct the operating point this key identifies.
    pub fn to_op(self) -> OperatingPoint {
        OperatingPoint {
            node_nm: f64::from_bits(self.node_bits),
            bits_x: self.bits_x,
            bits_w: self.bits_w,
            noise: NoiseModel {
                weight_sigma: f64::from_bits(self.wsig_bits),
                output_sigma: f64::from_bits(self.osig_bits),
                faults: FaultModel {
                    stuck_rate: f64::from_bits(self.stuck_bits),
                    drift_sigma: f64::from_bits(self.drift_bits),
                    adc_clip: f64::from_bits(self.clip_bits),
                    ir_drop: f64::from_bits(self.ir_bits),
                },
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_legacy_fixed_point() {
        let op = OperatingPoint::default();
        assert_eq!(op.node_nm, 45.0);
        assert_eq!((op.bits_x, op.bits_w), (8, 8));
        assert!(op.noise.is_noiseless());
        assert!(op.is_default_precision());
        // The storage multipliers are *exactly* 1.0 — the bit-identity
        // contract of the whole refactor rests on this.
        assert_eq!(op.sx().to_bits(), 1.0f64.to_bits());
        assert_eq!(op.sw().to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn node_constructor_keeps_default_precision() {
        let op = OperatingPoint::node(7.0);
        assert_eq!(op.node_nm, 7.0);
        assert!(op.is_default_precision());
        assert_eq!(op, OperatingPoint::node(7.0));
    }

    #[test]
    fn builders_compose() {
        let op = OperatingPoint::node(28.0).bits(6, 4).with_noise(NoiseModel {
            weight_sigma: 0.05,
            output_sigma: 0.01,
            ..Default::default()
        });
        assert_eq!(op.bits_label(), "6x4");
        assert!(!op.is_default_precision());
        assert!((op.sx() - 0.75).abs() < 1e-15);
        assert!((op.sw() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn key_round_trips_and_distinguishes() {
        let a = OperatingPoint::node(45.0).bits(8, 8);
        let b = OperatingPoint::node(45.0).bits(8, 4);
        let c = OperatingPoint::node(7.0).bits(8, 8);
        let d = a.with_noise(NoiseModel {
            weight_sigma: 0.1,
            output_sigma: 0.0,
            ..Default::default()
        });
        let e = a.with_noise(NoiseModel {
            faults: FaultModel::at_rate(0.01),
            ..Default::default()
        });
        assert_ne!(a.key(), b.key());
        assert_ne!(a.key(), c.key());
        assert_ne!(a.key(), d.key());
        assert_ne!(a.key(), e.key(), "fault model must be part of the key");
        assert_eq!(a.key(), OperatingPoint::default().key());
        for op in [a, b, c, d, e] {
            assert_eq!(op.key().to_op(), op);
        }
    }

    #[test]
    fn keys_are_ordered_deterministically() {
        let mut keys = vec![
            OperatingPoint::node(7.0).key(),
            OperatingPoint::node(45.0).bits(4, 4).key(),
            OperatingPoint::node(45.0).key(),
        ];
        keys.sort();
        let again = {
            let mut k = keys.clone();
            k.sort();
            k
        };
        assert_eq!(keys, again);
    }
}
