//! The unified [`Machine`] trait: one interface over all four
//! cycle-accurate simulators (and, via [`AnalyticMachine`], the
//! closed-form models), so sweep drivers iterate `&[Box<dyn Machine>]`
//! instead of hand-unrolling per-module match arms.
//!
//! Every implementation is a thin adapter over the module's existing
//! `simulate_layer` / `simulate_network` functions — the physics stays
//! where it is documented; this module only provides the common shape
//! plus a stable config [fingerprint](Machine::fingerprint) for the
//! [`crate::simulator::SweepCache`] memo key. Fingerprints hash each
//! config **field by field** (see [`Fp`]) rather than through `Debug`
//! output, so renaming a field or changing derive formatting can never
//! silently re-key (or worse, alias) persisted cache entries. The
//! [`OperatingPoint`] is *not* part of the fingerprint — it joins the
//! cache key separately as an [`super::op::OpKey`].

use super::op::OperatingPoint;
use super::{optical4f, photonic, reram, systolic, Component, SimResult};
use crate::analytic::{Processor, Workload};
use crate::networks::{ConvLayer, Network};

/// A simulated inference machine: anything that can price one conv layer
/// (and, by summation, a network) at an operating point.
///
/// `Send + Sync` is part of the contract so trait objects can be shared
/// across the [`crate::util::pool`] workers of a parallel sweep.
pub trait Machine: Send + Sync {
    /// Short stable identifier ("systolic", "reram", …) used in tables,
    /// CLI arguments and bench labels.
    fn name(&self) -> &'static str;

    /// Stable fingerprint of this machine's *configuration* (not its
    /// name alone): two instances with different knob settings must
    /// fingerprint differently, so cached sweep entries never alias
    /// across configs.
    fn fingerprint(&self) -> u64;

    /// Price one conv layer at `op`.
    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult;

    /// Price a whole network at `op`. The default merges per-layer
    /// results in layer order — implementations may override with a
    /// coefficient-hoisted fast path, but must produce bit-identical
    /// sums (the memoization tests rely on it).
    fn simulate_network(&self, net: &Network, op: &OperatingPoint) -> SimResult {
        let mut total = SimResult::default();
        for layer in &net.layers {
            total += &self.simulate_layer(layer, op);
        }
        total
    }
}

/// FNV-1a over a byte string — tiny, dependency-free, stable across
/// runs (persistent cache snapshots key on it, so stability is part of
/// the on-disk contract).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Field-explicit fingerprint builder: a running FNV-1a hash seeded by a
/// domain tag, extended one *named order of fields* at a time. Unlike
/// hashing `format!("{self:?}")`, the digest depends only on the field
/// values an impl feeds in — not on struct/field names, derive
/// formatting, or field display order changes — so a fingerprint changes
/// exactly when an impl's field list or a field value changes.
///
/// Every field is mixed as a fixed 8-byte little-endian word behind a
/// separator byte, so adjacent fields can never alias across boundaries.
pub(crate) struct Fp(u64);

impl Fp {
    pub(crate) fn new(tag: &str) -> Fp {
        Fp(fnv1a(tag.as_bytes()))
    }

    fn mix(mut self, bytes: &[u8]) -> Fp {
        // Separator: keeps (a, bc) distinct from (ab, c).
        self.0 ^= 0x1f;
        self.0 = self.0.wrapping_mul(FNV_PRIME);
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub(crate) fn u64(self, v: u64) -> Fp {
        self.mix(&v.to_le_bytes())
    }

    pub(crate) fn usize(self, v: usize) -> Fp {
        self.u64(v as u64)
    }

    pub(crate) fn u32(self, v: u32) -> Fp {
        self.u64(v as u64)
    }

    pub(crate) fn bool(self, v: bool) -> Fp {
        self.u64(v as u64)
    }

    /// Floats hash by IEEE-754 bit pattern — exact, no tolerance.
    pub(crate) fn f64(self, v: f64) -> Fp {
        self.u64(v.to_bits())
    }

    pub(crate) fn str(self, s: &str) -> Fp {
        self.mix(s.as_bytes())
    }

    pub(crate) fn finish(self) -> u64 {
        self.0
    }
}

impl Machine for systolic::SystolicConfig {
    fn name(&self) -> &'static str {
        "systolic"
    }

    fn fingerprint(&self) -> u64 {
        Fp::new("systolic")
            .usize(self.dim)
            .usize(self.sram_bytes)
            .usize(self.banks)
            .u32(self.hop_bits)
            .f64(self.reg_bytes_per_mac)
            .f64(self.e_dram_per_byte)
            .f64(self.act_bytes)
            .f64(self.psum_bytes)
            .finish()
    }

    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
        systolic::simulate_layer(self, layer, op)
    }

    fn simulate_network(&self, net: &Network, op: &OperatingPoint) -> SimResult {
        systolic::simulate_network(self, net, op)
    }
}

impl Machine for optical4f::Optical4FConfig {
    fn name(&self) -> &'static str {
        "optical4f"
    }

    fn fingerprint(&self) -> u64 {
        Fp::new("optical4f")
            .usize(self.slm_pixels)
            .usize(self.sram_bytes)
            .usize(self.banks)
            .f64(self.act_bytes)
            .f64(self.psum_bytes)
            .bool(self.laser_full_aperture)
            .finish()
    }

    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
        optical4f::simulate_layer(self, layer, op)
    }

    fn simulate_network(&self, net: &Network, op: &OperatingPoint) -> SimResult {
        optical4f::simulate_network(self, net, op)
    }
}

impl Machine for reram::ReramConfig {
    fn name(&self) -> &'static str {
        "reram"
    }

    fn fingerprint(&self) -> u64 {
        Fp::new("reram")
            .usize(self.dim)
            .usize(self.sram_bytes)
            .usize(self.banks)
            .u32(self.array.bits)
            .f64(self.array.v_rms)
            .f64(self.array.dt)
            .f64(self.reuse)
            .f64(self.e_program)
            .f64(self.signed_factor)
            .finish()
    }

    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
        reram::simulate_layer(self, layer, op)
    }

    fn simulate_network(&self, net: &Network, op: &OperatingPoint) -> SimResult {
        reram::simulate_network(self, net, op)
    }
}

impl Machine for photonic::PhotonicConfig {
    fn name(&self) -> &'static str {
        "photonic"
    }

    fn fingerprint(&self) -> u64 {
        Fp::new("photonic")
            .usize(self.dim)
            .usize(self.sram_bytes)
            .usize(self.banks)
            .f64(self.e_modulator)
            .f64(self.dacs_per_weight)
            .f64(self.signed_factor)
            .finish()
    }

    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
        photonic::simulate_layer(self, layer, op)
    }

    fn simulate_network(&self, net: &Network, op: &OperatingPoint) -> SimResult {
        photonic::simulate_network(self, net, op)
    }
}

/// Adapter exposing a closed-form [`Processor`] model as a [`Machine`]:
/// each layer is priced by its own eq. (8)/(9) workload, with the
/// memory/compute split mapped onto the ledger (SRAM/MAC buckets) so
/// analytic and cycle-accurate results render through the same tables.
///
/// The closed forms are calibrated at the paper's fixed 8-bit operand
/// width, so only `op.node_nm` is consumed here; precision sweeps are a
/// cycle-simulator feature.
#[derive(Clone, Copy, Debug)]
pub struct AnalyticMachine(pub Processor);

impl Machine for AnalyticMachine {
    fn name(&self) -> &'static str {
        self.0.short()
    }

    fn fingerprint(&self) -> u64 {
        Fp::new("analytic").str(self.0.short()).finish()
    }

    fn simulate_layer(&self, layer: &ConvLayer, op: &OperatingPoint) -> SimResult {
        let w = Workload::from_layer(*layer);
        let e = self.0.efficiency(&w, op.node_nm);
        let ops = layer.ops();
        let mut r = SimResult::default();
        r.macs = layer.macs();
        r.ops = ops;
        r.ledger.add(Component::Sram, e.e_mem * ops);
        r.ledger.add(Component::Mac, e.e_comp * ops);
        r
    }
}

/// The four cycle-accurate machines at their default (paper §VI/§VII)
/// configurations, in Fig. 6 chart order.
pub fn all_machines() -> Vec<Box<dyn Machine>> {
    vec![
        Box::new(systolic::SystolicConfig::default()),
        Box::new(reram::ReramConfig::default()),
        Box::new(photonic::PhotonicConfig::default()),
        Box::new(optical4f::Optical4FConfig::default()),
    ]
}

/// The four analytic processor models wrapped as machines, Fig. 6 order.
pub fn all_analytic_machines() -> Vec<Box<dyn Machine>> {
    Processor::ALL
        .iter()
        .map(|&p| Box::new(AnalyticMachine(p)) as Box<dyn Machine>)
        .collect()
}

/// Look up a default-config machine by (case-insensitive) name,
/// accepting the CLI aliases the `simulate` subcommand always took.
pub fn by_name(name: &str) -> Option<Box<dyn Machine>> {
    match name.to_ascii_lowercase().as_str() {
        "systolic" => Some(Box::new(systolic::SystolicConfig::default())),
        "optical4f" | "optical" | "4f" => {
            Some(Box::new(optical4f::Optical4FConfig::default()))
        }
        "photonic" | "sp" => Some(Box::new(photonic::PhotonicConfig::default())),
        "reram" | "memristor" => Some(Box::new(reram::ReramConfig::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;

    fn op(nm: f64) -> OperatingPoint {
        OperatingPoint::node(nm)
    }

    #[test]
    fn trait_network_matches_free_function() {
        let net = yolov3(1000);
        let cfg = systolic::SystolicConfig::default();
        let direct = systolic::simulate_network(&cfg, &net, &op(32.0));
        let via_trait = (&cfg as &dyn Machine).simulate_network(&net, &op(32.0));
        assert_eq!(direct.macs, via_trait.macs);
        assert_eq!(direct.ledger.total(), via_trait.ledger.total());
        assert_eq!(direct.time_units, via_trait.time_units);
    }

    #[test]
    fn default_network_impl_matches_override() {
        // The hoisted-coefficients override must be bit-identical to the
        // default per-layer merge (SweepCache correctness rests on this).
        struct PerLayer(systolic::SystolicConfig);
        impl Machine for PerLayer {
            fn name(&self) -> &'static str {
                "per-layer"
            }
            fn fingerprint(&self) -> u64 {
                0
            }
            fn simulate_layer(&self, l: &ConvLayer, o: &OperatingPoint) -> SimResult {
                systolic::simulate_layer(&self.0, l, o)
            }
        }
        let net = yolov3(1000);
        let cfg = systolic::SystolicConfig::default();
        let a = (&cfg as &dyn Machine).simulate_network(&net, &op(45.0));
        let b = PerLayer(cfg).simulate_network(&net, &op(45.0));
        assert_eq!(a.macs, b.macs);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.time_units, b.time_units);
        for c in Component::ALL {
            assert_eq!(a.ledger.get(c), b.ledger.get(c), "{c:?}");
        }
    }

    #[test]
    fn all_machines_have_unique_names_and_fingerprints() {
        let ms = all_machines();
        assert_eq!(ms.len(), 4);
        let mut names: Vec<&str> = ms.iter().map(|m| m.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4);
        let mut fps: Vec<u64> = ms.iter().map(|m| m.fingerprint()).collect();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), 4);
    }

    #[test]
    fn fingerprint_tracks_config_changes() {
        let a = systolic::SystolicConfig::default();
        let b = systolic::SystolicConfig {
            dim: 128,
            ..Default::default()
        };
        assert_ne!(Machine::fingerprint(&a), Machine::fingerprint(&b));
        assert_eq!(
            Machine::fingerprint(&a),
            Machine::fingerprint(&systolic::SystolicConfig::default())
        );
    }

    #[test]
    fn fingerprint_covers_every_field() {
        // Field-explicit hashing must react to EVERY knob, including the
        // ones a Debug-derived hash could silently drop in a refactor.
        let base = Machine::fingerprint(&systolic::SystolicConfig::default());
        let variants = [
            systolic::SystolicConfig {
                sram_bytes: 1,
                ..Default::default()
            },
            systolic::SystolicConfig {
                banks: 7,
                ..Default::default()
            },
            systolic::SystolicConfig {
                hop_bits: 41,
                ..Default::default()
            },
            systolic::SystolicConfig {
                reg_bytes_per_mac: 6.0,
                ..Default::default()
            },
            systolic::SystolicConfig {
                e_dram_per_byte: 1e-12,
                ..Default::default()
            },
            systolic::SystolicConfig {
                act_bytes: 2.0,
                ..Default::default()
            },
            systolic::SystolicConfig {
                psum_bytes: 8.0,
                ..Default::default()
            },
        ];
        let mut fps: Vec<u64> = variants.iter().map(Machine::fingerprint).collect();
        fps.push(base);
        let n = fps.len();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), n, "every field change must re-fingerprint");

        let r = reram::ReramConfig::default();
        let r2 = reram::ReramConfig {
            array: crate::energy::reram::ReramArray {
                v_rms: 0.08,
                ..r.array
            },
            ..r
        };
        assert_ne!(Machine::fingerprint(&r), Machine::fingerprint(&r2));
    }

    #[test]
    fn field_boundaries_do_not_alias() {
        // (a, bc) vs (ab, c)-style shifts must hash differently.
        let a = Fp::new("t").u64(1).u64(0).finish();
        let b = Fp::new("t").u64(0).u64(1).finish();
        assert_ne!(a, b);
        assert_ne!(Fp::new("t").str("ab").str("c").finish(), Fp::new("t").str("a").str("bc").finish());
        assert_ne!(Fp::new("x").finish(), Fp::new("y").finish());
    }

    #[test]
    fn by_name_aliases() {
        for (alias, want) in [
            ("systolic", "systolic"),
            ("4f", "optical4f"),
            ("OPTICAL", "optical4f"),
            ("sp", "photonic"),
            ("memristor", "reram"),
        ] {
            assert_eq!(by_name(alias).unwrap().name(), want, "{alias}");
        }
        assert!(by_name("abacus").is_none());
    }

    #[test]
    fn analytic_machine_matches_processor_efficiency() {
        let layer = ConvLayer::square(512, 128, 128, 3, 1);
        let m = AnalyticMachine(Processor::Optical4F);
        let r = m.simulate_layer(&layer, &op(32.0));
        let w = Workload::from_layer(layer);
        let want = Processor::Optical4F.efficiency(&w, 32.0).tops_per_watt();
        assert!((r.tops_per_watt() - want).abs() / want < 1e-12);
    }
}
