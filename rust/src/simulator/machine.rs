//! The unified [`Machine`] trait: one interface over all four
//! cycle-accurate simulators (and, via [`AnalyticMachine`], the
//! closed-form models), so sweep drivers iterate `&[Box<dyn Machine>]`
//! instead of hand-unrolling per-module match arms.
//!
//! Every implementation is a thin adapter over the module's existing
//! `simulate_layer` / `simulate_network` functions — the physics stays
//! where it is documented; this module only provides the common shape
//! plus a stable config [fingerprint](Machine::fingerprint) for the
//! [`crate::simulator::SweepCache`] memo key.

use super::{optical4f, photonic, reram, systolic, Component, SimResult};
use crate::analytic::{Processor, Workload};
use crate::networks::{ConvLayer, Network};

/// A simulated inference machine: anything that can price one conv layer
/// (and, by summation, a network) at a technology node.
///
/// `Send + Sync` is part of the contract so trait objects can be shared
/// across the [`crate::util::pool`] workers of a parallel sweep.
pub trait Machine: Send + Sync {
    /// Short stable identifier ("systolic", "reram", …) used in tables,
    /// CLI arguments and bench labels.
    fn name(&self) -> &'static str;

    /// Stable fingerprint of this machine's *configuration* (not its
    /// name alone): two instances with different knob settings must
    /// fingerprint differently, so cached sweep entries never alias
    /// across configs.
    fn fingerprint(&self) -> u64;

    /// Price one conv layer at `node_nm`.
    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult;

    /// Price a whole network at `node_nm`. The default merges per-layer
    /// results in layer order — implementations may override with a
    /// coefficient-hoisted fast path, but must produce bit-identical
    /// sums (the memoization tests rely on it).
    fn simulate_network(&self, net: &Network, node_nm: f64) -> SimResult {
        let mut total = SimResult::default();
        for layer in &net.layers {
            total += &self.simulate_layer(layer, node_nm);
        }
        total
    }
}

/// FNV-1a over a byte string — tiny, dependency-free, stable across
/// runs (the memo key only ever lives for one process, but stability
/// makes bench logs comparable).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint a config through its (stable, field-complete) Debug
/// rendering, domain-tagged so two machines with coincidentally equal
/// field lists still differ.
fn config_fingerprint(tag: &str, debug: &str) -> u64 {
    fnv1a(format!("{tag}:{debug}").as_bytes())
}

impl Machine for systolic::SystolicConfig {
    fn name(&self) -> &'static str {
        "systolic"
    }

    fn fingerprint(&self) -> u64 {
        config_fingerprint("systolic", &format!("{self:?}"))
    }

    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult {
        systolic::simulate_layer(self, layer, node_nm)
    }

    fn simulate_network(&self, net: &Network, node_nm: f64) -> SimResult {
        systolic::simulate_network(self, net, node_nm)
    }
}

impl Machine for optical4f::Optical4FConfig {
    fn name(&self) -> &'static str {
        "optical4f"
    }

    fn fingerprint(&self) -> u64 {
        config_fingerprint("optical4f", &format!("{self:?}"))
    }

    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult {
        optical4f::simulate_layer(self, layer, node_nm)
    }

    fn simulate_network(&self, net: &Network, node_nm: f64) -> SimResult {
        optical4f::simulate_network(self, net, node_nm)
    }
}

impl Machine for reram::ReramConfig {
    fn name(&self) -> &'static str {
        "reram"
    }

    fn fingerprint(&self) -> u64 {
        config_fingerprint("reram", &format!("{self:?}"))
    }

    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult {
        reram::simulate_layer(self, layer, node_nm)
    }

    fn simulate_network(&self, net: &Network, node_nm: f64) -> SimResult {
        reram::simulate_network(self, net, node_nm)
    }
}

impl Machine for photonic::PhotonicConfig {
    fn name(&self) -> &'static str {
        "photonic"
    }

    fn fingerprint(&self) -> u64 {
        config_fingerprint("photonic", &format!("{self:?}"))
    }

    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult {
        photonic::simulate_layer(self, layer, node_nm)
    }

    fn simulate_network(&self, net: &Network, node_nm: f64) -> SimResult {
        photonic::simulate_network(self, net, node_nm)
    }
}

/// Adapter exposing a closed-form [`Processor`] model as a [`Machine`]:
/// each layer is priced by its own eq. (8)/(9) workload, with the
/// memory/compute split mapped onto the ledger (SRAM/MAC buckets) so
/// analytic and cycle-accurate results render through the same tables.
#[derive(Clone, Copy, Debug)]
pub struct AnalyticMachine(pub Processor);

impl Machine for AnalyticMachine {
    fn name(&self) -> &'static str {
        self.0.short()
    }

    fn fingerprint(&self) -> u64 {
        config_fingerprint("analytic", &format!("{self:?}"))
    }

    fn simulate_layer(&self, layer: &ConvLayer, node_nm: f64) -> SimResult {
        let w = Workload::from_layer(*layer);
        let e = self.0.efficiency(&w, node_nm);
        let ops = layer.ops();
        let mut r = SimResult::default();
        r.macs = layer.macs();
        r.ops = ops;
        r.ledger.add(Component::Sram, e.e_mem * ops);
        r.ledger.add(Component::Mac, e.e_comp * ops);
        r
    }
}

/// The four cycle-accurate machines at their default (paper §VI/§VII)
/// configurations, in Fig. 6 chart order.
pub fn all_machines() -> Vec<Box<dyn Machine>> {
    vec![
        Box::new(systolic::SystolicConfig::default()),
        Box::new(reram::ReramConfig::default()),
        Box::new(photonic::PhotonicConfig::default()),
        Box::new(optical4f::Optical4FConfig::default()),
    ]
}

/// The four analytic processor models wrapped as machines, Fig. 6 order.
pub fn all_analytic_machines() -> Vec<Box<dyn Machine>> {
    Processor::ALL
        .iter()
        .map(|&p| Box::new(AnalyticMachine(p)) as Box<dyn Machine>)
        .collect()
}

/// Look up a default-config machine by (case-insensitive) name,
/// accepting the CLI aliases the `simulate` subcommand always took.
pub fn by_name(name: &str) -> Option<Box<dyn Machine>> {
    match name.to_ascii_lowercase().as_str() {
        "systolic" => Some(Box::new(systolic::SystolicConfig::default())),
        "optical4f" | "optical" | "4f" => {
            Some(Box::new(optical4f::Optical4FConfig::default()))
        }
        "photonic" | "sp" => Some(Box::new(photonic::PhotonicConfig::default())),
        "reram" | "memristor" => Some(Box::new(reram::ReramConfig::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks::yolov3::yolov3;

    #[test]
    fn trait_network_matches_free_function() {
        let net = yolov3(1000);
        let cfg = systolic::SystolicConfig::default();
        let direct = systolic::simulate_network(&cfg, &net, 32.0);
        let via_trait = (&cfg as &dyn Machine).simulate_network(&net, 32.0);
        assert_eq!(direct.macs, via_trait.macs);
        assert_eq!(direct.ledger.total(), via_trait.ledger.total());
        assert_eq!(direct.time_units, via_trait.time_units);
    }

    #[test]
    fn default_network_impl_matches_override() {
        // The hoisted-coefficients override must be bit-identical to the
        // default per-layer merge (SweepCache correctness rests on this).
        struct PerLayer(systolic::SystolicConfig);
        impl Machine for PerLayer {
            fn name(&self) -> &'static str {
                "per-layer"
            }
            fn fingerprint(&self) -> u64 {
                0
            }
            fn simulate_layer(&self, l: &ConvLayer, n: f64) -> SimResult {
                systolic::simulate_layer(&self.0, l, n)
            }
        }
        let net = yolov3(1000);
        let cfg = systolic::SystolicConfig::default();
        let a = (&cfg as &dyn Machine).simulate_network(&net, 45.0);
        let b = PerLayer(cfg).simulate_network(&net, 45.0);
        assert_eq!(a.macs, b.macs);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.time_units, b.time_units);
        for c in Component::ALL {
            assert_eq!(a.ledger.get(c), b.ledger.get(c), "{c:?}");
        }
    }

    #[test]
    fn all_machines_have_unique_names_and_fingerprints() {
        let ms = all_machines();
        assert_eq!(ms.len(), 4);
        let mut names: Vec<&str> = ms.iter().map(|m| m.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 4);
        let mut fps: Vec<u64> = ms.iter().map(|m| m.fingerprint()).collect();
        fps.sort();
        fps.dedup();
        assert_eq!(fps.len(), 4);
    }

    #[test]
    fn fingerprint_tracks_config_changes() {
        let a = systolic::SystolicConfig::default();
        let b = systolic::SystolicConfig {
            dim: 128,
            ..Default::default()
        };
        assert_ne!(Machine::fingerprint(&a), Machine::fingerprint(&b));
        assert_eq!(
            Machine::fingerprint(&a),
            Machine::fingerprint(&systolic::SystolicConfig::default())
        );
    }

    #[test]
    fn by_name_aliases() {
        for (alias, want) in [
            ("systolic", "systolic"),
            ("4f", "optical4f"),
            ("OPTICAL", "optical4f"),
            ("sp", "photonic"),
            ("memristor", "reram"),
        ] {
            assert_eq!(by_name(alias).unwrap().name(), want, "{alias}");
        }
        assert!(by_name("abacus").is_none());
    }

    #[test]
    fn analytic_machine_matches_processor_efficiency() {
        let layer = ConvLayer::square(512, 128, 128, 3, 1);
        let m = AnalyticMachine(Processor::Optical4F);
        let r = m.simulate_layer(&layer, 32.0);
        let w = Workload::from_layer(layer);
        let want = Processor::Optical4F.efficiency(&w, 32.0).tops_per_watt();
        assert!((r.tops_per_watt() - want).abs() / want < 1e-12);
    }
}
