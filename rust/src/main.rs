//! `aimc` — CLI for the analog in-memory compute reproduction.
//!
//! Subcommands regenerate every table/figure of the paper, run the
//! cycle-accurate simulators on arbitrary (network, machine, node)
//! combinations, verify the AOT artifacts against their goldens, and
//! serve inference through the PJRT coordinator.

use std::time::Instant;

use aimc::coordinator::exec::SimExecutor;
use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{energy as co_energy, smallcnn_network, ConvPath, IMAGE_ELEMS};
use aimc::networks::{by_name, zoo, DEFAULT_INPUT};
use aimc::report;
use aimc::runtime::Engine;
use aimc::simulator::{machine, sweep, Machine, SweepCache};
use aimc::technode::NODES;
use aimc::util::cli::Spec;
use aimc::util::pool::Pool;
use aimc::util::rng::Rng;
use aimc::util::table::Table;

fn spec() -> Spec {
    Spec::new(
        "aimc",
        "Analog, In-memory Compute Architectures for AI — reproduction CLI.\n\
         commands: table1 table2 table3 table4 fig6 fig7 fig8 fig9 fig10 \
         crossval all simulate sweep zoo verify serve",
    )
    .opt("net", "network name (fig8/fig9/fig10/simulate)", None)
    .opt("input", "input resolution (pixels per side)", Some("1000"))
    .opt("node", "technology node in nm (simulate)", Some("45"))
    .opt(
        "machine",
        "simulate on: systolic | optical4f | photonic | reram",
        Some("systolic"),
    )
    .opt("path", "serve datapath: exact | systolic | fft", Some("exact"))
    .opt(
        "threads",
        "worker threads for sweeps (default: AIMC_THREADS or all cores)",
        None,
    )
    .opt("requests", "serve: number of requests", Some("64"))
    .opt("workers", "serve: worker threads", Some("2"))
    .opt(
        "max-pending",
        "serve: admission bound on in-flight requests (reject beyond)",
        Some("1024"),
    )
    .flag(
        "synthetic",
        "serve: deterministic in-process backend (no artifacts/PJRT needed)",
    )
    .flag("csv", "emit CSV instead of aligned text")
}

fn emit(t: &Table, csv: bool) {
    if csv {
        print!("{}", t.to_csv());
    } else {
        println!("{}", t.render());
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let s = spec();
    let args = s.parse(std::env::args().skip(1))?;
    if args.positional.is_empty() {
        println!("{}", s.usage());
        return Ok(());
    }
    let csv = args.flag("csv");
    let input = args.get_usize("input", DEFAULT_INPUT)?;
    let net = args.get("net");

    for cmd in &args.positional {
        match cmd.as_str() {
            "table1" => emit(&report::table1(input), csv),
            "table2" => emit(&report::table2(input), csv),
            "table3" => emit(&report::table3(input), csv),
            "table4" => emit(&report::table4(), csv),
            "fig6" => emit(&report::fig6(), csv),
            "fig7" => emit(&report::fig7(), csv),
            "fig8" => emit(&report::fig8(net, input), csv),
            "fig9" => emit(&report::fig9(net, input), csv),
            "fig10" => {
                // The paper shows VGG19 (left) and YOLOv3 (right).
                match net {
                    Some(n) => emit(&report::fig10(Some(n), input), csv),
                    None => {
                        emit(&report::fig10(Some("VGG19"), input), csv);
                        emit(&report::fig10(Some("YOLOv3"), input), csv);
                    }
                }
            }
            "all" => {
                emit(&report::table1(input), csv);
                emit(&report::table2(input), csv);
                emit(&report::table3(input), csv);
                emit(&report::table4(), csv);
                emit(&report::fig6(), csv);
                emit(&report::fig7(), csv);
                emit(&report::fig8(net, input), csv);
                emit(&report::fig9(net, input), csv);
                emit(&report::fig10(Some("VGG19"), input), csv);
                emit(&report::fig10(Some("YOLOv3"), input), csv);
            }
            "crossval" => emit(&report::crossval(net, input), csv),
            "zoo" => cmd_zoo(input, csv),
            "simulate" => cmd_simulate(&args, input)?,
            "sweep" => cmd_sweep(&args, input, csv)?,
            "verify" => cmd_verify()?,
            "serve" => cmd_serve(&args)?,
            other => anyhow::bail!("unknown command {other:?}\n\n{}", s.usage()),
        }
    }
    Ok(())
}

fn cmd_zoo(input: usize, csv: bool) {
    let mut t = Table::new(
        &format!("network zoo @ {input} px"),
        &["network", "conv layers", "GMACs", "weights (M)"],
    );
    for net in zoo(input) {
        t.row(vec![
            net.name.to_string(),
            net.num_layers().to_string(),
            format!("{:.1}", net.total_macs() / 1e9),
            format!("{:.1}", net.total_weights() / 1e6),
        ]);
    }
    emit(&t, csv);
}

fn cmd_simulate(args: &aimc::util::cli::Args, input: usize) -> anyhow::Result<()> {
    let node = args.get_f64("node", 45.0)?;
    let name = args.get("net").unwrap_or("YOLOv3");
    let net = if name.eq_ignore_ascii_case("smallcnn") {
        smallcnn_network()
    } else {
        by_name(name, input)
            .ok_or_else(|| anyhow::anyhow!("unknown network {name:?} (try `aimc zoo`)"))?
    };
    let mname = args.get_or("machine", "systolic");
    let m = machine::by_name(mname).ok_or_else(|| {
        anyhow::anyhow!("unknown machine {mname:?} (systolic | optical4f | photonic | reram)")
    })?;
    let t0 = Instant::now();
    let cache = SweepCache::new();
    let r = cache.simulate_network(m.as_ref(), &net, node);
    println!(
        "{} on {} @ {node} nm  ({} layers, {:.1} GMACs, simulated in {:.1} ms, cache {})",
        net.name,
        m.name(),
        net.num_layers(),
        r.macs / 1e9,
        t0.elapsed().as_secs_f64() * 1e3,
        cache.stats()
    );
    println!(
        "  efficiency: {:.3} TOPS/W   energy/MAC: {:.4} pJ   time units: {:.3e}",
        r.tops_per_watt(),
        r.energy_per_mac() * 1e12,
        r.time_units
    );
    for (c, j) in r.ledger.breakdown() {
        println!(
            "  {:>5}: {:>10.4} pJ/MAC  ({:>5.1}%)",
            c.label(),
            j / r.macs * 1e12,
            100.0 * j / r.ledger.total()
        );
    }
    Ok(())
}

/// The full evaluation grid — every machine × every zoo network × every
/// node of the ladder — through the parallel, memoized sweep engine.
fn cmd_sweep(args: &aimc::util::cli::Args, input: usize, csv: bool) -> anyhow::Result<()> {
    let pool = match args.get("threads") {
        Some(_) => Pool::new(args.get_usize("threads", 0)?),
        None => Pool::auto(),
    };
    let machines = machine::all_machines();
    let nets = zoo(input);
    let nodes: Vec<f64> = NODES.iter().map(|n| n.nm).collect();
    let cache = SweepCache::new();
    let t0 = Instant::now();
    let records = sweep::sweep_on(&pool, &machines, &nets, &nodes, &cache);
    let elapsed = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!(
            "sweep — cycle-accurate TOPS/W, {} machines × {} networks × {} nodes @ {input} px",
            machines.len(),
            nets.len(),
            nodes.len()
        ),
        &["network", "node (nm)", "systolic", "ReRAM", "photonic", "optical 4F"],
    );
    // Records are machine-major; table rows are (network, node)-major
    // with one column per machine.
    let stride = nets.len() * nodes.len();
    for ni in 0..nets.len() {
        for ki in 0..nodes.len() {
            let mut cells = vec![nets[ni].name.to_string(), format!("{:.0}", nodes[ki])];
            for mi in 0..machines.len() {
                let r = &records[mi * stride + ni * nodes.len() + ki];
                cells.push(format!("{:.3}", r.result.tops_per_watt()));
            }
            t.row(cells);
        }
    }
    emit(&t, csv);
    eprintln!(
        "swept {} grid points in {elapsed:.2} s on {} threads (cache: {})",
        records.len(),
        pool.threads(),
        cache.stats()
    );
    Ok(())
}

fn cmd_verify() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    println!("platform: {}", engine.platform());
    let names = engine.artifact_names();
    let mut failed = 0;
    for name in &names {
        let t0 = Instant::now();
        match engine.verify_golden(name) {
            Ok(err) => {
                let rtol = engine.manifest().get(name).unwrap().rtol;
                let ok = err <= rtol;
                if !ok {
                    failed += 1;
                }
                println!(
                    "  {:28} max rel err {err:.3e} (rtol {rtol:.0e}) {} [{:.2}s]",
                    name,
                    if ok { "OK" } else { "FAIL" },
                    t0.elapsed().as_secs_f64()
                );
            }
            Err(e) => {
                failed += 1;
                println!("  {name:28} ERROR: {e:#}");
            }
        }
    }
    if failed > 0 {
        anyhow::bail!("{failed}/{} artifacts failed golden replay", names.len());
    }
    println!("all {} artifacts verified", names.len());
    Ok(())
}

fn cmd_serve(args: &aimc::util::cli::Args) -> anyhow::Result<()> {
    let path = ConvPath::parse(args.get_or("path", "exact"))
        .ok_or_else(|| anyhow::anyhow!("bad --path (exact | systolic | fft)"))?;
    let n_req = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", 2)?;
    let max_pending = args.get_usize("max-pending", 1024)?;
    let synthetic = args.flag("synthetic");
    println!(
        "starting server: path {path:?}, {workers} workers, {n_req} requests, \
         max_pending {max_pending}{}",
        if synthetic { ", synthetic backend" } else { "" }
    );

    let cfg = ServerConfig {
        path,
        workers,
        max_pending,
        ..Default::default()
    };
    let server = if synthetic {
        Server::start_sim(cfg, SimExecutor::default())?
    } else {
        Server::start(cfg)?
    };
    // Warm up compilation before timing.
    let _ = server.infer_blocking(vec![0.0; IMAGE_ELEMS])?;

    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> = (0..n_req).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
    let rxs: Vec<_> = images.into_iter().map(|im| server.infer(im)).collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let metrics = server.shutdown();
    println!("served {ok}/{n_req} OK — {}", metrics.summary());

    // Energy co-simulation for the served workload.
    let report = co_energy::co_simulate(&smallcnn_network(), 45.0);
    println!("energy co-simulation (per inference) {}", report.summary());
    Ok(())
}
