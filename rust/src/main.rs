//! `aimc` — CLI for the analog in-memory compute reproduction.
//!
//! Every report subcommand (tables, figures, crossval, zoo, sweep,
//! pareto, all) is a declarative [`aimc::report::Scenario`] evaluated
//! through ONE shared pool + sweep cache per invocation, then rendered
//! by the sink picked with `--format text|csv|json` (`--csv` is a
//! legacy alias). `--bits` adds a precision axis to `sweep`/`pareto`
//! (and pins the serving/simulate operating point), threading bit
//! widths through the same cache keys as the node axis.
//! With `--cache-dir` the sweep cache additionally persists across
//! invocations — keyed by (machine-config fingerprint, node, layer), so
//! a repeated run replays instead of re-simulating. The remaining
//! subcommands run the cycle simulators directly (`simulate`), verify
//! the AOT artifacts against their goldens (`verify`), fit the
//! closed-form energy surrogate from the same cache (`fit-surrogate`),
//! and serve inference through the PJRT coordinator (`serve` — with
//! `--surrogate` the workers price batches through the fitted table
//! instead of co-simulating, and `--max-uj-per-inf` arms the
//! energy-budget admission policy).
//!
//! Transformer workloads select by `name[@prefill|@decode]`
//! (`gpt2-small`, `tinyllama`, `tfm-tiny`): `intensity` sweeps the
//! prefill→decode arithmetic-intensity crossover over a `--batch` ×
//! `--seq` grid, `simulate --net` and `serve --network` accept the same
//! selector (serve prices its per-batch energy on the selected stream).

use std::path::{Path, PathBuf};
use std::time::Instant;

use aimc::coordinator::exec::SimExecutor;
use aimc::coordinator::server::{Server, ServerConfig};
use aimc::coordinator::{smallcnn_network, ConvPath, IMAGE_ELEMS};
use aimc::networks::by_name;
use aimc::networks::DEFAULT_INPUT;
use aimc::report::{self, Dataset, EvalCtx, OutputFormat};
use aimc::runtime::Engine;
use aimc::simulator::{machine, OperatingPoint, SweepCache};
use aimc::util::cli::Spec;
use aimc::util::json::Json;
use aimc::util::pool::Pool;
use aimc::util::rng::Rng;

fn spec() -> Spec {
    Spec::new(
        "aimc",
        "Analog, In-memory Compute Architectures for AI — reproduction CLI.\n\
         commands: table1 table2 table3 table4 fig6 fig7 fig8 fig9 fig10 \
         crossval surrogate-crossval all simulate sweep intensity pareto zoo faults \
         verify fit-surrogate serve",
    )
    .opt(
        "net",
        "network name (fig8/fig9/fig10/simulate); simulate also takes a \
         transformer selector name[@prefill|@decode]",
        None,
    )
    .opt(
        "network",
        "transformer stream selector name[@prefill|@decode] for intensity/serve \
         (e.g. gpt2-small@decode; configs: gpt2-small, tinyllama, tfm-tiny)",
        None,
    )
    .opt(
        "batch",
        "comma-separated batch grid (intensity); first entry sizes the \
         simulate/serve stream (default 1,4,16 / 1)",
        None,
    )
    .opt(
        "seq",
        "comma-separated sequence / KV-context grid (intensity); first entry \
         sizes the simulate/serve stream (default 64,256,1024 / 256)",
        None,
    )
    .opt(
        "nodes",
        "comma-separated technology-node list for intensity",
        Some("45,7"),
    )
    .opt("input", "input resolution (pixels per side)", Some("1000"))
    .opt("node", "technology node in nm (simulate/serve)", Some("45"))
    .opt(
        "bits",
        "bit widths, entries \"B\" or \"BXxBW\" (e.g. 8 or 8x4); comma-separated \
         list adds a precision axis to sweep/pareto; simulate/serve take one entry",
        None,
    )
    .opt(
        "machine",
        "simulate on: systolic | optical4f | photonic | reram",
        Some("systolic"),
    )
    .opt("path", "serve datapath: exact | systolic | fft", Some("exact"))
    .opt(
        "threads",
        "worker threads for scenario evaluation (default: AIMC_THREADS or all cores)",
        None,
    )
    .opt("format", "report output: text | csv | json", Some("text"))
    .opt(
        "cache-dir",
        "persist the sweep cache in this directory (repeat runs replay it)",
        None,
    )
    .opt("requests", "serve: number of requests", Some("64"))
    .opt("workers", "serve: worker threads", Some("2"))
    .opt(
        "max-pending",
        "serve: admission bound on in-flight requests (reject beyond)",
        Some("1024"),
    )
    .opt(
        "surrogate",
        "fit-surrogate: output path; serve: fitted table to price batches with",
        None,
    )
    .opt(
        "max-uj-per-inf",
        "serve: reject requests whose predicted energy exceeds this many µJ/inf",
        None,
    )
    .opt(
        "fault-rates",
        "faults: comma-separated fault-rate grid (stuck-at/drift/IR rate per point)",
        Some("0,0.001,0.01,0.05"),
    )
    .opt(
        "chaos",
        "serve --synthetic: scripted executor fault plan, clauses error=N, \
         stall=N:DUR, slow=N:FACTOR, backend=NAME (restrict the plan to \
         fleet lanes of one machine kind; e.g. error=5,stall=7:50ms,slow=3:4 \
         or error=3,backend=reram)",
        None,
    )
    .opt(
        "fleet",
        "serve: heterogeneous worker fleet, comma-separated \
         KIND@NODE[/BXxBW][:COUNT] (e.g. systolic@45:2,optical4f@22:2,reram@45:2); \
         overrides --workers, routes each batch to the cheapest live lane",
        None,
    )
    .opt(
        "slo-ns",
        "serve --fleet: route by nominal ns/inference instead of µJ/inference \
         (order-of-magnitude signal, not a timing model)",
        None,
    )
    .opt(
        "metrics-json",
        "serve: also write the final metrics (per-backend shards included) \
         to this path as JSON",
        None,
    )
    .flag(
        "synthetic",
        "serve: deterministic in-process backend (no artifacts/PJRT needed)",
    )
    .flag("csv", "emit CSV instead of aligned text (alias for --format csv)")
}

/// Parse `--bits`: comma-separated entries, each `"B"` (symmetric) or
/// `"BXxBW"` (activation × weight), widths in 1..=32.
fn parse_bits(spec: &str) -> anyhow::Result<Vec<(u32, u32)>> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        let (bx, bw) = match entry.split_once(['x', 'X']) {
            Some((x, w)) => (x.trim().parse::<u32>(), w.trim().parse::<u32>()),
            None => {
                let b = entry.parse::<u32>();
                (b.clone(), b)
            }
        };
        let (bx, bw) = match (bx, bw) {
            (Ok(x), Ok(w)) => (x, w),
            _ => anyhow::bail!("bad --bits entry {entry:?} (expected e.g. 8 or 8x4)"),
        };
        if !(1..=32).contains(&bx) || !(1..=32).contains(&bw) {
            anyhow::bail!("--bits widths must be in 1..=32, got {entry:?}");
        }
        out.push((bx, bw));
    }
    if out.is_empty() {
        anyhow::bail!("--bits needs at least one entry");
    }
    Ok(out)
}

/// Parse a comma-separated list of positive integers (`--batch`, `--seq`).
fn parse_usize_list(opt: &str, spec: &str) -> anyhow::Result<Vec<usize>> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let v: usize = entry
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --{opt} entry {entry:?} (expected an integer)"))?;
        if v == 0 {
            anyhow::bail!("--{opt} entries must be positive, got {entry:?}");
        }
        out.push(v);
    }
    if out.is_empty() {
        anyhow::bail!("--{opt} needs at least one entry");
    }
    Ok(out)
}

/// Parse `--fault-rates`: comma-separated rates in [0, 1] (0 = the
/// ideal device, so a degradation curve can anchor at the clean point).
fn parse_rate_list(spec: &str) -> anyhow::Result<Vec<f64>> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let v: f64 = entry.parse().map_err(|_| {
            anyhow::anyhow!("bad --fault-rates entry {entry:?} (expected a number)")
        })?;
        if !(0.0..=1.0).contains(&v) {
            anyhow::bail!("--fault-rates entries must be in [0, 1], got {entry:?}");
        }
        out.push(v);
    }
    if out.is_empty() {
        anyhow::bail!("--fault-rates needs at least one entry");
    }
    Ok(out)
}

/// Parse a comma-separated list of positive numbers (`--nodes`).
fn parse_f64_list(opt: &str, spec: &str) -> anyhow::Result<Vec<f64>> {
    let mut out = Vec::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let v: f64 = entry
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --{opt} entry {entry:?} (expected a number)"))?;
        let ok = v.is_finite() && v > 0.0;
        if !ok {
            anyhow::bail!("--{opt} entries must be positive, got {entry:?}");
        }
        out.push(v);
    }
    if out.is_empty() {
        anyhow::bail!("--{opt} needs at least one entry");
    }
    Ok(out)
}

/// Resolve a network name: the serving CNN, a transformer stream
/// selector (`name[@prefill|@decode]` at `batch`×`seq`), or a zoo CNN
/// at `input` px — in that precedence order.
fn resolve_network(
    name: &str,
    input: usize,
    batch: usize,
    seq: usize,
) -> Option<aimc::networks::Network> {
    if name.eq_ignore_ascii_case("smallcnn") {
        return Some(smallcnn_network());
    }
    aimc::networks::transformer::resolve(name, batch, seq).or_else(|| by_name(name, input))
}

/// Output sink: text and CSV stream per dataset exactly as the
/// pre-scenario CLI did; JSON buffers every dataset of the invocation
/// and emits ONE top-level array at the end, so `aimc all --format json`
/// is a single valid document.
struct Sink {
    format: OutputFormat,
    json: Vec<Json>,
}

impl Sink {
    fn new(format: OutputFormat) -> Sink {
        Sink {
            format,
            json: Vec::new(),
        }
    }

    fn emit(&mut self, ds: &Dataset) {
        match self.format {
            OutputFormat::Text => println!("{}", ds.render()),
            OutputFormat::Csv => print!("{}", ds.to_csv()),
            OutputFormat::Json => self.json.push(ds.to_json()),
        }
    }

    fn finish(self) {
        // Nothing emitted (e.g. `aimc serve --format json`) prints no
        // empty document.
        if self.format == OutputFormat::Json && !self.json.is_empty() {
            println!("{}", Json::Arr(self.json).pretty());
        }
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let s = spec();
    let args = s.parse(std::env::args().skip(1))?;
    if args.positional.is_empty() {
        println!("{}", s.usage());
        return Ok(());
    }
    let format_str = args.get_or("format", "text");
    let mut format = OutputFormat::parse(format_str)
        .ok_or_else(|| anyhow::anyhow!("bad --format {format_str:?} (text | csv | json)"))?;
    if format == OutputFormat::Text && args.flag("csv") {
        format = OutputFormat::Csv;
    }
    let input = args.get_usize("input", DEFAULT_INPUT)?;
    let net = args.get("net");

    // One pool + one sweep cache for everything this invocation runs:
    // `aimc all` is a scenario list over a single warm cache, not ten
    // cold starts.
    let pool = match args.get("threads") {
        Some(_) => Pool::new(args.get_usize("threads", 0)?),
        None => Pool::auto(),
    };
    let cache_dir = args.get("cache-dir").map(PathBuf::from);
    // Snapshots are sharded by config fingerprint (one file per
    // fingerprint, plus the legacy monolithic v3 file if present), so
    // concurrent invocations sharing --cache-dir never clobber each
    // other's entries.
    let cache = match &cache_dir {
        Some(dir) => SweepCache::load_sharded(dir),
        None => SweepCache::new(),
    };
    let ctx = EvalCtx {
        pool: &pool,
        cache: &cache,
    };
    let mut sink = Sink::new(format);

    // Run the command list, but flush the sink and persist the cache
    // even when a later command fails: work a successful `sweep` already
    // did (buffered JSON, simulated grid points) must not be discarded
    // because a trailing `verify` errored or a subcommand was mistyped.
    let commands = |sink: &mut Sink| -> anyhow::Result<()> {
        for cmd in &args.positional {
            match cmd.as_str() {
                "table1" => sink.emit(&report::table1(input).eval(&ctx)),
                "table2" => sink.emit(&report::table2(input).eval(&ctx)),
                "table3" => sink.emit(&report::table3(input).eval(&ctx)),
                "table4" => sink.emit(&report::table4().eval(&ctx)),
                "fig6" => sink.emit(&report::fig6().eval(&ctx)),
                "fig7" => sink.emit(&report::fig7().eval(&ctx)),
                "fig8" => sink.emit(&report::fig8(net, input).eval(&ctx)),
                "fig9" => sink.emit(&report::fig9(net, input).eval(&ctx)),
                "fig10" => {
                    // The paper shows VGG19 (left) and YOLOv3 (right).
                    match net {
                        Some(n) => sink.emit(&report::fig10(Some(n), input).eval(&ctx)),
                        None => {
                            sink.emit(&report::fig10(Some("VGG19"), input).eval(&ctx));
                            sink.emit(&report::fig10(Some("YOLOv3"), input).eval(&ctx));
                        }
                    }
                }
                "all" => {
                    for sc in report::all_scenarios(net, input) {
                        sink.emit(&sc.eval(&ctx));
                    }
                }
                "crossval" => sink.emit(&report::crossval(net, input).eval(&ctx)),
                "surrogate-crossval" => {
                    let ds = report::surrogate_crossval_scenario(input).eval(&ctx);
                    sink.emit(&ds);
                    // Acceptance gate: any machine × node over the bound
                    // fails the command (and the CI job running it).
                    let bound_pct = aimc::energy::surrogate::ERR_BOUND * 100.0;
                    let worst = ds
                        .rows
                        .iter()
                        .flat_map(|r| r.iter().skip(1))
                        .filter_map(|v| match v {
                            report::Value::Num(pct) => Some(*pct),
                            _ => None,
                        })
                        .fold(0.0, f64::max);
                    if worst > bound_pct {
                        anyhow::bail!(
                            "surrogate crossval failed: worst rel err {worst:.3}% \
                             exceeds the {bound_pct}% bound"
                        );
                    }
                    eprintln!(
                        "surrogate crossval OK: worst rel err {worst:.4}% \
                         (bound {bound_pct}%)"
                    );
                }
                "zoo" => sink.emit(&report::zoo_scenario(input).eval(&ctx)),
                "simulate" => cmd_simulate(&args, input, &pool, &cache)?,
                "sweep" => {
                    let bits = match args.get("bits") {
                        Some(spec) => parse_bits(spec)?,
                        None => Vec::new(),
                    };
                    let sc = report::sweep_scenario_with_bits(input, &bits);
                    let t0 = Instant::now();
                    let ds = sc.eval(&ctx);
                    let elapsed = t0.elapsed().as_secs_f64();
                    sink.emit(&ds);
                    eprintln!(
                        "swept {} grid points in {elapsed:.2} s on {} threads (cache: {})",
                        sc.grid_points(),
                        pool.threads(),
                        cache.stats()
                    );
                }
                "intensity" => {
                    use aimc::networks::transformer::{self, DEFAULT_BATCHES, DEFAULT_SEQS};
                    let sel = args.get("network").or(net).unwrap_or("gpt2-small");
                    let (tcfg, phase) = transformer::parse_selector(sel).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown transformer {sel:?} (gpt2-small | tinyllama | tfm-tiny, \
                             optional @prefill/@decode)"
                        )
                    })?;
                    let batches = match args.get("batch") {
                        Some(v) => parse_usize_list("batch", v)?,
                        None => DEFAULT_BATCHES.to_vec(),
                    };
                    let seqs = match args.get("seq") {
                        Some(v) => parse_usize_list("seq", v)?,
                        None => DEFAULT_SEQS.to_vec(),
                    };
                    let nodes = parse_f64_list("nodes", args.get_or("nodes", "45,7"))?;
                    let bits = match args.get("bits") {
                        Some(spec) => parse_bits(spec)?,
                        None => Vec::new(),
                    };
                    let sc =
                        report::intensity_scenario(&tcfg, phase, &nodes, &bits, &batches, &seqs);
                    let t0 = Instant::now();
                    let ds = sc.eval(&ctx);
                    sink.emit(&ds);
                    eprintln!(
                        "intensity crossover: {} rows in {:.2} s (cache: {})",
                        sc.row_count(),
                        t0.elapsed().as_secs_f64(),
                        cache.stats()
                    );
                }
                "pareto" => {
                    let sc = match args.get("bits") {
                        Some(spec) => {
                            report::pareto_scenario_with_bits(input, &parse_bits(spec)?)
                        }
                        None => report::pareto_scenario(input),
                    };
                    let t0 = Instant::now();
                    let ds = sc.eval(&ctx);
                    sink.emit(&ds);
                    eprintln!(
                        "pareto grid: {} rows in {:.2} s (cache: {})",
                        sc.row_count(),
                        t0.elapsed().as_secs_f64(),
                        cache.stats()
                    );
                }
                "faults" => {
                    let rates = match args.get("fault-rates") {
                        Some(spec) => parse_rate_list(spec)?,
                        None => Vec::new(),
                    };
                    let bits = match args.get("bits") {
                        Some(spec) => parse_bits(spec)?,
                        None => Vec::new(),
                    };
                    let sc = report::faults_scenario(input, &rates, &bits);
                    let t0 = Instant::now();
                    let ds = sc.eval(&ctx);
                    sink.emit(&ds);
                    eprintln!(
                        "fault grid: {} rows in {:.2} s (cache: {})",
                        sc.row_count(),
                        t0.elapsed().as_secs_f64(),
                        cache.stats()
                    );
                }
                "verify" => cmd_verify()?,
                "fit-surrogate" => cmd_fit_surrogate(&args, input, &cache)?,
                "serve" => cmd_serve(&args, input)?,
                other => anyhow::bail!("unknown command {other:?}\n\n{}", s.usage()),
            }
        }
        Ok(())
    };

    let result = commands(&mut sink);
    sink.finish();
    let saved = match &cache_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir).and_then(|()| cache.save_sharded(dir).map(|_| ()))
        }
        None => Ok(()),
    };
    // A command failure outranks a cache-save failure in the report,
    // but both paths run.
    result?;
    saved?;
    Ok(())
}

fn cmd_simulate(
    args: &aimc::util::cli::Args,
    input: usize,
    pool: &Pool,
    cache: &SweepCache,
) -> anyhow::Result<()> {
    let node = args.get_f64("node", 45.0)?;
    let op = match args.get("bits") {
        Some(spec) => {
            let bits = parse_bits(spec)?;
            if bits.len() != 1 {
                anyhow::bail!("simulate takes exactly one --bits entry");
            }
            OperatingPoint::node(node).bits(bits[0].0, bits[0].1)
        }
        None => OperatingPoint::node(node),
    };
    let name = args.get("net").unwrap_or("YOLOv3");
    let batch = match args.get("batch") {
        Some(v) => parse_usize_list("batch", v)?[0],
        None => 1,
    };
    let seq = match args.get("seq") {
        Some(v) => parse_usize_list("seq", v)?[0],
        None => 256,
    };
    let net = resolve_network(name, input, batch, seq).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown network {name:?} (try `aimc zoo`, or a transformer selector \
             like gpt2-small@decode)"
        )
    })?;
    let mname = args.get_or("machine", "systolic");
    let m = machine::by_name(mname).ok_or_else(|| {
        anyhow::anyhow!("unknown machine {mname:?} (systolic | optical4f | photonic | reram)")
    })?;
    let t0 = Instant::now();
    // Unique layer shapes fan out over the pool; the merge stays in
    // layer order, bit-identical to a serial pass.
    let r = cache.simulate_network_par(pool, m.as_ref(), &net, &op);
    println!(
        "{} on {} @ {node} nm {}b  ({} layers, {:.1} GMACs, simulated in {:.1} ms, cache {})",
        net.name,
        m.name(),
        op.bits_label(),
        net.num_layers(),
        r.macs / 1e9,
        t0.elapsed().as_secs_f64() * 1e3,
        cache.stats()
    );
    println!(
        "  efficiency: {:.3} TOPS/W   energy/MAC: {:.4} pJ   time units: {:.3e}",
        r.tops_per_watt(),
        r.energy_per_mac() * 1e12,
        r.time_units
    );
    for (c, j) in r.ledger.breakdown() {
        println!(
            "  {:>5}: {:>10.4} pJ/MAC  ({:>5.1}%)",
            c.label(),
            j / r.macs * 1e12,
            100.0 * j / r.ledger.total()
        );
    }
    Ok(())
}

fn cmd_verify() -> anyhow::Result<()> {
    let engine = Engine::discover()?;
    println!("platform: {}", engine.platform());
    let names = engine.artifact_names();
    let mut failed = 0;
    for name in &names {
        let t0 = Instant::now();
        match engine.verify_golden(name) {
            Ok(err) => {
                let rtol = engine.manifest().get(name).unwrap().rtol;
                let ok = err <= rtol;
                if !ok {
                    failed += 1;
                }
                println!(
                    "  {:28} max rel err {err:.3e} (rtol {rtol:.0e}) {} [{:.2}s]",
                    name,
                    if ok { "OK" } else { "FAIL" },
                    t0.elapsed().as_secs_f64()
                );
            }
            Err(e) => {
                failed += 1;
                println!("  {name:28} ERROR: {e:#}");
            }
        }
    }
    if failed > 0 {
        anyhow::bail!("{failed}/{} artifacts failed golden replay", names.len());
    }
    println!("all {} artifacts verified", names.len());
    Ok(())
}

/// Fit the closed-form energy surrogate from the cycle simulators (via
/// the invocation's shared sweep cache — with `--cache-dir` the grid
/// persists and a refit replays it) and write the model table to disk.
fn cmd_fit_surrogate(
    args: &aimc::util::cli::Args,
    input: usize,
    cache: &SweepCache,
) -> anyhow::Result<()> {
    use aimc::energy::surrogate::{self, MachineKind, SurrogateTable};
    let out = PathBuf::from(args.get_or("surrogate", "surrogate.json"));
    // Zoo shapes + the Table V reference layer + the serving network, so
    // both the crossval scenario and `serve --surrogate` are covered.
    let mut layers = surrogate::training_corpus(input);
    layers.extend(smallcnn_network().layers);
    let layers = surrogate::dedup_layers(layers);
    let nodes = surrogate::default_nodes();
    let t0 = Instant::now();
    let table = SurrogateTable::fit(cache, &MachineKind::ALL, &nodes, &layers)
        .map_err(|e| anyhow::anyhow!("surrogate fit failed: {e}"))?;
    let points = surrogate::crossval(&table, cache, &MachineKind::ALL, &nodes, &layers);
    let worst = points.iter().map(|p| p.max_rel_err).fold(0.0, f64::max);
    table.save(&out)?;
    println!(
        "fitted {} models ({} machines × {} nodes, {} layers) in {:.2} s \
         (cache {}); worst in-sample rel err {:.3}%; wrote {}",
        table.len(),
        MachineKind::ALL.len(),
        nodes.len(),
        layers.len(),
        t0.elapsed().as_secs_f64(),
        cache.stats(),
        worst * 100.0,
        out.display()
    );
    Ok(())
}

fn cmd_serve(args: &aimc::util::cli::Args, input: usize) -> anyhow::Result<()> {
    let path = ConvPath::parse(args.get_or("path", "exact"))
        .ok_or_else(|| anyhow::anyhow!("bad --path (exact | systolic | fft)"))?;
    // `--network` swaps the network the energy pricing (surrogate quote
    // or co-simulation) runs on — e.g. `gpt2-small@decode` prices the
    // decode stream serving actually executes per step. The compiled
    // executor datapaths stay SmallCNN-shaped (the only AOT artifacts).
    let resident = match args.get("network") {
        Some(sel) => {
            let batch = match args.get("batch") {
                Some(v) => parse_usize_list("batch", v)?[0],
                None => 1,
            };
            let seq = match args.get("seq") {
                Some(v) => parse_usize_list("seq", v)?[0],
                None => 256,
            };
            Some(resolve_network(sel, input, batch, seq).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown --network {sel:?} (try a zoo name or a transformer \
                     selector like gpt2-small@decode)"
                )
            })?)
        }
        None => None,
    };
    let n_req = args.get_usize("requests", 64)?;
    let workers = args.get_usize("workers", 2)?;
    let max_pending = args.get_usize("max-pending", 1024)?;
    let node = args.get_f64("node", 45.0)?;
    let energy_bits = match args.get("bits") {
        Some(spec) => {
            let bits = parse_bits(spec)?;
            if bits.len() != 1 {
                anyhow::bail!("serve takes exactly one --bits entry");
            }
            bits[0]
        }
        None => (8, 8),
    };
    let synthetic = args.flag("synthetic");
    let chaos = match args.get("chaos") {
        Some(spec) => {
            if !synthetic {
                anyhow::bail!(
                    "--chaos injects faults into the sim backend and needs --synthetic"
                );
            }
            Some(aimc::coordinator::exec::FaultPlan::parse(spec)?)
        }
        None => None,
    };
    // A corrupt/missing table must not take serving down: warn and fall
    // back to per-batch co-simulation.
    let surrogate = args.get("surrogate").and_then(|p| {
        match aimc::energy::surrogate::SurrogateTable::load(Path::new(p)) {
            Ok(t) => Some(std::sync::Arc::new(t)),
            Err(e) => {
                eprintln!("warn: refusing surrogate table: {e}; falling back to co-simulation");
                None
            }
        }
    });
    let max_uj_per_inf = match args.get("max-uj-per-inf") {
        Some(_) => Some(args.get_f64("max-uj-per-inf", 0.0)?),
        None => None,
    };
    let fleet = match args.get("fleet") {
        Some(spec) => Some(
            aimc::coordinator::server::parse_fleet(spec)
                .map_err(|e| anyhow::anyhow!("bad --fleet: {e}"))?,
        ),
        None => None,
    };
    let slo_ns = match args.get("slo-ns") {
        Some(_) => {
            if fleet.is_none() {
                anyhow::bail!("--slo-ns routes a fleet and needs --fleet");
            }
            Some(args.get_f64("slo-ns", 0.0)?)
        }
        None => None,
    };
    let metrics_json = args.get("metrics-json").map(PathBuf::from);
    println!(
        "starting server: path {path:?}, {} workers, {n_req} requests, \
         max_pending {max_pending}, energy @{node} nm {}x{}b ({} pricing on {}){}{}{}{}",
        match &fleet {
            Some(specs) => format!(
                "fleet [{}]",
                specs.iter().map(|s| s.label()).collect::<Vec<_>>().join(", ")
            ),
            None => workers.to_string(),
        },
        energy_bits.0,
        energy_bits.1,
        if surrogate.is_some() { "surrogate" } else { "co-simulation" },
        resident.as_ref().map(|n| n.name).unwrap_or("SmallCNN"),
        match max_uj_per_inf {
            Some(b) => format!(", budget {b} µJ/inf"),
            None => String::new(),
        },
        match slo_ns {
            Some(_) => ", routing by nominal ns/inf",
            None => "",
        },
        if synthetic { ", synthetic backend" } else { "" },
        match &chaos {
            Some(p) => format!(", chaos {p:?}"),
            None => String::new(),
        }
    );

    let cfg = ServerConfig {
        path,
        workers,
        max_pending,
        energy_node_nm: node,
        energy_bits,
        surrogate,
        max_uj_per_inf,
        resident,
        fleet,
        slo_ns,
        ..Default::default()
    };
    let server = if synthetic {
        match cfg.fleet_workers() {
            // Fleet + chaos: each lane gets the plan filtered to its own
            // machine kind, so `backend=NAME` clauses degrade exactly
            // the targeted lanes and routing has to shift around them.
            Some(specs) => {
                let plan = chaos.unwrap_or_default();
                Server::start_with(cfg, move |w| {
                    Ok(SimExecutor::default().with_plan(plan.for_backend(specs[w].kind)))
                })?
            }
            None => {
                let sim = match chaos {
                    Some(plan) => SimExecutor::default().with_plan(plan),
                    None => SimExecutor::default(),
                };
                Server::start_sim(cfg, sim)?
            }
        }
    } else {
        Server::start(cfg)?
    };
    // Warm up compilation before timing. With an energy budget armed
    // the warm-up itself may be shed — that is the policy working, not
    // a startup failure.
    if let Err(e) = server.infer_blocking(vec![0.0; IMAGE_ELEMS]) {
        if max_uj_per_inf.is_none() {
            return Err(e);
        }
    }

    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> = (0..n_req).map(|_| rng.normal_vec(IMAGE_ELEMS)).collect();
    let rxs: Vec<_> = images.into_iter().map(|im| server.infer(im)).collect();
    let mut ok = 0;
    for rx in rxs {
        if rx.recv()?.is_ok() {
            ok += 1;
        }
    }
    let quote = server.request_quote();
    let metrics = server.shutdown();
    println!("served {ok}/{n_req} OK — {}", metrics.summary());
    // Fleet mode: the per-backend shards are the headline numbers — one
    // row per backend label with its own µJ/inf, latency percentiles and
    // recovery counters.
    if let Some(table) = metrics.backend_table() {
        println!("per-backend serving:");
        println!("{table}");
    }
    if let Some(out) = &metrics_json {
        let mut obj = vec![
            ("count".to_string(), Json::Num(metrics.count() as f64)),
            ("rejected".to_string(), Json::Num(metrics.rejected() as f64)),
            ("throughput_rps".to_string(), Json::Num(metrics.throughput())),
            ("p50_us".to_string(), Json::Num(metrics.percentile_us(50.0) as f64)),
            ("p99_us".to_string(), Json::Num(metrics.percentile_us(99.0) as f64)),
            ("retries".to_string(), Json::Num(metrics.retries() as f64)),
            ("breaker_trips".to_string(), Json::Num(metrics.breaker_trips() as f64)),
            ("rerouted".to_string(), Json::Num(metrics.rerouted() as f64)),
        ];
        let backends: Vec<Json> = metrics
            .backends()
            .iter()
            .map(|(label, b)| {
                Json::Obj(vec![
                    ("backend".to_string(), Json::Str(label.clone())),
                    ("batches".to_string(), Json::Num(b.batches() as f64)),
                    ("images".to_string(), Json::Num(b.images() as f64)),
                    (
                        "uj_per_inf".to_string(),
                        match b.uj_per_inf() {
                            Some(uj) => Json::Num(uj),
                            None => Json::Null,
                        },
                    ),
                    ("p50_us".to_string(), Json::Num(b.p50_us() as f64)),
                    ("p99_us".to_string(), Json::Num(b.p99_us() as f64)),
                    ("breaker_trips".to_string(), Json::Num(b.breaker_trips() as f64)),
                    (
                        "surrogate_misses".to_string(),
                        Json::Num(b.surrogate_misses() as f64),
                    ),
                    ("source".to_string(), Json::Str(b.source().to_string())),
                ])
            })
            .collect();
        obj.push(("backends".to_string(), Json::Arr(backends)));
        std::fs::write(out, Json::Obj(obj).pretty() + "\n")?;
        println!("metrics JSON written to {}", out.display());
    }
    if let Some(q) = quote {
        println!(
            "per-request attribution @{} nm {}x{}b: systolic {:.2} µJ | optical-4F {:.2} µJ \
             (worst {:.2} µJ)",
            q.node_nm,
            q.bits_x,
            q.bits_w,
            q.systolic_uj(),
            q.optical_uj(),
            q.worst_uj(),
        );
    }
    // Accounting accumulated in the worker shards — the same workload
    // the latency numbers above were measured on. Absent (not zero)
    // when no batch was priced.
    match (
        metrics.systolic_uj_per_inference(),
        metrics.optical_uj_per_inference(),
    ) {
        (Some(sys), Some(opt)) => println!(
            "energy ({} pricing over {} batches / {} inferences) @{} nm {}x{}b: \
             systolic {sys:.2} µJ/inf | optical-4F {opt:.2} µJ/inf",
            metrics.energy_source(),
            metrics.energy_batches(),
            metrics.energy_images(),
            metrics.energy_node_nm(),
            metrics.energy_bits().0,
            metrics.energy_bits().1,
        ),
        _ => println!("energy: n/a (no batch was priced)"),
    }
    if metrics.budget_rejected() > 0 {
        println!(
            "energy budget shed {} requests (max {} µJ/inf)",
            metrics.budget_rejected(),
            max_uj_per_inf.unwrap_or(f64::NAN),
        );
    }
    Ok(())
}
