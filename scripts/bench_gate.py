#!/usr/bin/env python3
"""CI bench-regression gate.

Compares a fresh bench run against the committed baseline and fails the
build when either guarded metric regresses more than the tolerance:

  * serve  — throughput at the high-offered-load grid point
             (4 workers x 32 offered, co-simulation pricing) from
             BENCH_serve.json
  * serve  — surrogate_vs_cosim_speedup: closed-form energy quote vs a
             cold co-simulation of the resident network, also from
             BENCH_serve.json
  * sweep  — persistent-cache warm_speedup from BENCH_sweep.json
  * sweep  — transformer_decode.points_per_s (gpt2-small decode streams
             through the sweep engine), also from BENCH_sweep.json;
             skipped with a note when either side predates the metric
  * serve  — serve_under_faults.throughput_rps: the same serving grid
             cell under a scripted FaultPlan (transient errors + slow
             batches, retries on), from BENCH_serve.json; guards the
             recovery-path overhead and is likewise skipped with a note
             when either side predates the metric
  * serve  — serve_hetero_rps: throughput of the heterogeneous
             2-backend fleet cell (quote-based routing) at the
             high-offered-load point (32 offered), from
             BENCH_serve.json; optional with the same
             warn-and-skip-until-baselined contract

Usage:
    python3 scripts/bench_gate.py BENCH_baseline.json \
        rust/BENCH_serve.json rust/BENCH_sweep.json

    # refresh the baseline from a measured run (commit the result):
    python3 scripts/bench_gate.py --update BENCH_baseline.json \
        rust/BENCH_serve.json rust/BENCH_sweep.json

Tolerance defaults to 0.15 (15%); override with BENCH_GATE_TOLERANCE.
A metric the bench run emits but the baseline lacks (a key added after
the baseline was last refreshed) is reported and SKIPPED, never a
failure — the gate only binds on keys the baseline actually carries.
A baseline marked "provisional": true (floor values that were never
measured on CI hardware) runs the same comparison but is ADVISORY: a
miss is printed loudly and exits 0, so a guessed floor can never block
CI. Re-baseline from a green run via --update (which drops the
provisional flag) to make the gate binding.

Self-promoting CI flow: the tier1 workflow first tries to download the
`bench-baseline` artifact (a --update'd baseline, measured on CI
hardware) from the latest green run of `main` and gates BINDING against
it. Only when no green-run artifact exists does it fall back to the
committed provisional BENCH_baseline.json — ADVISORY by the flag above.
Every green run re-measures and re-uploads the artifact, so the gate
promotes itself from advisory to binding after the first green run on
CI hardware, with no hand-committed numbers involved.

Stdlib only — no pip dependencies.
"""

import json
import os
import sys

GUARD_WORKERS = 4
GUARD_OFFERED = 32


def fail(msg):
    print(f"bench gate: FAIL — {msg}")
    sys.exit(1)


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        fail(f"cannot read {path}: {e}")


def serve_rps(serve, path):
    for run in serve.get("runs", []):
        # The grid carries several pricing modes per (workers, offered)
        # cell; the throughput guard pins the historical co-simulation
        # path ("pricing" absent = pre-surrogate file layout).
        if run.get("pricing") not in (None, "cosim"):
            continue
        if run.get("workers") == GUARD_WORKERS and run.get("offered") == GUARD_OFFERED:
            return float(run["throughput_rps"])
    fail(
        f"{path} has no {GUARD_WORKERS}-worker / {GUARD_OFFERED}-offered "
        "cosim-priced run (bench grid changed without updating the gate?)"
    )


def surrogate_speedup(serve, path):
    try:
        return float(serve["surrogate_vs_cosim_speedup"])
    except (KeyError, TypeError, ValueError):
        fail(f"{path} has no surrogate_vs_cosim_speedup field")


def warm_speedup(sweep, path):
    try:
        return float(sweep["persistent_cache"]["warm_speedup"])
    except (KeyError, TypeError, ValueError):
        fail(f"{path} has no persistent_cache.warm_speedup field")


def decode_points_per_s(sweep):
    # Optional: bench runs predating the transformer-decode section lack
    # the field entirely. Returning None (-> metric not measured, skipped
    # with a note) keeps the gate usable across both layouts.
    try:
        return float(sweep["transformer_decode"]["points_per_s"])
    except (KeyError, TypeError, ValueError):
        return None


def serve_under_faults_rps(serve):
    # Optional, same contract as decode_points_per_s: bench runs that
    # predate the fault-injection section lack the key entirely.
    try:
        return float(serve["serve_under_faults"]["throughput_rps"])
    except (KeyError, TypeError, ValueError):
        return None


def serve_hetero_rps(serve):
    # Optional, same contract: the heterogeneous-fleet cell landed after
    # some baselines. Guard the high-offered-load (32) run, matching the
    # homogeneous throughput guard.
    try:
        for run in serve["serve_hetero"]["runs"]:
            if run.get("offered") == GUARD_OFFERED:
                return float(run["throughput_rps"])
    except (KeyError, TypeError, ValueError):
        pass
    return None


def main(argv):
    update = "--update" in argv
    paths = [a for a in argv if not a.startswith("--")]
    if len(paths) != 3:
        print(__doc__)
        sys.exit(2)
    baseline_path, serve_path, sweep_path = paths

    serve_doc = load(serve_path)
    sweep_doc = load(sweep_path)
    measured = {
        "serve_4w_32offered_rps": serve_rps(serve_doc, serve_path),
        "surrogate_vs_cosim_speedup": surrogate_speedup(serve_doc, serve_path),
        "warm_speedup": warm_speedup(sweep_doc, sweep_path),
    }
    decode_pps = decode_points_per_s(sweep_doc)
    if decode_pps is not None:
        measured["transformer_decode_points_per_s"] = decode_pps
    else:
        print(
            f"bench gate: NOTE — {sweep_path} has no transformer_decode "
            "section (older bench layout); metric not measured"
        )
    faulted_rps = serve_under_faults_rps(serve_doc)
    if faulted_rps is not None:
        measured["serve_under_faults_rps"] = faulted_rps
    else:
        print(
            f"bench gate: NOTE — {serve_path} has no serve_under_faults "
            "section (older bench layout); metric not measured"
        )
    hetero_rps = serve_hetero_rps(serve_doc)
    if hetero_rps is not None:
        measured["serve_hetero_rps"] = hetero_rps
    else:
        print(
            f"bench gate: NOTE — {serve_path} has no serve_hetero section "
            f"with an offered={GUARD_OFFERED} run (older bench layout); "
            "metric not measured"
        )

    if update:
        doc = {
            "note": (
                "Bench-regression baseline enforced by scripts/bench_gate.py. "
                "Refresh with: python3 scripts/bench_gate.py --update "
                "BENCH_baseline.json rust/BENCH_serve.json rust/BENCH_sweep.json"
            ),
            "serve_4w_32offered_rps": round(measured["serve_4w_32offered_rps"], 1),
            "surrogate_vs_cosim_speedup": round(
                measured["surrogate_vs_cosim_speedup"], 1
            ),
            "warm_speedup": round(measured["warm_speedup"], 2),
        }
        if "transformer_decode_points_per_s" in measured:
            doc["transformer_decode_points_per_s"] = round(
                measured["transformer_decode_points_per_s"], 1
            )
        if "serve_under_faults_rps" in measured:
            doc["serve_under_faults_rps"] = round(
                measured["serve_under_faults_rps"], 1
            )
        if "serve_hetero_rps" in measured:
            doc["serve_hetero_rps"] = round(measured["serve_hetero_rps"], 1)
        with open(baseline_path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"bench gate: baseline updated — {baseline_path}: {doc}")
        return

    baseline = load(baseline_path)
    tol = float(os.environ.get("BENCH_GATE_TOLERANCE", "0.15"))
    provisional = bool(baseline.get("provisional"))
    if provisional:
        print(
            "bench gate: NOTE — baseline is provisional (floor values never "
            "measured on CI hardware), so misses are ADVISORY, not failures. "
            "Re-baseline with --update (drops the flag) to make the gate bind."
        )

    failures = []
    skipped = []
    for key, got in measured.items():
        want = baseline.get(key)
        if want is None:
            # A metric the current bench emits but the committed baseline
            # predates (e.g. a key added by a newer bench run). Skipping
            # keeps old baselines green across metric additions; the gate
            # starts binding for the key after the next --update.
            print(
                f"bench gate: SKIP — baseline has no {key!r} "
                f"(measured {got:.2f}); re-baseline with --update to guard it"
            )
            skipped.append(key)
            continue
        floor = float(want) * (1.0 - tol)
        verdict = "ok" if got >= floor else "REGRESSED"
        print(
            f"bench gate: {key}: measured {got:.2f} vs baseline {float(want):.2f} "
            f"(floor {floor:.2f}, tolerance {tol:.0%}) — {verdict}"
        )
        if got < floor:
            failures.append(
                f"{key} regressed: {got:.2f} < {floor:.2f} "
                f"({float(want):.2f} - {tol:.0%})"
            )
    if failures:
        if provisional:
            print(
                "bench gate: ADVISORY MISS (provisional baseline, not failing "
                "the build) — " + "; ".join(failures)
            )
            print("bench gate: PASS (advisory)")
            return
        fail("; ".join(failures))
    suffix = f" ({len(skipped)} metric(s) skipped: {', '.join(skipped)})" if skipped else ""
    print(f"bench gate: PASS{suffix}")


if __name__ == "__main__":
    main(sys.argv[1:])
