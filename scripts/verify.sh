#!/usr/bin/env bash
# Tier-1 verification: build + full test suite, exactly the command
# ROADMAP.md pins. Run from anywhere; add --bench to also record the
# sweep-engine and serving-path perf numbers to rust/BENCH_sweep.json
# and rust/BENCH_serve.json.
set -euo pipefail

cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q

if [ "${1:-}" = "--bench" ]; then
    cargo bench --bench paper_benches -- sweep
    cargo bench --bench paper_benches -- serve
    echo "perf record:"
    cat BENCH_sweep.json BENCH_serve.json
fi

echo "tier-1 verify OK"
