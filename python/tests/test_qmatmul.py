"""Layer-1 correctness: qmatmul Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and block sizes; int accumulation must be EXACT
(bit-identical to the oracle), the f32 variant allclose.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul, qmatmul_f32
from compile.kernels import ref
from compile.kernels.qmatmul import pad_to_blocks

SETTINGS = dict(max_examples=25, deadline=None)


def _rand_codes(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape).astype(np.int32))


@given(
    lb=st.integers(1, 3),
    nb=st.integers(1, 3),
    mb=st.integers(1, 3),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_qmatmul_exact_vs_oracle(lb, nb, mb, block, seed):
    rng = np.random.default_rng(seed)
    x = _rand_codes(rng, (lb * block, nb * block))
    w = _rand_codes(rng, (nb * block, mb * block))
    got = qmatmul(x, w, block_l=block, block_n=block, block_m=block)
    want = ref.matmul_i32(x, w)
    assert got.dtype == jnp.int32
    assert jnp.array_equal(got, want), "int32 accumulation must be exact"


@given(
    lb=st.integers(1, 2),
    nb=st.integers(1, 3),
    mb=st.integers(1, 2),
    block=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_qmatmul_f32_vs_oracle(lb, nb, mb, block, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((lb * block, nb * block)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((nb * block, mb * block)).astype(np.float32))
    got = qmatmul_f32(x, w, block_l=block, block_n=block, block_m=block)
    want = ref.matmul_f32(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_rectangular_blocks():
    rng = np.random.default_rng(7)
    x = _rand_codes(rng, (64, 96))
    w = _rand_codes(rng, (96, 32))
    got = qmatmul(x, w, block_l=16, block_n=32, block_m=8)
    assert jnp.array_equal(got, ref.matmul_i32(x, w))


def test_single_block():
    rng = np.random.default_rng(8)
    x = _rand_codes(rng, (8, 8))
    w = _rand_codes(rng, (8, 8))
    got = qmatmul(x, w, block_l=8, block_n=8, block_m=8)
    assert jnp.array_equal(got, ref.matmul_i32(x, w))


def test_extreme_codes_no_overflow():
    """Worst-case +-127 codes over a deep contraction still fit int32."""
    n = 256
    x = jnp.full((8, n), 127, jnp.int32)
    w = jnp.full((n, 8), 127, jnp.int32)
    got = qmatmul(x, w, block_l=8, block_n=32, block_m=8)
    assert int(got[0, 0]) == 127 * 127 * n


def test_zero_inputs():
    x = jnp.zeros((16, 16), jnp.int32)
    w = jnp.zeros((16, 16), jnp.int32)
    got = qmatmul(x, w, block_l=8, block_n=8, block_m=8)
    assert jnp.array_equal(got, jnp.zeros((16, 16), jnp.int32))


def test_identity_weights():
    rng = np.random.default_rng(9)
    x = _rand_codes(rng, (32, 32))
    w = jnp.eye(32, dtype=jnp.int32)
    got = qmatmul(x, w, block_l=8, block_n=8, block_m=8)
    assert jnp.array_equal(got, x)


def test_shape_mismatch_raises():
    x = jnp.zeros((8, 16), jnp.int32)
    w = jnp.zeros((8, 8), jnp.int32)
    with pytest.raises(ValueError, match="contraction mismatch"):
        qmatmul(x, w, block_l=8, block_n=8, block_m=8)


def test_non_multiple_raises():
    x = jnp.zeros((9, 8), jnp.int32)
    w = jnp.zeros((8, 8), jnp.int32)
    with pytest.raises(ValueError, match="not multiples"):
        qmatmul(x, w, block_l=8, block_n=8, block_m=8)


@given(
    l=st.integers(1, 40),
    n=st.integers(1, 40),
    block=st.sampled_from([8, 16]),
)
@settings(**SETTINGS)
def test_pad_to_blocks_invariants(l, n, block):
    a = jnp.ones((l, n), jnp.float32)
    p = pad_to_blocks(a, (block, block))
    assert p.shape[0] % block == 0 and p.shape[1] % block == 0
    assert p.shape[0] - l < block and p.shape[1] - n < block
    assert float(p.sum()) == float(a.sum()), "padding must be zeros"
