"""Layer-1 correctness: fourier_pointwise Pallas kernel vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fourier_pointwise
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _check(xr, xi, kr, ki, block_h):
    yr, yi = fourier_pointwise(xr, xi, kr, ki, block_h=block_h)
    er, ei = ref.fourier_pointwise(xr, xi, kr, ki)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(er), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(yi), np.asarray(ei), rtol=1e-4, atol=1e-4)


@given(
    ci=st.integers(1, 8),
    co=st.integers(1, 8),
    hb=st.integers(1, 4),
    w=st.integers(1, 24),
    block_h=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_fourier_pointwise_vs_oracle(ci, co, hb, w, block_h, seed):
    rng = np.random.default_rng(seed)
    h = hb * block_h
    xr, xi = _rand(rng, (ci, h, w)), _rand(rng, (ci, h, w))
    kr, ki = _rand(rng, (co, ci, h, w)), _rand(rng, (co, ci, h, w))
    _check(xr, xi, kr, ki, block_h)


def test_single_channel_is_elementwise_product():
    rng = np.random.default_rng(3)
    xr, xi = _rand(rng, (1, 4, 5)), _rand(rng, (1, 4, 5))
    kr, ki = _rand(rng, (1, 1, 4, 5)), _rand(rng, (1, 1, 4, 5))
    yr, yi = fourier_pointwise(xr, xi, kr, ki, block_h=4)
    np.testing.assert_allclose(
        np.asarray(yr[0]), np.asarray(xr[0] * kr[0, 0] - xi[0] * ki[0, 0]), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(yi[0]), np.asarray(xr[0] * ki[0, 0] + xi[0] * kr[0, 0]), rtol=1e-5
    )


def test_real_only_inputs_stay_consistent():
    """Purely real activation x purely real kernel -> output = plain product sum."""
    rng = np.random.default_rng(4)
    xr = _rand(rng, (3, 8, 6))
    z = jnp.zeros_like(xr)
    kr = _rand(rng, (2, 3, 8, 6))
    kz = jnp.zeros_like(kr)
    yr, yi = fourier_pointwise(xr, z, kr, kz, block_h=8)
    np.testing.assert_allclose(
        np.asarray(yr), np.asarray(jnp.einsum("chw,ochw->ohw", xr, kr)), rtol=1e-4
    )
    np.testing.assert_allclose(np.asarray(yi), 0.0, atol=1e-6)


def test_imaginary_rotation():
    """Multiplying by i (kr=0, ki=1) swaps and negates quadratures."""
    rng = np.random.default_rng(5)
    xr, xi = _rand(rng, (1, 4, 4)), _rand(rng, (1, 4, 4))
    kr = jnp.zeros((1, 1, 4, 4), jnp.float32)
    ki = jnp.ones((1, 1, 4, 4), jnp.float32)
    yr, yi = fourier_pointwise(xr, xi, kr, ki, block_h=4)
    np.testing.assert_allclose(np.asarray(yr[0]), np.asarray(-xi[0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(yi[0]), np.asarray(xr[0]), rtol=1e-6)


def test_linearity_in_kernel():
    rng = np.random.default_rng(6)
    xr, xi = _rand(rng, (2, 4, 4)), _rand(rng, (2, 4, 4))
    kr1, ki1 = _rand(rng, (2, 2, 4, 4)), _rand(rng, (2, 2, 4, 4))
    kr2, ki2 = _rand(rng, (2, 2, 4, 4)), _rand(rng, (2, 2, 4, 4))
    y1 = fourier_pointwise(xr, xi, kr1, ki1, block_h=4)
    y2 = fourier_pointwise(xr, xi, kr2, ki2, block_h=4)
    ysum = fourier_pointwise(xr, xi, kr1 + kr2, ki1 + ki2, block_h=4)
    np.testing.assert_allclose(
        np.asarray(ysum[0]), np.asarray(y1[0] + y2[0]), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(ysum[1]), np.asarray(y1[1] + y2[1]), rtol=1e-4, atol=1e-5
    )


def test_shape_mismatch_raises():
    z3 = jnp.zeros((2, 4, 4), jnp.float32)
    z4 = jnp.zeros((3, 2, 4, 4), jnp.float32)
    with pytest.raises(ValueError, match="real/imag"):
        fourier_pointwise(z3, jnp.zeros((2, 4, 5)), z4, z4, block_h=4)
    with pytest.raises(ValueError, match="kernel spectrum"):
        fourier_pointwise(z3, z3, jnp.zeros((3, 1, 4, 4)), z4, block_h=4)


def test_bad_block_raises():
    z3 = jnp.zeros((1, 5, 4), jnp.float32)
    z4 = jnp.zeros((1, 1, 5, 4), jnp.float32)
    with pytest.raises(ValueError, match="multiple"):
        fourier_pointwise(z3, z3, z4, z4, block_h=4)
