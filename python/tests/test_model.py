"""Layer-2 correctness: both machine conv datapaths vs the exact oracle,
quantization behaviour, and the SmallCNN end-to-end forward."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.quant import (
    fake_quantize,
    fake_quantize_per_leading,
    qmax,
    quantize_per_leading,
    quantize_symmetric,
)

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def _rel(a, b):
    denom = max(float(jnp.max(jnp.abs(b))), 1e-12)
    return float(jnp.max(jnp.abs(a - b))) / denom


# ---------------------------------------------------------------- quant --


@given(bits=st.integers(2, 12), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_quantize_symmetric_bounds_and_error(bits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (32, 17))
    codes, scale = quantize_symmetric(x, bits)
    m = qmax(bits)
    assert int(jnp.max(jnp.abs(codes))) <= m
    # Round-trip error bounded by half an LSB.
    err = jnp.max(jnp.abs(codes.astype(jnp.float32) * scale - x))
    assert float(err) <= float(scale) * 0.5 + 1e-7


@given(seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_quantize_per_leading_scales_independent(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (4, 9))
    # Scale one slice hugely; other slices' quantization must be unaffected.
    x = x.at[0].mul(1000.0)
    _, scales = quantize_per_leading(x, 8)
    assert scales.shape == (4,)
    assert float(scales[0]) > 100 * float(scales[1])
    rt = fake_quantize_per_leading(x, 8)
    assert _rel(rt[1:], x[1:]) < 1e-2


def test_fake_quantize_none_is_identity():
    x = jnp.linspace(-1, 1, 7)
    assert jnp.array_equal(fake_quantize(x, None), x)


def test_fake_quantize_monotone_in_bits():
    rng = np.random.default_rng(11)
    x = _rand(rng, (64,))
    errs = [float(jnp.max(jnp.abs(fake_quantize(x, b) - x))) for b in (4, 6, 8, 10)]
    assert errs == sorted(errs, reverse=True)


# ------------------------------------------------------------ ref cross --


@given(
    ci=st.integers(1, 4),
    co=st.integers(1, 4),
    n=st.integers(5, 14),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ref_matmul_conv_equals_direct(ci, co, n, k, seed):
    if k > n:
        return
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (ci, n, n)), _rand(rng, (co, ci, k, k))
    a = ref.conv2d_via_matmul(x, w)
    b = ref.conv2d_valid(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


@given(
    ci=st.integers(1, 4),
    co=st.integers(1, 3),
    n=st.integers(5, 14),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ref_fft_conv_equals_direct(ci, co, n, k, seed):
    if k > n:
        return
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (ci, n, n)), _rand(rng, (co, ci, k, k))
    a = ref.conv2d_via_fft(x, w)
    b = ref.conv2d_valid(x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


@given(
    n=st.integers(4, 10),
    k=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_ref_strided_matmul_conv(n, k, stride, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (2, n, n)), _rand(rng, (3, 2, k, k))
    a = ref.conv2d_via_matmul(x, w, stride)
    b = ref.conv2d_valid(x, w, stride)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- machine paths --


@given(
    ci=st.integers(1, 4),
    co=st.integers(1, 4),
    n=st.integers(6, 16),
    k=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_conv2d_systolic_8bit_close_to_exact(ci, co, n, k, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (ci, n, n)), _rand(rng, (co, ci, k, k))
    got = model.conv2d_systolic(x, w, bits=8)
    want = model.conv2d_exact(x, w)
    assert _rel(got, want) < 0.05


@given(
    ci=st.integers(1, 3),
    co=st.integers(1, 3),
    n=st.integers(6, 14),
    k=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_conv2d_fft_ideal_matches_exact(ci, co, n, k, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (ci, n, n)), _rand(rng, (co, ci, k, k))
    got = model.conv2d_fft(x, w, bits=None)
    want = model.conv2d_exact(x, w)
    assert _rel(got, want) < 1e-4


def test_conv2d_fft_8bit_close_to_exact():
    rng = np.random.default_rng(21)
    x, w = _rand(rng, (3, 20, 20)), _rand(rng, (5, 3, 3, 3))
    got = model.conv2d_fft(x, w, bits=8)
    want = model.conv2d_exact(x, w)
    assert _rel(got, want) < 0.05


def test_conv2d_fft_adc_quantization_applies():
    rng = np.random.default_rng(22)
    x, w = _rand(rng, (2, 12, 12)), _rand(rng, (2, 2, 3, 3))
    ideal = model.conv2d_fft(x, w, bits=None, adc_bits=None)
    coarse = model.conv2d_fft(x, w, bits=None, adc_bits=4)
    assert _rel(coarse, ideal) > 1e-4  # ADC must actually quantize
    assert _rel(coarse, ideal) < 0.2


def test_conv2d_systolic_more_bits_more_accurate():
    rng = np.random.default_rng(23)
    x, w = _rand(rng, (3, 16, 16)), _rand(rng, (4, 3, 3, 3))
    want = model.conv2d_exact(x, w)
    e4 = _rel(model.conv2d_systolic(x, w, bits=4), want)
    e8 = _rel(model.conv2d_systolic(x, w, bits=8), want)
    assert e8 < e4


def test_conv2d_systolic_stride2():
    rng = np.random.default_rng(24)
    x, w = _rand(rng, (3, 17, 17)), _rand(rng, (4, 3, 3, 3))
    got = model.conv2d_systolic(x, w, stride=2, bits=8)
    want = model.conv2d_exact(x, w, stride=2)
    assert got.shape == want.shape == (4, 8, 8)
    assert _rel(got, want) < 0.05


def test_avg_pool2():
    x = jnp.arange(2 * 4 * 4, dtype=jnp.float32).reshape(2, 4, 4)
    p = model.avg_pool2(x)
    assert p.shape == (2, 2, 2)
    np.testing.assert_allclose(float(p[0, 0, 0]), float(x[0, :2, :2].mean()))


def test_avg_pool2_odd_edges_truncated():
    x = jnp.ones((1, 5, 7), jnp.float32)
    assert model.avg_pool2(x).shape == (1, 2, 3)


# ---------------------------------------------------------------- e2e ----


def test_smallcnn_paths_agree():
    rng = np.random.default_rng(30)
    x = _rand(rng, model.SMALLCNN_INPUT)
    exact = model.smallcnn_jit(x, "exact")
    sys8 = model.smallcnn_jit(x, "systolic")
    fft8 = model.smallcnn_jit(x, "fft")
    assert exact.shape == (model.SMALLCNN_CLASSES,)
    scale = float(jnp.max(jnp.abs(exact)))
    assert float(jnp.max(jnp.abs(sys8 - exact))) / scale < 0.1
    assert float(jnp.max(jnp.abs(fft8 - exact))) / scale < 0.1
    # Quantized paths must usually preserve the argmax decision.
    assert int(jnp.argmax(sys8)) == int(jnp.argmax(exact))
    assert int(jnp.argmax(fft8)) == int(jnp.argmax(exact))


def test_smallcnn_deterministic_params():
    p1 = model.smallcnn_init(0)
    p2 = model.smallcnn_init(0)
    for k in p1:
        assert jnp.array_equal(p1[k], p2[k])
    p3 = model.smallcnn_init(1)
    assert not jnp.array_equal(p1["conv0"], p3["conv0"])


def test_smallcnn_param_shapes():
    p = model.smallcnn_init()
    chans = model.SMALLCNN_CHANNELS
    for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
        assert p[f"conv{i}"].shape == (co, ci, model.SMALLCNN_K, model.SMALLCNN_K)
    assert p["head"].shape == (chans[-1], model.SMALLCNN_CLASSES)


def test_conv2d_dispatch():
    rng = np.random.default_rng(31)
    x, w = _rand(rng, (2, 10, 10)), _rand(rng, (2, 2, 3, 3))
    for path in ("exact", "systolic", "fft"):
        y = model.conv2d(x, w, path=path)
        assert y.shape == (2, 8, 8)
    with pytest.raises(AssertionError):
        model.conv2d(x, w, path="fft", stride=2)


# ----------------------------------------------- Fig. 4 channel tiling --


@given(
    ci=st.integers(1, 4),
    co=st.integers(1, 4),
    n=st.integers(5, 12),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_conv2d_fft_tiled_matches_exact(ci, co, n, k, seed):
    """Fig. 4's parallel-channel tiling: one FFT for all input channels,
    one measurement per output channel, cross-terms guaranteed outside the
    readout window."""
    if k > n:
        return
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (ci, n, n)), _rand(rng, (co, ci, k, k))
    got = model.conv2d_fft_tiled(x, w, bits=None)
    want = model.conv2d_exact(x, w)
    assert got.shape == want.shape
    assert _rel(got, want) < 1e-4


def test_conv2d_fft_tiled_quantized():
    rng = np.random.default_rng(42)
    x, w = _rand(rng, (3, 10, 10)), _rand(rng, (4, 3, 3, 3))
    got = model.conv2d_fft_tiled(x, w, bits=8)
    want = model.conv2d_exact(x, w)
    assert _rel(got, want) < 0.1
