"""AOT compile-path tests: artifact specs, HLO-text lowering, batching."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_artifact_specs_unique_and_wellformed():
    specs = aot.build_artifact_specs()
    names = [s[0] for s in specs]
    assert len(names) == len(set(names)), "artifact names must be unique"
    assert len(specs) >= 10
    for name, fn, args, rtol in specs:
        assert callable(fn)
        assert 0 < rtol < 1
        assert all(isinstance(a, jax.Array) for a in args)


def test_to_hlo_text_produces_parseable_module():
    fn = lambda x: (x * 2.0 + 1.0,)
    lowered = jax.jit(fn).lower(jnp.zeros((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    # The rust loader's parser requires classic HLO text structure.
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_qgemm_roundtrip_close_to_f32():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((256, 128)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((128, 256)).astype(np.float32))
    y = aot.qgemm(x, w)
    ref = x @ w
    err = float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref)))
    assert err < 0.05, err


def test_batched_vectorize_and_map_agree():
    rng = np.random.default_rng(4)
    xs = jnp.asarray(rng.standard_normal((3, *model.SMALLCNN_INPUT)).astype(np.float32))
    import functools

    fn = functools.partial(model.smallcnn, path="exact")
    via_map = aot._batched(fn, 3, vectorize=False)(xs)
    via_vmap = aot._batched(fn, 3, vectorize=True)(xs)
    np.testing.assert_allclose(
        np.asarray(via_map), np.asarray(via_vmap), rtol=1e-4, atol=1e-5
    )


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")),
    reason="artifacts not built",
)
def test_manifest_matches_artifact_files():
    art = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(art, "manifest.tsv")) as f:
        lines = [l.strip().split("\t") for l in f if l.strip()]
    assert len(lines) >= 10
    for name, in_shapes, out_shape, rtol in lines:
        assert os.path.exists(os.path.join(art, f"{name}.hlo.txt")), name
        n_inputs = len(in_shapes.split(";"))
        for i in range(n_inputs):
            p = os.path.join(art, f"{name}.in{i}.f32")
            assert os.path.exists(p), p
            shape = [int(d) for d in in_shapes.split(";")[i].split(",")]
            assert os.path.getsize(p) == 4 * int(np.prod(shape))
        out_p = os.path.join(art, f"{name}.out.f32")
        out_elems = int(np.prod([int(d) for d in out_shape.split(",")]))
        assert os.path.getsize(out_p) == 4 * out_elems
        float(rtol)
