"""Quantization helpers emulating the machines' converter precision.

The paper's machines move data through B-bit converters: the systolic array
uses 8-bit fixed-point operands (Sec. VII.A), the analog machines pass
every input through a DAC and every output through an ADC whose energy is
set by the bit precision (eqs. A3/A4, the 2^{2B} laws). These helpers are
the *numerical* counterpart of those converters: symmetric uniform
quantization to B bits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmax(bits: int) -> int:
    """Largest positive code of a signed symmetric B-bit quantizer (e.g. 127)."""
    return (1 << (bits - 1)) - 1


def quantize_symmetric(
    x: jax.Array, bits: int = 8
) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric quantization.

    Returns ``(codes, scale)`` with ``codes`` integer-valued (kept in int32
    for headroom; the systolic datapath consumes them as int8-range values)
    and ``x ~= codes * scale``.
    """
    m = qmax(bits)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / m
    codes = jnp.clip(jnp.round(x / scale), -m, m).astype(jnp.int32)
    return codes, scale


def quantize_per_leading(
    x: jax.Array, bits: int = 8
) -> tuple[jax.Array, jax.Array]:
    """Symmetric quantization with one scale per leading-axis slice.

    Used for weight tensors (one scale per output channel) — the systolic
    array reloads scales with each weight tile, and each kernel tile written
    to the Fourier-plane SLM is independently normalized to the modulator's
    dynamic range.
    """
    m = qmax(bits)
    flat = x.reshape(x.shape[0], -1)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1), 1e-30) / m
    bshape = (x.shape[0],) + (1,) * (x.ndim - 1)
    codes = jnp.clip(
        jnp.round(x / scale.reshape(bshape)), -m, m
    ).astype(jnp.int32)
    return codes, scale


def fake_quantize(x: jax.Array, bits: int | None) -> jax.Array:
    """Quantize-dequantize (per tensor). ``bits=None`` is the identity."""
    if bits is None:
        return x
    codes, scale = quantize_symmetric(x, bits)
    return codes.astype(x.dtype) * scale


def fake_quantize_per_leading(x: jax.Array, bits: int | None) -> jax.Array:
    """Quantize-dequantize with per-leading-slice scales."""
    if bits is None:
        return x
    codes, scale = quantize_per_leading(x, bits)
    bshape = (x.shape[0],) + (1,) * (x.ndim - 1)
    return codes.astype(x.dtype) * scale.reshape(bshape)
